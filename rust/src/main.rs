//! `talp-pages` binary — see cli::USAGE.

use talp_pages::cli;

fn main() {
    // Behave like a unix CLI under `| head`: die silently on SIGPIPE
    // instead of panicking in println!.
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cli::main_with_args(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
