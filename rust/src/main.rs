//! `talp-pages` binary — see cli::USAGE.

use talp_pages::cli;

/// Restore default SIGPIPE behaviour so the CLI dies silently under
/// `| head` instead of panicking in println!.  Declared directly (the
/// `libc` crate is unavailable in the offline image).
#[cfg(unix)]
fn restore_sigpipe() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGPIPE: i32 = 13;
    const SIG_DFL: usize = 0;
    unsafe {
        signal(SIGPIPE, SIG_DFL);
    }
}

#[cfg(not(unix))]
fn restore_sigpipe() {}

fn main() {
    restore_sigpipe();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cli::main_with_args(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
