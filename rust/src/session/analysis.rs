//! Stage 2: fold a [`Scan`] into render-ready data — POP
//! scaling-efficiency tables, Extra-P-style models, per-configuration
//! time series, regression/improvement findings, badge values and the
//! optional gate verdict.  Pure compute, no I/O: every emitter renders
//! from the same [`Analysis`], so output formats can never disagree
//! about the numbers.
//!
//! The per-experiment fan-out runs on the session's worker pool
//! (`util::par::parallel_map`) and merges in deterministic scan order,
//! which is what keeps `jobs = 1` and `jobs = N` byte-identical
//! downstream.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::gate::{GatePolicy, GateVerdict};
use crate::pages::detect::{self, DetectOptions, Finding};
use crate::pages::scanner::{MetricExperiment, MetricScan};
use crate::pages::timeseries::{self, TimeSeries};
use crate::pop::{self, RunMetrics};
use crate::util::par::parallel_map;
// Filesystem-safe experiment ids (page and badge names) use the same
// sanitizer as the run store's shard names.
use crate::util::text::slug;

use super::Scan;

/// Analyze-stage options (one of the per-stage types that replaced the
/// old `ReportOptions` god-struct; the scan stage's knobs live on
/// [`super::Session`]).
#[derive(Debug, Clone, Default)]
pub struct AnalyzeOptions {
    /// Regions to build tables/plots for (empty = every region found).
    pub regions: Vec<String>,
    /// Region whose parallel efficiency feeds the badges (default the
    /// implicit whole-execution `Global` region).
    pub region_for_badge: Option<String>,
    /// Change-detection thresholds.
    pub detect: DetectOptions,
    /// Regression-gate policy: when set, the scanned histories also
    /// fold into a [`GateVerdict`] carried on [`Analysis::gate`] — as
    /// data; writing `gate.*` files is the [`super::GateFiles`]
    /// emitter's job.
    pub gate: Option<GatePolicy>,
}

/// One badge's worth of data.  Both the badge-file emitter and the
/// HTML page render the SVG from these values, so the inline and
/// standalone copies are always byte-identical.
#[derive(Debug, Clone)]
pub struct BadgeDatum {
    /// Badge region (the label).
    pub region: String,
    /// Resource-configuration label, e.g. `2x8`.
    pub config: String,
    /// Parallel efficiency of the latest run.
    pub value: f64,
    /// Output-root-relative SVG path, e.g. `badges/exp__2x8.svg`.
    pub file: String,
}

/// One configuration's plotted series (only configurations with at
/// least two runs — a single point has no evolution).
#[derive(Debug, Clone)]
pub struct ConfigSeries {
    pub config: String,
    /// Full history length (the plot caption's "(N runs)").
    pub runs: usize,
    /// The plotted series — region-filtered when a selection was given.
    pub series: TimeSeries,
}

/// Everything the emitters need about one experiment.
#[derive(Debug)]
pub struct ExperimentAnalysis {
    /// Scan-root-relative experiment id, e.g. `mesh_1/strong_scaling`.
    pub id: String,
    /// Filesystem-safe form of the id (page and badge file names).
    pub slug: String,
    /// Distinct resource configurations, ordered by resources.
    pub configs: Vec<String>,
    /// Total runs across all configurations.
    pub total_runs: usize,
    pub badges: Vec<BadgeDatum>,
    /// (region, scaling-efficiency table) in display order.
    pub tables: Vec<(String, pop::ScalingTable)>,
    /// Detected changes, in configuration order then history order.
    pub findings: Vec<Finding>,
    /// Extra-P-style models per region (>= 3 configurations).
    pub models: Vec<(String, pop::extrap::Model)>,
    /// Per-configuration plotted series.
    pub series: Vec<ConfigSeries>,
    /// Full per-run histories per configuration, oldest first — the
    /// machine-readable report's payload.
    pub histories: Vec<(String, Vec<RunMetrics>)>,
}

/// Stage-2 output: the complete analyzed dataset, plus the scan-stage
/// counters carried through so any emitter subset reports them
/// correctly.
#[derive(Debug)]
pub struct Analysis {
    /// Display form of the scanned input root (index header line).
    pub input: String,
    /// Per-experiment analyses, in deterministic scan order.  Shared
    /// (`Arc`) so a resident consumer ([`analyze_incremental`], the
    /// serve subsystem) can carry clean experiments from one analysis
    /// to the next by reference instead of recomputing or cloning
    /// their full run histories.
    pub experiments: Vec<Arc<ExperimentAnalysis>>,
    /// Non-fatal scan warnings, as structured diagnostics.
    pub warnings: Vec<crate::check::Diagnostic>,
    /// Artifacts served from the metrics cache (not re-parsed).  These
    /// describe the *scan*, not any emitter, so a JSON-only emit on a
    /// warm cache still reports zero misses.
    pub cache_hits: usize,
    /// Artifacts parsed + reduced by the scan.
    pub cache_misses: usize,
    /// Regression-gate verdict (when [`AnalyzeOptions::gate`] was set).
    pub gate: Option<GateVerdict>,
}

impl Scan {
    /// Stage 2: compute tables, models, series, findings, badges and
    /// the optional gate verdict — as data, no I/O.
    pub fn analyze(self, opts: &AnalyzeOptions) -> Analysis {
        let gate = opts
            .gate
            .as_ref()
            .map(|policy| crate::gate::evaluate(&self.scan, policy));
        let partials = parallel_map(
            &self.scan.experiments,
            self.jobs,
            |exp| analyze_experiment(exp, opts),
        );
        // Materialize the per-config histories by *moving* the runs out
        // of the scan (the configurations partition them), so the
        // potentially large reduced metrics are never cloned.
        let experiments = self
            .scan
            .experiments
            .into_iter()
            .zip(partials)
            .map(|(exp, (mut analysis, history_idx))| {
                let mut slots: Vec<Option<RunMetrics>> =
                    exp.runs.into_iter().map(Some).collect();
                analysis.histories = history_idx
                    .into_iter()
                    .map(|(cfg, idx)| {
                        let runs = idx
                            .into_iter()
                            .map(|i| {
                                slots[i]
                                    .take()
                                    .expect("configs partition the runs")
                            })
                            .collect();
                        (cfg, runs)
                    })
                    .collect();
                Arc::new(analysis)
            })
            .collect();
        Analysis {
            input: self.root.display().to_string(),
            experiments,
            warnings: self.scan.warnings,
            cache_hits: self.scan.cache_hits,
            cache_misses: self.scan.cache_misses,
            gate,
        }
    }
}

/// Outcome of one [`analyze_incremental`] pass: the fresh [`Analysis`]
/// plus the incrementality counters the serve subsystem's `/statsz`
/// endpoint and the `serve_warm_reanalyze` bench use as the witness
/// that a single-run ingest did not rescan unaffected histories.
#[derive(Debug)]
pub struct Reanalysis {
    pub analysis: Analysis,
    /// (experiment, config) histories recomputed this pass.
    pub reanalyzed_histories: usize,
    /// Experiments carried over from the previous analysis by
    /// reference (`Arc::clone`) without recomputation.
    pub reused_experiments: usize,
}

/// Analyze `scan` by *borrowing* it — the resident counterpart of the
/// consuming [`Scan::analyze`].  When a previous [`Analysis`] and a
/// dirty-experiment set are given, only experiments that are dirty (or
/// new since the previous pass) go through [`analyze_experiment`]; the
/// rest reuse the previous pass's [`ExperimentAnalysis`] by reference.
/// The gate verdict is always recomputed — it folds cross-experiment
/// state and evaluation borrows the scan, so it stays cheap.
///
/// Determinism: the merged experiment list keeps scan order, and a
/// recomputed experiment's analysis is value-identical to what a cold
/// [`Scan::analyze`] over the same scan produces (the recomputed
/// histories clone the runs instead of moving them — same values, same
/// bytes downstream).
pub fn analyze_incremental(
    input: &str,
    scan: &MetricScan,
    jobs: usize,
    opts: &AnalyzeOptions,
    prev: Option<(&Analysis, &BTreeSet<String>)>,
) -> Reanalysis {
    let gate = opts
        .gate
        .as_ref()
        .map(|policy| crate::gate::evaluate(scan, policy));
    let previous: BTreeMap<&str, &Arc<ExperimentAnalysis>> = prev
        .map(|(a, _)| {
            a.experiments.iter().map(|e| (e.id.as_str(), e)).collect()
        })
        .unwrap_or_default();
    let recompute = |id: &str| match prev {
        None => true,
        Some((_, dirty)) => {
            dirty.contains(id) || !previous.contains_key(id)
        }
    };
    let stale: Vec<&MetricExperiment> = scan
        .experiments
        .iter()
        .filter(|exp| recompute(&exp.id))
        .collect();
    let fresh = parallel_map(&stale, jobs, |exp| {
        let (mut analysis, history_idx) = analyze_experiment(exp, opts);
        analysis.histories = history_idx
            .into_iter()
            .map(|(cfg, idx)| {
                let runs =
                    idx.into_iter().map(|i| exp.runs[i].clone()).collect();
                (cfg, runs)
            })
            .collect();
        Arc::new(analysis)
    });

    let mut fresh_iter = fresh.into_iter();
    let mut reanalyzed_histories = 0usize;
    let mut reused_experiments = 0usize;
    let experiments: Vec<Arc<ExperimentAnalysis>> = scan
        .experiments
        .iter()
        .map(|exp| {
            if recompute(&exp.id) {
                let a = fresh_iter
                    .next()
                    .expect("stale set and merge walk the same scan");
                reanalyzed_histories += a.histories.len();
                a
            } else {
                reused_experiments += 1;
                Arc::clone(previous[exp.id.as_str()])
            }
        })
        .collect();
    Reanalysis {
        analysis: Analysis {
            input: input.to_string(),
            experiments,
            warnings: scan.warnings.clone(),
            cache_hits: scan.cache_hits,
            cache_misses: scan.cache_misses,
            gate,
        },
        reanalyzed_histories,
        reused_experiments,
    }
}

/// Analyze one experiment from borrowed scan data.  Returns the
/// analysis with `histories` left empty plus the per-config run
/// indices; [`Scan::analyze`] fills the histories by moving the runs
/// out of the scan afterwards.
fn analyze_experiment(
    exp: &MetricExperiment,
    opts: &AnalyzeOptions,
) -> (ExperimentAnalysis, Vec<(String, Vec<usize>)>) {
    let exp_slug = slug(&exp.id);
    let latest = exp.latest_per_config();
    let badge_region = opts
        .region_for_badge
        .clone()
        .unwrap_or_else(|| "Global".to_string());

    // ---- badges: latest run per configuration ----
    let badges: Vec<BadgeDatum> = latest
        .iter()
        .filter_map(|run| {
            let reg = run.region(&badge_region)?;
            let cfg = run.resources().label();
            Some(BadgeDatum {
                region: badge_region.clone(),
                config: cfg.clone(),
                value: reg.metrics.parallel_efficiency,
                file: format!("badges/{exp_slug}__{cfg}.svg"),
            })
        })
        .collect();

    // ---- scaling-efficiency tables ----
    let all_regions = exp.regions();
    let table_regions: Vec<String> = if opts.regions.is_empty() {
        all_regions.clone()
    } else {
        all_regions
            .iter()
            .filter(|r| *r == "Global" || opts.regions.contains(r))
            .cloned()
            .collect()
    };
    let tables: Vec<(String, pop::ScalingTable)> = table_regions
        .iter()
        .filter_map(|region| {
            let items: Vec<(crate::sim::ResourceConfig, pop::RegionMetrics)> =
                latest
                    .iter()
                    .filter_map(|run| {
                        run.region(region)
                            .map(|r| (run.resources(), r.metrics))
                    })
                    .collect();
            pop::build_from_metrics(region, &items)
                .map(|t| (region.clone(), t))
        })
        .collect();

    // ---- per-config series: findings + plot data in one pass ----
    // Each configuration's history is filtered/sorted and its full
    // TimeSeries built exactly once; the detector and the plots share
    // it (a filtered copy is only built when regions were selected).
    let plot_regions: Vec<String> = if opts.regions.is_empty() {
        all_regions
    } else {
        // Selected regions are highlighted; Global is always kept so
        // the whole-program trend stays visible (paper: "The selected
        // regions are also highlighted in the time-series plots").
        let mut v = vec!["Global".to_string()];
        v.extend(opts.regions.iter().cloned());
        v.dedup();
        v
    };
    let mut findings = Vec::new();
    let mut series = Vec::new();
    let mut history_idx = Vec::new();
    let mut total_runs = 0usize;
    let configs = exp.configs();
    for cfg in &configs {
        let idx = exp.history_indices_for_config(cfg);
        let history: Vec<&RunMetrics> =
            idx.iter().map(|&i| &exp.runs[i]).collect();
        total_runs += history.len();
        if history.len() >= 2 {
            let full_ts = timeseries::build_from_metrics(cfg, &history, &[]);
            findings.extend(detect::detect_series(&full_ts, cfg, &opts.detect));
            // Plot series: with no region selection the full series IS
            // the plotted one; otherwise build the filtered subset.
            let ts = if opts.regions.is_empty() {
                full_ts
            } else {
                timeseries::build_from_metrics(cfg, &history, &plot_regions)
            };
            series.push(ConfigSeries {
                config: cfg.clone(),
                runs: history.len(),
                series: ts,
            });
        }
        history_idx.push((cfg.clone(), idx));
    }

    // ---- Extra-P-style scaling models (>= 3 configurations) ----
    let models = if latest.len() >= 3 {
        pop::extrap::fit_experiment_metrics(&latest, &table_regions)
    } else {
        Vec::new()
    };

    (
        ExperimentAnalysis {
            id: exp.id.clone(),
            slug: exp_slug,
            configs,
            total_runs,
            badges,
            tables,
            findings,
            models,
            series,
            // Filled by Scan::analyze, which moves the runs out of the
            // scan instead of cloning them here.
            histories: Vec::new(),
        },
        history_idx,
    )
}

#[cfg(test)]
mod tests {
    use super::super::tests::build_input;
    use super::*;
    use crate::session::Session;
    use crate::util::fs::TempDir;

    fn analyzed(opts: &AnalyzeOptions) -> Analysis {
        let td = TempDir::new("analysis").unwrap();
        build_input(&td);
        Session::new(td.path()).scan().unwrap().analyze(opts)
    }

    #[test]
    fn analysis_carries_tables_series_findings_and_badges() {
        let a = analyzed(&AnalyzeOptions {
            regions: vec!["initialize".into(), "timestep".into()],
            region_for_badge: Some("timestep".into()),
            ..Default::default()
        });
        assert_eq!(a.experiments.len(), 1);
        let e = &a.experiments[0];
        assert_eq!(e.id, "salpha/resolution_1");
        assert_eq!(e.slug, "salpha_resolution_1");
        assert_eq!(e.configs, ["2x8"]);
        assert_eq!(e.total_runs, 4);
        // Badge carries the selected region and the latest PE.
        assert_eq!(e.badges.len(), 1);
        assert_eq!(e.badges[0].region, "timestep");
        assert_eq!(e.badges[0].file, "badges/salpha_resolution_1__2x8.svg");
        // Tables keep Global plus the selected regions only.
        let table_regions: Vec<&str> =
            e.tables.iter().map(|(r, _)| r.as_str()).collect();
        assert!(table_regions.contains(&"Global"));
        assert!(table_regions.contains(&"initialize"));
        // The bug -> fix history surfaces as an improvement finding.
        assert!(e
            .findings
            .iter()
            .any(|f| f.kind == detect::ChangeKind::Improvement));
        // One plotted series (one config, 4 runs), region-filtered.
        assert_eq!(e.series.len(), 1);
        assert_eq!(e.series[0].runs, 4);
        assert!(e.series[0].series.regions().contains(&"Global".into()));
        // Histories carry all runs for the machine report.
        assert_eq!(e.histories.len(), 1);
        assert_eq!(e.histories[0].1.len(), 4);
        assert!(a.gate.is_none());
    }

    #[test]
    fn gate_policy_produces_a_verdict_as_data() {
        let a = analyzed(&AnalyzeOptions {
            gate: Some(GatePolicy::default()),
            ..Default::default()
        });
        let v = a.gate.as_ref().expect("verdict");
        // The fixture history is a bug -> fix (an improvement), so the
        // gate passes.
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
    }

    #[test]
    fn incremental_reuses_clean_experiments_by_reference() {
        let td = TempDir::new("analysis-incr").unwrap();
        build_input(&td);
        let opts = AnalyzeOptions::default();
        let scanned = Session::new(td.path()).scan().unwrap();
        let input = scanned.root().display().to_string();

        // A cold incremental pass (no previous analysis) recomputes
        // everything and matches the consuming path value-for-value.
        let cold =
            analyze_incremental(&input, &scanned.scan, 0, &opts, None);
        assert_eq!(cold.reanalyzed_histories, 1);
        assert_eq!(cold.reused_experiments, 0);
        let batch = Session::new(td.path())
            .scan()
            .unwrap()
            .analyze(&AnalyzeOptions::default());
        assert_eq!(
            cold.analysis.experiments[0].histories[0].1.len(),
            batch.experiments[0].histories[0].1.len()
        );
        assert_eq!(
            cold.analysis.experiments[0].findings.len(),
            batch.experiments[0].findings.len()
        );

        // A warm pass with nothing dirty reuses every experiment by
        // reference — the incrementality the serve mode banks on.
        let dirty = BTreeSet::new();
        let warm = analyze_incremental(
            &input,
            &scanned.scan,
            0,
            &opts,
            Some((&cold.analysis, &dirty)),
        );
        assert_eq!(warm.reanalyzed_histories, 0);
        assert_eq!(warm.reused_experiments, 1);
        assert!(Arc::ptr_eq(
            &warm.analysis.experiments[0],
            &cold.analysis.experiments[0]
        ));

        // Marking the experiment dirty recomputes it (fresh Arc, same
        // values).
        let dirty: BTreeSet<String> =
            ["salpha/resolution_1".to_string()].into_iter().collect();
        let redone = analyze_incremental(
            &input,
            &scanned.scan,
            0,
            &opts,
            Some((&cold.analysis, &dirty)),
        );
        assert_eq!(redone.reanalyzed_histories, 1);
        assert_eq!(redone.reused_experiments, 0);
        assert!(!Arc::ptr_eq(
            &redone.analysis.experiments[0],
            &cold.analysis.experiments[0]
        ));
        assert_eq!(
            redone.analysis.experiments[0].total_runs,
            cold.analysis.experiments[0].total_runs
        );
    }

    #[test]
    fn jobs_values_produce_identical_analyses() {
        let td = TempDir::new("analysis-jobs").unwrap();
        build_input(&td);
        let run = |jobs: usize| {
            Session::new(td.path())
                .jobs(jobs)
                .scan()
                .unwrap()
                .analyze(&AnalyzeOptions::default())
        };
        let (a, b) = (run(1), run(4));
        assert_eq!(a.experiments.len(), b.experiments.len());
        let (ea, eb) = (&a.experiments[0], &b.experiments[0]);
        assert_eq!(ea.configs, eb.configs);
        assert_eq!(ea.findings.len(), eb.findings.len());
        assert_eq!(
            ea.series[0].series.metric("Global", "elapsed"),
            eb.series[0].series.metric("Global", "elapsed")
        );
    }
}
