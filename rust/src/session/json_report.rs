//! The machine-readable report: a versioned, schema-stable
//! `report.json` so dashboards, multi-repo aggregators and predictors
//! can consume TALP-Pages data without scraping HTML.
//!
//! # The contract
//!
//! ```json
//! {
//!   "schema_version": 2,
//!   "experiments": [
//!     {
//!       "id": "<scan-root-relative experiment id>",
//!       "configs": [
//!         {
//!           "config": "<RxT label>",
//!           "history": [ { ...one run's reduced POP metrics... } ]
//!         }
//!       ],
//!       "detections": [
//!         { "region", "config", "commit", "kind", "factor",
//!           "at_index", "explanation": {"metric","before","after"}|null }
//!       ],
//!       "models": [
//!         { "region", "a", "b", "c", "smape", "formula", "grows" }
//!       ]
//!     }
//!   ],
//!   "warnings": [
//!     { "code": "TP0xx", "severity": "warning", "path": "...",
//!       "message": "...", "span": {"start", "len"}|null }
//!   ],
//!   "gate": { ...gate.json document... } | null
//! }
//! ```
//!
//! * Run-history entries are exactly the [`RunMetrics`] cache JSON
//!   (source, app, machine, timestamps, resources, git, per-region POP
//!   factors) — one serializer, one schema, already covered by the
//!   cache's fixpoint tests.
//! * Everything is deterministic and relocatable: no absolute paths,
//!   no wall clock, shortest-roundtrip f64 formatting — the same scan
//!   produces byte-identical documents for every `jobs` value and
//!   cache temperature (the golden-file test pins this).
//! * **Versioning rule:** consumers MUST reject a `schema_version`
//!   they do not know ([`ReportDocument::parse`] enforces this);
//!   producers bump [`SCHEMA_VERSION`] on any breaking shape change.
//!   Version 2 turned `warnings` from plain strings into structured
//!   diagnostic objects (stable `TP0xx` code, severity, file path,
//!   optional byte-offset span) shared with `talp-pages check`.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::pages::detect::{ChangeKind, Finding};
use crate::pop::RunMetrics;
use crate::util::json::{Json, JsonWriter};

use super::analysis::{Analysis, ExperimentAnalysis};
use super::emit::{Emitter, EmitterReport};

/// Version stamp of the `report.json` shape.  Bump on breaking
/// changes; consumers reject unknown versions instead of guessing.
/// (2: `warnings` became structured diagnostic objects.)
pub const SCHEMA_VERSION: u64 = 2;

/// Default file name inside the emitter's output directory.
pub const REPORT_FILE_NAME: &str = "report.json";

/// Writes `report.json` into its output directory.
pub struct JsonReport {
    out_dir: PathBuf,
}

impl JsonReport {
    pub fn new(out_dir: impl Into<PathBuf>) -> JsonReport {
        JsonReport { out_dir: out_dir.into() }
    }

    /// Build the document as a `Json` tree (pure).  Kept for consumers
    /// that want the tree (tests, the CI runner's store-equivalence
    /// check); the emitter itself streams through
    /// [`JsonReport::write_document`] instead.
    pub fn document(analysis: &Analysis) -> Json {
        let experiments: Vec<Json> = analysis
            .experiments
            .iter()
            .map(|e| experiment_json(e))
            .collect();
        Json::from_pairs(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("experiments", Json::Arr(experiments)),
            (
                "warnings",
                Json::Arr(
                    analysis.warnings.iter().map(warning_json).collect(),
                ),
            ),
            (
                "gate",
                analysis
                    .gate
                    .as_ref()
                    .map(|v| v.to_json())
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    /// Stream the document into `w` — byte-identical to
    /// `document(analysis).to_string_pretty()` (pinned by a test and
    /// the report goldens) without materializing the run histories as
    /// a tree.  The histories dominate the document (one `RunMetrics`
    /// object per stored run); detections, models and the gate verdict
    /// are small and go through the tree bridge.
    pub fn write_document(analysis: &Analysis, w: &mut JsonWriter) {
        w.begin_obj();
        w.key("schema_version");
        w.num(SCHEMA_VERSION as f64);
        w.key("experiments");
        w.begin_arr();
        for exp in &analysis.experiments {
            write_experiment(exp, w);
        }
        w.end_arr();
        w.key("warnings");
        w.begin_arr();
        for warning in &analysis.warnings {
            // Streamed in lockstep with `warning_json` — the two paths
            // must stay byte-identical (pinned by a test).
            w.begin_obj();
            w.key("code");
            w.str_val(warning.code);
            w.key("severity");
            w.str_val(warning.severity.id());
            w.key("path");
            w.str_val(&warning.path);
            w.key("message");
            w.str_val(&warning.message);
            w.key("span");
            match warning.span {
                Some(s) => {
                    w.begin_obj();
                    w.key("start");
                    w.num(s.start as f64);
                    w.key("len");
                    w.num(s.len as f64);
                    w.end_obj();
                }
                None => w.null(),
            }
            w.end_obj();
        }
        w.end_arr();
        w.key("gate");
        match &analysis.gate {
            Some(v) => w.value(&v.to_json()),
            None => w.null(),
        }
        w.end_obj();
    }
}

impl Emitter for JsonReport {
    fn name(&self) -> &'static str {
        "json-report"
    }

    fn emit(&mut self, analysis: &Analysis) -> Result<EmitterReport> {
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("creating {}", self.out_dir.display()))?;
        // Pre-size on the dominant term: ~1.6 KB of pretty-printed
        // JSON per run-history entry.
        let runs: usize = analysis
            .experiments
            .iter()
            .map(|e| e.histories.iter().map(|(_, h)| h.len()).sum::<usize>())
            .sum();
        let mut w = JsonWriter::with_capacity(4096 + runs * 1600, true);
        JsonReport::write_document(analysis, &mut w);
        w.newline();
        std::fs::write(self.out_dir.join(REPORT_FILE_NAME), w.into_string())?;
        Ok(EmitterReport {
            name: self.name(),
            files_written: 1,
            ..Default::default()
        })
    }
}

/// Stream one experiment (history entries via `RunMetrics::write_to`).
fn write_experiment(exp: &ExperimentAnalysis, w: &mut JsonWriter) {
    w.begin_obj();
    w.key("id");
    w.str_val(&exp.id);
    w.key("configs");
    w.begin_arr();
    for (cfg, runs) in &exp.histories {
        w.begin_obj();
        w.key("config");
        w.str_val(cfg);
        w.key("history");
        w.begin_arr();
        for run in runs {
            run.write_to(w);
        }
        w.end_arr();
        w.end_obj();
    }
    w.end_arr();
    w.key("detections");
    w.begin_arr();
    for f in &exp.findings {
        w.value(&finding_json(f));
    }
    w.end_arr();
    w.key("models");
    w.begin_arr();
    for (region, m) in &exp.models {
        w.begin_obj();
        w.key("region");
        w.str_val(region);
        w.key("a");
        w.num(m.a);
        w.key("b");
        w.num(m.b);
        w.key("c");
        w.num(m.c);
        w.key("smape");
        w.num(m.smape);
        w.key("formula");
        w.str_val(&m.formula());
        w.key("grows");
        w.boolean(m.grows());
        w.end_obj();
    }
    w.end_arr();
    w.end_obj();
}

fn experiment_json(exp: &ExperimentAnalysis) -> Json {
    let configs: Vec<Json> = exp
        .histories
        .iter()
        .map(|(cfg, runs)| {
            Json::from_pairs(vec![
                ("config", Json::Str(cfg.clone())),
                (
                    "history",
                    Json::Arr(runs.iter().map(RunMetrics::to_json).collect()),
                ),
            ])
        })
        .collect();
    let detections: Vec<Json> =
        exp.findings.iter().map(finding_json).collect();
    let models: Vec<Json> = exp
        .models
        .iter()
        .map(|(region, m)| {
            Json::from_pairs(vec![
                ("region", Json::Str(region.clone())),
                ("a", Json::Num(m.a)),
                ("b", Json::Num(m.b)),
                ("c", Json::Num(m.c)),
                ("smape", Json::Num(m.smape)),
                ("formula", Json::Str(m.formula())),
                ("grows", Json::Bool(m.grows())),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("id", Json::Str(exp.id.clone())),
        ("configs", Json::Arr(configs)),
        ("detections", Json::Arr(detections)),
        ("models", Json::Arr(models)),
    ])
}

/// One scan warning as its structured document object (schema v2).
fn warning_json(w: &crate::check::Diagnostic) -> Json {
    Json::from_pairs(vec![
        ("code", Json::Str(w.code.to_string())),
        ("severity", Json::Str(w.severity.id().to_string())),
        ("path", Json::Str(w.path.clone())),
        ("message", Json::Str(w.message.clone())),
        (
            "span",
            match w.span {
                Some(s) => Json::from_pairs(vec![
                    ("start", Json::Num(s.start as f64)),
                    ("len", Json::Num(s.len as f64)),
                ]),
                None => Json::Null,
            },
        ),
    ])
}

fn finding_json(f: &Finding) -> Json {
    Json::from_pairs(vec![
        ("region", Json::Str(f.region.clone())),
        ("config", Json::Str(f.config.clone())),
        ("at_index", Json::Num(f.at_index as f64)),
        (
            "commit",
            f.commit.clone().map(Json::Str).unwrap_or(Json::Null),
        ),
        (
            "kind",
            Json::Str(
                match f.kind {
                    ChangeKind::Regression => "regression",
                    ChangeKind::Improvement => "improvement",
                }
                .to_string(),
            ),
        ),
        ("factor", Json::Num(f.factor)),
        (
            "explanation",
            match &f.explanation {
                Some((metric, before, after)) => Json::from_pairs(vec![
                    ("metric", Json::Str(metric.clone())),
                    ("before", Json::Num(*before)),
                    ("after", Json::Num(*after)),
                ]),
                None => Json::Null,
            },
        ),
    ])
}

/// One experiment as read back from a `report.json`.
#[derive(Debug)]
pub struct ReportExperiment {
    pub id: String,
    /// (config label, run history oldest-first), reconstructed to full
    /// [`RunMetrics`].
    pub configs: Vec<(String, Vec<RunMetrics>)>,
    /// Raw detection records (region/config/kind/factor/...).
    pub detections: Vec<Json>,
    /// Raw model records (region/a/b/c/smape/formula/grows).
    pub models: Vec<Json>,
}

/// A parsed-and-validated `report.json` — the consumer half of the
/// contract.  [`ReportDocument::parse`] is strict about
/// `schema_version`: missing or unknown versions are errors, never
/// guesses.
#[derive(Debug)]
pub struct ReportDocument {
    pub schema_version: u64,
    pub experiments: Vec<ReportExperiment>,
    pub warnings: Vec<String>,
    /// The embedded gate verdict document, when the report was gated.
    pub gate: Option<Json>,
}

impl ReportDocument {
    /// Parse and validate a `report.json` document.
    pub fn parse(text: &str) -> Result<ReportDocument> {
        let j = Json::parse(text).context("report.json: invalid JSON")?;
        let version = j
            .get("schema_version")
            .and_then(Json::as_u64)
            .context("report.json: missing schema_version")?;
        if version != SCHEMA_VERSION {
            bail!(
                "report.json: unsupported schema_version {version} \
                 (this reader understands {SCHEMA_VERSION})"
            );
        }
        let mut experiments = Vec::new();
        for ej in j
            .get("experiments")
            .and_then(Json::as_arr)
            .context("report.json: missing experiments")?
        {
            let id = ej
                .get("id")
                .and_then(Json::as_str)
                .context("report.json: experiment without id")?
                .to_string();
            let mut configs = Vec::new();
            for cj in ej
                .get("configs")
                .and_then(Json::as_arr)
                .context("report.json: experiment without configs")?
            {
                let label = cj
                    .get("config")
                    .and_then(Json::as_str)
                    .context("report.json: config without label")?
                    .to_string();
                let mut history = Vec::new();
                for rj in cj
                    .get("history")
                    .and_then(Json::as_arr)
                    .context("report.json: config without history")?
                {
                    history.push(
                        RunMetrics::from_json(rj)
                            .context("report.json: bad history entry")?,
                    );
                }
                configs.push((label, history));
            }
            let raw_list = |key: &str| -> Vec<Json> {
                ej.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| a.to_vec())
                    .unwrap_or_default()
            };
            experiments.push(ReportExperiment {
                id,
                configs,
                detections: raw_list("detections"),
                models: raw_list("models"),
            });
        }
        // Warning objects flatten back to their canonical display
        // strings (`path: message [code]` / `path:offset: ...`).
        let warnings = j
            .get("warnings")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|w| {
                        let code = w.str_or("code", "?");
                        let path = w.str_or("path", "?");
                        let message = w.str_or("message", "");
                        match w
                            .at(&["span", "start"])
                            .and_then(Json::as_u64)
                        {
                            Some(start) => format!(
                                "{path}:{start}: {message} [{code}]"
                            ),
                            None => format!("{path}: {message} [{code}]"),
                        }
                    })
                    .collect()
            })
            .unwrap_or_default();
        let gate = match j.get("gate") {
            None | Some(Json::Null) => None,
            Some(g) => Some(g.clone()),
        };
        Ok(ReportDocument { schema_version: version, experiments, warnings, gate })
    }

    /// Overall gate status id (`pass`/`warn`/`fail`), when gated.
    pub fn gate_status(&self) -> Option<&str> {
        self.gate.as_ref().and_then(|g| {
            g.get("status").and_then(Json::as_str)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::build_input;
    use super::*;
    use crate::session::{AnalyzeOptions, Session};
    use crate::util::fs::TempDir;

    fn emit_report(gate: bool) -> (TempDir, Analysis) {
        let td = TempDir::new("json-in").unwrap();
        let out = TempDir::new("json-out").unwrap();
        build_input(&td);
        let analysis = Session::new(td.path()).scan().unwrap().analyze(
            &AnalyzeOptions {
                gate: gate.then(crate::gate::GatePolicy::default),
                ..Default::default()
            },
        );
        JsonReport::new(out.path()).emit(&analysis).unwrap();
        (out, analysis)
    }

    #[test]
    fn document_round_trips_through_the_parser() {
        let (out, analysis) = emit_report(true);
        let text = std::fs::read_to_string(
            out.path().join(REPORT_FILE_NAME),
        )
        .unwrap();
        let doc = ReportDocument::parse(&text).unwrap();
        assert_eq!(doc.schema_version, SCHEMA_VERSION);
        assert_eq!(doc.experiments.len(), 1);
        let exp = &doc.experiments[0];
        assert_eq!(exp.id, "salpha/resolution_1");
        assert_eq!(exp.configs.len(), 1);
        let (cfg, history) = &exp.configs[0];
        assert_eq!(cfg, "2x8");
        assert_eq!(history.len(), 4);
        // Reconstructed metrics are bit-exact vs the analysis.
        let orig = &analysis.experiments[0].histories[0].1;
        for (a, b) in history.iter().zip(orig) {
            assert_eq!(a.source, b.source);
            assert_eq!(a.effective_timestamp(), b.effective_timestamp());
            assert_eq!(
                a.region("Global").unwrap().metrics,
                b.region("Global").unwrap().metrics
            );
        }
        // The fixture's bug -> fix history shows up as a detection.
        assert!(!exp.detections.is_empty());
        assert!(exp
            .detections
            .iter()
            .any(|d| d.str_or("kind", "") == "improvement"));
        assert_eq!(doc.gate_status(), Some("pass"));
    }

    #[test]
    fn streamed_document_matches_tree_document() {
        // The emitter streams; `document()` builds the tree — the two
        // must stay byte-identical (gated and ungated).
        for gate in [true, false] {
            let (out, analysis) = emit_report(gate);
            let written = std::fs::read_to_string(
                out.path().join(REPORT_FILE_NAME),
            )
            .unwrap();
            assert_eq!(
                written,
                JsonReport::document(&analysis).to_string_pretty(),
                "gate={gate}"
            );
        }
    }

    #[test]
    fn warning_objects_stream_and_parse_back_as_display_strings() {
        use crate::check::{Diagnostic, Span};
        let analysis = Analysis {
            input: "in".into(),
            experiments: Vec::new(),
            warnings: vec![
                Diagnostic::warning("TP001", "exp/bad.json", "invalid JSON")
                    .with_span(Span { start: 17, len: 1 }),
                Diagnostic::warning("TP013", "exp/gone.json", "unreadable"),
            ],
            cache_hits: 0,
            cache_misses: 0,
            gate: None,
        };
        // Streamed output matches the tree builder byte-for-byte.
        let mut w = JsonWriter::with_capacity(512, true);
        JsonReport::write_document(&analysis, &mut w);
        let streamed = w.into_string();
        assert_eq!(
            streamed,
            JsonReport::document(&analysis)
                .to_string_pretty()
                .trim_end(),
        );
        // Objects carry the code/span...
        assert!(streamed.contains("\"code\": \"TP001\""));
        assert!(streamed.contains("\"start\": 17"));
        // ...and parse back into the canonical display strings.
        let doc = ReportDocument::parse(&streamed).unwrap();
        assert_eq!(
            doc.warnings,
            [
                "exp/bad.json:17: invalid JSON [TP001]",
                "exp/gone.json: unreadable [TP013]",
            ]
        );
    }

    #[test]
    fn ungated_report_has_null_gate() {
        let (out, _) = emit_report(false);
        let text = std::fs::read_to_string(
            out.path().join(REPORT_FILE_NAME),
        )
        .unwrap();
        assert!(text.contains("\"gate\": null"));
        let doc = ReportDocument::parse(&text).unwrap();
        assert!(doc.gate.is_none());
        assert!(doc.gate_status().is_none());
    }

    #[test]
    fn unknown_or_missing_schema_version_is_rejected() {
        let (out, _) = emit_report(false);
        let text = std::fs::read_to_string(
            out.path().join(REPORT_FILE_NAME),
        )
        .unwrap();
        // A future version must be rejected, not half-parsed.
        let bumped = text.replace(
            "\"schema_version\": 2",
            "\"schema_version\": 999",
        );
        assert_ne!(text, bumped, "version stamp must be present");
        let err = ReportDocument::parse(&bumped).unwrap_err().to_string();
        assert!(err.contains("999"), "{err}");
        // Missing version is just as fatal.
        let stripped = text.replace(
            "\"schema_version\": 2,",
            "",
        );
        assert!(ReportDocument::parse(&stripped).is_err());
        // Garbage is a parse error with context.
        assert!(ReportDocument::parse("{nope").is_err());
    }
}
