//! The HTML-site emitter: index page, one page per experiment
//! (scaling-efficiency tables + time-evolution plots + findings +
//! models), all rendered from the shared [`Analysis`] — this emitter
//! does string assembly and file writes only; every number was
//! computed in the analyze stage.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::pages::svgplot::{self, esc, Series};
use crate::pages::timeseries::PLOT_METRICS;
use crate::pages::{badge, html, table_html};
use crate::util::timefmt;

use super::analysis::{Analysis, ExperimentAnalysis};
use super::emit::{Emitter, EmitterReport};

/// Writes `index.html` plus `<slug>.html` per experiment into its
/// output directory.
pub struct HtmlSite {
    out_dir: PathBuf,
}

impl HtmlSite {
    pub fn new(out_dir: impl Into<PathBuf>) -> HtmlSite {
        HtmlSite { out_dir: out_dir.into() }
    }
}

impl Emitter for HtmlSite {
    fn name(&self) -> &'static str {
        "html-site"
    }

    fn emit(&mut self, analysis: &Analysis) -> Result<EmitterReport> {
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("creating {}", self.out_dir.display()))?;
        let mut report = EmitterReport { name: self.name(), ..Default::default() };
        let mut index_items = String::new();
        for exp in &analysis.experiments {
            let file = format!("{}.html", exp.slug);
            std::fs::write(
                self.out_dir.join(&file),
                html::page(
                    &format!("TALP report — {}", exp.id),
                    &experiment_body(exp),
                ),
            )?;
            report.pages_written += 1;
            index_items.push_str(&format!(
                "<li><a href=\"{}\">{}</a> — {} configs, {} runs</li>\n",
                file,
                esc(&exp.id),
                exp.configs.len(),
                exp.total_runs
            ));
        }
        std::fs::write(
            self.out_dir.join("index.html"),
            html::page("TALP-Pages report", &index_body(analysis, &index_items)),
        )?;
        report.pages_written += 1;
        report.files_written = report.pages_written;
        Ok(report)
    }
}

fn index_body(analysis: &Analysis, index_items: &str) -> String {
    let mut body = String::from("<h1>TALP-Pages performance report</h1>\n");
    if let Some(v) = &analysis.gate {
        let cls = match v.status {
            crate::gate::GateStatus::Pass => "gate-pass",
            crate::gate::GateStatus::Warn => "gate-warn",
            crate::gate::GateStatus::Fail => "gate-fail",
        };
        body.push_str(&format!(
            "<div class=\"gate {cls}\"><b>Performance gate: {}</b> — {}\n",
            v.status.label(),
            esc(&v.summary_line())
        ));
        let notable: Vec<_> = v.notable().collect();
        if !notable.is_empty() {
            body.push_str("<ul>\n");
            for c in notable {
                body.push_str(&format!(
                    "<li class=\"{}\">[{}] {} / {} / {} — {}</li>\n",
                    c.outcome.id(),
                    c.outcome.id().to_uppercase(),
                    esc(&c.experiment),
                    esc(&c.config),
                    esc(&c.region),
                    esc(&c.detail)
                ));
            }
            body.push_str("</ul>\n");
        }
        body.push_str(
            "<p><a href=\"gate.md\">gate.md</a> · \
             <a href=\"gate.json\">gate.json</a> · \
             <a href=\"gate.xml\">gate.xml</a></p></div>\n",
        );
    }
    if !analysis.warnings.is_empty() {
        body.push_str("<div class=\"warn\"><b>Warnings:</b><ul>");
        for w in &analysis.warnings {
            body.push_str(&format!("<li>{}</li>", esc(&w.to_string())));
        }
        body.push_str("</ul></div>\n");
    }
    body.push_str(&format!(
        "<p>{} experiment(s) found under <code>{}</code>.</p>\n<ul class=\"exp-list\">\n{index_items}</ul>\n",
        analysis.experiments.len(),
        esc(&analysis.input),
    ));
    body
}

/// Render one experiment's page body (pure string assembly).
fn experiment_body(exp: &ExperimentAnalysis) -> String {
    let mut body = format!("<h1>{}</h1>\n", esc(&exp.id));

    // ---- badges (inline copies of the badge files) ----
    body.push_str("<div class=\"badges\">\n");
    for b in &exp.badges {
        body.push_str(&badge::parallel_efficiency_badge(
            &b.region, &b.config, b.value,
        ));
    }
    body.push_str("</div>\n");

    // ---- scaling-efficiency tables ----
    for (region, table) in &exp.tables {
        body.push_str(&format!(
            "<h2>Scaling efficiency — region <code>{}</code></h2>\n",
            esc(region)
        ));
        body.push_str(&table_html::render(table));
    }

    // ---- detected changes ----
    if !exp.findings.is_empty() {
        body.push_str("<h2>Detected changes</h2>\n<ul class=\"findings\">\n");
        for f in &exp.findings {
            body.push_str(&format!(
                "<li class=\"{}\">{}</li>\n",
                match f.kind {
                    crate::pages::detect::ChangeKind::Regression => {
                        "regression"
                    }
                    crate::pages::detect::ChangeKind::Improvement => {
                        "improvement"
                    }
                },
                esc(&f.describe())
            ));
        }
        body.push_str("</ul>\n");
    }

    // ---- Extra-P-style scaling models ----
    if !exp.models.is_empty() {
        body.push_str("<h2>Scaling models (Extra-P-style)</h2>\n<ul>\n");
        for (region, m) in &exp.models {
            body.push_str(&format!(
                "<li><code>{}</code>: elapsed(p) ≈ {} (SMAPE {:.1}%){}</li>\n",
                esc(region),
                esc(&m.formula()),
                m.smape * 100.0,
                if m.grows() {
                    " <b>⚠ grows with resources</b>"
                } else {
                    ""
                }
            ));
        }
        body.push_str("</ul>\n");
    }

    // ---- time-evolution plots per configuration ----
    for cs in &exp.series {
        let ts = &cs.series;
        let regions = ts.regions();
        body.push_str(&format!(
            "<h2>Time evolution — {} ({} runs)</h2>\n",
            esc(&cs.config),
            cs.runs
        ));
        let toggle_info: Vec<(String, String, String)> = regions
            .iter()
            .enumerate()
            .map(|(i, r)| (r.clone(), svgplot::css_class(r), svgplot::color(i)))
            .collect();
        body.push_str(&html::toggles(&toggle_info));
        for (metric, label) in PLOT_METRICS {
            let series: Vec<Series> = regions
                .iter()
                .enumerate()
                .map(|(i, r)| Series {
                    label: r.clone(),
                    points: ts.metric(r, metric),
                    color: svgplot::color(i),
                })
                .filter(|s| !s.points.is_empty())
                .collect();
            if series.is_empty() {
                continue;
            }
            body.push_str(&svgplot::line_chart(label, &series, ""));
        }
        // Commit annotations under the plots.  Commit strings are
        // arbitrary parsed input, so take a char prefix (a byte slice
        // could split a UTF-8 sequence and panic).
        let commits: Vec<String> = ts
            .points
            .iter()
            .filter_map(|p| {
                p.commit.as_ref().map(|c| {
                    let short: String = c.chars().take(8).collect();
                    format!(
                        "<code>{}</code> ({})",
                        esc(&short),
                        timefmt::to_iso8601(p.timestamp)
                    )
                })
            })
            .collect();
        if !commits.is_empty() {
            body.push_str(&format!(
                "<p>Commits: {}</p>\n",
                commits.join(" · ")
            ));
        }
    }
    body
}

#[cfg(test)]
mod tests {
    use super::super::tests::build_input;
    use super::*;
    use crate::session::{AnalyzeOptions, Session};
    use crate::util::fs::TempDir;

    fn analyze(td: &TempDir, opts: &AnalyzeOptions) -> Analysis {
        Session::new(td.path()).scan().unwrap().analyze(opts)
    }

    fn write_site(
        analysis: &Analysis,
        out_dir: &std::path::Path,
    ) -> Result<EmitterReport> {
        HtmlSite::new(out_dir).emit(analysis)
    }

    #[test]
    fn site_renders_tables_plots_findings_and_index() {
        let td = TempDir::new("html-in").unwrap();
        let out = TempDir::new("html-out").unwrap();
        build_input(&td);
        let analysis = analyze(
            &td,
            &AnalyzeOptions {
                regions: vec!["initialize".into(), "timestep".into()],
                region_for_badge: Some("timestep".into()),
                ..Default::default()
            },
        );
        let r = write_site(&analysis, out.path()).unwrap();
        assert_eq!(r.pages_written, 2); // index + 1 experiment
        let page = std::fs::read_to_string(
            out.path().join("salpha_resolution_1.html"),
        )
        .unwrap();
        assert!(page.contains("Scaling efficiency"));
        assert!(page.contains("Time evolution"));
        assert!(page.contains("initialize"));
        assert!(page.contains("polyline"));
        assert!(page.contains("Commits:"));
        // The bug->fix history must surface as an automated finding.
        assert!(page.contains("Detected changes"), "no findings section");
        assert!(page.contains("sped up"));
        assert!(page.contains("OpenMP Serialization efficiency"));
        // The inline badge mentions the badge region.
        assert!(page.contains("timestep"));
        let index =
            std::fs::read_to_string(out.path().join("index.html")).unwrap();
        assert!(index.contains("salpha_resolution_1.html"));
        assert!(index.contains("1 experiment(s) found under"));
    }

    #[test]
    fn single_run_config_has_table_but_no_plot() {
        use crate::apps::{run_with_talp, CodeVersion, Genex};
        use crate::sim::{MachineSpec, ResourceConfig};
        let td = TempDir::new("html-in2").unwrap();
        let out = TempDir::new("html-out2").unwrap();
        let machine = MachineSpec::marenostrum5();
        let mut app = Genex::salpha(1, CodeVersion::fixed());
        app.timesteps = 2;
        let (d, _) = run_with_talp(
            &app,
            &machine,
            &ResourceConfig::new(2, 8),
            1,
            1_700_000_000,
        );
        d.write_file(&td.path().join("exp/one.json")).unwrap();
        let analysis = analyze(&td, &AnalyzeOptions::default());
        write_site(&analysis, out.path()).unwrap();
        let page =
            std::fs::read_to_string(out.path().join("exp.html")).unwrap();
        assert!(page.contains("Scaling efficiency"));
        assert!(!page.contains("Time evolution"));
    }

    #[test]
    fn warnings_and_gate_surface_in_index() {
        let td = TempDir::new("html-in3").unwrap();
        let out = TempDir::new("html-out3").unwrap();
        build_input(&td);
        std::fs::write(td.path().join("salpha/resolution_1/bad.json"), "][")
            .unwrap();
        let analysis = analyze(
            &td,
            &AnalyzeOptions {
                gate: Some(crate::gate::GatePolicy::default()),
                ..Default::default()
            },
        );
        assert_eq!(analysis.warnings.len(), 1);
        write_site(&analysis, out.path()).unwrap();
        let index =
            std::fs::read_to_string(out.path().join("index.html")).unwrap();
        assert!(index.contains("Warnings"));
        assert!(index.contains("Performance gate: PASS"));
        assert!(index.contains("gate.json"));
    }
}
