//! The gate-file emitter: writes the verdict triple — `gate.json`
//! (machines), `gate.md` (PR comments), `gate.xml` (JUnit) — when the
//! analysis carried a gate policy, and is a clean no-op otherwise, so
//! it can sit unconditionally in an emitter set.

use std::path::PathBuf;

use anyhow::Result;

use super::analysis::Analysis;
use super::emit::{Emitter, EmitterReport};

/// Writes `gate.json` / `gate.md` / `gate.xml` into its output
/// directory iff the analysis holds a [`crate::gate::GateVerdict`].
pub struct GateFiles {
    out_dir: PathBuf,
}

impl GateFiles {
    pub fn new(out_dir: impl Into<PathBuf>) -> GateFiles {
        GateFiles { out_dir: out_dir.into() }
    }
}

impl Emitter for GateFiles {
    fn name(&self) -> &'static str {
        "gate-files"
    }

    fn emit(&mut self, analysis: &Analysis) -> Result<EmitterReport> {
        let mut report = EmitterReport { name: self.name(), ..Default::default() };
        if let Some(v) = &analysis.gate {
            crate::gate::write_outputs(v, &self.out_dir)?;
            report.files_written = 3;
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::build_input;
    use super::*;
    use crate::session::{AnalyzeOptions, Session};
    use crate::util::fs::TempDir;

    #[test]
    fn writes_triple_with_verdict_and_nothing_without() {
        let td = TempDir::new("gatefiles-in").unwrap();
        build_input(&td);

        let out = TempDir::new("gatefiles-out").unwrap();
        let gated = Session::new(td.path()).scan().unwrap().analyze(
            &AnalyzeOptions {
                gate: Some(crate::gate::GatePolicy::default()),
                ..Default::default()
            },
        );
        let r = GateFiles::new(out.path()).emit(&gated).unwrap();
        assert_eq!(r.files_written, 3);
        for f in ["gate.json", "gate.md", "gate.xml"] {
            assert!(out.path().join(f).exists(), "{f} missing");
        }

        let out2 = TempDir::new("gatefiles-out2").unwrap();
        let plain = Session::new(td.path())
            .scan()
            .unwrap()
            .analyze(&AnalyzeOptions::default());
        let r = GateFiles::new(out2.path()).emit(&plain).unwrap();
        assert_eq!(r.files_written, 0);
        assert!(!out2.path().join("gate.json").exists());
    }
}
