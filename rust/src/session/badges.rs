//! The badge emitter: one parallel-efficiency SVG per (experiment,
//! configuration) under `badges/`, plus the `badges/gate.svg` verdict
//! badge when the analysis carried a gate policy.  Renders from the
//! same [`super::BadgeDatum`] values the HTML pages inline, so the
//! standalone files are byte-identical to the embedded copies.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::pages::badge;

use super::analysis::Analysis;
use super::emit::{Emitter, EmitterReport};

/// Writes `badges/*.svg` under its output root.
pub struct Badges {
    out_dir: PathBuf,
}

impl Badges {
    pub fn new(out_dir: impl Into<PathBuf>) -> Badges {
        Badges { out_dir: out_dir.into() }
    }
}

impl Emitter for Badges {
    fn name(&self) -> &'static str {
        "badges"
    }

    fn emit(&mut self, analysis: &Analysis) -> Result<EmitterReport> {
        let dir = self.out_dir.join("badges");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let mut report = EmitterReport { name: self.name(), ..Default::default() };
        for exp in &analysis.experiments {
            for b in &exp.badges {
                std::fs::write(
                    self.out_dir.join(&b.file),
                    badge::parallel_efficiency_badge(
                        &b.region, &b.config, b.value,
                    ),
                )?;
                report.badges_written += 1;
            }
        }
        if let Some(v) = &analysis.gate {
            std::fs::write(
                dir.join("gate.svg"),
                badge::gate_badge(v.status),
            )?;
            report.badges_written += 1;
        }
        report.files_written = report.badges_written;
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::build_input;
    use super::*;
    use crate::session::{AnalyzeOptions, Session};
    use crate::util::fs::TempDir;

    #[test]
    fn writes_pe_and_gate_badges() {
        let td = TempDir::new("badges-in").unwrap();
        let out = TempDir::new("badges-out").unwrap();
        build_input(&td);
        let analysis = Session::new(td.path()).scan().unwrap().analyze(
            &AnalyzeOptions {
                region_for_badge: Some("timestep".into()),
                gate: Some(crate::gate::GatePolicy::default()),
                ..Default::default()
            },
        );
        let r = Badges::new(out.path()).emit(&analysis).unwrap();
        assert_eq!(r.badges_written, 2, "one PE badge + the gate badge");
        let pe = std::fs::read_to_string(
            out.path().join("badges/salpha_resolution_1__2x8.svg"),
        )
        .unwrap();
        assert!(pe.contains("timestep"));
        let gate = std::fs::read_to_string(
            out.path().join("badges/gate.svg"),
        )
        .unwrap();
        assert!(gate.contains("perf gate"));
        assert!(gate.contains("passing"));
    }
}
