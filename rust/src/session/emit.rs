//! Stage 3: the pluggable output layer.  An [`Emitter`] turns the
//! analyzed data into files (or any other side effect); the built-in
//! set is [`super::HtmlSite`], [`super::Badges`], [`super::GateFiles`]
//! and [`super::JsonReport`], and embedders add their own by
//! implementing the one-method trait.
//!
//! Emitters run sequentially in slice order on the calling thread —
//! all parallelism lives in the scan/analyze stages, which is what
//! makes every emitter's output deterministic for free.

use anyhow::Result;

use super::analysis::Analysis;

/// What one emitter wrote.
#[derive(Debug, Clone, Default)]
pub struct EmitterReport {
    /// The emitter's [`Emitter::name`].
    pub name: &'static str,
    /// HTML pages written (index + per-experiment pages).
    pub pages_written: usize,
    /// SVG badges written.
    pub badges_written: usize,
    /// Total files written, badges and pages included.
    pub files_written: usize,
}

/// One output backend.  Emitters own their destination (constructor
/// argument), so one analysis can fan out to several directories or
/// formats in a single pass.
pub trait Emitter {
    /// Stable identifier for logs and [`EmitSummary::emitters`].
    fn name(&self) -> &'static str;

    /// Render `analysis` to this emitter's destination.
    fn emit(&mut self, analysis: &Analysis) -> Result<EmitterReport>;
}

/// Aggregate result of one [`Analysis::emit`] pass.
///
/// The cache counters are copied from the analysis (i.e. from the
/// *scan*), so they are identical no matter which emitters ran — a
/// JSON-only emit on a warm cache reports the same zero-miss scan a
/// full site emit would.
#[derive(Debug)]
pub struct EmitSummary {
    pub experiments: usize,
    pub pages_written: usize,
    pub badges_written: usize,
    /// Total files across all emitters (pages and badges included).
    pub files_written: usize,
    /// Scan warnings in display form (`path: message [code]`).
    pub warnings: Vec<String>,
    /// Artifacts served from the metrics cache (not re-parsed).
    pub cache_hits: usize,
    /// Artifacts parsed + reduced by the scan.
    pub cache_misses: usize,
    /// Regression-gate verdict (when the analysis carried a policy).
    pub gate: Option<crate::gate::GateVerdict>,
    /// Per-emitter breakdown, in run order.
    pub emitters: Vec<EmitterReport>,
}

impl Analysis {
    /// Stage 3: run every emitter over this analysis and aggregate
    /// their reports.  Emitters run in slice order; the first error
    /// aborts the pass.
    pub fn emit(
        &self,
        emitters: &mut [Box<dyn Emitter>],
    ) -> Result<EmitSummary> {
        let mut summary = EmitSummary {
            experiments: self.experiments.len(),
            pages_written: 0,
            badges_written: 0,
            files_written: 0,
            warnings: self.warnings.iter().map(|w| w.to_string()).collect(),
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            gate: self.gate.clone(),
            emitters: Vec::with_capacity(emitters.len()),
        };
        for emitter in emitters {
            let report = emitter.emit(self)?;
            summary.pages_written += report.pages_written;
            summary.badges_written += report.badges_written;
            summary.files_written += report.files_written;
            summary.emitters.push(report);
        }
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::build_input;
    use super::*;
    use crate::session::{AnalyzeOptions, Session};
    use crate::util::fs::TempDir;

    struct Counting(&'static str, usize);

    impl Emitter for Counting {
        fn name(&self) -> &'static str {
            self.0
        }

        fn emit(&mut self, a: &Analysis) -> Result<EmitterReport> {
            self.1 += 1;
            Ok(EmitterReport {
                name: self.0,
                files_written: a.experiments.len(),
                ..Default::default()
            })
        }
    }

    struct Failing;

    impl Emitter for Failing {
        fn name(&self) -> &'static str {
            "failing"
        }

        fn emit(&mut self, _a: &Analysis) -> Result<EmitterReport> {
            anyhow::bail!("boom")
        }
    }

    #[test]
    fn emit_aggregates_reports_and_carries_scan_counters() {
        let td = TempDir::new("emit").unwrap();
        build_input(&td);
        let analysis = Session::new(td.path())
            .scan()
            .unwrap()
            .analyze(&AnalyzeOptions::default());
        let mut emitters: Vec<Box<dyn Emitter>> =
            vec![Box::new(Counting("a", 0)), Box::new(Counting("b", 0))];
        let s = analysis.emit(&mut emitters).unwrap();
        assert_eq!(s.experiments, 1);
        assert_eq!(s.files_written, 2, "one per emitter per experiment");
        assert_eq!(s.emitters.len(), 2);
        assert_eq!(s.emitters[0].name, "a");
        // Counters come from the scan, not from any emitter.
        assert_eq!(s.cache_misses, 4);
        assert_eq!(s.cache_hits, 0);
        // A failing emitter aborts the pass with its error.
        let mut bad: Vec<Box<dyn Emitter>> = vec![Box::new(Failing)];
        assert!(analysis.emit(&mut bad).is_err());
    }
}
