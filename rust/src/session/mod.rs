//! The staged report pipeline — the crate's library-first core.
//!
//! Every consumer of TALP-Pages data (the `talp-pages report` CLI, the
//! regression gate, the in-process CI engine, `ci-sim`, and any
//! embedder) routes through the same three typed stages:
//!
//! ```text
//! Session::new(root)      Scan::analyze(opts)       Analysis::emit(&mut emitters)
//!   .jobs(n)                 POP reduction              ┌ HtmlSite   (index + pages)
//!   .cache(path)             Extra-P fits               ├ Badges     (SVG badges)
//!   .scan()?   ──Scan──▶     time series     ──Analysis─┼ GateFiles  (gate.json/md/xml)
//!                            detection                  └ JsonReport (report.json)
//!   (folder walk +           gate verdict
//!    metrics cache +         (pure data, no I/O)        ──▶ EmitSummary
//!    worker pool)
//! ```
//!
//! * [`Session`] owns the *scan-stage* options: the input source, the
//!   worker-pool size (`jobs`, 0 = auto) and the metrics-cache
//!   location.  The source is pluggable ([`ScanSource`]):
//!   [`Session::new`] walks the paper's Fig. 2 folder layout through
//!   the content-hash cache (`pages::cache`), so on a warm run
//!   unchanged artifacts skip JSON parse *and* POP reduction entirely;
//!   [`Session::from_store`] loads the reduced histories straight out
//!   of a persistent [`crate::store::RunStore`] — zero parsing, no
//!   matter how many commits of history it holds.
//! * [`Scan`] is the reduced history: per-experiment
//!   [`crate::pages::MetricExperiment`] runs plus the cache hit/miss
//!   counters.  Counting happens *here* — the counters describe the
//!   scan, not any output format, so they stay correct no matter which
//!   emitters run later.
//! * [`Scan::analyze`] computes everything downstream consumers render
//!   — scaling-efficiency tables, Extra-P-style models, time series,
//!   regression/improvement findings, badge values and the optional
//!   gate verdict — as pure data ([`Analysis`]), no I/O.  The
//!   per-experiment fan-out reuses the session's worker pool and merges
//!   in deterministic scan order, so `jobs = 1` and `jobs = N` produce
//!   identical analyses (and therefore byte-identical outputs).
//! * [`Analysis::emit`] runs any set of [`Emitter`]s over the data and
//!   aggregates their file counts into an [`EmitSummary`].
//!
//! Determinism contract: same input folder + same options produce
//! byte-identical emitter outputs for every `jobs` value and cache
//! temperature.  The machine-readable [`JsonReport`] output additionally
//! carries a `schema_version` so downstream consumers can reject
//! documents they do not understand
//! ([`json_report::SCHEMA_VERSION`]).

pub mod analysis;
pub mod badges;
pub mod emit;
pub mod gate_files;
pub mod html_site;
pub mod json_report;

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::pages::scanner::{self, MetricExperiment, MetricScan};
use crate::pages::MetricsCache;
use crate::store::{QuerySpec, RunStore};

pub use analysis::{
    analyze_incremental, Analysis, AnalyzeOptions, BadgeDatum, ConfigSeries,
    ExperimentAnalysis, Reanalysis,
};
pub use badges::Badges;
pub use emit::{EmitSummary, Emitter, EmitterReport};
pub use gate_files::GateFiles;
pub use html_site::HtmlSite;
pub use json_report::{
    JsonReport, ReportDocument, ReportExperiment, REPORT_FILE_NAME,
    SCHEMA_VERSION,
};

/// Where a session reads its runs from.
#[derive(Debug, Clone)]
pub enum ScanSource {
    /// Walk a Fig. 2 artifact folder, parsing through the metrics
    /// cache (the classic path).
    Dir(PathBuf),
    /// Load reduced runs from a persistent [`crate::store::RunStore`]
    /// — no artifact is read or parsed at all.  The [`QuerySpec`]
    /// narrows which runs load: the default (match-all) spec reads the
    /// whole store through the classic loader, anything narrower goes
    /// through [`RunStore::query`] and its index sidecars, decoding
    /// only the matching lines.
    Store(PathBuf, QuerySpec),
}

impl ScanSource {
    /// The path this source reads (scan root or store root).
    pub fn path(&self) -> &Path {
        match self {
            ScanSource::Dir(p) => p,
            ScanSource::Store(p, _) => p,
        }
    }
}

/// Scan-stage options: where to read, how many workers, where the
/// metrics cache lives.  Build one per input source, then call
/// [`Session::scan`].
#[derive(Debug, Clone)]
pub struct Session {
    source: ScanSource,
    jobs: usize,
    cache_path: Option<PathBuf>,
}

impl Session {
    /// A session over one Fig. 2 input folder.
    pub fn new(root: impl Into<PathBuf>) -> Session {
        Session::from_source(ScanSource::Dir(root.into()))
    }

    /// A session over a persistent run store — `analyze`/`emit` run
    /// unchanged, but the scan stage parses nothing (the metrics cache
    /// is irrelevant and ignored for this source).
    pub fn from_store(root: impl Into<PathBuf>) -> Session {
        Session::from_source(ScanSource::Store(
            root.into(),
            QuerySpec::default(),
        ))
    }

    /// A session over a *subset* of a persistent run store: only the
    /// runs matching `spec` are loaded (through the store's index
    /// sidecars when they are usable) — `report --store --last 200`
    /// stays O(answer), not O(history).
    pub fn from_store_query(
        root: impl Into<PathBuf>,
        spec: QuerySpec,
    ) -> Session {
        Session::from_source(ScanSource::Store(root.into(), spec))
    }

    /// A session over any [`ScanSource`].
    pub fn from_source(source: ScanSource) -> Session {
        Session { source, jobs: 0, cache_path: None }
    }

    /// Worker threads for artifact parsing and per-experiment analysis
    /// (0 = auto: available parallelism capped at 16).  Outputs are
    /// byte-identical for every value.
    pub fn jobs(mut self, jobs: usize) -> Session {
        self.jobs = jobs;
        self
    }

    /// Persist the metrics cache at `path` (loaded before the scan,
    /// saved after).  Without a cache path every scan is a cold start.
    pub fn cache(mut self, path: impl Into<PathBuf>) -> Session {
        self.cache_path = Some(path.into());
        self
    }

    /// Like [`Session::cache`], but taking an optional path (handy for
    /// threading a CLI `--cache` flag through unchanged).
    pub fn cache_opt(mut self, path: Option<PathBuf>) -> Session {
        self.cache_path = path;
        self
    }

    /// Stage 1: materialize the reduced histories from the source.
    ///
    /// * [`ScanSource::Dir`]: walk the folder, reduce every artifact
    ///   to [`crate::pop::RunMetrics`] through the content-hash cache,
    ///   and persist the refreshed cache.  Unparsable artifacts become
    ///   warnings, not errors — a CI report must survive one corrupt
    ///   file.
    /// * [`ScanSource::Store`]: load the records of a persistent
    ///   [`crate::store::RunStore`]; every run counts as a cache hit
    ///   (nothing parses), corrupt store records become warnings, and
    ///   an unknown store version is a hard error.
    pub fn scan(self) -> Result<Scan> {
        let (root, scan) = match &self.source {
            ScanSource::Dir(root) => {
                let mut cache = match &self.cache_path {
                    Some(p) => MetricsCache::load(p),
                    None => MetricsCache::new(),
                };
                let scan =
                    scanner::scan_metrics(root, &mut cache, self.jobs)?;
                if let Some(p) = &self.cache_path {
                    cache.save(p)?;
                }
                (root.clone(), scan)
            }
            ScanSource::Store(root, spec) => {
                let scan = if spec.is_match_all() {
                    // Whole-store reads keep the classic loader (and
                    // its per-line corruption warnings with spans).
                    RunStore::open_with_jobs(root, self.jobs)?
                        .into_scan()
                } else {
                    let outcome =
                        RunStore::query(root, self.jobs, spec)?;
                    crate::store::records_into_scan(
                        outcome.records,
                        outcome.warnings,
                    )
                };
                (root.clone(), scan)
            }
        };
        Ok(Scan { root, jobs: self.jobs, scan })
    }
}

/// Stage-1 output: the reduced metrics histories plus the cache
/// counters.  Feed it to [`Scan::analyze`] (implemented in
/// [`analysis`]) to compute the render-ready [`Analysis`].
#[derive(Debug)]
pub struct Scan {
    pub(crate) root: PathBuf,
    pub(crate) jobs: usize,
    pub(crate) scan: MetricScan,
}

impl Scan {
    /// The scanned input root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Non-fatal scan warnings (corrupt/unreadable artifacts), as
    /// structured [`crate::check::Diagnostic`]s with stable codes.
    pub fn warnings(&self) -> &[crate::check::Diagnostic] {
        &self.scan.warnings
    }

    /// Artifacts served from the metrics cache (not re-parsed).
    pub fn cache_hits(&self) -> usize {
        self.scan.cache_hits
    }

    /// Artifacts parsed + reduced by this scan.
    pub fn cache_misses(&self) -> usize {
        self.scan.cache_misses
    }

    /// The per-experiment reduced histories.
    pub fn experiments(&self) -> &[MetricExperiment] {
        &self.scan.experiments
    }
}

/// The emitter set behind `talp-pages report --format all`: HTML site,
/// SVG badges, gate verdict files and the machine-readable
/// `report.json`, all rooted at `out_dir`.
pub fn default_emitters(out_dir: &Path) -> Vec<Box<dyn Emitter>> {
    vec![
        Box::new(HtmlSite::new(out_dir)),
        Box::new(Badges::new(out_dir)),
        Box::new(GateFiles::new(out_dir)),
        Box::new(JsonReport::new(out_dir)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::talp::GitMeta;
    use crate::util::fs::TempDir;

    /// Build a realistic input folder: one experiment, one config,
    /// 4-commit history with the Fig. 7 bug fix in the middle.
    pub(crate) fn build_input(td: &TempDir) {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        for i in 0..4 {
            let version = if i < 2 {
                CodeVersion::buggy()
            } else {
                CodeVersion::fixed()
            };
            let mut app = Genex::salpha(1, version);
            app.timesteps = 2;
            let (mut d, _) = run_with_talp(&app, &machine, &res, 100 + i, 0);
            d.git = Some(GitMeta {
                commit: format!("{i:07x}a"),
                branch: "main".into(),
                commit_timestamp: 1_700_000_000 + i as i64 * 86400,
                message: format!("commit {i}"),
            });
            d.write_file(
                &td.path().join(format!("salpha/resolution_1/run_{i}.json")),
            )
            .unwrap();
        }
    }

    #[test]
    fn scan_counts_and_warnings() {
        let td = TempDir::new("session-scan").unwrap();
        build_input(&td);
        std::fs::write(td.path().join("salpha/resolution_1/bad.json"), "][")
            .unwrap();
        let scan = Session::new(td.path()).scan().unwrap();
        assert_eq!(scan.experiments().len(), 1);
        assert_eq!(scan.cache_hits(), 0);
        assert_eq!(scan.cache_misses(), 4);
        assert_eq!(scan.warnings().len(), 1);
        assert_eq!(scan.root(), td.path());
    }

    #[test]
    fn cached_session_rescan_parses_nothing() {
        let td = TempDir::new("session-cache").unwrap();
        build_input(&td);
        let cache = td.path().join("cache/.talp-cache.json");
        let cold = Session::new(td.path()).cache(&cache).scan().unwrap();
        assert_eq!(cold.cache_misses(), 4);
        assert!(cache.exists(), "scan must persist the cache");
        let warm = Session::new(td.path()).cache(&cache).scan().unwrap();
        assert_eq!(warm.cache_hits(), 4);
        assert_eq!(warm.cache_misses(), 0);
    }

    #[test]
    fn missing_root_is_an_error() {
        let td = TempDir::new("session-missing").unwrap();
        assert!(Session::new(td.path().join("nope")).scan().is_err());
        // A store source needs an existing store, not just a directory.
        assert!(Session::from_store(td.path()).scan().is_err());
    }

    #[test]
    fn store_backed_scan_parses_nothing_and_matches_dir_scan() {
        let td = TempDir::new("session-store-in").unwrap();
        build_input(&td);
        let sd = TempDir::new("session-store-db").unwrap();
        let store_root = sd.path().join("store");
        let mut store =
            crate::store::RunStore::create_or_open(&store_root).unwrap();
        crate::store::ingest_dir(&mut store, td.path()).unwrap();
        drop(store);

        let from_dir = Session::new(td.path()).scan().unwrap();
        let from_store = Session::from_store(&store_root).scan().unwrap();
        assert_eq!(from_store.cache_hits(), 4, "all runs served stored");
        assert_eq!(from_store.cache_misses(), 0);
        assert_eq!(
            from_dir.experiments().len(),
            from_store.experiments().len()
        );
        let (a, b) = (&from_dir.experiments()[0], &from_store.experiments()[0]);
        assert_eq!(a.id, b.id);
        assert_eq!(a.configs(), b.configs());
        assert_eq!(a.regions(), b.regions());
        let (ha, hb) =
            (a.history_for_config("2x8"), b.history_for_config("2x8"));
        assert_eq!(ha.len(), hb.len());
        for (ra, rb) in ha.iter().zip(&hb) {
            assert_eq!(ra.source, rb.source);
            assert_eq!(
                ra.region("Global").unwrap().metrics,
                rb.region("Global").unwrap().metrics
            );
        }
    }

    #[test]
    fn store_query_scan_narrows_to_matching_runs() {
        let td = TempDir::new("session-query-in").unwrap();
        build_input(&td);
        let sd = TempDir::new("session-query-db").unwrap();
        let store_root = sd.path().join("store");
        let mut store =
            crate::store::RunStore::create_or_open(&store_root).unwrap();
        crate::store::ingest_dir(&mut store, td.path()).unwrap();
        drop(store);

        let spec = QuerySpec { last: Some(2), ..Default::default() };
        let narrowed = Session::from_store_query(&store_root, spec)
            .scan()
            .unwrap();
        assert_eq!(narrowed.experiments().len(), 1);
        let hist = narrowed.experiments()[0].history_for_config("2x8");
        assert_eq!(hist.len(), 2, "only the last 2 runs load");
        // The narrowed runs are the tail of the full history, same
        // bytes.
        let full = Session::from_store(&store_root).scan().unwrap();
        let tail = full.experiments()[0].history_for_config("2x8");
        assert_eq!(hist[0].source, tail[tail.len() - 2].source);
        assert_eq!(hist[1].source, tail[tail.len() - 1].source);

        // A spec no stored run satisfies is an error only when it is
        // unanswerable (unknown commit), empty results otherwise.
        let spec = QuerySpec {
            experiment: Some("no-such-experiment".into()),
            ..Default::default()
        };
        let empty = Session::from_store_query(&store_root, spec)
            .scan()
            .unwrap();
        assert!(empty.experiments().is_empty());
        let spec = QuerySpec {
            since_commit: Some("ffffffff".into()),
            ..Default::default()
        };
        assert!(Session::from_store_query(&store_root, spec)
            .scan()
            .is_err());
    }
}
