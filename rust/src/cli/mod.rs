//! The `talp-pages` command-line interface — a thin consumer of the
//! staged [`crate::session`] pipeline.
//!
//! Subcommands mirror the paper's tooling:
//! * `report` (alias `ci-report`) — Fig. 2 folder -> report site;
//!   `--format json|html|all` picks the emitter set; `--store` reads
//!   a persistent run store instead of an artifact folder.
//! * `ingest`     — append a Fig. 2 folder's artifacts into a
//!   persistent run store (only new content hashes are parsed);
//!   `--format` pins an ingestion adapter, default auto-detects.
//! * `sim`        — seeded deterministic workload simulator: emit a
//!   scenario-axis corpus in any registered adapter's format.
//! * `check`      — static analysis of every input surface (artifact
//!   trees, stores, policies, caches, reports, bench baselines) with
//!   stable `TP0xx` diagnostics and SARIF output; `report`/`gate`/
//!   `ingest` accept `--check` to run it as a pre-flight.
//! * `metadata`   — stamp git metadata into fresh TALP JSONs (Fig. 6).
//! * `run`        — run a workload under TALP on the simulator, emitting
//!   a TALP JSON (the "performance job" of Fig. 5).
//! * `compare`    — run the four tool chains on TeaLeaf and print the
//!   Table 1/2-style comparison.
//! * `ci-sim`     — run the full in-process CI demo (Fig. 4 / Fig. 7).
//! * `calibrate`  — validate the AOT artifacts against the native
//!   reference via PJRT.
//! * `badge`      — render one SVG badge.

pub mod args;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::apps::{self, Workload};
use crate::check;
use crate::ci;
use crate::gate::GatePolicy;
use crate::pages;
use crate::pop;
use crate::session::{
    AnalyzeOptions, Badges, Emitter, GateFiles, HtmlSite, JsonReport,
    Session,
};
use crate::sim::{MachineSpec, ResourceConfig};
use crate::store;
use crate::tools;
use crate::util::timefmt;

use args::Args;

pub const USAGE: &str = "\
talp-pages — continuous performance monitoring (TALP-Pages reproduction)

USAGE:
  talp-pages report (--input <dir> | --store <dir>) --output <dir>
             [--format json|html|all] [--regions <r>...]
             [--region-for-badge <r>] [--jobs <n>] [--cache <file>]
             [--gate <policy.json>] [--check]      (alias: ci-report)
             (store sources also take the `store query` filters)
  talp-pages ingest --input <dir> --store <dir> [--jobs <n>]
             [--format auto|talp|root-bench|beeswarm]
             [--commit <sha>] [--branch <name>] [--timestamp <iso8601>]
             [--message <m>] [--compact] [--check]
  talp-pages gate (--input <dir> | --store <dir>)
             [--policy <policy.json>] [--output <dir>] [--jobs <n>]
             [--cache <file>] [--check]  (exit 0 = pass/warn, 1 = fail)
  talp-pages gate-init --output <policy.json>
  talp-pages store stats --store <dir> [--jobs <n>]
  talp-pages store query --store <dir> [--experiment <pat>]
             [--config <pat>] [--since-commit <sha>]
             [--since <iso8601|unix>] [--until <iso8601|unix>]
             [--last <n>] [--output <file.jsonl>] [--no-index]
             [--bench-json] [--jobs <n>]
  talp-pages store compact --store <dir> [--threshold <0..1>]
             [--jobs <n>]
  talp-pages store fsck --store <dir> [--repair] [--jobs <n>]
             (crash-recovery scan, dry-run by default; exit 1 while
              errors remain)
  talp-pages store synth --store <dir> [--experiments <n>]
             [--configs <RxT>...] [--runs-per-shard <n>] [--seed <n>]
             [--machine <mn5|raven>]
  talp-pages sim --output <dir> [--seed <n>] [--runs <n>]
             [--axes <axis>...] [--format talp|root-bench|beeswarm]
             [--machine <mn5|raven>]
             (axes: weak-scaling|strong-scaling|hybrid|noise|drift|step)
  talp-pages serve --store <dir> [--addr <host:port>] [--watch <dir>]
             [--gate <policy.json>] [--regions <r>...]
             [--region-for-badge <r>] [--jobs <n>]
             [--max-body-bytes <n>] [--poll-ms <n>]
             [--read-timeout-ms <n>] [--write-timeout-ms <n>]
             [--max-connections <n>]
             (resident monitor; SIGTERM/SIGINT exits cleanly)
  talp-pages check [--input <dir> | --store <dir>] [--policy <p.json>]
             [--cache <file>] [--report <file>] [--bench <file>]
             [--format text|sarif] [--sarif <file>] [--jobs <n>]
             (exit 0 = clean, 1 = warnings, 2 = errors)
  talp-pages metadata --input <dir> --commit <sha> --branch <name>
             --timestamp <iso8601> [--message <m>]
  talp-pages run --app <tealeaf|genex|mpi-stencil> --machine <mn5|raven>
             --config <RxT> [--grid <n>] [--seed <n>] --output <file>
  talp-pages compare [--grid <n>] [--configs <RxT>...] [--region <r>]
  talp-pages ci-sim --output <dir> [--commits <n>] [--fix-at <n>]
             [--jobs <n>] [--gate <policy.json>]
  talp-pages calibrate
  talp-pages badge --label <text> --value <0..1> --output <file>
  talp-pages detect --input <dir> [--threshold <0..1>]
  talp-pages model --input <dir> [--regions <r>...]
  talp-pages summary --input <file.json> [--region <r>]
  talp-pages init-ci --flavor <gitlab|github> --output <file>
             [--regions <r>...] [--region-for-badge <r>]
             [--gate-policy <path>]

Fault injection (builds with `--features failpoints` only): every
subcommand takes a trailing `--failpoints '<spec>'`, or set the
TALP_FAILPOINTS env var; see the util::failpoint module docs.
";

pub fn main_with_args(argv: &[String]) -> Result<i32> {
    let args = Args::parse(argv);
    // Fault-injection activation rides on every subcommand as a
    // trailing flag (flag parsing is global, so position is free, but
    // it must come *after* the positionals — `--key` greedily consumes
    // the following non-`--` tokens).  On builds without the
    // `failpoints` feature this errors loudly instead of silently
    // running the real syscalls under a chaos spec.
    if let Some(spec) = args.get("failpoints") {
        crate::util::failpoint::configure(spec)?;
    }
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        println!("{USAGE}");
        return Ok(2);
    };
    match cmd {
        "report" | "ci-report" => ci_report(&args),
        "ingest" => ingest_cmd(&args),
        "gate" => gate_cmd(&args),
        "gate-init" => gate_init(&args),
        "store" => store_cmd(&args),
        "sim" => sim_cmd(&args),
        "serve" => serve_cmd(&args),
        "check" => check_cmd(&args),
        "metadata" => metadata(&args),
        "run" => run_app(&args),
        "compare" => compare(&args),
        "ci-sim" => ci_sim(&args),
        "calibrate" => calibrate(),
        "badge" => badge(&args),
        "detect" => detect_cmd(&args),
        "model" => model_cmd(&args),
        "summary" => summary_cmd(&args),
        "init-ci" => init_ci(&args),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

/// Emitter set for `--format` rooted at `out`: `html` is the site
/// (pages + badges + gate files), `json` is `report.json` only, `all`
/// is both.
fn emitters_for(format: &str, out: &Path) -> Result<Vec<Box<dyn Emitter>>> {
    Ok(match format {
        "html" => vec![
            Box::new(HtmlSite::new(out)) as Box<dyn Emitter>,
            Box::new(Badges::new(out)),
            Box::new(GateFiles::new(out)),
        ],
        "json" => vec![Box::new(JsonReport::new(out)) as Box<dyn Emitter>],
        "all" => crate::session::default_emitters(out),
        other => bail!("unknown --format '{other}' (json|html|all)"),
    })
}

/// The query-narrowing flags shared by `store query` and the `--store`
/// source of `report`/`gate`, parsed into a [`store::QuerySpec`].
/// `--since`/`--until` accept ISO-8601 or a raw unix-seconds integer.
fn query_spec_from(args: &Args) -> Result<store::QuerySpec> {
    let parse_ts = |flag: &str| -> Result<Option<i64>> {
        let Some(v) = args.get(flag) else { return Ok(None) };
        timefmt::from_iso8601(v)
            .or_else(|| v.parse::<i64>().ok())
            .map(Some)
            .with_context(|| {
                format!(
                    "--{flag} '{v}' is neither ISO-8601 (e.g. \
                     2026-01-01T00:00:00Z) nor a unix timestamp"
                )
            })
    };
    Ok(store::QuerySpec {
        experiment: args.get("experiment").map(str::to_string),
        config: args.get("config").map(str::to_string),
        since_commit: args.get("since-commit").map(str::to_string),
        since: parse_ts("since")?,
        until: parse_ts("until")?,
        last: args
            .get("last")
            .map(|v| v.parse::<usize>())
            .transpose()
            .context("--last must be a run count")?,
    })
}

/// Build the scan-stage session from the shared source flags: exactly
/// one of `--input <dir>` (artifact folder) or `--store <dir>` (run
/// store).  The `default_cache` (used by `report`) only applies to the
/// folder source — a store-backed scan parses nothing to cache.  A
/// store source additionally takes the [`query_spec_from`] filters.
fn source_session(
    args: &Args,
    default_cache: Option<PathBuf>,
) -> Result<Session> {
    let session = match (args.get("input"), args.get("store")) {
        (Some(_), Some(_)) => {
            bail!("--input and --store are mutually exclusive")
        }
        (None, None) => {
            bail!("one of --input <dir> or --store <dir> is required")
        }
        (Some(input), None) => {
            // The narrowing flags are store-query filters; on a folder
            // scan they would be silently ignored, which reads exactly
            // like a filter that matched nothing.  Refuse instead.
            for flag in
                ["experiment", "config", "since-commit", "since", "until", "last"]
            {
                if args.has(flag) {
                    bail!("--{flag} only applies to --store sources");
                }
            }
            Session::new(PathBuf::from(input)).cache_opt(
                args.get("cache").map(PathBuf::from).or(default_cache),
            )
        }
        (None, Some(store_root)) => {
            // Same strictness as the exclusivity check above: a store
            // scan parses nothing, so a user-given cache location is a
            // misunderstanding, not something to drop silently.
            if args.has("cache") {
                bail!("--cache only applies to --input folder scans");
            }
            Session::from_store_query(
                PathBuf::from(store_root),
                query_spec_from(args)?,
            )
        }
    };
    Ok(session.jobs(args.get_jobs()?))
}

/// `talp-pages check`: static analysis of every input surface (see
/// [`crate::check`]) without executing a report run.  `--format sarif`
/// streams SARIF 2.1.0 to stdout (nothing else is printed there);
/// `--sarif <file>` additionally writes it next to the text output.
fn check_cmd(args: &Args) -> Result<i32> {
    let opts = check::CheckOptions {
        input: args.get("input").map(PathBuf::from),
        store: args.get("store").map(PathBuf::from),
        policy: args.get("policy").map(PathBuf::from),
        cache: args.get("cache").map(PathBuf::from),
        report: args.get("report").map(PathBuf::from),
        bench: args.get("bench").map(PathBuf::from),
        jobs: args.get_jobs()?,
    };
    let rep = check::run_check(&opts)?;
    match args.get("format").unwrap_or("text") {
        "text" => print!("{}", rep.render_text()),
        "sarif" => print!("{}", check::sarif::render(&rep)),
        other => bail!("unknown --format '{other}' (text|sarif)"),
    }
    if let Some(f) = args.get("sarif") {
        let p = PathBuf::from(f);
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&p, check::sarif::render(&rep))?;
        eprintln!("wrote {}", p.display());
    }
    Ok(rep.exit_code())
}

/// Shared `--check` pre-flight for `report`/`gate`/`ingest`: run the
/// static analyzer over the surfaces the command is about to consume,
/// print findings to stderr, and abort with the check's exit code on
/// *errors* — warnings are shown but the run proceeds (they are the
/// same conditions the pipeline tolerates anyway).
fn preflight(opts: &check::CheckOptions) -> Result<Option<i32>> {
    let rep = check::run_check(opts)?;
    if !rep.diagnostics.is_empty() {
        eprint!("{}", rep.render_text());
    }
    if rep.status() == check::CheckStatus::Errors {
        eprintln!("check: aborting before the run (drop --check to force)");
        return Ok(Some(rep.exit_code()));
    }
    Ok(None)
}

fn ci_report(args: &Args) -> Result<i32> {
    let output = PathBuf::from(args.require("output")?);
    let format = args.get("format").unwrap_or("all");
    let mut emitters = emitters_for(format, &output)?;
    let session = source_session(
        args,
        Some(output.join(pages::cache::CACHE_FILE_NAME)),
    )?;
    if args.has("check") {
        let copts = check::CheckOptions {
            input: args.get("input").map(PathBuf::from),
            store: args.get("store").map(PathBuf::from),
            policy: args.get("gate").map(PathBuf::from),
            // The cache the report will actually use (folder scans
            // only; a missing file is an ordinary cold start).
            cache: if args.has("store") {
                None
            } else {
                args.get("cache")
                    .map(PathBuf::from)
                    .or_else(|| Some(output.join(pages::cache::CACHE_FILE_NAME)))
            },
            jobs: args.get_jobs()?,
            ..Default::default()
        };
        if let Some(code) = preflight(&copts)? {
            return Ok(code);
        }
    }
    let opts = AnalyzeOptions {
        regions: args
            .get_all("regions")
            .iter()
            .map(|s| s.to_string())
            .collect(),
        region_for_badge: args.get("region-for-badge").map(str::to_string),
        gate: args
            .get("gate")
            .map(|p| GatePolicy::from_file(Path::new(p)))
            .transpose()?,
        ..Default::default()
    };
    let summary = session.scan()?.analyze(&opts).emit(&mut emitters)?;
    for w in &summary.warnings {
        eprintln!("warning: {w}");
    }
    println!(
        "report: {} experiment(s), {} page(s), {} badge(s), {} file(s) \
         -> {} (cache: {} hit(s), {} parse(s))",
        summary.experiments,
        summary.pages_written,
        summary.badges_written,
        summary.files_written,
        output.display(),
        summary.cache_hits,
        summary.cache_misses
    );
    // Inline gating: the report's own scan fed the verdict, so a warm
    // cache gates without parsing a single artifact.
    if let Some(v) = &summary.gate {
        println!("{}", v.summary_line());
        return Ok(v.exit_code());
    }
    Ok(0)
}

/// `talp-pages ingest`: append a Fig. 2 folder's artifacts into the
/// persistent run store.  Content-addressed and incremental — only
/// artifacts whose hash is not yet stored are parsed, so CI can ingest
/// the full accumulated history folder every pipeline for O(changed)
/// cost.
fn ingest_cmd(args: &Args) -> Result<i32> {
    let input = PathBuf::from(args.require("input")?);
    let store_root = PathBuf::from(args.require("store")?);
    if args.has("check") {
        // Two passes (the analyzer treats --input/--store as exclusive
        // sources): the artifact folder about to be ingested, then the
        // existing store — but only if one is already there, since
        // create_or_open would legitimately create it below.
        let jobs = args.get_jobs()?;
        if let Some(code) = preflight(&check::CheckOptions {
            input: Some(input.clone()),
            jobs,
            ..Default::default()
        })? {
            return Ok(code);
        }
        if store_root.join(store::MANIFEST_FILE_NAME).exists() {
            if let Some(code) = preflight(&check::CheckOptions {
                store: Some(store_root.clone()),
                jobs,
                ..Default::default()
            })? {
                return Ok(code);
            }
        }
    }
    // Single-writer discipline: a resident `serve` (or another ingest)
    // holds `.talp-store.lock` — refuse up front instead of
    // interleaving shard appends with it.
    let lock = store::StoreLock::acquire(&store_root)?;
    let mut run_store = store::RunStore::create_or_open(&store_root)?;
    // Optional ingest-time commit stamp for artifacts that skipped the
    // `metadata` step (already-stamped runs keep their own metadata).
    // The companion flags only mean something with --commit — silently
    // storing unstamped runs would scramble cross-commit ordering.
    if args.get("commit").is_none() {
        for flag in ["branch", "timestamp", "message"] {
            if args.has(flag) {
                bail!("--{flag} requires --commit");
            }
        }
    }
    // Strict timestamp parsing: silently stamping ingest wall-clock
    // time would scramble the cross-commit ordering this metadata
    // exists to protect.
    let commit_timestamp = match args.get("timestamp") {
        Some(t) => timefmt::from_iso8601(t).with_context(|| {
            format!(
                "--timestamp '{t}' is not ISO-8601 (want e.g. \
                 2026-01-01T00:00:00Z or ...+01:00)"
            )
        })?,
        None => timefmt::now_unix(),
    };
    let commit_meta = args.get("commit").map(|sha| crate::talp::GitMeta {
        commit: sha.to_string(),
        branch: args.get("branch").unwrap_or("main").to_string(),
        commit_timestamp,
        message: args.get("message").unwrap_or("").to_string(),
    });
    // One admission path shared with serve and the CI runner; --format
    // pins an adapter, the default auto-detects per file.
    let mut admission = store::Admission::new()
        .jobs(args.get_jobs()?)
        .commit(commit_meta.as_ref());
    match args.get("format").unwrap_or("auto") {
        "auto" => {}
        name => {
            admission =
                admission.format(crate::adapters::by_name(name).with_context(
                    || {
                        format!(
                            "unknown --format '{name}' (auto|{})",
                            crate::adapters::names()
                        )
                    },
                )?)
        }
    }
    let report = admission.ingest_dir(&mut run_store, &input)?;
    for w in &report.warnings {
        eprintln!("warning: {w}");
    }
    println!(
        "ingest: {} artifact(s) scanned, {} parsed, {} stored, {} already \
         stored -> {} ({} run(s), {} experiment(s) total)",
        report.scanned,
        report.parsed,
        report.stored,
        report.already_stored,
        store_root.display(),
        run_store.len(),
        run_store.experiment_count()
    );
    if !report.formats.is_empty() {
        let breakdown = report
            .formats
            .iter()
            .map(|(name, runs)| format!("{name} {runs} run(s)"))
            .collect::<Vec<_>>()
            .join(", ");
        println!("formats: {breakdown}");
    }
    if args.has("compact") {
        let stats = run_store.compact()?;
        println!(
            "compacted: {} record(s) across {} shard(s), {} stale file(s) \
             removed",
            stats.records, stats.shards, stats.removed_files
        );
    }
    // Indexes ride along with every ingest: refresh missing/stale
    // sidecars so the first query after an ingest is already warm.
    // (After --compact this only touches shards compaction skipped —
    // rewritten ones got fresh sidecars atomically.)
    run_store.refresh_indexes()?;
    // Explicit release surfaces removal errors (and routes through the
    // `store::lock::release` failpoint); drop would hide both.
    lock.release()?;
    Ok(0)
}

/// `talp-pages store <stats|query|compact|fsck|synth>`: direct
/// operations on a persistent run store — corpus shape, indexed
/// selection, tiered compaction, crash-recovery fsck, and a
/// synthetic-corpus generator for scale testing.
fn store_cmd(args: &Args) -> Result<i32> {
    let Some(sub) = args.positional.get(1).map(String::as_str) else {
        bail!(
            "store needs a subcommand (stats|query|compact|fsck|synth)\n{USAGE}"
        );
    };
    match sub {
        "stats" => store_stats_cmd(args),
        "query" => store_query_cmd(args),
        "compact" => store_compact_cmd(args),
        "fsck" => store_fsck_cmd(args),
        "synth" => store_synth_cmd(args),
        other => {
            bail!(
                "unknown store subcommand '{other}' \
                 (stats|query|compact|fsck|synth)"
            )
        }
    }
}

/// `store stats`: corpus shape, per-shard health and index freshness.
/// The `decoded ... line(s)` counter is the sub-linearity witness the
/// CI `store-scale` job greps: 0 on a fully indexed store.
fn store_stats_cmd(args: &Args) -> Result<i32> {
    let root = PathBuf::from(args.require("store")?);
    let st = store::RunStore::stats(&root, args.get_jobs()?)?;
    for w in &st.warnings {
        eprintln!("warning: {w}");
    }
    let s = &st.stats;
    println!(
        "store: {} — {} run(s) live of {} indexed line(s) across {} \
         shard(s)",
        root.display(),
        s.live_runs,
        s.indexed_lines,
        s.shards
    );
    println!(
        "decoded {} of {} indexed line(s); indexes: {} fresh, {} rebuilt",
        s.decoded_lines, s.indexed_lines, s.indexes_fresh, s.indexes_rebuilt
    );
    for row in &st.shards {
        println!(
            "  {}: {} run(s) in {} line(s), {} B ({:.0}% dead), {} \
             corrupt, ts {}..{}, commits {}..{}, index {}",
            row.file,
            row.runs,
            row.lines,
            row.bytes,
            row.dead_ratio() * 100.0,
            row.corrupt_lines,
            row.ts_min,
            row.ts_max,
            short_sha(&row.commit_first),
            short_sha(&row.commit_last),
            row.index
        );
    }
    Ok(0)
}

/// `store query`: matching runs as JSON lines (stdout or `--output`),
/// selection summary on stderr.  `--no-index` runs the sequential
/// full-scan control instead — same results, linear cost.
fn store_query_cmd(args: &Args) -> Result<i32> {
    let root = PathBuf::from(args.require("store")?);
    let spec = query_spec_from(args)?;
    let jobs = args.get_jobs()?;
    let t0 = std::time::Instant::now();
    let outcome = if args.has("no-index") {
        store::RunStore::query_full_scan(&root, jobs, &spec)?
    } else {
        store::RunStore::query(&root, jobs, &spec)?
    };
    let elapsed_s = t0.elapsed().as_secs_f64();
    for w in &outcome.warnings {
        eprintln!("warning: {w}");
    }
    let mut text = String::new();
    for rec in &outcome.records {
        text.push_str(&rec.to_line());
        text.push('\n');
    }
    match args.get("output") {
        Some(f) => {
            let p = PathBuf::from(f);
            if let Some(parent) = p.parent() {
                std::fs::create_dir_all(parent)?;
            }
            std::fs::write(&p, &text)?;
            eprintln!("wrote {}", p.display());
        }
        None => print!("{text}"),
    }
    let s = &outcome.stats;
    eprintln!(
        "query: {} run(s) matched of {} live from {} shard(s); decoded \
         {} of {} indexed line(s); indexes: {} fresh, {} rebuilt",
        s.matched_runs,
        s.live_runs,
        s.shards,
        s.decoded_lines,
        s.indexed_lines,
        s.indexes_fresh,
        s.indexes_rebuilt
    );
    if args.has("bench-json") {
        // Machine-readable record for the CI store-scale job — the
        // same shape `benches/perf_hotpaths.rs` emits.
        let name = if args.has("no-index") {
            "store_query_full_scan"
        } else {
            "store_query_indexed"
        };
        let record = crate::util::json::Json::from_pairs(vec![
            ("bench", crate::util::json::Json::Str(name.into())),
            (
                "live_runs",
                crate::util::json::Json::Num(s.live_runs as f64),
            ),
            (
                "matched_runs",
                crate::util::json::Json::Num(s.matched_runs as f64),
            ),
            (
                "decoded_lines",
                crate::util::json::Json::Num(s.decoded_lines as f64),
            ),
            ("elapsed_s", crate::util::json::Json::Num(elapsed_s)),
        ]);
        println!("BENCH_JSON {}", record.to_string_compact());
    }
    Ok(0)
}

/// `store compact`: tiered compaction — rewrite only shards whose
/// dead-byte ratio crosses `--threshold` (default
/// [`store::COMPACT_DEAD_RATIO`]); `--threshold 0` rewrites every
/// shard with any dead byte.
fn store_compact_cmd(args: &Args) -> Result<i32> {
    let root = PathBuf::from(args.require("store")?);
    let threshold: f64 = args
        .get("threshold")
        .map(|v| v.parse())
        .transpose()
        .context("--threshold must be a number (dead-byte ratio, 0..1)")?
        .unwrap_or(store::COMPACT_DEAD_RATIO);
    if !(0.0..=1.0).contains(&threshold) {
        bail!("--threshold must be within 0..1 (got {threshold})");
    }
    // Compaction rewrites shards in place: writer lock, same as ingest.
    let lock = store::StoreLock::acquire(&root)?;
    let mut run_store =
        store::RunStore::open_with_jobs(&root, args.get_jobs()?)?;
    for w in run_store.warnings() {
        eprintln!("warning: {w}");
    }
    let stats = run_store.compact_with(threshold)?;
    run_store.refresh_indexes()?;
    lock.release()?;
    println!(
        "compacted: {} record(s) across {} shard(s), {} stale file(s) \
         removed (threshold {:.0}% dead)",
        stats.records,
        stats.shards,
        stats.removed_files,
        threshold * 100.0
    );
    Ok(0)
}

/// `store fsck`: crash-recovery scan over a run store — orphan temp
/// files, torn shard tails, manifest drift, stale sidecars, orphaned
/// locks (see [`store::fsck`]).  Dry-run by default; `--repair` heals
/// under the writer lock.  Exit 0 when no errors remain, 1 otherwise
/// (so CI can assert a recovered store is actually consistent).
fn store_fsck_cmd(args: &Args) -> Result<i32> {
    let root = PathBuf::from(args.require("store")?);
    let opts = store::FsckOptions {
        repair: args.has("repair"),
        jobs: args.get_jobs()?,
    };
    let rep = store::fsck(&root, &opts)?;
    print!("{}", rep.render_text());
    Ok(if rep.errors_remaining() > 0 { 1 } else { 0 })
}

/// `store synth`: append a synthetic history corpus — one simulated
/// run per config, fanned out across experiments, commits and
/// timestamps.  Real `RunMetrics` payloads at an arbitrary scale,
/// which is what the CI `store-scale` job uses to prove queries stay
/// sub-linear at >= 50k stored runs.
fn store_synth_cmd(args: &Args) -> Result<i32> {
    let root = PathBuf::from(args.require("store")?);
    let experiments = args.get_u64("experiments", 4)? as usize;
    let runs_per_shard = args.get_u64("runs-per-shard", 100)? as usize;
    let seed = args.get_u64("seed", 7)?;
    let machine = parse_machine(args)?;
    let configs: Vec<ResourceConfig> = {
        let labels = args.get_all("configs");
        if labels.is_empty() {
            vec![ResourceConfig::new(2, 8)]
        } else {
            labels
                .iter()
                .map(|l| {
                    ResourceConfig::parse_label(l)
                        .with_context(|| format!("bad config '{l}'"))
                })
                .collect::<Result<Vec<_>>>()?
        }
    };
    let lock = store::StoreLock::acquire(&root)?;
    let mut run_store = store::RunStore::create_or_open(&root)?;
    // The corpus itself comes from the shared simulator module so
    // `store synth` and `talp-pages sim` stay one generator.
    let batch = crate::sim::corpus::synth_batch(
        experiments,
        &configs,
        runs_per_shard,
        seed,
        &machine,
    );
    let appended = run_store.append_all(batch)?;
    let indexed = run_store.refresh_indexes()?;
    lock.release()?;
    println!(
        "synth: {} run(s) appended ({} experiment(s) x {} config(s) x \
         {} run(s)), {} sidecar(s) written -> {}",
        appended,
        experiments,
        configs.len(),
        runs_per_shard,
        indexed,
        root.display()
    );
    Ok(0)
}

/// `talp-pages sim`: the seeded deterministic workload simulator —
/// emit a corpus of runs across scenario axes (weak/strong scaling,
/// hybrid MPI+OpenMP, noise regimes, drifting baselines, step
/// regressions) in any registered adapter's on-disk format.  The same
/// seed always produces a byte-identical corpus.
fn sim_cmd(args: &Args) -> Result<i32> {
    let out = PathBuf::from(args.require("output")?);
    let seed = args.get_u64("seed", 7)?;
    let runs = args.get_u64("runs", 6)? as usize;
    let machine = parse_machine(args)?;
    let axes = {
        let labels = args.get_all("axes");
        if labels.is_empty() {
            crate::sim::corpus::Axis::all().to_vec()
        } else {
            labels
                .iter()
                .map(|l| {
                    crate::sim::corpus::Axis::parse(l).with_context(|| {
                        format!(
                            "unknown axis '{l}' ({})",
                            crate::sim::corpus::Axis::labels()
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?
        }
    };
    let fname = args.get("format").unwrap_or("talp");
    let adapter = crate::adapters::by_name(fname).with_context(|| {
        format!("unknown --format '{fname}' ({})", crate::adapters::names())
    })?;
    let spec = crate::sim::corpus::CorpusSpec {
        runs,
        axes,
        machine,
        ..crate::sim::corpus::CorpusSpec::new(seed)
    };
    let written = crate::sim::corpus::write_corpus(&spec, &out, adapter)?;
    println!(
        "sim: {} run(s) across {} axis(es) ({} each) -> {} (seed {}, \
         format {})",
        written,
        spec.axes.len(),
        spec.runs,
        out.display(),
        seed,
        adapter.name()
    );
    Ok(0)
}

/// `talp-pages serve`: the resident monitoring service over a run
/// store (see [`crate::serve`]).  Takes the store writer lock for its
/// whole lifetime; serves until SIGTERM/SIGINT (or `POST /shutdown`),
/// then drains, flushes a pending watch ingest and exits 0.
fn serve_cmd(args: &Args) -> Result<i32> {
    let mut opts = crate::serve::ServeOptions::new(PathBuf::from(
        args.require("store")?,
    ));
    if let Some(addr) = args.get("addr") {
        opts.addr = addr.to_string();
    }
    opts.watch = args.get("watch").map(PathBuf::from);
    opts.jobs = args.get_jobs()?;
    opts.max_body_bytes =
        args.get_u64("max-body-bytes", opts.max_body_bytes as u64)? as usize;
    opts.poll_ms = args.get_u64("poll-ms", opts.poll_ms)?;
    opts.read_timeout_ms =
        args.get_u64("read-timeout-ms", opts.read_timeout_ms)?;
    opts.write_timeout_ms =
        args.get_u64("write-timeout-ms", opts.write_timeout_ms)?;
    opts.max_connections =
        args.get_u64("max-connections", opts.max_connections as u64)?
            as usize;
    // Same analysis knobs as `report`, so the served payloads are the
    // batch payloads for the same flags.
    opts.analyze = AnalyzeOptions {
        regions: args
            .get_all("regions")
            .iter()
            .map(|s| s.to_string())
            .collect(),
        region_for_badge: args.get("region-for-badge").map(str::to_string),
        gate: args
            .get("gate")
            .map(|p| GatePolicy::from_file(Path::new(p)))
            .transpose()?,
        ..Default::default()
    };
    crate::serve::run(opts)?;
    Ok(0)
}

/// First 8 chars of a sha for table rows ("-" when absent).
fn short_sha(sha: &str) -> &str {
    if sha.is_empty() {
        "-"
    } else {
        &sha[..sha.len().min(8)]
    }
}

/// `talp-pages gate`: evaluate a regression-gate policy over a Fig. 2
/// folder and exit non-zero on failure — the CI enforcement point.
fn gate_cmd(args: &Args) -> Result<i32> {
    if args.has("check") {
        let copts = check::CheckOptions {
            input: args.get("input").map(PathBuf::from),
            store: args.get("store").map(PathBuf::from),
            policy: args.get("policy").map(PathBuf::from),
            cache: args.get("cache").map(PathBuf::from),
            jobs: args.get_jobs()?,
            ..Default::default()
        };
        if let Some(code) = preflight(&copts)? {
            return Ok(code);
        }
    }
    let policy = match args.get("policy") {
        Some(p) => GatePolicy::from_file(Path::new(p))?,
        None => GatePolicy::default(),
    };
    let analysis = source_session(args, None)?
        .scan()?
        .analyze(&AnalyzeOptions { gate: Some(policy), ..Default::default() });
    for w in &analysis.warnings {
        eprintln!("warning: {w}");
    }
    if let Some(out) = args.get("output") {
        let dir = PathBuf::from(out);
        let mut emitters: Vec<Box<dyn Emitter>> =
            vec![Box::new(GateFiles::new(&dir))];
        analysis.emit(&mut emitters)?;
        println!(
            "wrote {}/gate.json, gate.md, gate.xml",
            dir.display()
        );
    }
    let verdict = analysis.gate.as_ref().expect("gate policy was set");
    println!("{}", verdict.summary_line());
    for c in verdict.notable() {
        println!(
            "  [{}] {} / {} / {} — {}",
            c.outcome.id().to_uppercase(),
            c.experiment,
            c.config,
            c.region,
            c.detail
        );
    }
    Ok(verdict.exit_code())
}

/// `talp-pages gate-init`: write a ready-to-commit starter policy.
fn gate_init(args: &Args) -> Result<i32> {
    let out = PathBuf::from(args.require("output")?);
    if let Some(p) = out.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(&out, GatePolicy::example_json())?;
    println!("wrote {}", out.display());
    Ok(0)
}

fn metadata(args: &Args) -> Result<i32> {
    let input = PathBuf::from(args.require("input")?);
    let commit = ci::Commit {
        sha: args.require("commit")?.to_string(),
        branch: args
            .get("branch")
            .unwrap_or("main")
            .to_string(),
        timestamp: args
            .get("timestamp")
            .and_then(timefmt::from_iso8601)
            .unwrap_or_else(timefmt::now_unix),
        message: args.get("message").unwrap_or("").to_string(),
        version: crate::apps::CodeVersion::fixed(),
    };
    let n = ci::gitmeta::stamp_tree(&input, &commit)?;
    println!("stamped {n} file(s) under {}", input.display());
    Ok(0)
}

fn parse_machine(args: &Args) -> Result<MachineSpec> {
    let name = args.get("machine").unwrap_or("mn5");
    MachineSpec::by_name(name)
        .with_context(|| format!("unknown machine '{name}' (mn5|raven)"))
}

fn parse_config(args: &Args) -> Result<ResourceConfig> {
    let label = args.get("config").unwrap_or("2x8");
    ResourceConfig::parse_label(label)
        .with_context(|| format!("bad --config '{label}' (want e.g. 2x56)"))
}

fn build_app(args: &Args) -> Result<Box<dyn Workload>> {
    let grid = args.get_u64("grid", 800)?;
    Ok(match args.get("app").unwrap_or("tealeaf") {
        "tealeaf" => {
            let mut t = apps::TeaLeaf::with_grid(grid, grid);
            t.timesteps = args.get_u64("timesteps", 2)? as u32;
            t.cg_iters = args.get_u64("iters", 20)? as u32;
            Box::new(t)
        }
        "genex" => {
            let mut g = apps::Genex::salpha(
                args.get_u64("resolution", 1)? as u32,
                if args.has("buggy") {
                    apps::CodeVersion::buggy()
                } else {
                    apps::CodeVersion::fixed()
                },
            );
            g.timesteps = args.get_u64("timesteps", 6)? as u32;
            Box::new(g)
        }
        "mpi-stencil" => Box::new(apps::MpiStencil::fig3()),
        other => bail!("unknown app '{other}'"),
    })
}

fn run_app(args: &Args) -> Result<i32> {
    let machine = parse_machine(args)?;
    let config = parse_config(args)?;
    let app = build_app(args)?;
    let seed = args.get_u64("seed", 0xC0FFEE)?;
    let (data, summary) = apps::run_with_talp(
        app.as_ref(),
        &machine,
        &config,
        seed,
        timefmt::now_unix(),
    );
    let out = PathBuf::from(args.require("output")?);
    data.write_file(&out)?;
    println!(
        "ran {} on {} {}: elapsed {:.3}s (sim), {} events -> {}",
        app.name(),
        machine.name,
        config.label(),
        summary.elapsed_s,
        summary.total_events,
        out.display()
    );
    Ok(0)
}

fn compare(args: &Args) -> Result<i32> {
    let grid = args.get_u64("grid", 1200)?;
    let region = args.get("region").unwrap_or("Global");
    let configs: Vec<ResourceConfig> = {
        let labels = args.get_all("configs");
        if labels.is_empty() {
            vec![ResourceConfig::new(2, 14), ResourceConfig::new(4, 14)]
        } else {
            labels
                .iter()
                .map(|l| {
                    ResourceConfig::parse_label(l)
                        .with_context(|| format!("bad config '{l}'"))
                })
                .collect::<Result<Vec<_>>>()?
        }
    };
    let mut app = apps::TeaLeaf::with_grid(grid, grid);
    app.timesteps = args.get_u64("timesteps", 2)? as u32;
    app.cg_iters = args.get_u64("iters", 12)? as u32;
    let machine = parse_machine(args)?;
    let work = crate::util::fs::TempDir::new("compare")?;

    let mut t1 = crate::util::bench::Table::new(
        "Runtime overhead (Table 1 shape)",
        &["tool", "config", "clean [s]", "instrumented [s]", "overhead"],
    );
    let mut t2 = crate::util::bench::Table::new(
        "Post-processing requirements (Table 2 shape)",
        &["tool", "memory", "storage", "time"],
    );
    for kind in tools::ToolKind::all() {
        let mut runs = Vec::new();
        for cfg in &configs {
            let dir = work.path().join(kind.short()).join(cfg.label());
            let run = tools::instrument(
                kind, &app, &machine, cfg, 42, timefmt::now_unix(), &dir,
            )?;
            t1.row(&[
                kind.name().to_string(),
                cfg.label(),
                format!("{:.3}", run.clean_elapsed_s),
                format!("{:.3}", run.elapsed_s),
                format!("{:.1}%", run.overhead_fraction() * 100.0),
            ]);
            runs.push(run);
        }
        let refs: Vec<&tools::InstrumentedRun> = runs.iter().collect();
        let (table, usage) = tools::postprocess(kind, &refs, region)?;
        t2.row(&[
            kind.name().to_string(),
            crate::util::stats::fmt_bytes(usage.peak_memory_bytes),
            crate::util::stats::fmt_bytes(usage.storage_bytes),
            crate::util::stats::fmt_duration(usage.wall_time_s),
        ]);
        if let Some(table) = table {
            println!("\n--- {} ---", kind.name());
            print!("{}", table.render_text());
        }
    }
    println!();
    t1.print();
    println!();
    t2.print();
    Ok(0)
}

fn ci_sim(args: &Args) -> Result<i32> {
    let out = PathBuf::from(args.require("output")?);
    let n = args.get_u64("commits", 8)? as usize;
    let fix_at = args.get_u64("fix-at", n as u64 / 2)? as usize;
    let repo = ci::Repo::genex_history(n, fix_at, 7, 1_700_000_000);
    let jobs = ci::MatrixSpec {
        case: "salpha".into(),
        resolutions: vec![args.get_u64("resolution", 1)? as u32],
        configurations: vec![
            ("1Nx2MPI".into(), 2, 8),
            ("2Nx4MPI".into(), 4, 8),
        ],
        machine_tags: vec!["mn5".into()],
    }
    .expand();
    let opts = ci::PipelineOptions {
        analyze: AnalyzeOptions {
            regions: vec!["initialize".into(), "timestep".into()],
            region_for_badge: Some("timestep".into()),
            // The sim always runs the gate stage — pipelines record a
            // verdict like real CI would (--gate overrides the policy).
            gate: Some(match args.get("gate") {
                Some(p) => GatePolicy::from_file(Path::new(p))?,
                None => GatePolicy::default(),
            }),
            ..Default::default()
        },
        jobs: args.get_jobs()?,
    };
    let mut engine = ci::CiEngine::new(&out)?;
    let mut failed_pipelines = 0usize;
    for commit in &repo.commits {
        let r = engine.run_pipeline(commit, &jobs, &opts)?;
        let gate_note = match r.gate() {
            Some(v) => {
                if v.exit_code() != 0 {
                    failed_pipelines += 1;
                }
                format!(", gate {}", v.status.label())
            }
            None => String::new(),
        };
        println!(
            "pipeline {:>3} {} \"{}\": {} jobs, {} history files, report in {:.2}s{}",
            r.pipeline_id,
            r.commit_short,
            truncate(&commit.message, 48),
            r.jobs_run,
            r.history_files,
            r.wall_time_s,
            gate_note
        );
    }
    println!(
        "pages: {} | artifacts: {} | store: {} run(s) across {} \
         experiment(s) | gate: {}/{} pipeline(s) failed",
        engine.pages_dir().display(),
        crate::util::stats::fmt_bytes(engine.artifact_bytes()),
        engine.run_store().len(),
        engine.run_store().experiment_count(),
        failed_pipelines,
        repo.commits.len()
    );
    Ok(0)
}

fn calibrate() -> Result<i32> {
    let Some(reg) = crate::runtime::Registry::open_default() else {
        bail!("no artifacts found — run `make artifacts` first");
    };
    let cal = crate::runtime::calibrate::run(&reg)?;
    println!("{}", cal.to_json().to_string_pretty());
    Ok(0)
}

fn badge(args: &Args) -> Result<i32> {
    let label = args.require("label")?;
    let value: f64 = args
        .require("value")?
        .parse()
        .context("--value must be a number")?;
    let out = PathBuf::from(args.require("output")?);
    let svg = pages::badge::render(
        label,
        &format!("{value:.2}"),
        pages::badge::efficiency_color(value),
    );
    if let Some(p) = out.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(&out, svg)?;
    println!("wrote {}", out.display());
    Ok(0)
}

/// `talp-pages detect`: scan a Fig. 2 folder and print automated
/// regression/improvement findings for every experiment history.
fn detect_cmd(args: &Args) -> Result<i32> {
    let input = PathBuf::from(args.require("input")?);
    let threshold: f64 = args
        .get("threshold")
        .map(|v| v.parse())
        .transpose()
        .context("--threshold must be a number")?
        .unwrap_or(0.15);
    let opts = pages::detect::DetectOptions { threshold, ..Default::default() };
    let scan = pages::scan(&input)?;
    let mut total = 0;
    for exp in &scan.experiments {
        for cfg in exp.configs() {
            let history = exp.history_for_config(&cfg);
            if history.len() < 2 {
                continue;
            }
            for f in pages::detect::detect(&cfg, &history, &opts) {
                println!("[{}] {}", exp.id, f.describe());
                total += 1;
            }
        }
    }
    println!("{total} finding(s) across {} experiment(s)", scan.experiments.len());
    Ok(0)
}

/// `talp-pages model`: Extra-P-style scaling models per experiment.
fn model_cmd(args: &Args) -> Result<i32> {
    let input = PathBuf::from(args.require("input")?);
    let regions: Vec<String> = args
        .get_all("regions")
        .iter()
        .map(|s| s.to_string())
        .collect();
    let scan = pages::scan(&input)?;
    for exp in &scan.experiments {
        let latest = exp.latest_per_config();
        if latest.len() < 2 {
            continue;
        }
        println!("# {}", exp.id);
        for (region, m) in pop::extrap::fit_experiment(&latest, &regions) {
            println!(
                "  {region:<24} elapsed(p) ~ {}  (SMAPE {:.1}%){}",
                m.formula(),
                m.smape * 100.0,
                if m.grows() { "  <-- grows with resources!" } else { "" }
            );
        }
    }
    Ok(0)
}

/// `talp-pages summary`: human-readable POP summary of one TALP JSON
/// (what `dlb --talp-summary` prints on a real system).
fn summary_cmd(args: &Args) -> Result<i32> {
    let input = PathBuf::from(args.require("input")?);
    let data = crate::talp::RunData::read_file(&input)?;
    println!(
        "{} on {} — {} ({} nodes), {}",
        data.app,
        data.machine,
        data.resources().label(),
        data.nodes,
        timefmt::to_iso8601(data.timestamp)
    );
    if let Some(g) = &data.git {
        println!(
            "commit {} ({}) @ {}",
            &g.commit[..g.commit.len().min(8)],
            g.branch,
            timefmt::to_iso8601(g.commit_timestamp)
        );
    }
    let wanted = args.get("region");
    for reg in &data.regions {
        if let Some(w) = wanted {
            if reg.name != w {
                continue;
            }
        }
        let m = pop::compute(reg, data.threads);
        println!("\nregion '{}' ({} visits)", reg.name, reg.visits);
        println!("  elapsed              {:>10.4} s", m.elapsed_s);
        println!("  parallel efficiency  {:>10.2}", m.parallel_efficiency);
        println!(
            "    MPI PE {:.2} (LB {:.2} x Comm {:.2})  OpenMP PE {:.2} \
             (LB {:.2} x Sched {:.2} x Serial {:.2})",
            m.mpi_parallel_efficiency,
            m.mpi_load_balance,
            m.mpi_communication_efficiency,
            m.omp_parallel_efficiency,
            m.omp_load_balance,
            m.omp_scheduling_efficiency,
            m.omp_serialization_efficiency
        );
        println!(
            "  useful IPC {:.2} | frequency {:.2} GHz | {} instructions",
            m.useful_ipc, m.frequency_ghz, m.total_useful_instructions
        );
    }
    Ok(0)
}

/// `talp-pages init-ci`: write a ready-to-commit pipeline file.
fn init_ci(args: &Args) -> Result<i32> {
    let out = PathBuf::from(args.require("output")?);
    let spec = ci::MatrixSpec::performance_cpu_fast();
    let regions: Vec<&str> = {
        let r = args.get_all("regions");
        if r.is_empty() {
            vec!["initialize", "timestep"]
        } else {
            r
        }
    };
    let badge = args.get("region-for-badge").unwrap_or("timestep");
    let gate_policy = args.get("gate-policy").unwrap_or(".talp-gate.json");
    let text = match args.get("flavor").unwrap_or("gitlab") {
        "gitlab" => {
            ci::templates::gitlab_ci_yaml(&spec, &regions, badge, gate_policy)
        }
        "github" => ci::templates::github_actions_yaml(
            &spec,
            &regions,
            badge,
            gate_policy,
        ),
        other => bail!("unknown --flavor '{other}' (gitlab|github)"),
    };
    if let Some(p) = out.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(&out, text)?;
    println!("wrote {}", out.display());
    Ok(0)
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n])
    }
}

/// Helper shared with tests: quick scaling table printout for a folder.
pub fn print_folder_table(input: &Path, region: &str) -> Result<String> {
    let scan = pages::scan(input)?;
    let mut out = String::new();
    for exp in &scan.experiments {
        if let Some(t) = pop::build(region, &exp.latest_per_config()) {
            out.push_str(&format!("# {}\n{}", exp.id, t.render_text()));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn run_cli(line: &str) -> Result<i32> {
        main_with_args(
            &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn usage_on_empty_and_unknown() {
        assert_eq!(main_with_args(&[]).unwrap(), 2);
        assert!(run_cli("frobnicate").is_err());
        assert_eq!(run_cli("help").unwrap(), 0);
    }

    #[test]
    fn run_then_report_cycle() {
        let td = TempDir::new("cli").unwrap();
        let json = td.path().join("talp/exp/talp_2x4.json");
        let out = td.path().join("public");
        assert_eq!(
            run_cli(&format!(
                "run --app genex --machine mn5 --config 2x4 --timesteps 2 \
                 --output {}",
                json.display()
            ))
            .unwrap(),
            0
        );
        assert!(json.exists());
        assert_eq!(
            run_cli(&format!(
                "metadata --input {} --commit abcdef1234567890 --branch main \
                 --timestamp 2024-07-15T12:00:00Z",
                td.path().join("talp").display()
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&format!(
                "ci-report --input {} --output {} --regions initialize \
                 timestep --region-for-badge timestep",
                td.path().join("talp").display(),
                out.display()
            ))
            .unwrap(),
            0
        );
        assert!(out.join("index.html").exists());
        assert!(
            out.join("report.json").exists(),
            "default format emits the machine-readable report too"
        );
        let table = print_folder_table(&td.path().join("talp"), "Global")
            .unwrap();
        assert!(table.contains("Parallel efficiency"));
    }

    #[test]
    fn report_format_selects_emitters() {
        let td = TempDir::new("cli-format").unwrap();
        let input = td.path().join("talp");
        for i in 0..2 {
            assert_eq!(
                run_cli(&format!(
                    "run --app genex --machine mn5 --config 2x4 \
                     --timesteps 2 --seed {} --output {}",
                    70 + i,
                    input.join(format!("exp/run_{i}.json")).display()
                ))
                .unwrap(),
                0
            );
        }
        // --format json: only the machine-readable report.
        let json_out = td.path().join("json");
        assert_eq!(
            run_cli(&format!(
                "report --input {} --output {} --format json",
                input.display(),
                json_out.display()
            ))
            .unwrap(),
            0
        );
        assert!(json_out.join("report.json").exists());
        assert!(!json_out.join("index.html").exists());
        assert!(!json_out.join("badges").exists());
        let doc = crate::session::ReportDocument::parse(
            &std::fs::read_to_string(json_out.join("report.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(doc.experiments.len(), 1);

        // --format html: the site without report.json.
        let html_out = td.path().join("html");
        assert_eq!(
            run_cli(&format!(
                "report --input {} --output {} --format html",
                input.display(),
                html_out.display()
            ))
            .unwrap(),
            0
        );
        assert!(html_out.join("index.html").exists());
        assert!(!html_out.join("report.json").exists());

        // An unknown format is a clear error.
        let err = run_cli(&format!(
            "report --input {} --output {} --format yaml",
            input.display(),
            td.path().join("x").display()
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("json|html|all"), "{err}");
    }

    #[test]
    fn ingest_then_store_backed_report_and_gate() {
        let td = TempDir::new("cli-store").unwrap();
        let input = td.path().join("talp");
        for i in 0..2 {
            assert_eq!(
                run_cli(&format!(
                    "run --app genex --machine mn5 --config 2x4 \
                     --timesteps 2 --seed {} --output {}",
                    90 + i,
                    input.join(format!("exp/run_{i}.json")).display()
                ))
                .unwrap(),
                0
            );
        }
        let store = td.path().join("store");
        assert_eq!(
            run_cli(&format!(
                "ingest --input {} --store {} --commit abc123 \
                 --branch main --timestamp 2024-07-15T12:00:00Z --compact",
                input.display(),
                store.display()
            ))
            .unwrap(),
            0
        );
        // Store-backed report: no --input anywhere near it.
        let out = td.path().join("site");
        assert_eq!(
            run_cli(&format!(
                "report --store {} --output {} --format json",
                store.display(),
                out.display()
            ))
            .unwrap(),
            0
        );
        assert!(out.join("report.json").exists());
        // Store-backed gating works too (floor-free policy: this tests
        // the plumbing, not the simulator's absolute efficiencies).
        let pol = td.path().join("quiet.json");
        std::fs::write(
            &pol,
            r#"{"version":1,"defaults":{"max_elapsed_increase":0.9}}"#,
        )
        .unwrap();
        assert_eq!(
            run_cli(&format!(
                "gate --store {} --policy {}",
                store.display(),
                pol.display()
            ))
            .unwrap(),
            0
        );
        // Source flags are strictly exclusive and required.
        let err = run_cli(&format!(
            "report --input {} --store {} --output {}",
            input.display(),
            store.display(),
            out.display()
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
        let err = run_cli(&format!("gate --policy {}", pol.display()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--input"), "{err}");
        // --cache is a folder-scan knob; with --store it is an error,
        // not silently dropped.
        let err = run_cli(&format!(
            "gate --store {} --cache {}",
            store.display(),
            td.path().join("c.json").display()
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--cache"), "{err}");
        // A store path that is not a store errors clearly.
        assert!(run_cli(&format!(
            "report --store {} --output {}",
            input.display(),
            out.display()
        ))
        .is_err());
    }

    #[test]
    fn store_subcommands_cycle() {
        let td = TempDir::new("cli-store-sub").unwrap();
        let input = td.path().join("talp");
        for i in 0..3 {
            assert_eq!(
                run_cli(&format!(
                    "run --app genex --machine mn5 --config 2x4 \
                     --timesteps 2 --seed {} --output {}",
                    50 + i,
                    input.join(format!("exp/run_{i}.json")).display()
                ))
                .unwrap(),
                0
            );
        }
        let store = td.path().join("store");
        assert_eq!(
            run_cli(&format!(
                "ingest --input {} --store {}",
                input.display(),
                store.display()
            ))
            .unwrap(),
            0
        );
        // ingest refreshed the sidecars, so stats decodes nothing and
        // both query paths are available.
        assert_eq!(
            run_cli(&format!("store stats --store {}", store.display()))
                .unwrap(),
            0
        );
        // Indexed query vs the full-scan control: byte-identical.
        let qi = td.path().join("indexed.jsonl");
        let qf = td.path().join("full.jsonl");
        assert_eq!(
            run_cli(&format!(
                "store query --store {} --last 2 --output {}",
                store.display(),
                qi.display()
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&format!(
                "store query --store {} --last 2 --no-index --output {}",
                store.display(),
                qf.display()
            ))
            .unwrap(),
            0
        );
        let indexed = std::fs::read_to_string(&qi).unwrap();
        let full = std::fs::read_to_string(&qf).unwrap();
        assert_eq!(indexed, full, "indexed and full-scan must agree");
        assert_eq!(indexed.lines().count(), 2);
        // ... and across worker counts.
        let q1 = td.path().join("jobs1.jsonl");
        assert_eq!(
            run_cli(&format!(
                "store query --store {} --last 2 --jobs 1 --output {}",
                store.display(),
                q1.display()
            ))
            .unwrap(),
            0
        );
        assert_eq!(std::fs::read_to_string(&q1).unwrap(), indexed);
        // The same filters narrow a store-backed report.
        let site = td.path().join("site");
        assert_eq!(
            run_cli(&format!(
                "report --store {} --output {} --format json --last 1",
                store.display(),
                site.display()
            ))
            .unwrap(),
            0
        );
        let doc = crate::session::ReportDocument::parse(
            &std::fs::read_to_string(site.join("report.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(doc.experiments.len(), 1);
        // On a folder scan the filters are refused, not ignored.
        let err = run_cli(&format!(
            "report --input {} --output {} --last 1",
            input.display(),
            td.path().join("x").display()
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--store"), "{err}");
        // Tiered compaction runs (nothing above threshold here).
        assert_eq!(
            run_cli(&format!(
                "store compact --store {}",
                store.display()
            ))
            .unwrap(),
            0
        );
        // Synthetic corpus: 2 experiments x 2 configs x 3 runs.
        let s2 = td.path().join("s2");
        assert_eq!(
            run_cli(&format!(
                "store synth --store {} --experiments 2 --configs 2x4 \
                 4x4 --runs-per-shard 3",
                s2.display()
            ))
            .unwrap(),
            0
        );
        let qs = td.path().join("synth.jsonl");
        assert_eq!(
            run_cli(&format!(
                "store query --store {} --experiment exp01 --last 1 \
                 --output {}",
                s2.display(),
                qs.display()
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            std::fs::read_to_string(&qs).unwrap().lines().count(),
            2,
            "one newest run per config of exp01"
        );
        // Bad inputs stay clear errors.
        assert!(run_cli("store").is_err());
        assert!(run_cli(&format!(
            "store frobnicate --store {}",
            s2.display()
        ))
        .is_err());
        let err = run_cli(&format!(
            "store query --store {} --last nope",
            s2.display()
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--last"), "{err}");
        let err = run_cli(&format!(
            "store query --store {} --since not-a-time",
            s2.display()
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--since"), "{err}");
        let err = run_cli(&format!(
            "store compact --store {} --threshold 7",
            s2.display()
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("0..1"), "{err}");
    }

    #[test]
    fn badge_subcommand() {
        let td = TempDir::new("cli-badge").unwrap();
        let f = td.path().join("b.svg");
        assert_eq!(
            run_cli(&format!(
                "badge --label PE --value 0.87 --output {}",
                f.display()
            ))
            .unwrap(),
            0
        );
        assert!(std::fs::read_to_string(&f).unwrap().contains("0.87"));
    }

    #[test]
    fn bad_inputs_error_cleanly() {
        assert!(run_cli("run --app nope --output /tmp/x.json").is_err());
        assert!(run_cli("run --app tealeaf --config 5y5 --output /tmp/x.json")
            .is_err());
        assert!(run_cli("badge --label x --value abc --output /tmp/b.svg")
            .is_err());
        assert!(run_cli("ci-report --input /nonexistent --output /tmp/o")
            .is_err());
    }

    #[test]
    fn jobs_zero_and_absurd_are_clear_errors() {
        let td = TempDir::new("cli-jobs").unwrap();
        let (i, o) = (td.path().join("in"), td.path().join("out"));
        std::fs::create_dir_all(&i).unwrap();
        for (line, needle) in [
            (format!("ci-report --input {} --output {} --jobs 0",
                     i.display(), o.display()), ">= 1"),
            (format!("gate --input {} --jobs 99999", i.display()), "512"),
            (format!("ci-sim --output {} --jobs nope", o.display()),
             "not a number"),
        ] {
            let err = run_cli(&line).unwrap_err().to_string();
            assert!(err.contains(needle), "{line}: {err}");
        }
    }

    #[test]
    fn gate_init_then_gate_cycle() {
        let td = TempDir::new("cli-gate").unwrap();
        let pol = td.path().join("policy/.talp-gate.json");
        assert_eq!(
            run_cli(&format!("gate-init --output {}", pol.display()))
                .unwrap(),
            0
        );
        assert!(crate::gate::GatePolicy::from_file(&pol).is_ok());
        // A quiet folder gates green and writes the verdict triple.
        // (Use a floor-free policy: this checks the CLI cycle, not the
        // simulator's absolute efficiency numbers.)
        let quiet_pol = td.path().join("quiet.json");
        std::fs::write(
            &quiet_pol,
            r#"{"version":1,"defaults":{"max_elapsed_increase":0.5}}"#,
        )
        .unwrap();
        let input = td.path().join("talp");
        std::fs::create_dir_all(&input).unwrap();
        for i in 0..4 {
            assert_eq!(
                run_cli(&format!(
                    "run --app genex --machine mn5 --config 2x4 \
                     --timesteps 2 --seed {} --output {}",
                    40 + i,
                    input.join(format!("exp/run_{i}.json")).display()
                ))
                .unwrap(),
                0
            );
        }
        let gate_out = td.path().join("gate");
        let code = run_cli(&format!(
            "gate --input {} --policy {} --output {}",
            input.display(),
            quiet_pol.display(),
            gate_out.display()
        ))
        .unwrap();
        assert_eq!(code, 0, "clean history must pass");
        for f in ["gate.json", "gate.md", "gate.xml"] {
            assert!(gate_out.join(f).exists(), "{f} missing");
        }
        // Unknown policy file is a clear error.
        assert!(run_cli(&format!(
            "gate --input {} --policy /nonexistent.json",
            input.display()
        ))
        .is_err());
    }

    #[test]
    fn gate_init_policy_is_self_check_clean() {
        // The starter policy the tool hands out must pass its own
        // static analyzer (a policy-only check has no corpus, so no
        // referential findings apply — exit 0, not 1).
        let td = TempDir::new("cli-selfcheck").unwrap();
        let pol = td.path().join(".talp-gate.json");
        assert_eq!(
            run_cli(&format!("gate-init --output {}", pol.display()))
                .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&format!("check --policy {}", pol.display())).unwrap(),
            0
        );
    }

    #[test]
    fn check_subcommand_exit_codes() {
        let td = TempDir::new("cli-check").unwrap();
        // No targets at all is a usage error, not a finding.
        assert!(run_cli("check").is_err());
        assert!(run_cli("check --input a --store b").is_err());

        // One valid artifact: clean (0).
        let input = td.path().join("talp");
        assert_eq!(
            run_cli(&format!(
                "run --app genex --machine mn5 --config 2x4 --timesteps 2 \
                 --output {}",
                input.join("exp/run_0.json").display()
            ))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&format!("check --input {}", input.display())).unwrap(),
            0
        );

        // An unmeasured bench baseline: warnings (1).
        let bench = td.path().join("BENCH.json");
        std::fs::write(&bench, "{\"bench\": \"a\", \"warm_s\": 0}\n")
            .unwrap();
        assert_eq!(
            run_cli(&format!("check --bench {}", bench.display())).unwrap(),
            1
        );

        // A corrupt artifact: errors (2) — check escalates what the
        // report engine would merely skip.
        std::fs::write(input.join("exp/bad.json"), "{\"oops").unwrap();
        assert_eq!(
            run_cli(&format!("check --input {}", input.display())).unwrap(),
            2
        );

        // --sarif writes a parseable SARIF file alongside.
        let sarif = td.path().join("out/check.sarif");
        assert_eq!(
            run_cli(&format!(
                "check --input {} --sarif {}",
                input.display(),
                sarif.display()
            ))
            .unwrap(),
            2
        );
        let text = std::fs::read_to_string(&sarif).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            doc.get("version").and_then(crate::util::json::Json::as_str),
            Some("2.1.0")
        );
        assert!(run_cli(&format!(
            "check --input {} --format yaml",
            input.display()
        ))
        .is_err());
    }

    #[test]
    fn preflight_check_aborts_on_errors_and_passes_clean() {
        let td = TempDir::new("cli-preflight").unwrap();
        let input = td.path().join("talp");
        assert_eq!(
            run_cli(&format!(
                "run --app genex --machine mn5 --config 2x4 --timesteps 2 \
                 --output {}",
                input.join("exp/run_0.json").display()
            ))
            .unwrap(),
            0
        );
        let out = td.path().join("site");
        assert_eq!(
            run_cli(&format!(
                "report --input {} --output {} --format json --check",
                input.display(),
                out.display()
            ))
            .unwrap(),
            0
        );
        assert!(out.join("report.json").exists());

        // A corrupt artifact aborts the gated run before any emit.
        std::fs::write(input.join("exp/bad.json"), "][").unwrap();
        let out2 = td.path().join("site2");
        assert_eq!(
            run_cli(&format!(
                "report --input {} --output {} --format json --check",
                input.display(),
                out2.display()
            ))
            .unwrap(),
            2
        );
        assert!(
            !out2.join("report.json").exists(),
            "pre-flight must abort before emitting"
        );
        // Without --check the same run proceeds (tolerant pipeline).
        assert_eq!(
            run_cli(&format!(
                "report --input {} --output {} --format json",
                input.display(),
                out2.display()
            ))
            .unwrap(),
            0
        );
        assert!(out2.join("report.json").exists());

        // gate --check: a broken policy aborts with the check code.
        let pol = td.path().join("broken.json");
        std::fs::write(&pol, "{\"version\": ").unwrap();
        assert_eq!(
            run_cli(&format!(
                "gate --input {} --policy {} --check",
                input.display(),
                pol.display()
            ))
            .unwrap(),
            2
        );
        // ingest --check: the corrupt artifact aborts before the store
        // is even created.
        let store = td.path().join("store");
        assert_eq!(
            run_cli(&format!(
                "ingest --input {} --store {} --check",
                input.display(),
                store.display()
            ))
            .unwrap(),
            2
        );
        assert!(!store.exists(), "aborted ingest must not create a store");
    }
}
