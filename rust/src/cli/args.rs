//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key v1 v2 ...` (multi-value
//! until the next `--`), and positional arguments.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let mut values = Vec::new();
                while i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    values.push(argv[i + 1].clone());
                    i += 1;
                }
                args.options
                    .entry(key.to_string())
                    .or_default()
                    .extend(values);
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options
            .get(key)
            .and_then(|v| v.first())
            .map(String::as_str)
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.options
            .get(key)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{key}"),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: '{v}' is not a number")),
        }
    }

    /// Maximum explicit `--jobs` the CLI accepts (the single source of
    /// truth is the pool clamp in `util::par`).
    pub const MAX_JOBS: u64 = crate::util::par::MAX_JOBS as u64;

    /// Parse and validate `--jobs`.  Absent means auto-sizing (the
    /// library's `0` sentinel); explicit values must be `1..=512` —
    /// `--jobs 0` and absurd pool sizes are clear errors instead of a
    /// silently degenerate worker pool.
    pub fn get_jobs(&self) -> Result<usize> {
        match self.get("jobs") {
            None => Ok(0),
            Some(v) => {
                let n: u64 = v.parse().map_err(|_| {
                    anyhow::anyhow!("--jobs: '{v}' is not a number")
                })?;
                if n == 0 {
                    bail!(
                        "--jobs must be >= 1 (omit the flag for \
                         auto-sizing)"
                    );
                }
                if n > Self::MAX_JOBS {
                    bail!(
                        "--jobs {n} exceeds the maximum of {} worker \
                         threads",
                        Self::MAX_JOBS
                    );
                }
                Ok(n as usize)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(
            &s.split_whitespace().map(String::from).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn flags_options_positionals() {
        let a = parse("ci-report --input ./talp --output out --verbose");
        assert_eq!(a.positional, ["ci-report"]);
        assert_eq!(a.get("input"), Some("./talp"));
        assert_eq!(a.get("output"), Some("out"));
        assert!(a.has("verbose"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn multi_values() {
        let a = parse("x --regions initialize timestep --badge t");
        assert_eq!(a.get_all("regions"), ["initialize", "timestep"]);
        assert_eq!(a.get("badge"), Some("t"));
    }

    #[test]
    fn require_and_numbers() {
        let a = parse("x --n 12");
        assert_eq!(a.get_u64("n", 0).unwrap(), 12);
        assert_eq!(a.get_u64("m", 7).unwrap(), 7);
        assert!(a.require("absent").is_err());
        let b = parse("x --n twelve");
        assert!(b.get_u64("n", 0).is_err());
    }

    #[test]
    fn jobs_validation() {
        assert_eq!(parse("x").get_jobs().unwrap(), 0, "absent = auto");
        assert_eq!(parse("x --jobs 4").get_jobs().unwrap(), 4);
        assert_eq!(parse("x --jobs 512").get_jobs().unwrap(), 512);
        let err = parse("x --jobs 0").get_jobs().unwrap_err().to_string();
        assert!(err.contains(">= 1"), "{err}");
        let err = parse("x --jobs 100000").get_jobs().unwrap_err().to_string();
        assert!(err.contains("512"), "{err}");
        assert!(parse("x --jobs many").get_jobs().is_err());
        assert!(parse("x --jobs -3").get_jobs().is_err());
    }
}
