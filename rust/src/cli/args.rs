//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key v1 v2 ...` (multi-value
//! until the next `--`), and positional arguments.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let mut values = Vec::new();
                while i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    values.push(argv[i + 1].clone());
                    i += 1;
                }
                args.options
                    .entry(key.to_string())
                    .or_default()
                    .extend(values);
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options
            .get(key)
            .and_then(|v| v.first())
            .map(String::as_str)
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.options
            .get(key)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{key}"),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: '{v}' is not a number")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(
            &s.split_whitespace().map(String::from).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn flags_options_positionals() {
        let a = parse("ci-report --input ./talp --output out --verbose");
        assert_eq!(a.positional, ["ci-report"]);
        assert_eq!(a.get("input"), Some("./talp"));
        assert_eq!(a.get("output"), Some("out"));
        assert!(a.has("verbose"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn multi_values() {
        let a = parse("x --regions initialize timestep --badge t");
        assert_eq!(a.get_all("regions"), ["initialize", "timestep"]);
        assert_eq!(a.get("badge"), Some("t"));
    }

    #[test]
    fn require_and_numbers() {
        let a = parse("x --n 12");
        assert_eq!(a.get_u64("n", 0).unwrap(), 12);
        assert_eq!(a.get_u64("m", 7).unwrap(), 7);
        assert!(a.require("absent").is_err());
        let b = parse("x --n twelve");
        assert!(b.get_u64("n", 0).is_err());
    }
}
