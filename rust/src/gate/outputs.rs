//! Verdict renderers for CI surfaces:
//!
//! * `gate.json` — machine-readable (see [`GateVerdict::to_json`]);
//! * `gate.md`   — markdown summary, paste-able as a PR/MR comment;
//! * `gate.xml`  — JUnit-style XML, so GitLab's `reports: junit` and
//!   GitHub test-summary actions render failures natively.
//!
//! All three are deterministic (no timestamps, no hostnames) and are
//! written together by [`write_outputs`].

use std::path::Path;

use anyhow::{Context, Result};

use super::verdict::{CheckOutcome, GateCheck, GateVerdict};

/// Write `gate.json`, `gate.md` and `gate.xml` into `dir`.
pub fn write_outputs(v: &GateVerdict, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    std::fs::write(dir.join("gate.json"), v.to_json().to_string_pretty())?;
    std::fs::write(dir.join("gate.md"), v.to_markdown())?;
    std::fs::write(dir.join("gate.xml"), v.to_junit_xml())?;
    Ok(())
}

fn xml_esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            '\'' => out.push_str("&apos;"),
            c => out.push(c),
        }
    }
    out
}

/// Markdown-table cell: pipes would break the row.
fn md_cell(s: &str) -> String {
    s.replace('|', "\\|")
}

/// First `n` characters (not bytes — commit strings are arbitrary
/// parsed input and a byte slice could split a UTF-8 sequence).
fn char_prefix(s: &str, n: usize) -> String {
    s.chars().take(n).collect()
}

fn measured_text(c: &GateCheck) -> String {
    match &c.kind {
        super::verdict::CheckKind::ElapsedRegression => {
            format!("{:+.1}%", c.measured * 100.0)
        }
        super::verdict::CheckKind::FactorFloor(_) => {
            format!("{:.2}", c.measured)
        }
    }
}

fn limit_text(c: &GateCheck) -> String {
    match &c.kind {
        super::verdict::CheckKind::ElapsedRegression => {
            format!("{:+.1}%", c.limit * 100.0)
        }
        super::verdict::CheckKind::FactorFloor(_) => {
            format!("≥ {:.2}", c.limit)
        }
    }
}

impl GateVerdict {
    /// The PR-comment markdown summary.
    pub fn to_markdown(&self) -> String {
        let mut out = format!(
            "## TALP performance gate: **{}**\n\n",
            self.status.label()
        );
        out.push_str(&format!(
            "Policy `{}` — {} check(s): {} passed, {} warned, {} failed, \
             {} allowed, {} skipped.\n\n",
            md_cell(&self.policy_source),
            self.counts.total(),
            self.counts.pass,
            self.counts.warn,
            self.counts.fail,
            self.counts.allowed,
            self.counts.skipped
        ));

        // Table of everything that is not a plain pass/skip.
        let notable: Vec<&GateCheck> = self.notable().collect();
        if notable.is_empty() {
            out.push_str("No regressions or floor violations detected.\n");
            return out;
        }
        out.push_str(
            "| Status | Experiment | Config | Region | Check | Measured | Limit |\n\
             |---|---|---|---|---|---|---|\n",
        );
        for c in &notable {
            out.push_str(&format!(
                "| {} | `{}` | `{}` | `{}` | {} | {} | {} |\n",
                c.outcome.id().to_uppercase(),
                md_cell(&c.experiment),
                md_cell(&c.config),
                md_cell(&c.region),
                md_cell(&c.kind.label()),
                measured_text(c),
                limit_text(c)
            ));
        }
        out.push('\n');
        for c in &notable {
            out.push_str(&format!(
                "- **{} / {} / {}** — {}{}{}\n",
                md_cell(&c.experiment),
                md_cell(&c.config),
                md_cell(&c.region),
                md_cell(&c.detail),
                match &c.commit {
                    Some(sha) => {
                        format!(" (at `{}`)", md_cell(&char_prefix(sha, 8)))
                    }
                    None => String::new(),
                },
                match &c.allowed_by {
                    Some(reason) =>
                        format!(" — allowed: {}", md_cell(reason)),
                    None => String::new(),
                }
            ));
        }
        out
    }

    /// JUnit-style XML: one testsuite per experiment, one testcase per
    /// check.  `Fail` maps to `<failure>`, `Skipped` to `<skipped>`,
    /// `Warn`/`Allowed` pass with an explanatory `<system-out>`.
    pub fn to_junit_xml(&self) -> String {
        // Group checks by experiment, preserving first-seen order
        // (checks are already in deterministic experiment order).
        let mut suites: Vec<(&str, Vec<&GateCheck>)> = Vec::new();
        for c in &self.checks {
            let start_new = suites
                .last()
                .map(|(id, _)| *id != c.experiment.as_str())
                .unwrap_or(true);
            if start_new {
                suites.push((c.experiment.as_str(), Vec::new()));
            }
            suites.last_mut().unwrap().1.push(c);
        }
        let mut body = String::new();
        let (mut tests, mut failures, mut skipped) = (0usize, 0usize, 0usize);
        for (exp, list) in &suites {
            let s_fail = list
                .iter()
                .filter(|c| c.outcome == CheckOutcome::Fail)
                .count();
            let s_skip = list
                .iter()
                .filter(|c| c.outcome == CheckOutcome::Skipped)
                .count();
            tests += list.len();
            failures += s_fail;
            skipped += s_skip;
            body.push_str(&format!(
                "  <testsuite name=\"{}\" tests=\"{}\" failures=\"{s_fail}\" \
                 errors=\"0\" skipped=\"{s_skip}\">\n",
                xml_esc(exp),
                list.len()
            ));
            for c in list {
                body.push_str(&format!(
                    "    <testcase classname=\"{}.{}\" name=\"{} {}\"",
                    xml_esc(&c.experiment),
                    xml_esc(&c.config),
                    xml_esc(&c.region),
                    xml_esc(&c.kind.id())
                ));
                match c.outcome {
                    CheckOutcome::Pass => body.push_str("/>\n"),
                    CheckOutcome::Fail => body.push_str(&format!(
                        ">\n      <failure message=\"{}\"/>\n    </testcase>\n",
                        xml_esc(&c.detail)
                    )),
                    CheckOutcome::Skipped => body.push_str(&format!(
                        ">\n      <skipped message=\"{}\"/>\n    </testcase>\n",
                        xml_esc(&c.detail)
                    )),
                    CheckOutcome::Warn => body.push_str(&format!(
                        ">\n      <system-out>warning: {}</system-out>\n    </testcase>\n",
                        xml_esc(&c.detail)
                    )),
                    CheckOutcome::Allowed => body.push_str(&format!(
                        ">\n      <system-out>allowed: {}</system-out>\n    </testcase>\n",
                        xml_esc(&c.detail)
                    )),
                }
            }
            body.push_str("  </testsuite>\n");
        }
        format!(
            "<?xml version=\"1.0\" encoding=\"UTF-8\"?>\n\
             <testsuites name=\"talp-gate\" tests=\"{tests}\" \
             failures=\"{failures}\" errors=\"0\" skipped=\"{skipped}\">\n\
             {body}</testsuites>\n"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::policy::Severity;
    use super::super::verdict::{
        CheckKind, CheckOutcome, GateCheck, GateVerdict,
    };
    use super::*;
    use crate::util::fs::TempDir;

    fn check(
        exp: &str,
        region: &str,
        outcome: CheckOutcome,
        detail: &str,
    ) -> GateCheck {
        GateCheck {
            experiment: exp.into(),
            config: "2x8".into(),
            region: region.into(),
            kind: CheckKind::ElapsedRegression,
            severity: Severity::Fail,
            outcome,
            measured: 0.62,
            limit: 0.15,
            commit: Some("abcdef1234567890".into()),
            detail: detail.into(),
            allowed_by: None,
        }
    }

    fn sample() -> GateVerdict {
        GateVerdict::from_checks(
            ".talp-gate.json".into(),
            vec![
                check("alpha", "Global", CheckOutcome::Pass, "fine"),
                check("alpha", "solve", CheckOutcome::Fail, "bad <jump> & co"),
                check("beta", "Global", CheckOutcome::Skipped, "2 samples"),
                check("beta", "solve", CheckOutcome::Warn, "warned"),
            ],
        )
    }

    #[test]
    fn markdown_lists_notable_checks_only() {
        let md = sample().to_markdown();
        assert!(md.starts_with("## TALP performance gate: **FAIL**"));
        assert!(md.contains("| FAIL | `alpha` |"));
        assert!(md.contains("| WARN | `beta` |"));
        assert!(!md.contains("| PASS"), "passes stay out of the table");
        assert!(md.contains("+62.0%"));
        assert!(md.contains("+15.0%"));
        assert!(md.contains("(at `abcdef12`)"));
        assert!(md.contains("4 check(s): 1 passed, 1 warned, 1 failed"));
    }

    #[test]
    fn markdown_clean_verdict_is_short() {
        let v = GateVerdict::from_checks(
            "p".into(),
            vec![check("alpha", "Global", CheckOutcome::Pass, "fine")],
        );
        let md = v.to_markdown();
        assert!(md.contains("**PASS**"));
        assert!(md.contains("No regressions or floor violations detected."));
        assert!(!md.contains("| Status |"));
    }

    #[test]
    fn junit_counts_and_escaping() {
        let xml = sample().to_junit_xml();
        assert!(xml.starts_with("<?xml version=\"1.0\""));
        assert!(xml.contains(
            "<testsuites name=\"talp-gate\" tests=\"4\" failures=\"1\" \
             errors=\"0\" skipped=\"1\">"
        ));
        assert!(xml.contains(
            "<testsuite name=\"alpha\" tests=\"2\" failures=\"1\" \
             errors=\"0\" skipped=\"0\">"
        ));
        assert!(xml.contains(
            "<failure message=\"bad &lt;jump&gt; &amp; co\"/>"
        ));
        assert!(xml.contains("<skipped message=\"2 samples\"/>"));
        assert!(xml.contains("<system-out>warning: warned</system-out>"));
        assert!(xml.contains(
            "<testcase classname=\"alpha.2x8\" name=\"Global \
             elapsed_regression\"/>"
        ));
        assert!(xml.trim_end().ends_with("</testsuites>"));
    }

    #[test]
    fn write_outputs_creates_all_three() {
        let td = TempDir::new("gate-out").unwrap();
        let dir = td.path().join("nested/gate");
        write_outputs(&sample(), &dir).unwrap();
        for f in ["gate.json", "gate.md", "gate.xml"] {
            assert!(dir.join(f).exists(), "{f} missing");
        }
        let json =
            std::fs::read_to_string(dir.join("gate.json")).unwrap();
        assert!(json.contains("\"status\": \"fail\""));
    }

    #[test]
    fn multibyte_commit_does_not_panic() {
        // Commit strings are arbitrary parsed input: truncation must
        // respect char boundaries ('é' straddles byte index 8 here).
        let mut c = check("alpha", "solve", CheckOutcome::Fail, "bad");
        c.commit = Some("abcdefgé-rest".into());
        let v = GateVerdict::from_checks("p".into(), vec![c]);
        let md = v.to_markdown();
        assert!(md.contains("(at `abcdefgé`)"), "{md}");
        let _ = v.to_junit_xml();
    }

    #[test]
    fn outputs_are_deterministic() {
        let v = sample();
        assert_eq!(v.to_markdown(), sample().to_markdown());
        assert_eq!(v.to_junit_xml(), sample().to_junit_xml());
        assert_eq!(
            v.to_json().to_string_pretty(),
            sample().to_json().to_string_pretty()
        );
    }
}
