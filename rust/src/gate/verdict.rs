//! The gate's result model: one [`GateCheck`] per evaluated
//! `(experiment, config, region, kind)`, rolled up into a
//! [`GateVerdict`] with a single overall status and an exit code.
//!
//! Everything here is **deterministic**: no wall clock, no hostnames,
//! no float formatting that depends on locale — the same scan and
//! policy always produce byte-identical `gate.json` / `gate.md` /
//! `gate.xml`, regardless of `--jobs` or cache temperature (the CI
//! acceptance criterion).

use crate::util::json::Json;

use super::policy::Severity;

/// Overall gate status (worst check outcome wins).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateStatus {
    Pass,
    Warn,
    Fail,
}

impl GateStatus {
    pub fn id(&self) -> &'static str {
        match self {
            GateStatus::Pass => "pass",
            GateStatus::Warn => "warn",
            GateStatus::Fail => "fail",
        }
    }

    /// Uppercase for log lines and markdown headers.
    pub fn label(&self) -> &'static str {
        match self {
            GateStatus::Pass => "PASS",
            GateStatus::Warn => "WARN",
            GateStatus::Fail => "FAIL",
        }
    }
}

/// What a check measured.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckKind {
    /// Latest elapsed time vs the trailing-window baseline.
    ElapsedRegression,
    /// Absolute floor on one POP factor of the latest run.
    FactorFloor(String),
}

impl CheckKind {
    pub fn id(&self) -> String {
        match self {
            CheckKind::ElapsedRegression => "elapsed_regression".to_string(),
            CheckKind::FactorFloor(f) => format!("min_{f}"),
        }
    }

    pub fn label(&self) -> String {
        match self {
            CheckKind::ElapsedRegression => "elapsed regression".to_string(),
            CheckKind::FactorFloor(f) => format!("{f} floor"),
        }
    }
}

/// Outcome of one check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckOutcome {
    Pass,
    /// Violated a `severity: warn` rule.
    Warn,
    /// Violated a `severity: fail` rule.
    Fail,
    /// Violated, but covered by an `allow[]` entry.
    Allowed,
    /// Not evaluable (insufficient samples, muted rule, missing metric).
    Skipped,
}

impl CheckOutcome {
    pub fn id(&self) -> &'static str {
        match self {
            CheckOutcome::Pass => "pass",
            CheckOutcome::Warn => "warn",
            CheckOutcome::Fail => "fail",
            CheckOutcome::Allowed => "allowed",
            CheckOutcome::Skipped => "skipped",
        }
    }
}

/// One evaluated check.
#[derive(Debug, Clone)]
pub struct GateCheck {
    pub experiment: String,
    pub config: String,
    pub region: String,
    pub kind: CheckKind,
    /// The policy severity that applied (even when the check passed).
    pub severity: Severity,
    pub outcome: CheckOutcome,
    /// Regression: relative elapsed increase; floor: the factor value.
    pub measured: f64,
    /// Regression: `max_elapsed_increase`; floor: the minimum.
    pub limit: f64,
    /// Commit of the latest run in the series, when stamped.
    pub commit: Option<String>,
    /// Human one-liner with the numbers behind the outcome.
    pub detail: String,
    /// Reason of the matching allow entry (outcome == Allowed).
    pub allowed_by: Option<String>,
}

/// Check tallies by outcome.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GateCounts {
    pub pass: usize,
    pub warn: usize,
    pub fail: usize,
    pub allowed: usize,
    pub skipped: usize,
}

impl GateCounts {
    pub fn total(&self) -> usize {
        self.pass + self.warn + self.fail + self.allowed + self.skipped
    }
}

/// The rolled-up verdict.
#[derive(Debug, Clone)]
pub struct GateVerdict {
    pub status: GateStatus,
    pub policy_source: String,
    pub counts: GateCounts,
    pub checks: Vec<GateCheck>,
}

impl GateVerdict {
    /// Roll checks up: any `Fail` fails the gate, else any `Warn`
    /// makes it `Warn`, else `Pass` (allowed/skipped never gate).
    pub fn from_checks(
        policy_source: String,
        checks: Vec<GateCheck>,
    ) -> GateVerdict {
        let mut counts = GateCounts::default();
        for c in &checks {
            match c.outcome {
                CheckOutcome::Pass => counts.pass += 1,
                CheckOutcome::Warn => counts.warn += 1,
                CheckOutcome::Fail => counts.fail += 1,
                CheckOutcome::Allowed => counts.allowed += 1,
                CheckOutcome::Skipped => counts.skipped += 1,
            }
        }
        let status = if counts.fail > 0 {
            GateStatus::Fail
        } else if counts.warn > 0 {
            GateStatus::Warn
        } else {
            GateStatus::Pass
        };
        GateVerdict { status, policy_source, counts, checks }
    }

    /// Checks worth surfacing to a human (violations and allowlisted
    /// violations) — the shared filter behind the markdown table, the
    /// HTML index section and the CLI log, so the three surfaces can
    /// never disagree about what is notable.
    pub fn notable(&self) -> impl Iterator<Item = &GateCheck> {
        self.checks.iter().filter(|c| {
            matches!(
                c.outcome,
                CheckOutcome::Warn | CheckOutcome::Fail | CheckOutcome::Allowed
            )
        })
    }

    /// CI contract: 0 = pass (warnings included), 1 = fail.
    pub fn exit_code(&self) -> i32 {
        match self.status {
            GateStatus::Fail => 1,
            _ => 0,
        }
    }

    /// One-line summary for CLI output and pipeline logs.
    pub fn summary_line(&self) -> String {
        format!(
            "gate: {} — {} check(s): {} pass, {} warn, {} fail, \
             {} allowed, {} skipped (policy: {})",
            self.status.label(),
            self.counts.total(),
            self.counts.pass,
            self.counts.warn,
            self.counts.fail,
            self.counts.allowed,
            self.counts.skipped,
            self.policy_source
        )
    }

    /// The machine-readable `gate.json` document.
    pub fn to_json(&self) -> Json {
        let checks: Vec<Json> = self
            .checks
            .iter()
            .map(|c| {
                Json::from_pairs(vec![
                    ("experiment", Json::Str(c.experiment.clone())),
                    ("config", Json::Str(c.config.clone())),
                    ("region", Json::Str(c.region.clone())),
                    ("kind", Json::Str(c.kind.id())),
                    ("severity", Json::Str(c.severity.id().to_string())),
                    ("outcome", Json::Str(c.outcome.id().to_string())),
                    ("measured", Json::Num(c.measured)),
                    ("limit", Json::Num(c.limit)),
                    (
                        "commit",
                        c.commit
                            .clone()
                            .map(Json::Str)
                            .unwrap_or(Json::Null),
                    ),
                    ("detail", Json::Str(c.detail.clone())),
                    (
                        "allowed_by",
                        c.allowed_by
                            .clone()
                            .map(Json::Str)
                            .unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("version", Json::Num(1.0)),
            ("status", Json::Str(self.status.id().to_string())),
            ("policy", Json::Str(self.policy_source.clone())),
            (
                "counts",
                Json::from_pairs(vec![
                    ("pass", Json::Num(self.counts.pass as f64)),
                    ("warn", Json::Num(self.counts.warn as f64)),
                    ("fail", Json::Num(self.counts.fail as f64)),
                    ("allowed", Json::Num(self.counts.allowed as f64)),
                    ("skipped", Json::Num(self.counts.skipped as f64)),
                ]),
            ),
            ("checks", Json::Arr(checks)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn check(
        region: &str,
        kind: CheckKind,
        outcome: CheckOutcome,
    ) -> GateCheck {
        GateCheck {
            experiment: "exp".into(),
            config: "2x8".into(),
            region: region.into(),
            kind,
            severity: Severity::Fail,
            outcome,
            measured: 0.5,
            limit: 0.15,
            commit: Some("abc12345".into()),
            detail: "detail".into(),
            allowed_by: None,
        }
    }

    #[test]
    fn rollup_and_exit_codes() {
        let v = GateVerdict::from_checks(
            "p".into(),
            vec![
                check("a", CheckKind::ElapsedRegression, CheckOutcome::Pass),
                check("b", CheckKind::ElapsedRegression, CheckOutcome::Skipped),
            ],
        );
        assert_eq!(v.status, GateStatus::Pass);
        assert_eq!(v.exit_code(), 0);

        let v = GateVerdict::from_checks(
            "p".into(),
            vec![
                check("a", CheckKind::ElapsedRegression, CheckOutcome::Warn),
                check("b", CheckKind::ElapsedRegression, CheckOutcome::Allowed),
            ],
        );
        assert_eq!(v.status, GateStatus::Warn);
        assert_eq!(v.exit_code(), 0, "warnings do not fail the pipeline");

        let v = GateVerdict::from_checks(
            "p".into(),
            vec![
                check("a", CheckKind::ElapsedRegression, CheckOutcome::Warn),
                check("b", CheckKind::ElapsedRegression, CheckOutcome::Fail),
            ],
        );
        assert_eq!(v.status, GateStatus::Fail);
        assert_eq!(v.exit_code(), 1);
        assert_eq!(v.counts.total(), 2);
        assert!(v.summary_line().contains("gate: FAIL"));
        assert!(v.summary_line().contains("1 fail"));
    }

    #[test]
    fn json_shape() {
        let v = GateVerdict::from_checks(
            ".talp-gate.json".into(),
            vec![check(
                "solve",
                CheckKind::FactorFloor("parallel_efficiency".into()),
                CheckOutcome::Fail,
            )],
        );
        let j = v.to_json();
        assert_eq!(j.str_or("status", ""), "fail");
        assert_eq!(j.str_or("policy", ""), ".talp-gate.json");
        let c = &j.get("checks").unwrap().as_arr().unwrap()[0];
        assert_eq!(c.str_or("kind", ""), "min_parallel_efficiency");
        assert_eq!(c.str_or("outcome", ""), "fail");
        assert_eq!(c.num_or("limit", 0.0), 0.15);
        assert_eq!(c.str_or("commit", ""), "abc12345");
        // Round-trips through the writer without loss.
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.str_or("status", ""), "fail");
    }

    #[test]
    fn kind_ids() {
        assert_eq!(CheckKind::ElapsedRegression.id(), "elapsed_regression");
        assert_eq!(
            CheckKind::FactorFloor("omp_load_balance".into()).id(),
            "min_omp_load_balance"
        );
        assert_eq!(
            CheckKind::FactorFloor("ipc".into()).label(),
            "ipc floor"
        );
    }
}
