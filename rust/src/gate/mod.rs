//! The regression gate: detection turned into an enforceable CI
//! pass/fail policy.
//!
//! The paper's promise is *early* feedback — but a report a developer
//! has to open is late by definition.  This subsystem makes the
//! detector's signal binding: a committed policy file declares what
//! counts as a regression (`policy`), the engine folds the scanned
//! [`crate::pop::RunMetrics`] histories into a verdict (`engine`), and
//! the renderers emit the three artifacts CI systems consume
//! (`outputs`): `gate.json` (machines), `gate.md` (PR comments),
//! `gate.xml` (JUnit, so pipeline UIs render failures natively).
//!
//! Wiring (all through the staged [`crate::session`] pipeline — the
//! verdict is computed in the analyze stage and carried as data):
//! * `talp-pages gate` evaluates standalone (exit 0 = pass/warn,
//!   1 = fail) and serves warm runs entirely from the metrics cache;
//! * `talp-pages report --gate <policy>` gates inline on the scan the
//!   report just used — zero extra parsing;
//! * `ci::runner` records the verdict per pipeline
//!   ([`crate::ci::PipelineResult::gate`]);
//! * the `session::HtmlSite` / `session::Badges` / `session::GateFiles`
//!   emitters surface it on the HTML index, as a `gate` badge and as
//!   the `gate.json`/`gate.md`/`gate.xml` triple;
//! * `ci::templates` emits a ready-made gate job in both the GitLab
//!   and GitHub pipeline flavors.
//!
//! Everything is deterministic: same scan + same policy = byte-identical
//! verdict files, for every `--jobs` value and cache temperature.

pub mod engine;
pub mod outputs;
pub mod policy;
pub mod verdict;

pub use engine::evaluate;
pub use outputs::write_outputs;
pub use policy::{
    AllowEntry, GatePolicy, RuleOverride, Severity, Thresholds,
    GATEABLE_FACTORS,
};
pub use verdict::{
    CheckKind, CheckOutcome, GateCheck, GateCounts, GateStatus, GateVerdict,
};
