//! The declarative gate policy — what "regression" means for *this*
//! repository, committed next to the code it protects.
//!
//! A policy is a JSON document (conventionally `.talp-gate.json`):
//!
//! ```json
//! {
//!   "version": 1,
//!   "defaults": {
//!     "max_elapsed_increase": 0.15,
//!     "noise_sigma": 4.0,
//!     "min_samples": 3,
//!     "warmup": 0,
//!     "window": 4,
//!     "severity": "fail"
//!   },
//!   "rules": [
//!     { "region": "timestep", "config": "*",
//!       "max_elapsed_increase": 0.1, "min_parallel_efficiency": 0.5,
//!       "severity": "warn" }
//!   ],
//!   "allow": [
//!     { "region": "initialize", "config": "*", "commit": "9dc04ca",
//!       "reason": "known regression, tracked in #42" }
//!   ]
//! }
//! ```
//!
//! * **defaults** override the built-in thresholds for every check.
//! * **rules** match on `(experiment, config, region)` patterns (exact,
//!   `"*"`, or trailing-`*` prefix) and override only the fields they
//!   set.  Later matching rules win.  `"severity": "off"` disables
//!   checks for everything a rule matches.
//! * **allow** entries downgrade a firing check to *allowed* (recorded
//!   in the verdict, but never failing the gate) when the latest run's
//!   commit matches the entry's commit prefix — the escape hatch for
//!   known, accepted regressions.
//!
//! Parsing is strict: unknown keys, malformed numbers, out-of-range
//! thresholds and unknown factor names are errors, not warnings — a
//! typo in a CI policy must not silently gate nothing.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// What a violated check does to the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Record the violation, keep the gate green.
    Warn,
    /// Fail the gate (non-zero exit).
    Fail,
    /// Do not check at all (rule-level mute).
    Off,
}

impl Severity {
    pub fn id(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Fail => "fail",
            Severity::Off => "off",
        }
    }

    fn parse(s: &str) -> Result<Severity> {
        match s {
            "warn" => Ok(Severity::Warn),
            "fail" => Ok(Severity::Fail),
            "off" => Ok(Severity::Off),
            other => bail!("policy: unknown severity '{other}' (warn|fail|off)"),
        }
    }
}

/// POP factors a policy may set floors for (ids match
/// `pages::timeseries::TimeSeries::metric`).
pub const GATEABLE_FACTORS: &[&str] = &[
    "parallel_efficiency",
    "mpi_parallel_efficiency",
    "mpi_load_balance",
    "mpi_communication_efficiency",
    "omp_parallel_efficiency",
    "omp_load_balance",
    "omp_scheduling_efficiency",
    "omp_serialization_efficiency",
    "ipc",
    "frequency",
];

/// Fully-resolved thresholds for one `(experiment, config, region)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Thresholds {
    /// Maximum tolerated relative elapsed-time increase of the latest
    /// run over the trailing-window baseline (0.15 = +15%).
    pub max_elapsed_increase: f64,
    /// Multiples of the window's stddev the change must also exceed
    /// before it can fire (suppresses noise on jittery platforms).
    pub noise_sigma: f64,
    /// Minimum history points (after warm-up) to evaluate the
    /// regression check at all; below this the check is *skipped*.
    pub min_samples: usize,
    /// History points discarded from the start of every series before
    /// any statistics (ignore unstable early history).
    pub warmup: usize,
    /// Trailing-window size the baseline mean/stddev is computed over.
    pub window: usize,
    pub severity: Severity,
    /// Absolute floors on the latest run's POP factors
    /// (factor id -> minimum value).
    pub min_factors: BTreeMap<String, f64>,
}

impl Default for Thresholds {
    fn default() -> Thresholds {
        Thresholds {
            max_elapsed_increase: 0.15,
            noise_sigma: 4.0,
            min_samples: 3,
            warmup: 0,
            window: 4,
            severity: Severity::Fail,
            min_factors: BTreeMap::new(),
        }
    }
}

/// One `rules[]` entry: match patterns plus the fields it overrides.
#[derive(Debug, Clone, Default)]
pub struct RuleOverride {
    pub experiment: String,
    pub config: String,
    pub region: String,
    pub max_elapsed_increase: Option<f64>,
    pub noise_sigma: Option<f64>,
    pub min_samples: Option<usize>,
    pub warmup: Option<usize>,
    pub window: Option<usize>,
    pub severity: Option<Severity>,
    pub min_factors: BTreeMap<String, f64>,
}

impl RuleOverride {
    fn matches(&self, exp: &str, cfg: &str, region: &str) -> bool {
        pat_match(&self.experiment, exp)
            && pat_match(&self.config, cfg)
            && pat_match(&self.region, region)
    }

    fn apply(&self, t: &mut Thresholds) {
        if let Some(v) = self.max_elapsed_increase {
            t.max_elapsed_increase = v;
        }
        if let Some(v) = self.noise_sigma {
            t.noise_sigma = v;
        }
        if let Some(v) = self.min_samples {
            t.min_samples = v;
        }
        if let Some(v) = self.warmup {
            t.warmup = v;
        }
        if let Some(v) = self.window {
            t.window = v;
        }
        if let Some(v) = self.severity {
            t.severity = v;
        }
        for (k, v) in &self.min_factors {
            t.min_factors.insert(k.clone(), *v);
        }
    }
}

/// One `allow[]` entry: an accepted, known regression.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub experiment: String,
    pub config: String,
    pub region: String,
    /// Commit-sha prefix the latest run must carry ("*" = any).
    pub commit: String,
    pub reason: String,
}

/// A parsed gate policy.
#[derive(Debug, Clone)]
pub struct GatePolicy {
    /// Where the policy came from (file path or "built-in"), recorded
    /// in the verdict so CI logs are self-explaining.
    pub source: String,
    pub defaults: Thresholds,
    pub rules: Vec<RuleOverride>,
    pub allow: Vec<AllowEntry>,
}

impl Default for GatePolicy {
    fn default() -> GatePolicy {
        GatePolicy {
            source: "built-in".to_string(),
            defaults: Thresholds::default(),
            rules: Vec::new(),
            allow: Vec::new(),
        }
    }
}

/// Exact match, `"*"`, or trailing-`*` prefix.  Shared with the
/// `check` analyzer so "rule matches nothing" uses gate semantics.
pub(crate) fn pat_match(pat: &str, s: &str) -> bool {
    if pat == "*" || pat == s {
        return true;
    }
    match pat.strip_suffix('*') {
        Some(prefix) => s.starts_with(prefix),
        None => false,
    }
}

const SETTING_KEYS: &[&str] = &[
    "max_elapsed_increase",
    "noise_sigma",
    "min_samples",
    "warmup",
    "window",
    "severity",
    "min_parallel_efficiency",
    "min_factors",
];
const MATCH_KEYS: &[&str] = &["experiment", "config", "region"];
const ALLOW_KEYS: &[&str] =
    &["experiment", "config", "region", "commit", "reason"];

fn get_f64(obj: &Json, key: &str) -> Result<Option<f64>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .with_context(|| format!("policy: '{key}' must be a number")),
    }
}

fn get_usize(obj: &Json, key: &str) -> Result<Option<usize>> {
    match obj.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_u64()
            .map(|n| Some(n as usize))
            .with_context(|| {
                format!("policy: '{key}' must be a non-negative integer")
            }),
    }
}

/// A match/commit field must be an actual string: `str_or` defaults
/// would silently widen a typo'd value (e.g. `"region": 5`) to `"*"`.
fn get_str<'a>(obj: &'a Json, key: &str, default: &'a str) -> Result<&'a str> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_str()
            .with_context(|| format!("policy: '{key}' must be a string")),
    }
}

/// A section that is present must have the expected JSON shape —
/// silently ignoring a mis-shaped `rules`/`allow`/`defaults` would
/// gate nothing while CI stays green.
fn get_arr<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
    match j.get(key) {
        None => Ok(&[]),
        Some(v) => v
            .as_arr()
            .with_context(|| format!("policy: '{key}' must be an array")),
    }
}

fn get_severity(obj: &Json) -> Result<Option<Severity>> {
    match obj.get("severity") {
        None => Ok(None),
        Some(v) => {
            let s = v
                .as_str()
                .context("policy: 'severity' must be a string")?;
            Severity::parse(s).map(Some)
        }
    }
}

fn get_min_factors(obj: &Json) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    if let Some(v) = get_f64(obj, "min_parallel_efficiency")? {
        out.insert("parallel_efficiency".to_string(), v);
    }
    if let Some(mf) = obj.get("min_factors") {
        let pairs = mf
            .as_obj()
            .context("policy: 'min_factors' must be an object")?;
        for (factor, vj) in pairs {
            if !GATEABLE_FACTORS.contains(&factor.as_str()) {
                bail!(
                    "policy: unknown factor '{factor}' in min_factors \
                     (known: {})",
                    GATEABLE_FACTORS.join(", ")
                );
            }
            let v = vj.as_f64().with_context(|| {
                format!("policy: min_factors.{factor} must be a number")
            })?;
            out.insert(factor.clone(), v);
        }
    }
    Ok(out)
}

fn reject_unknown_keys(obj: &Json, allowed: &[&[&str]], what: &str) -> Result<()> {
    if let Some(pairs) = obj.as_obj() {
        for (k, _) in pairs {
            if !allowed.iter().any(|set| set.contains(&k.as_str())) {
                bail!("policy: unknown key '{k}' in {what}");
            }
        }
    }
    Ok(())
}

fn validate(t: &Thresholds, what: &str) -> Result<()> {
    if !(t.max_elapsed_increase > 0.0) || !t.max_elapsed_increase.is_finite() {
        bail!("policy: {what}: max_elapsed_increase must be > 0");
    }
    if !(t.noise_sigma >= 0.0) || !t.noise_sigma.is_finite() {
        bail!("policy: {what}: noise_sigma must be >= 0");
    }
    if t.min_samples < 2 {
        bail!("policy: {what}: min_samples must be >= 2 (need a baseline)");
    }
    if t.window < 1 {
        bail!("policy: {what}: window must be >= 1");
    }
    Ok(())
}

impl GatePolicy {
    /// Parse from JSON text; `source` labels the origin in the verdict.
    pub fn parse(text: &str, source: &str) -> Result<GatePolicy> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("policy {source}: {e}"))?;
        reject_unknown_keys(
            &j,
            &[&["version", "defaults", "rules", "allow"]],
            "policy root",
        )?;
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .context("policy: missing or non-integer 'version'")?;
        if version != 1 {
            bail!("policy: unsupported version {version} (this build reads 1)");
        }

        let mut defaults = Thresholds::default();
        if let Some(d) = j.get("defaults") {
            if d.as_obj().is_none() {
                bail!("policy: 'defaults' must be an object");
            }
            reject_unknown_keys(d, &[SETTING_KEYS], "defaults")?;
            let over = parse_override(d, false)?;
            over.apply(&mut defaults);
        }
        validate(&defaults, "defaults")?;

        let mut rules = Vec::new();
        for (i, rj) in get_arr(&j, "rules")?.iter().enumerate() {
            reject_unknown_keys(
                rj,
                &[MATCH_KEYS, SETTING_KEYS],
                &format!("rules[{i}]"),
            )?;
            if rj.as_obj().is_none() {
                bail!("policy: rules[{i}] must be an object");
            }
            let rule = parse_override(rj, true)?;
            // Cheap sanity: the rule must parse against the defaults.
            let mut probe = defaults.clone();
            rule.apply(&mut probe);
            validate(&probe, &format!("rules[{i}]"))?;
            rules.push(rule);
        }

        let mut allow = Vec::new();
        for (i, aj) in get_arr(&j, "allow")?.iter().enumerate() {
            if aj.as_obj().is_none() {
                bail!("policy: allow[{i}] must be an object");
            }
            reject_unknown_keys(aj, &[ALLOW_KEYS], &format!("allow[{i}]"))?;
            allow.push(AllowEntry {
                experiment: get_str(aj, "experiment", "*")?.to_string(),
                config: get_str(aj, "config", "*")?.to_string(),
                region: get_str(aj, "region", "*")?.to_string(),
                commit: get_str(aj, "commit", "*")?.to_string(),
                reason: get_str(aj, "reason", "")?.to_string(),
            });
        }

        Ok(GatePolicy {
            source: source.to_string(),
            defaults,
            rules,
            allow,
        })
    }

    /// Read and parse a policy file.
    pub fn from_file(path: &Path) -> Result<GatePolicy> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading policy {}", path.display()))?;
        GatePolicy::parse(&text, &path.display().to_string())
    }

    /// Resolve the thresholds for one `(experiment, config, region)`:
    /// defaults, then every matching rule in order.
    pub fn effective(&self, exp: &str, cfg: &str, region: &str) -> Thresholds {
        let mut t = self.defaults.clone();
        for rule in &self.rules {
            if rule.matches(exp, cfg, region) {
                rule.apply(&mut t);
            }
        }
        t
    }

    /// First allow-entry covering a firing check, if any.
    pub fn allowed(
        &self,
        exp: &str,
        cfg: &str,
        region: &str,
        commit: Option<&str>,
    ) -> Option<&AllowEntry> {
        self.allow.iter().find(|a| {
            pat_match(&a.experiment, exp)
                && pat_match(&a.config, cfg)
                && pat_match(&a.region, region)
                && (a.commit == "*"
                    || commit
                        .map(|c| c.starts_with(&a.commit))
                        .unwrap_or(false))
        })
    }

    /// A ready-to-commit starter policy (`talp-pages gate-init`).
    pub fn example_json() -> &'static str {
        r#"{
  "version": 1,
  "defaults": {
    "max_elapsed_increase": 0.15,
    "noise_sigma": 4.0,
    "min_samples": 3,
    "warmup": 0,
    "window": 4,
    "severity": "fail"
  },
  "rules": [
    {
      "region": "timestep",
      "config": "*",
      "max_elapsed_increase": 0.1,
      "min_parallel_efficiency": 0.5
    }
  ],
  "allow": []
}
"#
    }
}

fn parse_override(obj: &Json, with_match: bool) -> Result<RuleOverride> {
    let pat = |key| -> Result<String> {
        if with_match {
            get_str(obj, key, "*").map(str::to_string)
        } else {
            Ok("*".to_string())
        }
    };
    Ok(RuleOverride {
        experiment: pat("experiment")?,
        config: pat("config")?,
        region: pat("region")?,
        max_elapsed_increase: get_f64(obj, "max_elapsed_increase")?,
        noise_sigma: get_f64(obj, "noise_sigma")?,
        min_samples: get_usize(obj, "min_samples")?,
        warmup: get_usize(obj, "warmup")?,
        window: get_usize(obj, "window")?,
        severity: get_severity(obj)?,
        min_factors: get_min_factors(obj)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_parses_and_resolves() {
        let p =
            GatePolicy::parse(GatePolicy::example_json(), "example").unwrap();
        assert_eq!(p.source, "example");
        assert_eq!(p.rules.len(), 1);
        // Default region untouched by the rule.
        let t = p.effective("e", "2x8", "initialize");
        assert_eq!(t.max_elapsed_increase, 0.15);
        assert!(t.min_factors.is_empty());
        // Rule region: tightened threshold + PE floor.
        let t = p.effective("e", "2x8", "timestep");
        assert_eq!(t.max_elapsed_increase, 0.1);
        assert_eq!(t.min_factors.get("parallel_efficiency"), Some(&0.5));
        assert_eq!(t.severity, Severity::Fail);
    }

    #[test]
    fn later_rules_override_earlier() {
        let p = GatePolicy::parse(
            r#"{"version":1,"rules":[
                {"region":"*","max_elapsed_increase":0.3},
                {"region":"solve","max_elapsed_increase":0.05,
                 "severity":"warn"}
            ]}"#,
            "t",
        )
        .unwrap();
        assert_eq!(p.effective("e", "c", "other").max_elapsed_increase, 0.3);
        let t = p.effective("e", "c", "solve");
        assert_eq!(t.max_elapsed_increase, 0.05);
        assert_eq!(t.severity, Severity::Warn);
    }

    #[test]
    fn patterns_exact_star_and_prefix() {
        assert!(pat_match("*", "anything"));
        assert!(pat_match("solve", "solve"));
        assert!(!pat_match("solve", "solver"));
        assert!(pat_match("salpha/*", "salpha/resolution_1/mn5"));
        assert!(!pat_match("salpha/*", "beta/resolution_1"));
    }

    #[test]
    fn allow_matches_commit_prefix() {
        let p = GatePolicy::parse(
            r#"{"version":1,"allow":[
                {"region":"init*","commit":"9dc04ca","reason":"known"}
            ]}"#,
            "t",
        )
        .unwrap();
        assert!(p
            .allowed("e", "2x8", "initialize", Some("9dc04ca1f00"))
            .is_some());
        assert!(p.allowed("e", "2x8", "initialize", Some("badc0ffee")).is_none());
        assert!(p.allowed("e", "2x8", "initialize", None).is_none());
        assert!(p.allowed("e", "2x8", "timestep", Some("9dc04ca")).is_none());
    }

    #[test]
    fn strict_parsing_rejects_garbage() {
        for (text, what) in [
            ("{", "not json"),
            (r#"{"version":2}"#, "bad version"),
            (r#"{"rules":[]}"#, "missing version"),
            (r#"{"version":1,"defaults":{"max_elapsed_increse":0.1}}"#, "typo key"),
            (r#"{"version":1,"defaults":{"severity":"explode"}}"#, "bad severity"),
            (r#"{"version":1,"defaults":{"min_samples":1}}"#, "min_samples"),
            (r#"{"version":1,"defaults":{"max_elapsed_increase":0}}"#, "zero threshold"),
            (r#"{"version":1,"defaults":{"window":0}}"#, "zero window"),
            (r#"{"version":1,"defaults":{"min_factors":{"bogus":0.5}}}"#, "bad factor"),
            (r#"{"version":1,"rules":[{"min_samples":-3}]}"#, "negative"),
            (r#"{"version":1,"allow":[{"because":"x"}]}"#, "allow key"),
            (r#"{"version":1,"extra":{}}"#, "root key"),
            // Mis-shaped sections must error, not silently gate nothing.
            (r#"{"version":1,"rules":{"region":"x"}}"#, "rules not array"),
            (r#"{"version":1,"allow":{"region":"x"}}"#, "allow not array"),
            (r#"{"version":1,"defaults":[]}"#, "defaults not object"),
            (r#"{"version":1,"rules":["x"]}"#, "rule not object"),
            // Non-string match fields must not widen to "*".
            (r#"{"version":1,"rules":[{"region":5,"severity":"off"}]}"#,
             "numeric region"),
            (r#"{"version":1,"allow":[{"commit":1234567}]}"#,
             "numeric commit"),
        ] {
            assert!(
                GatePolicy::parse(text, "t").is_err(),
                "should reject: {what}"
            );
        }
    }

    #[test]
    fn min_factors_merge_across_rules() {
        let p = GatePolicy::parse(
            r#"{"version":1,
                "defaults":{"min_parallel_efficiency":0.4},
                "rules":[{"region":"solve",
                          "min_factors":{"omp_load_balance":0.7}}]}"#,
            "t",
        )
        .unwrap();
        let t = p.effective("e", "c", "solve");
        assert_eq!(t.min_factors.get("parallel_efficiency"), Some(&0.4));
        assert_eq!(t.min_factors.get("omp_load_balance"), Some(&0.7));
        // Non-matching region keeps only the default floor.
        let t = p.effective("e", "c", "other");
        assert_eq!(t.min_factors.len(), 1);
    }

    #[test]
    fn default_policy_is_valid() {
        let p = GatePolicy::default();
        assert_eq!(p.source, "built-in");
        validate(&p.defaults, "defaults").unwrap();
    }
}
