//! Gate evaluation: fold a scanned metrics history and a policy into a
//! deterministic [`GateVerdict`].
//!
//! The engine consumes the same precomputed [`crate::pop::RunMetrics`]
//! histories the report engine renders from (`pages::scan_metrics`), so
//! a warm cache gates without parsing a single artifact, and the
//! verdict is byte-identical for every `--jobs` value (scan order is
//! deterministic, evaluation is a pure fold in that order).
//!
//! Per `(experiment, configuration, region)` two kinds of checks run:
//!
//! 1. **Elapsed regression** — the latest run's elapsed time against
//!    the mean of the trailing window, with the same noise-floor test
//!    the detector uses ([`crate::pages::detect::exceeds_noise_floor`])
//!    plus policy knobs: warm-up trimming, a minimum sample count
//!    (below it the check is *skipped*, not failed), and the relative
//!    threshold.
//! 2. **Factor floors** — absolute minimums on the latest run's POP
//!    factors (`min_factors` / `min_parallel_efficiency`).
//!
//! A firing check resolves through the policy's allow-list (known
//! regressions become `Allowed`) and its severity (`warn` never fails
//! the pipeline, `fail` does, `off` skips the region entirely).

use crate::pages::detect::exceeds_noise_floor;
use crate::pages::scanner::MetricScan;
use crate::pages::timeseries::{self, TimeSeries};
use crate::util::stats;

use super::policy::{GatePolicy, Severity, Thresholds};
use super::verdict::{
    CheckKind, CheckOutcome, GateCheck, GateVerdict,
};

/// Evaluate `policy` over every experiment/config/region in `scan`.
pub fn evaluate(scan: &MetricScan, policy: &GatePolicy) -> GateVerdict {
    let mut checks = Vec::new();
    for exp in &scan.experiments {
        for cfg in exp.configs() {
            let history = exp.history_for_config(&cfg);
            let ts = timeseries::build_from_metrics(&cfg, &history, &[]);
            for region in ts.regions() {
                let t = policy.effective(&exp.id, &cfg, &region);
                check_region(
                    &mut checks, policy, &t, &exp.id, &cfg, &region, &ts,
                );
            }
        }
    }
    GateVerdict::from_checks(policy.source.clone(), checks)
}

/// Resolve a firing check through allow-list and severity.
fn resolve(
    policy: &GatePolicy,
    t: &Thresholds,
    exp: &str,
    cfg: &str,
    region: &str,
    commit: Option<&str>,
) -> (CheckOutcome, Option<String>) {
    if let Some(a) = policy.allowed(exp, cfg, region, commit) {
        let reason = if a.reason.is_empty() {
            "allowed by policy".to_string()
        } else {
            a.reason.clone()
        };
        return (CheckOutcome::Allowed, Some(reason));
    }
    match t.severity {
        Severity::Warn => (CheckOutcome::Warn, None),
        // `Off` regions never reach here (skipped earlier); treat a
        // hypothetical fall-through as fail-safe.
        Severity::Fail | Severity::Off => (CheckOutcome::Fail, None),
    }
}

fn check_region(
    out: &mut Vec<GateCheck>,
    policy: &GatePolicy,
    t: &Thresholds,
    exp: &str,
    cfg: &str,
    region: &str,
    ts: &TimeSeries,
) {
    let commit = ts
        .points
        .last()
        .and_then(|p| p.commit.clone());
    let base = |kind: CheckKind| GateCheck {
        experiment: exp.to_string(),
        config: cfg.to_string(),
        region: region.to_string(),
        kind,
        severity: t.severity,
        outcome: CheckOutcome::Skipped,
        measured: 0.0,
        limit: 0.0,
        commit: commit.clone(),
        detail: String::new(),
        allowed_by: None,
    };

    if t.severity == Severity::Off {
        let mut c = base(CheckKind::ElapsedRegression);
        c.detail = "muted by policy rule (severity: off)".to_string();
        out.push(c);
        return;
    }

    // ---- 1. elapsed-regression check ----
    let elapsed = ts.metric(region, "elapsed");
    let series: &[(i64, f64)] = if elapsed.len() > t.warmup {
        &elapsed[t.warmup..]
    } else {
        &[]
    };
    let mut c = base(CheckKind::ElapsedRegression);
    c.limit = t.max_elapsed_increase;
    // Policy parsing enforces min_samples >= 2; re-clamp here so a
    // hand-built Thresholds cannot index an empty series.
    let min_samples = t.min_samples.max(2);
    if series.len() < min_samples {
        c.detail = format!(
            "{} sample(s) after warm-up, policy needs {min_samples}",
            series.len()
        );
    } else {
        let n = series.len();
        let latest = series[n - 1].1;
        let lo = (n - 1).saturating_sub(t.window);
        let window: Vec<f64> =
            series[lo..n - 1].iter().map(|(_, v)| *v).collect();
        let baseline = stats::mean(&window);
        if !latest.is_finite() || !baseline.is_finite() {
            // Fail closed on garbage data: a NaN would sail through
            // every `>` comparison and silently green-light the gate.
            c.detail = "non-finite elapsed time in series".to_string();
        } else if baseline <= 0.0 {
            c.detail = "non-positive baseline elapsed time".to_string();
        } else {
            let rel = (latest - baseline) / baseline;
            c.measured = rel;
            let over_threshold = rel > t.max_elapsed_increase;
            let fired = over_threshold
                && exceeds_noise_floor(&window, latest, t.noise_sigma);
            // The detail must match the numbers it quotes: a change
            // over the threshold but inside the platform's noise floor
            // passes *because of the noise test*, not the threshold.
            let judgement = if fired {
                format!("exceeds {:+.1}%", t.max_elapsed_increase * 100.0)
            } else if over_threshold {
                format!(
                    "exceeds {:+.1}% but is within the noise floor \
                     ({} sigma)",
                    t.max_elapsed_increase * 100.0,
                    t.noise_sigma
                )
            } else {
                format!("within {:+.1}%", t.max_elapsed_increase * 100.0)
            };
            c.detail = format!(
                "elapsed {latest:.4} s vs baseline {baseline:.4} s \
                 over {} run(s): {:+.1}% {judgement}",
                window.len(),
                rel * 100.0,
            );
            if fired {
                let (outcome, allowed_by) = resolve(
                    policy, t, exp, cfg, region, commit.as_deref(),
                );
                c.outcome = outcome;
                c.allowed_by = allowed_by;
            } else {
                c.outcome = CheckOutcome::Pass;
            }
        }
    }
    out.push(c);

    // ---- 2. factor-floor checks (deterministic BTreeMap order) ----
    for (factor, min) in &t.min_factors {
        let series = ts.metric(region, factor);
        let mut c = base(CheckKind::FactorFloor(factor.clone()));
        c.limit = *min;
        match series.last() {
            None => {
                c.detail = format!("factor '{factor}' absent from series");
            }
            Some((_, value)) if !value.is_finite() => {
                c.detail = format!("factor '{factor}' is non-finite");
            }
            Some((_, value)) => {
                c.measured = *value;
                if value < min {
                    let (outcome, allowed_by) = resolve(
                        policy, t, exp, cfg, region, commit.as_deref(),
                    );
                    c.outcome = outcome;
                    c.allowed_by = allowed_by;
                    c.detail = format!(
                        "{factor} {value:.4} below floor {min:.4}"
                    );
                } else {
                    c.outcome = CheckOutcome::Pass;
                    c.detail = format!(
                        "{factor} {value:.4} meets floor {min:.4}"
                    );
                }
            }
        }
        out.push(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pages::scanner::MetricExperiment;
    use crate::pop::{RegionMetrics, RegionSummary, RunMetrics};
    use crate::talp::GitMeta;

    fn metrics(elapsed: f64, pe: f64) -> RegionMetrics {
        RegionMetrics {
            ncpus: 4,
            nranks: 2,
            nthreads: 2,
            elapsed_s: elapsed,
            total_useful_s: elapsed * 4.0 * pe,
            total_useful_instructions: 1_000_000,
            total_useful_cycles: 500_000,
            parallel_efficiency: pe,
            mpi_parallel_efficiency: 0.9,
            mpi_communication_efficiency: 0.95,
            mpi_load_balance: 0.95,
            mpi_load_balance_in: 0.97,
            mpi_load_balance_inter: 0.98,
            omp_parallel_efficiency: 0.9,
            omp_load_balance: 0.93,
            omp_scheduling_efficiency: 0.97,
            omp_serialization_efficiency: 0.99,
            useful_ipc: 2.0,
            frequency_ghz: 2.5,
            insn_per_cpu: 250_000.0,
        }
    }

    fn run(i: usize, elapsed: f64, pe: f64) -> RunMetrics {
        RunMetrics {
            source: format!("exp/run_{i:02}.json"),
            app: "app".into(),
            machine: "mn5".into(),
            timestamp: 1000 + i as i64 * 100,
            ranks: 2,
            threads: 2,
            nodes: 1,
            git: Some(GitMeta {
                commit: format!("c{i:07}"),
                branch: "main".into(),
                commit_timestamp: 1000 + i as i64 * 100,
                message: String::new(),
            }),
            regions: vec![RegionSummary {
                name: "Global".into(),
                visits: 1,
                metrics: metrics(elapsed, pe),
            }],
        }
    }

    fn scan_of(elapsed: &[f64]) -> MetricScan {
        scan_of_pe(elapsed, 0.8)
    }

    fn scan_of_pe(elapsed: &[f64], pe: f64) -> MetricScan {
        MetricScan {
            experiments: vec![MetricExperiment {
                id: "exp".into(),
                runs: elapsed
                    .iter()
                    .enumerate()
                    .map(|(i, e)| run(i, *e, pe))
                    .collect(),
            }],
            ..Default::default()
        }
    }

    fn find<'a>(
        v: &'a GateVerdict,
        kind_id: &str,
    ) -> &'a GateCheck {
        v.checks
            .iter()
            .find(|c| c.kind.id() == kind_id)
            .unwrap_or_else(|| panic!("no check '{kind_id}': {v:?}"))
    }

    #[test]
    fn clean_history_passes() {
        let v = evaluate(
            &scan_of(&[10.0, 10.0, 10.0, 10.0]),
            &GatePolicy::default(),
        );
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
        assert_eq!(v.exit_code(), 0);
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Pass);
        assert_eq!(c.commit.as_deref(), Some("c0000003"));
    }

    #[test]
    fn injected_regression_fails() {
        let v = evaluate(
            &scan_of(&[10.0, 10.0, 10.0, 16.0]),
            &GatePolicy::default(),
        );
        assert_eq!(v.status, crate::gate::GateStatus::Fail);
        assert_eq!(v.exit_code(), 1);
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Fail);
        assert!((c.measured - 0.6).abs() < 1e-9, "{}", c.measured);
        assert!(c.detail.contains("+60.0%"), "{}", c.detail);
    }

    #[test]
    fn improvement_never_fires() {
        let v = evaluate(
            &scan_of(&[10.0, 10.0, 10.0, 4.0]),
            &GatePolicy::default(),
        );
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
    }

    #[test]
    fn short_history_skips_not_fails() {
        let v = evaluate(&scan_of(&[10.0, 16.0]), &GatePolicy::default());
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Skipped);
        assert!(c.detail.contains("needs 3"), "{}", c.detail);
        assert_eq!(v.counts.skipped, 1);
    }

    #[test]
    fn warmup_trims_unstable_early_history() {
        // First point is a wild outlier; warm-up discards it, so the
        // stable tail passes.
        let policy = GatePolicy::parse(
            r#"{"version":1,"defaults":{"warmup":1,"min_samples":3}}"#,
            "t",
        )
        .unwrap();
        let v = evaluate(&scan_of(&[99.0, 10.0, 10.0, 10.0]), &policy);
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Pass);
        assert!(c.detail.contains("over 2 run(s)"), "{}", c.detail);
    }

    #[test]
    fn noise_sigma_suppresses_jittery_series() {
        // Noisy history: the last point is high but within the window's
        // scatter (sigma over [8,12,8,12] is ~2.3; 4*sigma ~ 9.2).
        let v = evaluate(
            &scan_of(&[8.0, 12.0, 8.0, 12.0, 13.0]),
            &GatePolicy::default(),
        );
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Pass, "{}", c.detail);
        // The detail must credit the noise test, not claim the +30%
        // change was within the +15% threshold.
        assert!(c.detail.contains("noise floor"), "{}", c.detail);
        // The same +30% on a flat series fires.
        let v = evaluate(
            &scan_of(&[10.0, 10.0, 10.0, 10.0, 13.0]),
            &GatePolicy::default(),
        );
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Fail, "{}", c.detail);
    }

    #[test]
    fn warn_severity_records_without_failing() {
        let policy = GatePolicy::parse(
            r#"{"version":1,"defaults":{"severity":"warn"}}"#,
            "t",
        )
        .unwrap();
        let v = evaluate(&scan_of(&[10.0, 10.0, 10.0, 16.0]), &policy);
        assert_eq!(v.status, crate::gate::GateStatus::Warn);
        assert_eq!(v.exit_code(), 0);
        assert_eq!(v.counts.warn, 1);
    }

    #[test]
    fn allowlist_downgrades_known_regression() {
        let policy = GatePolicy::parse(
            r#"{"version":1,"allow":[
                {"region":"Global","commit":"c0000003",
                 "reason":"accepted for accuracy fix"}]}"#,
            "t",
        )
        .unwrap();
        let v = evaluate(&scan_of(&[10.0, 10.0, 10.0, 16.0]), &policy);
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Allowed);
        assert_eq!(
            c.allowed_by.as_deref(),
            Some("accepted for accuracy fix")
        );
        // A later commit with the same regression is NOT covered.
        let v = evaluate(&scan_of(&[10.0, 10.0, 10.0, 16.0, 16.5]), &policy);
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Pass, "new baseline absorbed it");
    }

    #[test]
    fn severity_off_mutes_region() {
        let policy = GatePolicy::parse(
            r#"{"version":1,"rules":[{"region":"Global","severity":"off"}]}"#,
            "t",
        )
        .unwrap();
        let v = evaluate(&scan_of(&[10.0, 10.0, 10.0, 16.0]), &policy);
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Skipped);
        assert!(c.detail.contains("muted"));
    }

    #[test]
    fn factor_floor_fires_on_low_efficiency() {
        let policy = GatePolicy::parse(
            r#"{"version":1,"defaults":{"min_parallel_efficiency":0.6}}"#,
            "t",
        )
        .unwrap();
        let v = evaluate(&scan_of_pe(&[10.0, 10.0, 10.0], 0.45), &policy);
        assert_eq!(v.status, crate::gate::GateStatus::Fail);
        let c = find(&v, "min_parallel_efficiency");
        assert_eq!(c.outcome, CheckOutcome::Fail);
        assert_eq!(c.measured, 0.45);
        assert_eq!(c.limit, 0.6);
        // Healthy PE passes the same policy.
        let v = evaluate(&scan_of_pe(&[10.0, 10.0, 10.0], 0.85), &policy);
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
    }

    #[test]
    fn non_finite_metrics_skip_instead_of_passing() {
        let policy = GatePolicy::parse(
            r#"{"version":1,"defaults":{"min_parallel_efficiency":0.6}}"#,
            "t",
        )
        .unwrap();
        // NaN efficiency: the floor check must not report "meets
        // floor" (NaN < min is false); it must skip visibly.
        let v = evaluate(&scan_of_pe(&[10.0, 10.0, 10.0], f64::NAN), &policy);
        let c = find(&v, "min_parallel_efficiency");
        assert_eq!(c.outcome, CheckOutcome::Skipped, "{}", c.detail);
        assert!(c.detail.contains("non-finite"), "{}", c.detail);
        // NaN elapsed likewise skips the regression check.
        let v = evaluate(
            &scan_of(&[10.0, 10.0, 10.0, f64::NAN]),
            &GatePolicy::default(),
        );
        let c = find(&v, "elapsed_regression");
        assert_eq!(c.outcome, CheckOutcome::Skipped, "{}", c.detail);
    }

    #[test]
    fn empty_scan_passes_vacuously() {
        let v = evaluate(&MetricScan::default(), &GatePolicy::default());
        assert_eq!(v.status, crate::gate::GateStatus::Pass);
        assert_eq!(v.counts.total(), 0);
    }
}
