//! Static analysis of every input surface — `talp-pages check`.
//!
//! The report/gate/ingest pipeline is deliberately tolerant: a corrupt
//! artifact or shard line degrades to a skip-warning so one bad file
//! never sinks a CI report.  That tolerance is the wrong default when a
//! human asks "is my setup correct?" — a typo'd gate policy or a
//! drifted store should surface *before* a run, as a precise finding,
//! not mid-pipeline as free text.  This module is that pre-flight
//! analyzer: it validates, without executing a report run, everything
//! the tool consumes —
//!
//! * TALP artifact trees (`--input`),
//! * the persistent run store: manifest + JSONL shards (`--store`),
//! * gate policies (`--policy`),
//! * the metrics cache (`--cache`),
//! * emitted `report.json` documents (`--report`),
//! * the committed bench baseline (`--bench`),
//!
//! and emits structured [`Diagnostic`]s: a stable `TP0xx` code (see
//! [`describe`] for the full table), a severity, the file path, an
//! optional byte-offset [`Span`] (recovered from the streaming JSON
//! reader's offset errors), and a fix-it hint.  Output is deterministic
//! text ([`CheckReport::render_text`]) or SARIF 2.1.0 ([`sarif`]), with
//! gate-style exit codes: 0 clean, 1 warnings, 2 errors.
//!
//! Beyond per-file validation, [`run_check`] performs the cross-file
//! referential analysis nothing else does: policy rules or allow
//! entries whose `(experiment, config, region)` patterns match nothing
//! in the scanned corpus (TP040/TP041), manifest↔shard drift and
//! duplicate records (TP014/TP015/TP016), index sidecars out of sync
//! with their shard and shards past the compaction threshold
//! (TP017/TP018), equal effective timestamps inside one history
//! (TP050), and NaN/negative metric values (TP051/TP052).
//!
//! The scanner and store loaders share this module's [`Diagnostic`]
//! type for their skip-warnings, so `report.json` warnings carry codes
//! and paths too; severity is per *instance* — the same corrupt
//! artifact is a warning to the tolerant report engine and an error to
//! `check`.

use std::fmt;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::pages::cache::MetricsCache;
use crate::pages::scanner::{scan_metrics, MetricScan};
use crate::store::RunStore;

pub mod sarif;
pub mod surfaces;

/// How bad a finding is.  `Info` never affects the exit code.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warning,
    Info,
}

impl Severity {
    pub fn id(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Info => "info",
        }
    }

    /// SARIF 2.1.0 result `level`.
    pub fn sarif_level(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Info => "note",
        }
    }
}

/// Byte-offset region inside the diagnosed file (what the streaming
/// JSON reader's offset errors recover).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub start: usize,
    pub len: usize,
}

/// One structured finding.
///
/// `Display` renders the canonical one-liner — `path: message [code]`,
/// or `path:offset: message [code]` when a span is known — which is
/// also the string form `report.json` consumers reconstruct.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Stable `TP0xx` code (see [`describe`]).
    pub code: &'static str,
    pub severity: Severity,
    /// File the finding is about (display form).
    pub path: String,
    pub span: Option<Span>,
    pub message: String,
    /// Optional fix-it suggestion.
    pub hint: Option<String>,
}

impl Diagnostic {
    pub fn new(
        code: &'static str,
        severity: Severity,
        path: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            severity,
            path: path.into(),
            span: None,
            message: message.into(),
            hint: None,
        }
    }

    pub fn error(
        code: &'static str,
        path: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic::new(code, Severity::Error, path, message)
    }

    pub fn warning(
        code: &'static str,
        path: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic::new(code, Severity::Warning, path, message)
    }

    pub fn info(
        code: &'static str,
        path: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic::new(code, Severity::Info, path, message)
    }

    pub fn with_span(mut self, span: Span) -> Diagnostic {
        self.span = Some(span);
        self
    }

    pub fn with_hint(mut self, hint: impl Into<String>) -> Diagnostic {
        self.hint = Some(hint.into());
        self
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.span {
            Some(s) => write!(
                f,
                "{}:{}: {} [{}]",
                self.path, s.start, self.message, self.code
            ),
            None => {
                write!(f, "{}: {} [{}]", self.path, self.message, self.code)
            }
        }
    }
}

/// Overall outcome, gate-style: the worst severity present wins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckStatus {
    Clean,
    Warnings,
    Errors,
}

impl CheckStatus {
    pub fn id(self) -> &'static str {
        match self {
            CheckStatus::Clean => "clean",
            CheckStatus::Warnings => "warnings",
            CheckStatus::Errors => "errors",
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            CheckStatus::Clean => "CLEAN",
            CheckStatus::Warnings => "WARN",
            CheckStatus::Errors => "ERROR",
        }
    }

    /// 0 clean, 1 warnings, 2 errors — mirrors the gate's exit codes.
    pub fn exit_code(self) -> i32 {
        match self {
            CheckStatus::Clean => 0,
            CheckStatus::Warnings => 1,
            CheckStatus::Errors => 2,
        }
    }
}

/// The collected findings of one check run.
#[derive(Debug, Default)]
pub struct CheckReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl CheckReport {
    pub fn new() -> CheckReport {
        CheckReport::default()
    }

    pub fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }

    /// Deterministic order: path, then span start (span-less first),
    /// then code, then message — so output never depends on scan
    /// parallelism or directory-iteration order.
    pub fn sort(&mut self) {
        self.diagnostics.sort_by(|a, b| {
            a.path
                .cmp(&b.path)
                .then_with(|| {
                    a.span
                        .map(|s| s.start)
                        .cmp(&b.span.map(|s| s.start))
                })
                .then_with(|| a.code.cmp(b.code))
                .then_with(|| a.message.cmp(&b.message))
        });
    }

    pub fn count(&self, severity: Severity) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == severity)
            .count()
    }

    pub fn status(&self) -> CheckStatus {
        if self.count(Severity::Error) > 0 {
            CheckStatus::Errors
        } else if self.count(Severity::Warning) > 0 {
            CheckStatus::Warnings
        } else {
            CheckStatus::Clean
        }
    }

    pub fn exit_code(&self) -> i32 {
        self.status().exit_code()
    }

    pub fn summary_line(&self) -> String {
        format!(
            "check: {} — {} diagnostic(s): {} error(s), {} warning(s), \
             {} info",
            self.status().label(),
            self.diagnostics.len(),
            self.count(Severity::Error),
            self.count(Severity::Warning),
            self.count(Severity::Info),
        )
    }

    /// One line per diagnostic (`severity: path: message [code]`), hint
    /// lines indented beneath, then the summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{}: {d}\n", d.severity.id()));
            if let Some(h) = &d.hint {
                out.push_str(&format!("  hint: {h}\n"));
            }
        }
        out.push_str(&self.summary_line());
        out.push('\n');
        out
    }
}

/// Short description of a diagnostic code — the SARIF rule text and
/// the README table, from one source.
pub fn describe(code: &str) -> &'static str {
    match code {
        "TP001" => "invalid JSON syntax",
        "TP002" => "not a valid TALP artifact",
        "TP003" => "invalid gate policy",
        "TP010" => "store manifest missing or invalid",
        "TP011" => "store version not understood by this build",
        "TP012" => "corrupt store shard record",
        "TP013" => "unreadable input file",
        "TP014" => "unexpected or misnamed file in store shards",
        "TP015" => "duplicate store record for one (source, hash)",
        "TP016" => "identical content stored under several source paths",
        "TP017" => "store index sidecar out of sync with its shard",
        "TP018" => "shard dead-byte ratio above the compaction threshold",
        "TP019" => "orphaned store writer lock",
        "TP020" => "metrics cache version skew (will cold-start)",
        "TP021" => "metrics cache invalid (will cold-start)",
        "TP022" => "artifact tree mixes ingestion formats",
        "TP023" => "ambiguous artifact format (several adapters claim it)",
        "TP024" => "recognized by an ingestion adapter but fails to parse",
        "TP025" => "fsck-detectable store damage (torn shard tail or \
                    stale manifest)",
        "TP026" => "interrupted-operation residue (orphan temp or \
                    sidecar file left by a crash)",
        "TP030" => "report schema_version not understood by this build",
        "TP031" => "report document invalid",
        "TP040" => "policy rule matches nothing in the corpus",
        "TP041" => "policy allow entry matches nothing in the corpus",
        "TP050" => "equal effective timestamps within one history",
        "TP051" => "metric value is NaN",
        "TP052" => "metric value is negative",
        "TP060" => "bench baseline is unmeasured",
        _ => "unknown diagnostic code",
    }
}

/// What [`run_check`] should look at.  At least one target is
/// required; `input` and `store` are mutually exclusive (same rule as
/// `report`).
#[derive(Debug, Default)]
pub struct CheckOptions {
    pub input: Option<PathBuf>,
    pub store: Option<PathBuf>,
    pub policy: Option<PathBuf>,
    pub cache: Option<PathBuf>,
    pub report: Option<PathBuf>,
    pub bench: Option<PathBuf>,
    /// Worker threads for the artifact/store scan (0 = auto).  Output
    /// is byte-identical for every value (pinned by tests).
    pub jobs: usize,
}

/// Run every requested check and return the sorted report.  `Err` is
/// reserved for unusable invocations (no targets, conflicting flags,
/// missing scan root); everything found *in* the inputs is a
/// [`Diagnostic`], not an error.
pub fn run_check(opts: &CheckOptions) -> Result<CheckReport> {
    if opts.input.is_some() && opts.store.is_some() {
        bail!("--input and --store are mutually exclusive");
    }
    if opts.input.is_none()
        && opts.store.is_none()
        && opts.policy.is_none()
        && opts.cache.is_none()
        && opts.report.is_none()
        && opts.bench.is_none()
    {
        bail!(
            "nothing to check: pass --input <dir>, --store <dir>, \
             --policy, --cache, --report or --bench"
        );
    }

    let mut rep = CheckReport::new();

    // The corpus the referential checks run against: a throwaway scan
    // of the artifact tree (never persisted into any cache), or the
    // store's records.
    let mut corpus: Option<MetricScan> = None;
    if let Some(input) = &opts.input {
        let scan =
            scan_metrics(input, &mut MetricsCache::new(), opts.jobs)?;
        // Files the TALP scanner rejects may be valid artifacts in
        // another registered ingestion format: re-sniff each TP002
        // through the adapter registry before judging it.
        let mut formats: std::collections::BTreeMap<&'static str, usize> =
            std::collections::BTreeMap::new();
        for d in &scan.warnings {
            let mut d = d.clone();
            if d.code == "TP002" {
                match reclassify_foreign(&d) {
                    Reclass::Foreign(name) => {
                        // A valid artifact in another format is not a
                        // finding — ingest admits it via its adapter.
                        *formats.entry(name).or_insert(0) += 1;
                        continue;
                    }
                    Reclass::Diag(foreign) => d = foreign,
                    Reclass::Keep => {}
                }
            }
            // The report engine tolerates a corrupt artifact; check
            // mode exists to catch it, so escalate to an error.
            if d.code == "TP001" || d.code == "TP002" {
                d.severity = Severity::Error;
            }
            rep.push(d);
        }
        let talp_files: usize =
            scan.experiments.iter().map(|e| e.runs.len()).sum();
        if talp_files > 0 {
            formats.insert("talp", talp_files);
        }
        if formats.len() >= 2 {
            let mix = formats
                .iter()
                .map(|(name, n)| format!("{name} {n}"))
                .collect::<Vec<_>>()
                .join(", ");
            rep.push(
                Diagnostic::info(
                    "TP022",
                    input.display().to_string(),
                    format!(
                        "tree mixes {} ingestion formats ({mix})",
                        formats.len()
                    ),
                )
                .with_hint(
                    "intentional mixes are fine; pin one with `ingest \
                     --format <name>` to reject strays",
                ),
            );
        }
        corpus = Some(scan);
    }
    if let Some(store) = &opts.store {
        surfaces::check_store(store, &mut rep);
        // For the referential corpus, reuse the loader; its own
        // warnings are discarded — the shard pass above already
        // reported them (with spans).
        if let Ok(s) = RunStore::open_with_jobs(store, opts.jobs) {
            corpus = Some(s.into_scan());
        }
    }

    if let Some(scan) = &corpus {
        surfaces::check_corpus(scan, &mut rep);
    }

    if let Some(policy_path) = &opts.policy {
        let policy = surfaces::check_policy(policy_path, &mut rep);
        if let (Some(policy), Some(scan)) = (policy, &corpus) {
            surfaces::check_policy_refs(
                &policy,
                policy_path,
                scan,
                &mut rep,
            );
        }
    }

    if let Some(cache) = &opts.cache {
        // A missing cache file is an ordinary cold start, not a
        // finding.
        if cache.exists() {
            for d in MetricsCache::check_file(cache) {
                rep.push(d);
            }
        }
    }

    if let Some(report) = &opts.report {
        surfaces::check_report(report, &mut rep);
    }

    if let Some(bench) = &opts.bench {
        surfaces::check_bench(bench, &mut rep);
    }

    rep.sort();
    Ok(rep)
}

/// What a second look through the adapter registry made of a file the
/// TALP scanner rejected (TP002).
enum Reclass {
    /// A valid artifact in another registered format (adapter name) —
    /// not a finding at all.
    Foreign(&'static str),
    /// Replace the TP002 with this sharper diagnostic (TP023/TP024).
    Diag(Diagnostic),
    /// Genuinely not ours; the TP002 stands.
    Keep,
}

fn reclassify_foreign(d: &Diagnostic) -> Reclass {
    let Ok(bytes) = std::fs::read(&d.path) else {
        return Reclass::Keep;
    };
    match crate::adapters::detect(&bytes) {
        crate::adapters::Detection::Ambiguous(a, b) => Reclass::Diag(
            Diagnostic::error(
                "TP023",
                d.path.as_str(),
                format!(
                    "ambiguous format — detected as both '{a}' and '{b}'"
                ),
            )
            .with_hint(
                "pass an explicit --format to ingest, or remove the \
                 colliding top-level keys",
            ),
        ),
        crate::adapters::Detection::Match(a) if a.name() != "talp" => {
            match a.parse(&bytes, &d.path) {
                Ok(_) => Reclass::Foreign(a.name()),
                Err(e) => Reclass::Diag(
                    Diagnostic::error(
                        "TP024",
                        d.path.as_str(),
                        format!(
                            "recognized as a '{}' artifact but it fails \
                             to parse: {e:#}",
                            a.name()
                        ),
                    )
                    .with_hint("fix the file or remove it from the tree"),
                ),
            }
        }
        _ => Reclass::Keep,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(
        code: &'static str,
        sev: Severity,
        path: &str,
        span: Option<usize>,
    ) -> Diagnostic {
        let d = Diagnostic::new(code, sev, path, "m");
        match span {
            Some(start) => d.with_span(Span { start, len: 1 }),
            None => d,
        }
    }

    #[test]
    fn display_with_and_without_span() {
        let d = Diagnostic::warning("TP001", "a.json", "invalid JSON");
        assert_eq!(d.to_string(), "a.json: invalid JSON [TP001]");
        let d = d.with_span(Span { start: 42, len: 1 });
        assert_eq!(d.to_string(), "a.json:42: invalid JSON [TP001]");
    }

    #[test]
    fn status_is_worst_severity_and_info_never_counts() {
        let mut rep = CheckReport::new();
        assert_eq!(rep.status(), CheckStatus::Clean);
        assert_eq!(rep.exit_code(), 0);
        rep.push(diag("TP016", Severity::Info, "x", None));
        assert_eq!(rep.status(), CheckStatus::Clean, "info stays clean");
        rep.push(diag("TP050", Severity::Warning, "x", None));
        assert_eq!(rep.exit_code(), 1);
        rep.push(diag("TP001", Severity::Error, "x", None));
        assert_eq!(rep.exit_code(), 2);
    }

    #[test]
    fn sort_orders_by_path_span_code_message() {
        let mut rep = CheckReport::new();
        rep.push(diag("TP012", Severity::Warning, "b", Some(9)));
        rep.push(diag("TP001", Severity::Error, "b", Some(3)));
        rep.push(diag("TP013", Severity::Warning, "b", None));
        rep.push(diag("TP060", Severity::Warning, "a", None));
        rep.sort();
        let order: Vec<(&str, &str, Option<usize>)> = rep
            .diagnostics
            .iter()
            .map(|d| (d.path.as_str(), d.code, d.span.map(|s| s.start)))
            .collect();
        assert_eq!(
            order,
            [
                ("a", "TP060", None),
                ("b", "TP013", None), // span-less first within a path
                ("b", "TP001", Some(3)),
                ("b", "TP012", Some(9)),
            ]
        );
    }

    #[test]
    fn render_text_includes_hints_and_summary() {
        let mut rep = CheckReport::new();
        rep.push(
            Diagnostic::warning("TP060", "bench.json", "unmeasured")
                .with_hint("run cargo bench"),
        );
        let text = rep.render_text();
        assert!(text.contains("warning: bench.json: unmeasured [TP060]"));
        assert!(text.contains("  hint: run cargo bench"));
        assert!(text.ends_with(
            "check: WARN — 1 diagnostic(s): 0 error(s), 1 warning(s), \
             0 info\n"
        ));
    }

    #[test]
    fn every_emitted_code_is_described() {
        for code in [
            "TP001", "TP002", "TP003", "TP010", "TP011", "TP012",
            "TP013", "TP014", "TP015", "TP016", "TP017", "TP018",
            "TP019", "TP020", "TP021", "TP022", "TP023", "TP024",
            "TP025", "TP026",
            "TP030", "TP031", "TP040", "TP041",
            "TP050", "TP051", "TP052", "TP060",
        ] {
            assert_ne!(describe(code), "unknown diagnostic code", "{code}");
        }
        assert_eq!(describe("TP999"), "unknown diagnostic code");
    }

    #[test]
    fn run_check_rejects_unusable_invocations() {
        assert!(run_check(&CheckOptions::default()).is_err(), "no target");
        let both = CheckOptions {
            input: Some("a".into()),
            store: Some("b".into()),
            ..Default::default()
        };
        assert!(run_check(&both).is_err(), "input+store conflict");
    }
}
