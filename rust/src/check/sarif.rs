//! SARIF 2.1.0 output for [`CheckReport`] — the interchange format
//! GitHub code scanning, GitLab SAST and most editors ingest, so
//! `talp-pages check --format sarif` findings annotate the offending
//! files directly in a merge request.
//!
//! One run, one tool (`talp-pages check`), one rule per distinct
//! `TP0xx` code present in the report (described via
//! [`super::describe`]), one result per diagnostic.  Spans map to
//! `region.byteOffset`/`byteLength` (SARIF's binary-region form —
//! checked files are byte streams to the JSON reader, not line-based
//! text).  Output is deterministic: diagnostics keep the report's
//! sorted order and rules are sorted by code.

use crate::util::json::Json;

use super::{describe, CheckReport, Diagnostic};

/// The SARIF 2.1.0 schema URI (also what consumers key the version
/// check on).
pub const SARIF_SCHEMA: &str = "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/Schemata/sarif-schema-2.1.0.json";

/// Tool homepage advertised in the SARIF driver block.
const INFORMATION_URI: &str = "https://arxiv.org/abs/2510.12436";

fn rule_json(code: &str) -> Json {
    Json::from_pairs(vec![
        ("id", Json::Str(code.to_string())),
        (
            "shortDescription",
            Json::from_pairs(vec![(
                "text",
                Json::Str(describe(code).to_string()),
            )]),
        ),
    ])
}

fn result_json(d: &Diagnostic) -> Json {
    let text = match &d.hint {
        Some(h) => format!("{} (hint: {h})", d.message),
        None => d.message.clone(),
    };
    let mut physical = vec![(
        "artifactLocation",
        Json::from_pairs(vec![("uri", Json::Str(d.path.clone()))]),
    )];
    if let Some(span) = d.span {
        physical.push((
            "region",
            Json::from_pairs(vec![
                ("byteOffset", Json::Num(span.start as f64)),
                ("byteLength", Json::Num(span.len as f64)),
            ]),
        ));
    }
    Json::from_pairs(vec![
        ("ruleId", Json::Str(d.code.to_string())),
        ("level", Json::Str(d.severity.sarif_level().to_string())),
        (
            "message",
            Json::from_pairs(vec![("text", Json::Str(text))]),
        ),
        (
            "locations",
            Json::Arr(vec![Json::from_pairs(vec![(
                "physicalLocation",
                Json::from_pairs(physical),
            )])]),
        ),
    ])
}

/// Build the SARIF document tree for a (sorted) report.
pub fn to_sarif(rep: &CheckReport) -> Json {
    let mut codes: Vec<&str> =
        rep.diagnostics.iter().map(|d| d.code).collect();
    codes.sort_unstable();
    codes.dedup();
    let driver = Json::from_pairs(vec![
        ("name", Json::Str("talp-pages check".to_string())),
        ("informationUri", Json::Str(INFORMATION_URI.to_string())),
        ("rules", Json::Arr(codes.into_iter().map(rule_json).collect())),
    ]);
    let run = Json::from_pairs(vec![
        ("tool", Json::from_pairs(vec![("driver", driver)])),
        (
            "results",
            Json::Arr(rep.diagnostics.iter().map(result_json).collect()),
        ),
    ]);
    Json::from_pairs(vec![
        ("$schema", Json::Str(SARIF_SCHEMA.to_string())),
        ("version", Json::Str("2.1.0".to_string())),
        ("runs", Json::Arr(vec![run])),
    ])
}

/// Render the report as pretty-printed SARIF (trailing newline
/// included, ready for `--sarif <file>`).
pub fn render(rep: &CheckReport) -> String {
    to_sarif(rep).to_string_pretty()
}

#[cfg(test)]
mod tests {
    use super::super::{CheckReport, Severity, Span};
    use super::*;

    fn sample() -> CheckReport {
        let mut rep = CheckReport::new();
        rep.push(
            Diagnostic::error("TP001", "exp/bad.json", "invalid JSON")
                .with_span(Span { start: 17, len: 1 }),
        );
        rep.push(
            Diagnostic::warning("TP060", "BENCH.json", "unmeasured")
                .with_hint("run cargo bench"),
        );
        rep.push(Diagnostic::info("TP016", "store", "dup content"));
        rep.push(Diagnostic::error("TP001", "exp/bad2.json", "invalid"));
        rep.sort();
        rep
    }

    #[test]
    fn document_shape_levels_rules_and_regions() {
        let doc = to_sarif(&sample());
        assert_eq!(
            doc.get("$schema").and_then(Json::as_str),
            Some(SARIF_SCHEMA)
        );
        assert_eq!(
            doc.get("version").and_then(Json::as_str),
            Some("2.1.0")
        );
        let runs = doc.get("runs").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), 1);
        // Rules: distinct codes, sorted, each described.
        let rules = runs[0]
            .at(&["tool", "driver", "rules"])
            .and_then(Json::as_arr)
            .unwrap();
        let ids: Vec<&str> = rules
            .iter()
            .filter_map(|r| r.get("id").and_then(Json::as_str))
            .collect();
        assert_eq!(ids, ["TP001", "TP016", "TP060"], "deduped + sorted");
        assert_eq!(
            rules[0]
                .at(&["shortDescription", "text"])
                .and_then(Json::as_str),
            Some("invalid JSON syntax")
        );
        // Results mirror the report order with mapped levels.
        let results =
            runs[0].get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 4);
        let levels: Vec<&str> = results
            .iter()
            .filter_map(|r| r.get("level").and_then(Json::as_str))
            .collect();
        assert_eq!(levels, ["warning", "error", "error", "note"]);
        // Span -> byte region; span-less results omit the region.
        let with_span = results
            .iter()
            .find(|r| {
                r.at(&[
                    "locations",
                ])
                .and_then(Json::as_arr)
                .and_then(|l| {
                    l[0].at(&["physicalLocation", "artifactLocation", "uri"])
                        .and_then(Json::as_str)
                })
                    == Some("exp/bad.json")
            })
            .unwrap();
        let region = with_span
            .at(&["locations"])
            .and_then(Json::as_arr)
            .and_then(|l| {
                l[0].at(&["physicalLocation", "region"]).cloned()
            })
            .unwrap();
        assert_eq!(region.get("byteOffset").and_then(Json::as_u64), Some(17));
        assert_eq!(region.get("byteLength").and_then(Json::as_u64), Some(1));
        let spanless = &results[3];
        assert!(results[3]
            .at(&["locations"])
            .and_then(Json::as_arr)
            .map(|l| l[0]
                .at(&["physicalLocation", "region"])
                .is_none())
            .unwrap_or(false),
            "{spanless:?}");
        // Hints ride in the message text.
        assert!(results
            .iter()
            .any(|r| r.at(&["message", "text"]).and_then(Json::as_str)
                == Some("unmeasured (hint: run cargo bench)")));
    }

    #[test]
    fn render_parses_back_and_is_deterministic() {
        let rep = sample();
        let a = render(&rep);
        let b = render(&rep);
        assert_eq!(a, b);
        assert!(a.ends_with('\n'));
        Json::parse(&a).expect("rendered SARIF is valid JSON");
    }
}
