//! Per-surface checkers behind [`super::run_check`]: each function
//! inspects one input kind (store, corpus, policy, report, bench
//! baseline) and appends [`Diagnostic`]s to the shared report.
//!
//! Severity policy: anything the pipeline would *refuse to run on*
//! (bad manifest, unparsable policy or report) is an error; anything it
//! would silently tolerate or skip (corrupt shard lines, drifted shard
//! names, duplicate records, suspicious metric values) is a warning —
//! `check` exists precisely to make that tolerated damage visible.
//! Benign-but-notable facts (identical content stored twice) are info.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::path::{Path, PathBuf};

use crate::gate::policy::{pat_match, GatePolicy};
use crate::pages::scanner::MetricScan;
use crate::pop::RegionMetrics;
use crate::session::ReportDocument;
use crate::store::{
    trim_line, LockInfo, ShardIndex, StoredRun, COMPACT_DEAD_RATIO,
    LOCK_FILE_NAME, MANIFEST_FILE_NAME, SHARDS_DIR, STORE_VERSION,
};
use crate::util::json::{error_offset, Json};
use crate::util::text::slug;

use super::{CheckReport, Diagnostic, Span};

/// One decoded shard line's location and identity — what the sidecar
/// validation (TP017) and dead-ratio accounting (TP018) run on.
struct LineInfo {
    offset: usize,
    len: usize,
    hash: String,
    source: String,
}

/// Validate a run store's manifest and every shard file: manifest
/// presence/shape/version (TP010/TP011, errors — the loader refuses
/// these too), corrupt records (TP012, *errors* here even though the
/// loader merely skips them), stray or drifted files in `shards/`
/// (TP014), duplicate `(source, hash)` records (TP015), identical
/// content stored under several paths (TP016, info), index sidecars
/// out of sync with their shard (TP017 — queries degrade to the
/// sequential scan), shards past the compaction threshold (TP018,
/// info with a fix-it), an orphaned writer lockfile (TP019 — a
/// *live* holder is normal operation and stays silent),
/// fsck-detectable crash damage (TP025, error — a torn or
/// unterminated final record, or a manifest that drifted from the
/// shards on disk) and interrupted-operation residue (TP026, warning
/// — `.tmp` staging files, empty shards, orphan sidecars); both
/// carry the `store fsck --repair` fix-it.
pub fn check_store(root: &Path, rep: &mut CheckReport) {
    let manifest = root.join(MANIFEST_FILE_NAME);
    let manifest_disp = manifest.display().to_string();
    let text = match std::fs::read_to_string(&manifest) {
        Ok(t) => t,
        Err(_) => {
            rep.push(
                Diagnostic::error(
                    "TP010",
                    root.display().to_string(),
                    format!("not a run store (no {MANIFEST_FILE_NAME})"),
                )
                .with_hint("run `talp-pages ingest` to create a store here"),
            );
            return;
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            rep.push(
                Diagnostic::error(
                    "TP010",
                    manifest_disp,
                    format!("corrupt manifest: {}", e.message),
                )
                .with_span(Span { start: e.offset, len: 1 }),
            );
            return;
        }
    };
    match doc.get("version").and_then(Json::as_u64) {
        None => {
            rep.push(Diagnostic::error(
                "TP010",
                manifest_disp,
                "manifest has no version",
            ));
            return;
        }
        Some(v) if v != STORE_VERSION => {
            rep.push(Diagnostic::error(
                "TP011",
                manifest_disp,
                format!(
                    "store version {v}; this build understands only \
                     version {STORE_VERSION}"
                ),
            ));
            return;
        }
        Some(_) => {}
    }

    // Writer lock: a live holder (a resident `serve`, an in-flight
    // `ingest`) is normal; an orphaned one blocks nothing (takeover
    // handles it) but says a writer died mid-run — worth surfacing.
    let lock_path = root.join(LOCK_FILE_NAME);
    if let Ok(text) = std::fs::read_to_string(&lock_path) {
        let held = LockInfo::parse(&text);
        let alive = held
            .map(|i| i.holder_alive(crate::util::timefmt::now_unix()))
            .unwrap_or(false);
        if !alive {
            let what = match held {
                Some(i) => format!(
                    "orphaned writer lock (pid {} is not running)",
                    i.pid
                ),
                None => "unreadable writer lock".to_string(),
            };
            rep.push(
                Diagnostic::warning(
                    "TP019",
                    lock_path.display().to_string(),
                    what,
                )
                .with_hint(
                    "a writer crashed without releasing \
                     `.talp-store.lock`; the next writer takes it over \
                     automatically, or delete the file",
                ),
            );
        }
    }

    // Shard pass: deterministic (sorted) file order, line order within
    // each file — the exact order the loader admits records in.
    let shards_dir = root.join(SHARDS_DIR);
    let mut entries: Vec<std::path::PathBuf> = std::fs::read_dir(&shards_dir)
        .map(|rd| rd.flatten().map(|e| e.path()).collect())
        .unwrap_or_default();
    entries.sort();
    let mut seen: HashSet<(String, String)> = HashSet::new();
    let mut by_hash: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut sidecars: Vec<PathBuf> = Vec::new();
    let mut shard_lines: BTreeMap<PathBuf, Vec<LineInfo>> = BTreeMap::new();
    let mut shard_sizes: BTreeMap<PathBuf, u64> = BTreeMap::new();
    for path in entries {
        let disp = path.display().to_string();
        if path.extension().and_then(|e| e.to_str()) != Some("jsonl") {
            if path.is_dir() {
                continue;
            }
            // Index sidecars are expected residents; they get their
            // own validation pass (TP017) below.
            if path.extension().and_then(|e| e.to_str()) == Some("idx") {
                sidecars.push(path);
                continue;
            }
            // `.tmp` staging files are interrupted-operation residue
            // (TP026): a durable write crashed between staging and
            // rename.  The loader ignores them either way.
            if path.extension().and_then(|e| e.to_str()) == Some("tmp") {
                rep.push(
                    Diagnostic::warning(
                        "TP026",
                        disp,
                        format!(
                            "interrupted-operation residue in {SHARDS_DIR}/ \
                             (a `.tmp` staging file whose rename never \
                             happened) — the loader ignores it"
                        ),
                    )
                    .with_hint(
                        "`talp-pages store fsck --repair` removes crash \
                         residue",
                    ),
                );
                continue;
            }
            rep.push(
                Diagnostic::warning(
                    "TP014",
                    disp,
                    format!("unexpected file in {SHARDS_DIR}/ (not .jsonl) \
                             — the loader ignores it"),
                )
                .with_hint(
                    "files that are not part of the store layout can be \
                     moved out or deleted",
                ),
            );
            continue;
        }
        let fname = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or_default()
            .to_string();
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) => {
                rep.push(Diagnostic::warning(
                    "TP013",
                    disp,
                    format!("unreadable ({e}) — skipped"),
                ));
                continue;
            }
        };
        if bytes.is_empty() {
            // A zero-byte shard is crash residue: an append was
            // interrupted between creating the file and writing its
            // first record.  The loader skips it, but the store is in
            // neither its before- nor after-append state until it
            // goes (TP026).
            rep.push(
                Diagnostic::warning(
                    "TP026",
                    disp,
                    "empty shard file (an append was interrupted between \
                     creating the file and writing its first record)",
                )
                .with_hint(
                    "`talp-pages store fsck --repair` removes crash \
                     residue",
                ),
            );
            continue;
        }
        shard_sizes.insert(path.clone(), bytes.len() as u64);
        let lines = shard_lines.entry(path.clone()).or_default();
        let ends_nl = bytes.last() == Some(&b'\n');
        let fragments = bytes.split(|&b| b == b'\n').count();
        let mut misnamed_reported = false;
        let mut lineno = 0usize;
        let mut offset = 0usize;
        for line in bytes.split(|&b| b == b'\n') {
            lineno += 1;
            let line_start = offset;
            offset += line.len() + 1;
            let lead =
                line.iter().take_while(|b| b.is_ascii_whitespace()).count();
            // An unterminated final line is an interrupted append
            // (TP025): decodable means the crash fell between payload
            // and newline, torn means mid-payload.  Either way the
            // next append would land on the same line and corrupt it.
            let is_tail = !ends_nl && lineno == fragments;
            let line = trim_line(line);
            if line.is_empty() {
                continue;
            }
            let rec = match StoredRun::from_line(line) {
                Ok(rec) => rec,
                Err(e) => {
                    let mut d = if is_tail {
                        Diagnostic::error(
                            "TP025",
                            disp.clone(),
                            format!(
                                "torn final record at line {lineno} \
                                 ({e:#}) — an append was interrupted \
                                 mid-write"
                            ),
                        )
                        .with_hint(
                            "`talp-pages store fsck --repair` truncates \
                             the torn tail back to the last intact record",
                        )
                    } else {
                        Diagnostic::error(
                            "TP012",
                            disp.clone(),
                            format!(
                                "corrupt record at line {lineno} ({e:#})"
                            ),
                        )
                        .with_hint(
                            "`talp-pages ingest --compact` rewrites shards \
                             without corrupt lines",
                        )
                    };
                    if let Some(off) = error_offset(&e) {
                        d = d.with_span(Span {
                            start: line_start + lead + off,
                            len: 1,
                        });
                    }
                    rep.push(d);
                    continue;
                }
            };
            if is_tail {
                rep.push(
                    Diagnostic::error(
                        "TP025",
                        disp.clone(),
                        format!(
                            "final record at line {lineno} has no \
                             terminating newline (an append was \
                             interrupted between its payload and the \
                             newline) — the next append would merge \
                             into it"
                        ),
                    )
                    .with_hint(
                        "`talp-pages store fsck --repair` writes the \
                         missing newline",
                    ),
                );
            }
            let expected = format!(
                "{}__{}.jsonl",
                slug(&rec.experiment),
                rec.run.resources().label()
            );
            if expected != fname && !misnamed_reported {
                misnamed_reported = true;
                rep.push(
                    Diagnostic::warning(
                        "TP014",
                        disp.clone(),
                        format!(
                            "record at line {lineno} belongs in {expected} \
                             (experiment '{}', config {})",
                            rec.experiment,
                            rec.run.resources().label()
                        ),
                    )
                    .with_hint(
                        "`talp-pages ingest --compact` re-buckets drifted \
                         records",
                    ),
                );
            }
            let key = (rec.run.source.clone(), rec.hash.clone());
            if !seen.insert(key) {
                rep.push(
                    Diagnostic::warning(
                        "TP015",
                        disp.clone(),
                        format!(
                            "duplicate record at line {lineno} for {} \
                             (hash {})",
                            rec.run.source, rec.hash
                        ),
                    )
                    .with_hint(
                        "`talp-pages ingest --compact` drops duplicates",
                    ),
                );
            }
            by_hash
                .entry(rec.hash.clone())
                .or_default()
                .insert(rec.run.source.clone());
            lines.push(LineInfo {
                offset: line_start + lead,
                len: line.len(),
                hash: rec.hash.clone(),
                source: rec.run.source.clone(),
            });
        }
    }
    for (hash, sources) in &by_hash {
        if sources.len() >= 2 {
            let list: Vec<&str> =
                sources.iter().map(String::as_str).collect();
            rep.push(Diagnostic::info(
                "TP016",
                root.display().to_string(),
                format!(
                    "content hash {hash} is stored under {} source paths \
                     ({}) — each counts as its own history point",
                    sources.len(),
                    list.join(", ")
                ),
            ));
        }
    }

    // TP025: manifest drift.  Every writer rewrites the manifest after
    // mutating shards, so a `shards` array that disagrees with the
    // files on disk is the signature of a crash between the shard
    // mutation and the manifest rewrite that follows it (or of a
    // hand-edited shard).
    if let Some(Json::Arr(listed)) = doc.get("shards") {
        let mut in_manifest: BTreeSet<String> = BTreeSet::new();
        for entry in listed {
            let (Some(file), Some(bytes)) = (
                entry.get("file").and_then(Json::as_str),
                entry.get("bytes").and_then(Json::as_u64),
            ) else {
                continue;
            };
            in_manifest.insert(file.to_string());
            let shard = shards_dir.join(file);
            match shard_sizes.get(&shard) {
                Some(&actual) if actual != bytes => {
                    rep.push(
                        Diagnostic::error(
                            "TP025",
                            shard.display().to_string(),
                            format!(
                                "manifest drift: the manifest says this \
                                 shard is {bytes} bytes but it is {actual} \
                                 on disk"
                            ),
                        )
                        .with_hint(
                            "`talp-pages store fsck --repair` rewrites \
                             the manifest from the shards on disk",
                        ),
                    );
                }
                Some(_) => {}
                None if !shard.exists() => {
                    rep.push(
                        Diagnostic::error(
                            "TP025",
                            shard.display().to_string(),
                            "manifest drift: the manifest lists this \
                             shard but it does not exist on disk",
                        )
                        .with_hint(
                            "`talp-pages store fsck --repair` rewrites \
                             the manifest from the shards on disk",
                        ),
                    );
                }
                // Present but unreadable or empty: TP013/TP026 already
                // said what is wrong with the file itself.
                None => {}
            }
        }
        for shard in shard_sizes.keys() {
            let name = shard
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_default();
            if !in_manifest.contains(&name) {
                rep.push(
                    Diagnostic::error(
                        "TP025",
                        shard.display().to_string(),
                        "manifest drift: this shard is not listed in the \
                         manifest",
                    )
                    .with_hint(
                        "`talp-pages store fsck --repair` rewrites the \
                         manifest from the shards on disk",
                    ),
                );
            }
        }
    }

    // Liveness replay (the loader's admit rules: duplicates drop,
    // same-source-new-hash supersedes) so the index and dead-byte
    // passes below know which lines a query would actually serve.
    let mut live: BTreeMap<PathBuf, BTreeSet<usize>> = BTreeMap::new();
    {
        let mut keys: HashSet<(String, String)> = HashSet::new();
        let mut owner: HashMap<String, (PathBuf, usize, String)> =
            HashMap::new();
        for (path, lines) in &shard_lines {
            for l in lines {
                if !keys.insert((l.source.clone(), l.hash.clone())) {
                    continue;
                }
                if let Some((old_path, old_off, old_hash)) = owner.insert(
                    l.source.clone(),
                    (path.clone(), l.offset, l.hash.clone()),
                ) {
                    keys.remove(&(l.source.clone(), old_hash));
                    if let Some(offs) = live.get_mut(&old_path) {
                        offs.remove(&old_off);
                    }
                }
                live.entry(path.clone()).or_default().insert(l.offset);
            }
        }
    }

    // TP017: existing sidecars that disagree with their shard.  A
    // missing sidecar is not a finding (the loader rebuilds on
    // demand); a wrong one degrades every query of that shard to the
    // sequential scan, which is exactly the slow path the index
    // exists to avoid.  First problem per sidecar.
    for sc in &sidecars {
        let shard = sc.with_extension("");
        if !shard.exists() {
            // Residue, not skew: the companion shard is gone (an
            // interrupted compaction removed it before the sidecar
            // cleanup ran), so there is nothing to be out of sync
            // *with* — TP026, with the fsck fix-it.
            rep.push(
                Diagnostic::warning(
                    "TP026",
                    sc.display().to_string(),
                    "orphan sidecar — its companion shard does not exist",
                )
                .with_hint(
                    "`talp-pages store fsck --repair` removes crash \
                     residue",
                ),
            );
            continue;
        }
        let problem: Option<String> = if let Some(lines) =
            shard_lines.get(&shard)
        {
            match ShardIndex::load(&shard) {
                Err(e) => Some(format!(
                    "unparsable ({e:#}) — queries fall back to the \
                     sequential scan"
                )),
                Ok(None) => None,
                Ok(Some(idx)) => {
                    let actual =
                        shard_sizes.get(&shard).copied().unwrap_or(0);
                    if idx.shard_bytes != actual {
                        Some(format!(
                            "stale: shard is {actual} bytes but the index \
                             was built from {} — queries fall back to the \
                             sequential scan",
                            idx.shard_bytes
                        ))
                    } else {
                        index_skew(&idx, lines, live.get(&shard))
                    }
                }
            }
        } else {
            // Unreadable shard: TP013 already reported it; nothing to
            // validate the sidecar against.
            None
        };
        if let Some(msg) = problem {
            rep.push(
                Diagnostic::warning(
                    "TP017",
                    sc.display().to_string(),
                    msg,
                )
                .with_hint(
                    "indexes rebuild on demand — the next `talp-pages \
                     store query` heals this sidecar",
                ),
            );
        }
    }

    // TP018: shards past the tiered-compaction threshold.  Info, not
    // a warning — results stay correct, the store just burns bytes
    // and decode time on lines nothing can ever serve again.
    for (path, lines) in &shard_lines {
        let total = shard_sizes.get(path).copied().unwrap_or(0);
        if total == 0 {
            continue;
        }
        let live_bytes: u64 = match live.get(path) {
            Some(offs) => lines
                .iter()
                .filter(|l| offs.contains(&l.offset))
                .map(|l| l.len as u64 + 1)
                .sum(),
            None => 0,
        };
        let dead = total.saturating_sub(live_bytes);
        let ratio = dead as f64 / total as f64;
        if ratio > COMPACT_DEAD_RATIO {
            rep.push(
                Diagnostic::info(
                    "TP018",
                    path.display().to_string(),
                    format!(
                        "dead-byte ratio {ratio:.2} exceeds the compaction \
                         threshold {COMPACT_DEAD_RATIO} ({dead} of {total} \
                         bytes are superseded, duplicate or corrupt)"
                    ),
                )
                .with_hint(
                    "`talp-pages store compact` rewrites shards past the \
                     threshold",
                ),
            );
        }
    }
}

/// First disagreement between a fresh-looking sidecar and its shard's
/// decoded lines: an entry pointing nowhere, a length or content-hash
/// mismatch, or a live record the index does not cover (a query
/// replaying these entries would silently miss it — the one skew the
/// size-based freshness check cannot catch).
fn index_skew(
    idx: &ShardIndex,
    lines: &[LineInfo],
    live: Option<&BTreeSet<usize>>,
) -> Option<String> {
    let by_offset: HashMap<usize, &LineInfo> =
        lines.iter().map(|l| (l.offset, l)).collect();
    for (i, e) in idx.entries.iter().enumerate() {
        let Some(l) = by_offset.get(&e.offset) else {
            return Some(format!(
                "entry {i} points at offset {}, which is not the start of \
                 a record line",
                e.offset
            ));
        };
        if l.len != e.len {
            return Some(format!(
                "entry {i} says {} byte(s) but the line at offset {} has \
                 {}",
                e.len, e.offset, l.len
            ));
        }
        if l.hash != e.hash {
            return Some(format!(
                "entry {i} carries a stale content hash ({} indexed, {} \
                 on disk)",
                e.hash, l.hash
            ));
        }
    }
    if let Some(live) = live {
        let covered: HashSet<usize> =
            idx.entries.iter().map(|e| e.offset).collect();
        let missing =
            live.iter().filter(|o| !covered.contains(o)).count();
        if missing > 0 {
            return Some(format!(
                "count mismatch: {missing} live record(s) missing from \
                 the {} indexed entries",
                idx.entries.len()
            ));
        }
    }
    None
}

/// The nine per-region metric values a stored/scanned run carries,
/// labeled for diagnostics.
fn metric_values(m: &RegionMetrics) -> [(&'static str, f64); 9] {
    [
        ("elapsed_s", m.elapsed_s),
        ("total_useful_s", m.total_useful_s),
        ("parallel_efficiency", m.parallel_efficiency),
        ("mpi_parallel_efficiency", m.mpi_parallel_efficiency),
        ("mpi_communication_efficiency", m.mpi_communication_efficiency),
        ("mpi_load_balance", m.mpi_load_balance),
        ("omp_parallel_efficiency", m.omp_parallel_efficiency),
        ("useful_ipc", m.useful_ipc),
        ("frequency_ghz", m.frequency_ghz),
    ]
}

/// Cross-run analysis over a scanned or store-loaded corpus: equal
/// effective timestamps within one configuration's history (TP050 —
/// ordering then silently falls back to file names) and NaN/negative
/// metric values (TP051/TP052 — the factor math clamps its own
/// output, so these only arise from damaged or hand-edited data).
pub fn check_corpus(scan: &MetricScan, rep: &mut CheckReport) {
    for exp in &scan.experiments {
        for cfg in exp.configs() {
            let hist = exp.history_for_config(&cfg);
            for w in hist.windows(2) {
                if w[0].effective_timestamp() == w[1].effective_timestamp()
                {
                    rep.push(
                        Diagnostic::warning(
                            "TP050",
                            w[1].source.clone(),
                            format!(
                                "effective timestamp {} equals {}'s in \
                                 {}/{cfg} — history order falls back to \
                                 file names",
                                w[1].effective_timestamp(),
                                w[0].source,
                                exp.id
                            ),
                        )
                        .with_hint(
                            "stamp distinct commit timestamps with \
                             `talp-pages metadata`",
                        ),
                    );
                }
            }
        }
        for run in &exp.runs {
            for reg in &run.regions {
                for (name, v) in metric_values(&reg.metrics) {
                    if v.is_nan() {
                        rep.push(Diagnostic::warning(
                            "TP051",
                            run.source.clone(),
                            format!("region '{}': {name} is NaN", reg.name),
                        ));
                    } else if v < 0.0 {
                        rep.push(Diagnostic::warning(
                            "TP052",
                            run.source.clone(),
                            format!(
                                "region '{}': {name} is negative ({v})",
                                reg.name
                            ),
                        ));
                    }
                }
            }
        }
    }
}

/// Parse-validate a gate policy (TP003, error — a policy the gate
/// would refuse).  Returns the parsed policy so [`check_policy_refs`]
/// can cross-check it against a corpus.
pub fn check_policy(
    path: &Path,
    rep: &mut CheckReport,
) -> Option<GatePolicy> {
    let disp = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            rep.push(Diagnostic::error(
                "TP013",
                disp,
                format!("unreadable ({e})"),
            ));
            return None;
        }
    };
    match GatePolicy::parse(&text, &disp) {
        Ok(p) => Some(p),
        Err(e) => {
            let root = e.root_cause().to_string();
            // The parser prefixes its own messages with the policy
            // source; the diagnostic already carries the path.
            let msg = root
                .strip_prefix(&format!("policy {disp}: "))
                .or_else(|| root.strip_prefix("policy: "))
                .unwrap_or(&root);
            let mut d = Diagnostic::error(
                "TP003",
                disp.clone(),
                format!("invalid gate policy: {msg}"),
            );
            if let Some(off) = error_offset(&e) {
                d = d.with_span(Span { start: off, len: 1 });
            }
            rep.push(d.with_hint(
                "`talp-pages gate-init` writes a known-good starting \
                 policy",
            ));
            None
        }
    }
}

/// Referential check of a parsed policy against a corpus: every
/// `rules[]` (TP040) and `allow[]` (TP041) entry must match at least
/// one `(experiment, config, region)` the corpus actually contains —
/// a matcher that matches nothing usually means a typo'd pattern
/// silently gating (or allowing) nothing.  Skipped when the corpus has
/// no experiments at all.
pub fn check_policy_refs(
    policy: &GatePolicy,
    policy_path: &Path,
    scan: &MetricScan,
    rep: &mut CheckReport,
) {
    if scan.experiments.is_empty() {
        return;
    }
    let matches_any = |exp_pat: &str, cfg_pat: &str, region_pat: &str| {
        scan.experiments.iter().any(|exp| {
            pat_match(exp_pat, &exp.id)
                && exp.configs().iter().any(|c| pat_match(cfg_pat, c))
                && exp.regions().iter().any(|r| pat_match(region_pat, r))
        })
    };
    let disp = policy_path.display().to_string();
    for (i, rule) in policy.rules.iter().enumerate() {
        if !matches_any(&rule.experiment, &rule.config, &rule.region) {
            rep.push(
                Diagnostic::warning(
                    "TP040",
                    disp.clone(),
                    format!(
                        "rules[{i}] (experiment '{}', config '{}', region \
                         '{}') matches nothing in the corpus",
                        rule.experiment, rule.config, rule.region
                    ),
                )
                .with_hint(
                    "compare the patterns against the experiment ids, \
                     configs and regions in the report",
                ),
            );
        }
    }
    for (i, a) in policy.allow.iter().enumerate() {
        // The commit pattern is deliberately ignored: it matches the
        // *future* run that triggers the allowance, not stored history.
        if !matches_any(&a.experiment, &a.config, &a.region) {
            rep.push(
                Diagnostic::warning(
                    "TP041",
                    disp.clone(),
                    format!(
                        "allow[{i}] (experiment '{}', config '{}', region \
                         '{}') matches nothing in the corpus",
                        a.experiment, a.config, a.region
                    ),
                )
                .with_hint(
                    "stale allow entries can be deleted once the \
                     accepted regression left the history window",
                ),
            );
        }
    }
}

/// Validate an emitted `report.json` against the consumer contract:
/// unknown/missing `schema_version` (TP030) vs any other shape or
/// syntax problem (TP031, with a byte span when the JSON reader has
/// one).
pub fn check_report(path: &Path, rep: &mut CheckReport) {
    let disp = path.display().to_string();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            rep.push(Diagnostic::error(
                "TP013",
                disp,
                format!("unreadable ({e})"),
            ));
            return;
        }
    };
    if let Err(e) = ReportDocument::parse(&text) {
        let full = format!("{e:#}");
        if full.contains("schema_version") {
            rep.push(Diagnostic::error("TP030", disp, full).with_hint(
                "regenerate the report with this build of talp-pages",
            ));
        } else {
            let mut d = Diagnostic::error("TP031", disp, full);
            if let Some(off) = error_offset(&e) {
                d = d.with_span(Span { start: off, len: 1 });
            }
            rep.push(d);
        }
    }
}

/// Validate a committed bench baseline (JSONL of `BENCH_JSON` records):
/// unparsable lines are TP001 errors; a baseline whose every `*_s`
/// timing is zero has never been measured (TP060) — deltas computed
/// against it are meaningless, which is easy to miss because the
/// comparison scripts just skip non-positive baselines.
pub fn check_bench(path: &Path, rep: &mut CheckReport) {
    let disp = path.display().to_string();
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) => {
            rep.push(Diagnostic::error(
                "TP013",
                disp,
                format!("unreadable ({e})"),
            ));
            return;
        }
    };
    let mut lineno = 0usize;
    let mut offset = 0usize;
    let mut timed_records = 0usize;
    let mut measured = 0usize;
    for line in bytes.split(|&b| b == b'\n') {
        lineno += 1;
        let line_start = offset;
        offset += line.len() + 1;
        let lead =
            line.iter().take_while(|b| b.is_ascii_whitespace()).count();
        let line = trim_line(line);
        if line.is_empty() {
            continue;
        }
        let doc = match Json::from_slice(line) {
            Ok(d) => d,
            Err(e) => {
                rep.push(
                    Diagnostic::error(
                        "TP001",
                        disp.clone(),
                        format!(
                            "invalid JSON at line {lineno}: {}",
                            e.message
                        ),
                    )
                    .with_span(Span {
                        start: line_start + lead + e.offset,
                        len: 1,
                    }),
                );
                continue;
            }
        };
        if doc.get("bench").and_then(Json::as_str) == Some("_meta") {
            continue;
        }
        let mut timed = false;
        if let Some(pairs) = doc.as_obj() {
            for (key, val) in pairs {
                if !key.ends_with("_s") {
                    continue;
                }
                if let Some(v) = val.as_f64() {
                    timed = true;
                    if v > 0.0 {
                        measured += 1;
                    }
                }
            }
        }
        if timed {
            timed_records += 1;
        }
    }
    if timed_records > 0 && measured == 0 {
        rep.push(
            Diagnostic::warning(
                "TP060",
                disp,
                format!(
                    "all timings across {timed_records} bench record(s) \
                     are zero — the baseline is unmeasured"
                ),
            )
            .with_hint(
                "run `cargo bench --bench perf_hotpaths` and commit the \
                 refreshed BENCH_JSON lines",
            ),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pages::scanner::MetricExperiment;
    use crate::pop::RunMetrics;
    use crate::store::RunStore;
    use crate::talp::{GitMeta, ProcStats, RegionData, RunData};
    use crate::util::fs::TempDir;

    fn run_metrics(source: &str, ranks: u32, ts: i64) -> RunMetrics {
        let data = RunData {
            dlb_version: "t".into(),
            app: "app".into(),
            machine: "mn5".into(),
            timestamp: ts,
            ranks,
            threads: 2,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 1.0,
                visits: 1,
                procs: (0..ranks)
                    .map(|r| ProcStats {
                        rank: r,
                        elapsed_s: 1.0,
                        useful_s: 1.5,
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: Some(GitMeta {
                commit: format!("c{ts:07x}"),
                branch: "main".into(),
                commit_timestamp: ts,
                message: String::new(),
            }),
        };
        RunMetrics::from_run(&data, source)
    }

    fn codes(rep: &CheckReport) -> Vec<&'static str> {
        rep.diagnostics.iter().map(|d| d.code).collect()
    }

    #[test]
    fn store_checks_manifest_shards_duplicates_and_content() {
        let td = TempDir::new("check-store").unwrap();
        let root = td.path().join("store");
        let mut s = RunStore::create_or_open(&root).unwrap();
        s.append("exp", "h1", run_metrics("a.json", 2, 1)).unwrap();
        s.append("exp", "same", run_metrics("b.json", 2, 2)).unwrap();
        // Identical content at a second path: TP016 (info).
        s.append("exp", "same", run_metrics("c.json", 2, 3)).unwrap();
        let shard = root.join(SHARDS_DIR).join("exp__2x2.jsonl");
        let mut text = std::fs::read_to_string(&shard).unwrap();
        // Exact duplicate line: TP015.
        let first = text.lines().next().unwrap().to_string();
        text.push_str(&first);
        text.push('\n');
        // Truncated record: TP012 with a span.
        text.push_str("{\"hash\":\"h9\",\"experiment\":\"exp\",\"run\":{");
        text.push('\n');
        std::fs::write(&shard, text).unwrap();
        // Stray `.tmp` staging file: TP026 (crash residue).
        std::fs::write(
            root.join(SHARDS_DIR).join("exp__2x2.jsonl.tmp"),
            "junk",
        )
        .unwrap();

        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        rep.sort();
        let mut found = codes(&rep);
        found.sort();
        // TP018 rides along: the duplicate and the corrupt line are
        // dead bytes, and together they always cross the threshold.
        // TP025 rides along too: the hand-edited shard no longer has
        // the byte count the manifest recorded.
        assert_eq!(
            found,
            ["TP012", "TP015", "TP016", "TP018", "TP025", "TP026"],
            "{rep:?}"
        );
        let tp012 = rep
            .diagnostics
            .iter()
            .find(|d| d.code == "TP012")
            .unwrap();
        assert_eq!(tp012.severity, crate::check::Severity::Error);
        let span = tp012.span.expect("truncation has an offset");
        let shard_len = std::fs::read(&shard).unwrap().len();
        assert!(span.start <= shard_len);
        let tp016 = rep
            .diagnostics
            .iter()
            .find(|d| d.code == "TP016")
            .unwrap();
        assert!(tp016.message.contains("b.json"), "{}", tp016.message);
        assert!(tp016.message.contains("c.json"), "{}", tp016.message);

        // A record whose shard assignment drifted: TP014 on the shard.
        let stray = root.join(SHARDS_DIR).join("other__9x9.jsonl");
        std::fs::copy(&shard, &stray).unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(
            rep.diagnostics.iter().any(|d| d.code == "TP014"
                && d.path.ends_with("other__9x9.jsonl")
                && d.message.contains("belongs in exp__2x2.jsonl")),
            "{rep:?}"
        );
    }

    #[test]
    fn store_crash_damage_ladder_tp025_tp026() {
        let td = TempDir::new("check-crash").unwrap();
        let root = td.path().join("store");
        let mut s = RunStore::create_or_open(&root).unwrap();
        s.append("exp", "h1", run_metrics("a.json", 2, 1)).unwrap();
        s.append("exp", "h2", run_metrics("b.json", 2, 2)).unwrap();
        s.refresh_indexes().unwrap();
        let shard = root.join(SHARDS_DIR).join("exp__2x2.jsonl");
        let pristine = std::fs::read(&shard).unwrap();

        // Rung 1 — unterminated final record: the crash fell between
        // the payload and its newline (TP025 error, fsck fix-it).
        let mut bytes = pristine.clone();
        assert_eq!(bytes.pop(), Some(b'\n'));
        std::fs::write(&shard, &bytes).unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        let found = codes(&rep);
        assert!(found.contains(&"TP025"), "{rep:?}");
        let d = rep
            .diagnostics
            .iter()
            .find(|d| d.code == "TP025")
            .unwrap();
        assert_eq!(d.severity, crate::check::Severity::Error);
        assert!(d.message.contains("no terminating newline"), "{}", d.message);
        assert!(
            d.hint.as_deref().unwrap_or_default().contains("fsck"),
            "{d:?}"
        );

        // Rung 2 — torn final record: the crash fell mid-payload.
        let mut bytes = pristine.clone();
        bytes.truncate(pristine.len() - 10);
        std::fs::write(&shard, &bytes).unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(
            rep.diagnostics.iter().any(|d| d.code == "TP025"
                && d.message.contains("torn final record")),
            "{rep:?}"
        );
        std::fs::write(&shard, &pristine).unwrap();

        // Rung 3 — empty shard file: residue of an append that died
        // before its first record (TP026 warning).
        let empty = root.join(SHARDS_DIR).join("late__4x4.jsonl");
        std::fs::write(&empty, b"").unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP026"], "{rep:?}");
        assert_eq!(
            rep.diagnostics[0].severity,
            crate::check::Severity::Warning
        );
        assert!(
            rep.diagnostics[0].message.contains("empty shard"),
            "{rep:?}"
        );
        std::fs::remove_file(&empty).unwrap();

        // Rung 4 — a shard the manifest never heard of (the crash hit
        // after the shard append but before the manifest rewrite).
        let extra = root.join(SHARDS_DIR).join("other__2x2.jsonl");
        std::fs::copy(&shard, &extra).unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(
            rep.diagnostics.iter().any(|d| d.code == "TP025"
                && d.message.contains("not listed in the manifest")),
            "{rep:?}"
        );
        std::fs::remove_file(&extra).unwrap();

        // Clean again: every rung healed.
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(rep.diagnostics.is_empty(), "{rep:?}");
    }

    #[test]
    fn store_lock_orphaned_vs_live() {
        let td = TempDir::new("check-lock").unwrap();
        let root = td.path().join("store");
        let mut s = RunStore::create_or_open(&root).unwrap();
        s.append("exp", "h1", run_metrics("a.json", 2, 1)).unwrap();
        s.refresh_indexes().unwrap();

        // No lock: clean store, no diagnostics at all.
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(rep.diagnostics.is_empty(), "{rep:?}");

        // Dead-pid lock: TP019 (warning) naming the pid.
        std::fs::write(
            root.join(LOCK_FILE_NAME),
            "{\"pid\":4000000000,\"timestamp\":1700000000}",
        )
        .unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP019"], "{rep:?}");
        let d = &rep.diagnostics[0];
        assert_eq!(d.severity, crate::check::Severity::Warning);
        assert!(d.message.contains("4000000000"), "{}", d.message);

        // Unparsable lock: also TP019 — garbage must still surface.
        std::fs::write(root.join(LOCK_FILE_NAME), "][ not json").unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP019"], "{rep:?}");

        // A live holder (our own pid) is normal operation: silent.
        std::fs::write(
            root.join(LOCK_FILE_NAME),
            format!(
                "{{\"pid\":{},\"timestamp\":{}}}",
                std::process::id(),
                crate::util::timefmt::now_unix()
            ),
        )
        .unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(rep.diagnostics.is_empty(), "{rep:?}");
    }

    #[test]
    fn store_index_skew_and_dead_ratio_ladder() {
        let td = TempDir::new("check-idx").unwrap();
        let root = td.path().join("store");
        let mut s = RunStore::create_or_open(&root).unwrap();
        s.append("exp", "h1", run_metrics("a.json", 2, 1)).unwrap();
        s.append("exp", "h2", run_metrics("b.json", 2, 2)).unwrap();
        s.refresh_indexes().unwrap();
        // Fresh, valid sidecars: perfectly clean.
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(rep.diagnostics.is_empty(), "{rep:?}");

        let shard = root.join(SHARDS_DIR).join("exp__2x2.jsonl");
        let sidecar = crate::store::sidecar_path(&shard);

        // Rung 1 — stale: the shard grew after the index was built.
        s.append("exp", "h3", run_metrics("c.json", 2, 3)).unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP017"], "{rep:?}");
        let d = &rep.diagnostics[0];
        assert_eq!(d.severity, crate::check::Severity::Warning);
        assert_eq!(d.path, sidecar.display().to_string());
        assert!(d.message.contains("stale"), "{}", d.message);
        s.refresh_indexes().unwrap();

        // Rung 2 — same-size content skew: a hash the freshness check
        // cannot catch, only entry validation can.
        let text = std::fs::read_to_string(&sidecar).unwrap();
        let swapped = text.replacen("h1", "hX", 1);
        assert_ne!(text, swapped, "fixture must actually change");
        std::fs::write(&sidecar, &swapped).unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP017"], "{rep:?}");
        assert!(
            rep.diagnostics[0].message.contains("stale content hash"),
            "{}",
            rep.diagnostics[0].message
        );

        // Rung 3 — mangled sidecar: structurally unparsable.
        std::fs::write(&sidecar, "{\"index_version\": ").unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP017"], "{rep:?}");
        assert!(
            rep.diagnostics[0].message.contains("unparsable"),
            "{}",
            rep.diagnostics[0].message
        );
        std::fs::write(&sidecar, &text).unwrap();

        // Rung 4 — orphan sidecar without a companion shard: residue
        // (TP026), not index skew.
        let ghost =
            root.join(SHARDS_DIR).join("ghost__1x1.jsonl.idx");
        std::fs::write(&ghost, "junk").unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP026"], "{rep:?}");
        assert!(
            rep.diagnostics[0].message.contains("orphan"),
            "{}",
            rep.diagnostics[0].message
        );
        std::fs::remove_file(&ghost).unwrap();

        // Rung 5 — supersede two of five records: 0.40 dead, past the
        // 0.25 threshold (TP018, info, with the compact fix-it).
        s.append("exp", "h4", run_metrics("a.json", 2, 4)).unwrap();
        s.append("exp", "h5", run_metrics("b.json", 2, 5)).unwrap();
        s.refresh_indexes().unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP018"], "{rep:?}");
        let d = &rep.diagnostics[0];
        assert_eq!(d.severity, crate::check::Severity::Info);
        assert_eq!(d.path, shard.display().to_string());
        assert!(d.message.contains("0.40"), "{}", d.message);
        assert!(d.message.contains("0.25"), "{}", d.message);
        assert!(
            d.hint.as_deref().unwrap_or_default().contains("compact"),
            "{d:?}"
        );

        // ... and compaction clears it.
        s.compact().unwrap();
        s.refresh_indexes().unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert!(rep.diagnostics.is_empty(), "{rep:?}");
    }

    #[test]
    fn store_manifest_problems_are_errors() {
        let td = TempDir::new("check-manifest").unwrap();
        let root = td.path().join("plain");
        std::fs::create_dir_all(&root).unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP010"], "no manifest");

        let manifest = root.join(MANIFEST_FILE_NAME);
        std::fs::write(&manifest, "{\"version\": ").unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP010"], "corrupt manifest");
        assert!(rep.diagnostics[0].span.is_some(), "syntax error spans");

        std::fs::write(&manifest, "{}").unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP010"], "version-less manifest");

        std::fs::write(&manifest, "{\"version\": 999}").unwrap();
        let mut rep = CheckReport::new();
        check_store(&root, &mut rep);
        assert_eq!(codes(&rep), ["TP011"]);
        assert!(rep.diagnostics[0].message.contains("999"));
    }

    #[test]
    fn corpus_flags_equal_timestamps_nan_and_negative() {
        let mut r1 = run_metrics("exp/a.json", 2, 100);
        let r2 = run_metrics("exp/b.json", 2, 100); // same effective ts
        let mut r3 = run_metrics("exp/c.json", 2, 200);
        r1.regions[0].metrics.parallel_efficiency = f64::NAN;
        r3.regions[0].metrics.useful_ipc = -0.5;
        let scan = MetricScan {
            experiments: vec![MetricExperiment {
                id: "exp".into(),
                runs: vec![r1, r2, r3],
            }],
            ..Default::default()
        };
        let mut rep = CheckReport::new();
        check_corpus(&scan, &mut rep);
        let mut found = codes(&rep);
        found.sort();
        assert_eq!(found, ["TP050", "TP051", "TP052"], "{rep:?}");
        let tp050 = rep
            .diagnostics
            .iter()
            .find(|d| d.code == "TP050")
            .unwrap();
        // The later history entry (file-name order) carries the flag.
        assert_eq!(tp050.path, "exp/b.json");
        assert!(tp050.message.contains("exp/a.json"));
        let tp051 = rep
            .diagnostics
            .iter()
            .find(|d| d.code == "TP051")
            .unwrap();
        assert!(
            tp051.message.contains("parallel_efficiency is NaN"),
            "{}",
            tp051.message
        );
        // Clean corpus stays clean.
        let clean = MetricScan {
            experiments: vec![MetricExperiment {
                id: "exp".into(),
                runs: vec![
                    run_metrics("exp/a.json", 2, 1),
                    run_metrics("exp/b.json", 2, 2),
                ],
            }],
            ..Default::default()
        };
        let mut rep = CheckReport::new();
        check_corpus(&clean, &mut rep);
        assert!(rep.diagnostics.is_empty(), "{rep:?}");
    }

    #[test]
    fn policy_parse_and_reference_checks() {
        let td = TempDir::new("check-policy").unwrap();
        let good = td.path().join("gate.json");
        std::fs::write(
            &good,
            r#"{"version":1,
                "rules":[{"region":"Global","max_elapsed_increase":0.1},
                         {"region":"nonexistent"}],
                "allow":[{"experiment":"gone*","reason":"r"}]}"#,
        )
        .unwrap();
        let mut rep = CheckReport::new();
        let policy =
            check_policy(&good, &mut rep).expect("valid policy parses");
        assert!(rep.diagnostics.is_empty());

        let scan = MetricScan {
            experiments: vec![MetricExperiment {
                id: "exp".into(),
                runs: vec![run_metrics("exp/a.json", 2, 1)],
            }],
            ..Default::default()
        };
        check_policy_refs(&policy, &good, &scan, &mut rep);
        let mut found = codes(&rep);
        found.sort();
        assert_eq!(found, ["TP040", "TP041"], "{rep:?}");
        assert!(rep.diagnostics.iter().any(|d| d
            .message
            .contains("rules[1]")));

        // Empty corpus: referential checks are skipped entirely.
        let mut rep = CheckReport::new();
        check_policy_refs(
            &policy,
            &good,
            &MetricScan::default(),
            &mut rep,
        );
        assert!(rep.diagnostics.is_empty());

        // A syntactically broken policy: TP003 with a byte span.
        let bad = td.path().join("bad.json");
        std::fs::write(&bad, "{\"version\": 1, ").unwrap();
        let mut rep = CheckReport::new();
        assert!(check_policy(&bad, &mut rep).is_none());
        assert_eq!(codes(&rep), ["TP003"]);
        assert!(rep.diagnostics[0].span.is_some(), "{rep:?}");

        // A semantically broken policy: TP003, no span, parser prefix
        // stripped.
        let typo = td.path().join("typo.json");
        std::fs::write(&typo, r#"{"version":1,"defaults":{"windw":3}}"#)
            .unwrap();
        let mut rep = CheckReport::new();
        assert!(check_policy(&typo, &mut rep).is_none());
        assert_eq!(codes(&rep), ["TP003"]);
        let msg = &rep.diagnostics[0].message;
        assert!(msg.contains("unknown key 'windw'"), "{msg}");
        assert!(
            !msg.contains("policy:"),
            "parser prefix must be stripped: {msg}"
        );
    }

    #[test]
    fn report_schema_skew_vs_shape_errors() {
        let td = TempDir::new("check-report").unwrap();
        let p = td.path().join("report.json");
        std::fs::write(&p, "{\"schema_version\": 999}").unwrap();
        let mut rep = CheckReport::new();
        check_report(&p, &mut rep);
        assert_eq!(codes(&rep), ["TP030"]);
        assert!(rep.diagnostics[0].message.contains("999"));

        std::fs::write(&p, "[1, 2").unwrap();
        let mut rep = CheckReport::new();
        check_report(&p, &mut rep);
        assert_eq!(codes(&rep), ["TP031"]);
        assert!(rep.diagnostics[0].span.is_some(), "{rep:?}");

        let mut rep = CheckReport::new();
        check_report(&td.path().join("gone.json"), &mut rep);
        assert_eq!(codes(&rep), ["TP013"]);
    }

    #[test]
    fn bench_baseline_zero_timings_flagged_unmeasured() {
        let td = TempDir::new("check-bench").unwrap();
        let p = td.path().join("BENCH_hotpaths.json");
        std::fs::write(
            &p,
            "{\"bench\": \"_meta\", \"note\": \"n\"}\n\
             {\"bench\": \"a\", \"cold_s\": 0, \"warm_s\": 0}\n\
             {\"bench\": \"b\", \"load_s\": 0}\n",
        )
        .unwrap();
        let mut rep = CheckReport::new();
        check_bench(&p, &mut rep);
        assert_eq!(codes(&rep), ["TP060"]);
        assert!(rep.diagnostics[0].message.contains("2 bench record(s)"));

        // One real measurement anywhere clears the finding.
        std::fs::write(
            &p,
            "{\"bench\": \"a\", \"cold_s\": 0}\n\
             {\"bench\": \"b\", \"load_s\": 0.25}\n",
        )
        .unwrap();
        let mut rep = CheckReport::new();
        check_bench(&p, &mut rep);
        assert!(rep.diagnostics.is_empty(), "{rep:?}");

        // A corrupt line is a TP001 error with a file-absolute span.
        std::fs::write(&p, "{\"bench\": \"a\"}\n{\"bench\": ][\n").unwrap();
        let mut rep = CheckReport::new();
        check_bench(&p, &mut rep);
        assert_eq!(codes(&rep), ["TP001"]);
        let span = rep.diagnostics[0].span.expect("span");
        assert!(span.start > "{\"bench\": \"a\"}".len(), "{span:?}");
    }
}
