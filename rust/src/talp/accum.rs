//! Per-(region, rank, thread) time/counter accumulators — TALP's core
//! data structure.
//!
//! TALP keeps everything as running sums updated at PMPI/OMPT callback
//! boundaries; nothing is ever buffered or written until finalize.  That
//! is the whole point of the paper: the post-processing cost collapses
//! because the reduction happened during the run.

use crate::sim::PhaseKind;

/// Running timers for one cpu (rank, thread) in one region.  All times
/// in seconds (serialized as integer nanoseconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CpuTimers {
    /// Computation the app wanted to do (includes I/O unless the region
    /// is instrumented — TALP's documented blindness, §Discussion).
    pub useful_s: f64,
    /// Master-thread time inside MPI.
    pub mpi_s: f64,
    /// Worker idle while master is inside MPI.
    pub mpi_worker_idle_s: f64,
    /// Worker idle while master runs serial code.
    pub omp_serialization_s: f64,
    /// OpenMP runtime overhead (fork/join, chunk dispatch).
    pub omp_scheduling_s: f64,
    /// Idle at parallel-region barriers (load imbalance).
    pub omp_barrier_s: f64,
    /// Instructions / cycles retired during useful time.
    pub useful_instructions: u64,
    pub useful_cycles: u64,
}

impl CpuTimers {
    pub fn add_phase(
        &mut self,
        kind: PhaseKind,
        dur_s: f64,
        instructions: u64,
        cycles: u64,
    ) {
        match kind {
            PhaseKind::Useful => {
                self.useful_s += dur_s;
                self.useful_instructions += instructions;
                self.useful_cycles += cycles;
            }
            // TALP cannot see I/O: it lands in useful time with zero
            // retired instructions (skewing IPC — exactly the trap the
            // paper warns about and the reason to instrument IO regions).
            PhaseKind::Io => self.useful_s += dur_s,
            PhaseKind::Mpi => self.mpi_s += dur_s,
            PhaseKind::MpiWorkerIdle => self.mpi_worker_idle_s += dur_s,
            PhaseKind::OmpSerialization => self.omp_serialization_s += dur_s,
            PhaseKind::OmpScheduling => self.omp_scheduling_s += dur_s,
            PhaseKind::OmpBarrier => self.omp_barrier_s += dur_s,
        }
    }

    pub fn merge(&mut self, other: &CpuTimers) {
        self.useful_s += other.useful_s;
        self.mpi_s += other.mpi_s;
        self.mpi_worker_idle_s += other.mpi_worker_idle_s;
        self.omp_serialization_s += other.omp_serialization_s;
        self.omp_scheduling_s += other.omp_scheduling_s;
        self.omp_barrier_s += other.omp_barrier_s;
        self.useful_instructions += other.useful_instructions;
        self.useful_cycles += other.useful_cycles;
    }

    pub fn total_accounted_s(&self) -> f64 {
        self.useful_s
            + self.mpi_s
            + self.mpi_worker_idle_s
            + self.omp_serialization_s
            + self.omp_scheduling_s
            + self.omp_barrier_s
    }
}

/// All cpus of one region: indexed [rank][thread].
#[derive(Debug, Clone, Default)]
pub struct RegionAccum {
    pub cpus: Vec<Vec<CpuTimers>>,
    /// Per-rank region elapsed time (sum over enter/exit visits).
    pub elapsed_per_rank_s: Vec<f64>,
    /// Per-rank currently-open enter timestamp (during the run).
    pub open_since: Vec<Option<f64>>,
    pub visits: u64,
}

impl RegionAccum {
    pub fn new(ranks: usize, threads: usize) -> RegionAccum {
        RegionAccum {
            cpus: vec![vec![CpuTimers::default(); threads]; ranks],
            elapsed_per_rank_s: vec![0.0; ranks],
            open_since: vec![None; ranks],
            visits: 0,
        }
    }

    pub fn is_open(&self, rank: usize) -> bool {
        self.open_since[rank].is_some()
    }

    pub fn enter(&mut self, rank: usize, t: f64) {
        debug_assert!(self.open_since[rank].is_none(), "double enter");
        self.open_since[rank] = Some(t);
        if rank == 0 {
            self.visits += 1;
        }
    }

    pub fn exit(&mut self, rank: usize, t: f64) {
        if let Some(t0) = self.open_since[rank].take() {
            self.elapsed_per_rank_s[rank] += (t - t0).max(0.0);
        }
    }

    /// Region elapsed: max over ranks (global wall inside the region).
    pub fn elapsed_s(&self) -> f64 {
        self.elapsed_per_rank_s
            .iter()
            .cloned()
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_routing() {
        let mut t = CpuTimers::default();
        t.add_phase(PhaseKind::Useful, 1.0, 100, 50);
        t.add_phase(PhaseKind::Mpi, 0.5, 0, 0);
        t.add_phase(PhaseKind::Io, 0.25, 0, 0);
        t.add_phase(PhaseKind::OmpBarrier, 0.125, 0, 0);
        assert_eq!(t.useful_s, 1.25); // io folded into useful
        assert_eq!(t.mpi_s, 0.5);
        assert_eq!(t.omp_barrier_s, 0.125);
        assert_eq!(t.useful_instructions, 100);
        assert!((t.total_accounted_s() - 1.875).abs() < 1e-12);
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = CpuTimers::default();
        a.add_phase(PhaseKind::Useful, 1.0, 10, 5);
        let mut b = CpuTimers::default();
        b.add_phase(PhaseKind::Useful, 2.0, 20, 10);
        b.add_phase(PhaseKind::OmpScheduling, 0.5, 0, 0);
        a.merge(&b);
        assert_eq!(a.useful_s, 3.0);
        assert_eq!(a.useful_instructions, 30);
        assert_eq!(a.omp_scheduling_s, 0.5);
    }

    #[test]
    fn region_elapsed_accumulates_visits() {
        let mut r = RegionAccum::new(2, 1);
        r.enter(0, 0.0);
        r.exit(0, 1.0);
        r.enter(0, 5.0);
        r.exit(0, 7.0);
        r.enter(1, 0.0);
        r.exit(1, 2.5);
        assert_eq!(r.elapsed_per_rank_s[0], 3.0);
        assert_eq!(r.elapsed_per_rank_s[1], 2.5);
        assert_eq!(r.elapsed_s(), 3.0);
        assert_eq!(r.visits, 2);
    }

    #[test]
    fn exit_without_enter_is_ignored() {
        let mut r = RegionAccum::new(1, 1);
        r.exit(0, 3.0);
        assert_eq!(r.elapsed_per_rank_s[0], 0.0);
    }
}
