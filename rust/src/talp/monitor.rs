//! The TALP monitor: an `EventSink` that computes POP raw measurements
//! on the fly (the paper's "TALP module of DLB").
//!
//! Every phase event updates the accumulators of all currently-open
//! regions of that rank (regions nest; `Global` is implicit and always
//! open).  At finalize the monitor freezes into a [`TalpReport`] that
//! serializes to the DLB-style JSON (talp::json).
//!
//! The cost model mirrors DLB TALP 3.5: a shared-memory timer update per
//! phase boundary, a PAPI counter read where hardware counters are
//! collected, and a PMPI wrapper surcharge per MPI call.  No trace bytes
//! are ever written during the run.

use std::collections::HashMap;

use crate::sim::{CostModel, Event, EventSink, RegionMark};

use super::accum::RegionAccum;

/// DLB TALP-like instrumentation costs (seconds).  Calibrated so the
/// Table 1 ranking holds on the paper's TeaLeaf configurations:
/// CPT ~ Score-P < DLB < Extrae, with the OMPT chunk callback + PAPI
/// read being DLB's dominant term.
pub const TALP_COST: CostModel = CostModel {
    per_event_s: 6.0e-7,         // OMPT callback + shmem timer update
    per_counter_read_s: 1.1e-6,  // PAPI read at boundary
    per_region_s: 4.0e-7,        // region API call
    per_mpi_s: 8.0e-7,           // PMPI wrapper
    flush_every_bytes: 0,
    flush_stall_s: 0.0,
    bytes_per_event: 0,
};

/// Live monitor attached to a run.
pub struct TalpMonitor {
    ranks: usize,
    threads: usize,
    /// Region name -> accumulator.  Insertion order preserved for
    /// deterministic JSON output.
    regions: Vec<(String, RegionAccum)>,
    index: HashMap<String, usize>,
    /// Open-region stack per rank (indices into `regions`).
    open: Vec<Vec<usize>>,
    elapsed_s: f64,
    finalized: bool,
}

/// Frozen result of one monitored run.
#[derive(Debug, Clone)]
pub struct TalpReport {
    pub ranks: usize,
    pub threads: usize,
    pub elapsed_s: f64,
    pub regions: Vec<(String, RegionAccum)>,
}

impl TalpMonitor {
    pub fn new(ranks: u32, threads: u32) -> TalpMonitor {
        let mut m = TalpMonitor {
            ranks: ranks as usize,
            threads: threads as usize,
            regions: Vec::new(),
            index: HashMap::new(),
            open: vec![Vec::new(); ranks as usize],
            elapsed_s: 0.0,
            finalized: false,
        };
        // The implicit whole-execution region.
        m.region_id("Global");
        m
    }

    fn region_id(&mut self, name: &str) -> usize {
        if let Some(&i) = self.index.get(name) {
            return i;
        }
        let i = self.regions.len();
        self.regions
            .push((name.to_string(), RegionAccum::new(self.ranks, self.threads)));
        self.index.insert(name.to_string(), i);
        i
    }

    pub fn finalize(self) -> TalpReport {
        assert!(self.finalized, "finalize() before engine on_finalize");
        TalpReport {
            ranks: self.ranks,
            threads: self.threads,
            elapsed_s: self.elapsed_s,
            regions: self.regions,
        }
    }
}

impl EventSink for TalpMonitor {
    fn name(&self) -> &str {
        "talp"
    }

    fn cost_model(&self) -> CostModel {
        TALP_COST
    }

    fn on_event(&mut self, ev: &Event) {
        let rank = ev.rank as usize;
        let thread = ev.thread as usize;
        let dur = (ev.t_end - ev.t_start).max(0.0);
        // Charge the phase to every open region of this rank.
        // (Cloning the open list avoids aliasing regions while mutating.)
        for idx in 0..self.open[rank].len() {
            let region = self.open[rank][idx];
            let acc = &mut self.regions[region].1;
            acc.cpus[rank][thread].add_phase(
                ev.kind,
                dur,
                ev.instructions,
                ev.cycles,
            );
        }
    }

    fn on_region(&mut self, mark: &RegionMark) {
        let rank = mark.rank as usize;
        let idx = self.region_id(&mark.name);
        if mark.enter {
            self.regions[idx].1.enter(rank, mark.t);
            self.open[rank].push(idx);
        } else {
            self.regions[idx].1.exit(rank, mark.t);
            if let Some(pos) =
                self.open[rank].iter().rposition(|&i| i == idx)
            {
                self.open[rank].remove(pos);
            }
        }
    }

    fn on_finalize(&mut self, elapsed: f64) {
        self.elapsed_s = elapsed;
        self.finalized = true;
    }
}

impl TalpReport {
    pub fn region(&self, name: &str) -> Option<&RegionAccum> {
        self.regions
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, a)| a)
    }

    pub fn region_names(&self) -> Vec<&str> {
        self.regions.iter().map(|(n, _)| n.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{
        self, CollKind, Imbalance, MachineSpec, NoiseModel, OmpSchedule,
        Program, ResourceConfig, RunConfig, Step,
    };

    fn tiny_run(ranks: u32, threads: u32) -> TalpReport {
        let mut p = Program::new();
        p.region("initialize", |p| {
            p.push(Step::Serial {
                flops: 1e8,
                working_set_bytes: 1e7,
                rank_weights: vec![1.0],
            });
        });
        p.region("timestep", |p| {
            p.push(Step::Parallel {
                flops: 1e9,
                working_set_bytes: 1e7,
                imbalance: Imbalance::Linear { skew: 0.3 },
                schedule: OmpSchedule::Static,
                rank_weights: vec![1.0],
                insn_factor: 1.0,
            });
            p.push(Step::Collective {
                kind: CollKind::Allreduce,
                bytes_per_rank: 8,
            });
        });
        let cfg = RunConfig::new(
            MachineSpec::marenostrum5(),
            ResourceConfig::new(ranks, threads),
        )
        .with_noise(NoiseModel::none());
        let mut mon = TalpMonitor::new(ranks, threads);
        sim::run(&p, &cfg, &mut [&mut mon]);
        mon.finalize()
    }

    #[test]
    fn captures_global_and_api_regions() {
        let rep = tiny_run(2, 4);
        assert_eq!(rep.region_names(), ["Global", "initialize", "timestep"]);
        assert!(rep.elapsed_s > 0.0);
    }

    #[test]
    fn global_covers_subregions() {
        let rep = tiny_run(2, 4);
        let g = rep.region("Global").unwrap();
        let init = rep.region("initialize").unwrap();
        let ts = rep.region("timestep").unwrap();
        assert!(g.elapsed_s() >= init.elapsed_s() + ts.elapsed_s() - 1e-9);
        // Useful time nests: Global's useful includes both regions'.
        let sum_useful = |a: &RegionAccum| -> f64 {
            a.cpus.iter().flatten().map(|c| c.useful_s).sum()
        };
        assert!(
            sum_useful(g) >= sum_useful(init) + sum_useful(ts) - 1e-9
        );
    }

    #[test]
    fn serial_region_has_serialization_time() {
        let rep = tiny_run(2, 4);
        let init = rep.region("initialize").unwrap();
        // Workers (threads 1..) idled while master computed serially.
        let worker_serial: f64 = init
            .cpus
            .iter()
            .map(|threads| {
                threads[1..].iter().map(|c| c.omp_serialization_s).sum::<f64>()
            })
            .sum();
        assert!(worker_serial > 0.0);
        // Master has no serialization time.
        assert_eq!(init.cpus[0][0].omp_serialization_s, 0.0);
    }

    #[test]
    fn mpi_time_only_in_timestep() {
        let rep = tiny_run(2, 4);
        let init = rep.region("initialize").unwrap();
        let ts = rep.region("timestep").unwrap();
        let mpi = |a: &RegionAccum| -> f64 {
            a.cpus.iter().flatten().map(|c| c.mpi_s).sum()
        };
        assert_eq!(mpi(init), 0.0);
        assert!(mpi(ts) > 0.0);
    }

    #[test]
    fn counters_only_on_useful_time() {
        let rep = tiny_run(2, 4);
        let g = rep.region("Global").unwrap();
        for threads in &g.cpus {
            for c in threads {
                if c.useful_s == 0.0 {
                    assert_eq!(c.useful_instructions, 0);
                }
            }
        }
        let total_insn: u64 = g
            .cpus
            .iter()
            .flatten()
            .map(|c| c.useful_instructions)
            .sum();
        assert!(total_insn > 0);
    }

    #[test]
    fn single_rank_single_thread_accounting_closes() {
        let rep = tiny_run(1, 1);
        let g = rep.region("Global").unwrap();
        let t = &g.cpus[0][0];
        // One cpu: accounted time ~== elapsed (no hidden categories).
        assert!(
            (t.total_accounted_s() - g.elapsed_s()).abs()
                < 0.05 * g.elapsed_s(),
            "accounted {} vs elapsed {}",
            t.total_accounted_s(),
            g.elapsed_s()
        );
    }
}
