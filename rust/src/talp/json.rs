//! The TALP JSON schema (DLB-3.5-flavoured) and its parsed form.
//!
//! One JSON per run.  TALP writes per-process aggregates per region —
//! enough for every factor in the paper's tables — plus run metadata;
//! the `talp metadata` CI wrapper later injects a `git` block (ci::gitmeta).
//!
//! [`RunData`] is the parsed, validated form shared by the POP metric
//! computation (pop::metrics), the folder scanner (pages::scanner) and
//! the time-series builder (pages::timeseries).

use anyhow::{bail, Context, Result};

use crate::sim::{MachineSpec, ResourceConfig};
use crate::util::json::Json;
use crate::util::timefmt;

use super::monitor::TalpReport;

pub const DLB_VERSION: &str = "3.5.0-sim";

const NS: f64 = 1e9;

/// Per-process aggregates for one region.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProcStats {
    pub rank: u32,
    pub node: u32,
    pub elapsed_s: f64,
    pub useful_s: f64,
    pub mpi_s: f64,
    pub mpi_worker_idle_s: f64,
    pub omp_serialization_s: f64,
    pub omp_scheduling_s: f64,
    pub omp_barrier_s: f64,
    pub useful_instructions: u64,
    pub useful_cycles: u64,
}

/// One region's measurements.
#[derive(Debug, Clone, Default)]
pub struct RegionData {
    pub name: String,
    pub elapsed_s: f64,
    pub visits: u64,
    pub procs: Vec<ProcStats>,
}

/// Git metadata injected by the `talp metadata` wrapper (paper Fig. 6).
#[derive(Debug, Clone, PartialEq)]
pub struct GitMeta {
    pub commit: String,
    pub branch: String,
    pub commit_timestamp: i64,
    pub message: String,
}

/// A fully parsed TALP JSON.
#[derive(Debug, Clone)]
pub struct RunData {
    pub dlb_version: String,
    pub app: String,
    pub machine: String,
    /// End-of-execution wall clock (unix seconds).
    pub timestamp: i64,
    pub ranks: u32,
    pub threads: u32,
    pub nodes: u32,
    pub regions: Vec<RegionData>,
    pub git: Option<GitMeta>,
}

impl RunData {
    /// Build from a finished monitor plus run context.
    pub fn from_report(
        report: &TalpReport,
        app: &str,
        machine: &MachineSpec,
        resources: &ResourceConfig,
        timestamp: i64,
    ) -> RunData {
        let regions = report
            .regions
            .iter()
            .map(|(name, acc)| {
                let procs = (0..report.ranks)
                    .map(|r| {
                        let mut p = ProcStats {
                            rank: r as u32,
                            node: resources.node_of_rank(r as u32, machine),
                            elapsed_s: acc.elapsed_per_rank_s[r],
                            ..Default::default()
                        };
                        for c in &acc.cpus[r] {
                            p.useful_s += c.useful_s;
                            p.mpi_s += c.mpi_s;
                            p.mpi_worker_idle_s += c.mpi_worker_idle_s;
                            p.omp_serialization_s += c.omp_serialization_s;
                            p.omp_scheduling_s += c.omp_scheduling_s;
                            p.omp_barrier_s += c.omp_barrier_s;
                            p.useful_instructions += c.useful_instructions;
                            p.useful_cycles += c.useful_cycles;
                        }
                        p
                    })
                    .collect();
                RegionData {
                    name: name.clone(),
                    elapsed_s: acc.elapsed_s(),
                    visits: acc.visits,
                    procs,
                }
            })
            .collect();
        RunData {
            dlb_version: DLB_VERSION.to_string(),
            app: app.to_string(),
            machine: machine.name.clone(),
            timestamp,
            ranks: report.ranks as u32,
            threads: report.threads as u32,
            nodes: resources.nodes_used(machine),
            regions,
            git: None,
        }
    }

    pub fn resources(&self) -> ResourceConfig {
        ResourceConfig::new(self.ranks, self.threads)
    }

    pub fn region(&self, name: &str) -> Option<&RegionData> {
        self.regions.iter().find(|r| r.name == name)
    }

    /// The timestamp TALP-Pages plots against: git commit time when the
    /// metadata wrapper ran, execution end time otherwise (paper
    /// §Time-evolution plots).
    pub fn effective_timestamp(&self) -> i64 {
        self.git
            .as_ref()
            .map(|g| g.commit_timestamp)
            .unwrap_or(self.timestamp)
    }

    // ---------- JSON ----------

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("dlb_version", Json::Str(self.dlb_version.clone()));
        root.set("app", Json::Str(self.app.clone()));
        root.set("machine", Json::Str(self.machine.clone()));
        root.set(
            "timestamp",
            Json::Str(timefmt::to_iso8601(self.timestamp)),
        );
        root.set(
            "resources",
            Json::from_pairs(vec![
                ("num_mpi_ranks", Json::Num(self.ranks as f64)),
                ("num_omp_threads", Json::Num(self.threads as f64)),
                (
                    "num_cpus",
                    Json::Num((self.ranks * self.threads) as f64),
                ),
                ("num_nodes", Json::Num(self.nodes as f64)),
            ]),
        );
        let mut regions = Json::obj();
        for reg in &self.regions {
            let procs: Vec<Json> = reg
                .procs
                .iter()
                .map(|p| {
                    Json::from_pairs(vec![
                        ("rank", Json::Num(p.rank as f64)),
                        ("node", Json::Num(p.node as f64)),
                        ("elapsed_time_ns", ns(p.elapsed_s)),
                        ("useful_time_ns", ns(p.useful_s)),
                        ("mpi_time_ns", ns(p.mpi_s)),
                        ("mpi_worker_idle_time_ns", ns(p.mpi_worker_idle_s)),
                        (
                            "omp_serialization_time_ns",
                            ns(p.omp_serialization_s),
                        ),
                        ("omp_scheduling_time_ns", ns(p.omp_scheduling_s)),
                        ("omp_load_balance_time_ns", ns(p.omp_barrier_s)),
                        (
                            "useful_instructions",
                            Json::Num(p.useful_instructions as f64),
                        ),
                        ("useful_cycles", Json::Num(p.useful_cycles as f64)),
                    ])
                })
                .collect();
            regions.set(
                &reg.name,
                Json::from_pairs(vec![
                    ("elapsed_time_ns", ns(reg.elapsed_s)),
                    ("visits", Json::Num(reg.visits as f64)),
                    ("processes", Json::Arr(procs)),
                ]),
            );
        }
        root.set("regions", regions);
        if let Some(g) = &self.git {
            root.set(
                "git",
                Json::from_pairs(vec![
                    ("commit", Json::Str(g.commit.clone())),
                    ("branch", Json::Str(g.branch.clone())),
                    (
                        "commit_timestamp",
                        Json::Str(timefmt::to_iso8601(g.commit_timestamp)),
                    ),
                    ("message", Json::Str(g.message.clone())),
                ]),
            );
        }
        root
    }

    pub fn from_json(j: &Json) -> Result<RunData> {
        let res = j.get("resources").context("missing resources")?;
        let ranks = res
            .get("num_mpi_ranks")
            .and_then(Json::as_u64)
            .context("missing num_mpi_ranks")? as u32;
        let threads = res
            .get("num_omp_threads")
            .and_then(Json::as_u64)
            .context("missing num_omp_threads")? as u32;
        if ranks == 0 || threads == 0 {
            bail!("resources must be positive ({ranks}x{threads})");
        }
        let nodes =
            res.get("num_nodes").and_then(Json::as_u64).unwrap_or(1) as u32;
        let timestamp = j
            .get("timestamp")
            .and_then(Json::as_str)
            .and_then(timefmt::from_iso8601)
            .context("missing/bad timestamp")?;
        let mut regions = Vec::new();
        let regs = j
            .get("regions")
            .and_then(Json::as_obj)
            .context("missing regions")?;
        for (name, rj) in regs {
            let mut procs = Vec::new();
            for pj in rj
                .get("processes")
                .and_then(Json::as_arr)
                .context("missing processes")?
            {
                procs.push(ProcStats {
                    rank: pj.num_or("rank", 0.0) as u32,
                    node: pj.num_or("node", 0.0) as u32,
                    elapsed_s: pj.num_or("elapsed_time_ns", 0.0) / NS,
                    useful_s: pj.num_or("useful_time_ns", 0.0) / NS,
                    mpi_s: pj.num_or("mpi_time_ns", 0.0) / NS,
                    mpi_worker_idle_s: pj
                        .num_or("mpi_worker_idle_time_ns", 0.0)
                        / NS,
                    omp_serialization_s: pj
                        .num_or("omp_serialization_time_ns", 0.0)
                        / NS,
                    omp_scheduling_s: pj
                        .num_or("omp_scheduling_time_ns", 0.0)
                        / NS,
                    omp_barrier_s: pj.num_or("omp_load_balance_time_ns", 0.0)
                        / NS,
                    useful_instructions: pj
                        .get("useful_instructions")
                        .and_then(Json::as_u64)
                        .unwrap_or(0),
                    useful_cycles: pj
                        .get("useful_cycles")
                        .and_then(Json::as_u64)
                        .unwrap_or(0),
                });
            }
            if procs.len() != ranks as usize {
                bail!(
                    "region '{name}': {} processes for {ranks} ranks",
                    procs.len()
                );
            }
            regions.push(RegionData {
                name: name.clone(),
                elapsed_s: rj.num_or("elapsed_time_ns", 0.0) / NS,
                visits: rj.get("visits").and_then(Json::as_u64).unwrap_or(1),
                procs,
            });
        }
        if regions.is_empty() {
            bail!("no regions in TALP json");
        }
        let git = j.get("git").map(|g| GitMeta {
            commit: g.str_or("commit", "").to_string(),
            branch: g.str_or("branch", "").to_string(),
            commit_timestamp: g
                .get("commit_timestamp")
                .and_then(Json::as_str)
                .and_then(timefmt::from_iso8601)
                .unwrap_or(timestamp),
            message: g.str_or("message", "").to_string(),
        });
        Ok(RunData {
            dlb_version: j.str_or("dlb_version", "unknown").to_string(),
            app: j.str_or("app", "unknown").to_string(),
            machine: j.str_or("machine", "unknown").to_string(),
            timestamp,
            ranks,
            threads,
            nodes,
            regions,
            git,
        })
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Parse artifact text, attributing errors to `path`.  The single
    /// parse pipeline shared by [`RunData::read_file`] and the report
    /// engine's cached scan (which reads raw bytes itself to hash them).
    pub fn parse_str(text: &str, path: &std::path::Path) -> Result<RunData> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        RunData::from_json(&j)
            .with_context(|| format!("parsing {}", path.display()))
    }

    pub fn read_file(path: &std::path::Path) -> Result<RunData> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        RunData::parse_str(&text, path)
    }
}

fn ns(secs: f64) -> Json {
    Json::Num((secs * NS).round())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunData {
        RunData {
            dlb_version: DLB_VERSION.into(),
            app: "tealeaf".into(),
            machine: "mn5".into(),
            timestamp: 1_721_046_896,
            ranks: 2,
            threads: 4,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 10.0,
                visits: 1,
                procs: vec![
                    ProcStats {
                        rank: 0,
                        node: 0,
                        elapsed_s: 10.0,
                        useful_s: 36.0,
                        mpi_s: 1.0,
                        mpi_worker_idle_s: 3.0,
                        omp_serialization_s: 0.5,
                        omp_scheduling_s: 0.2,
                        omp_barrier_s: 0.3,
                        useful_instructions: 1_000_000,
                        useful_cycles: 500_000,
                    },
                    ProcStats {
                        rank: 1,
                        node: 0,
                        elapsed_s: 10.0,
                        useful_s: 34.0,
                        mpi_s: 2.0,
                        mpi_worker_idle_s: 6.0,
                        omp_serialization_s: 0.7,
                        omp_scheduling_s: 0.4,
                        omp_barrier_s: 0.9,
                        useful_instructions: 900_000,
                        useful_cycles: 450_000,
                    },
                ],
            }],
            git: Some(GitMeta {
                commit: "9dc04ca0".into(),
                branch: "main".into(),
                commit_timestamp: 1_721_000_000,
                message: "fix scaling bug".into(),
            }),
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let r = sample();
        let j = r.to_json();
        let back = RunData::from_json(&j).unwrap();
        assert_eq!(back.app, "tealeaf");
        assert_eq!(back.ranks, 2);
        assert_eq!(back.threads, 4);
        assert_eq!(back.timestamp, r.timestamp);
        let g = back.region("Global").unwrap();
        assert_eq!(g.procs.len(), 2);
        assert!((g.procs[1].useful_s - 34.0).abs() < 1e-6);
        assert_eq!(g.procs[0].useful_instructions, 1_000_000);
        let git = back.git.unwrap();
        assert_eq!(git.commit, "9dc04ca0");
        assert_eq!(git.commit_timestamp, 1_721_000_000);
    }

    #[test]
    fn effective_timestamp_prefers_git() {
        let mut r = sample();
        assert_eq!(r.effective_timestamp(), 1_721_000_000);
        r.git = None;
        assert_eq!(r.effective_timestamp(), 1_721_046_896);
    }

    #[test]
    fn file_roundtrip() {
        let td = crate::util::fs::TempDir::new("talpjson").unwrap();
        let path = td.path().join("sub/talp_2x4.json");
        let r = sample();
        r.write_file(&path).unwrap();
        let back = RunData::read_file(&path).unwrap();
        assert_eq!(back.resources().label(), "2x4");
    }

    #[test]
    fn rejects_malformed() {
        for text in [
            "{}",
            r#"{"resources":{"num_mpi_ranks":0,"num_omp_threads":1}}"#,
            r#"{"resources":{"num_mpi_ranks":1,"num_omp_threads":1},
                "timestamp":"2024-01-01T00:00:00Z","regions":{}}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(RunData::from_json(&j).is_err(), "{text}");
        }
    }

    #[test]
    fn rejects_process_count_mismatch() {
        let mut r = sample();
        r.regions[0].procs.pop();
        let j = r.to_json();
        assert!(RunData::from_json(&j).is_err());
    }
}
