//! The TALP JSON schema (DLB-3.5-flavoured) and its parsed form.
//!
//! One JSON per run.  TALP writes per-process aggregates per region —
//! enough for every factor in the paper's tables — plus run metadata;
//! the `talp metadata` CI wrapper later injects a `git` block (ci::gitmeta).
//!
//! [`RunData`] is the parsed, validated form shared by the POP metric
//! computation (pop::metrics), the folder scanner (pages::scanner) and
//! the time-series builder (pages::timeseries).

use anyhow::{bail, Context, Result};

use crate::sim::{MachineSpec, ResourceConfig};
use crate::util::json::{Event, FieldCursor, Json, JsonReader, JsonWriter};
use crate::util::timefmt;

use super::monitor::TalpReport;

pub const DLB_VERSION: &str = "3.5.0-sim";

const NS: f64 = 1e9;

/// Per-process aggregates for one region.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProcStats {
    pub rank: u32,
    pub node: u32,
    pub elapsed_s: f64,
    pub useful_s: f64,
    pub mpi_s: f64,
    pub mpi_worker_idle_s: f64,
    pub omp_serialization_s: f64,
    pub omp_scheduling_s: f64,
    pub omp_barrier_s: f64,
    pub useful_instructions: u64,
    pub useful_cycles: u64,
}

/// One region's measurements.
#[derive(Debug, Clone, Default)]
pub struct RegionData {
    pub name: String,
    pub elapsed_s: f64,
    pub visits: u64,
    pub procs: Vec<ProcStats>,
}

/// Git metadata injected by the `talp metadata` wrapper (paper Fig. 6).
#[derive(Debug, Clone, PartialEq)]
pub struct GitMeta {
    pub commit: String,
    pub branch: String,
    pub commit_timestamp: i64,
    pub message: String,
}

/// A fully parsed TALP JSON.
#[derive(Debug, Clone)]
pub struct RunData {
    pub dlb_version: String,
    pub app: String,
    pub machine: String,
    /// End-of-execution wall clock (unix seconds).
    pub timestamp: i64,
    pub ranks: u32,
    pub threads: u32,
    pub nodes: u32,
    pub regions: Vec<RegionData>,
    pub git: Option<GitMeta>,
}

impl RunData {
    /// Build from a finished monitor plus run context.
    pub fn from_report(
        report: &TalpReport,
        app: &str,
        machine: &MachineSpec,
        resources: &ResourceConfig,
        timestamp: i64,
    ) -> RunData {
        let regions = report
            .regions
            .iter()
            .map(|(name, acc)| {
                let procs = (0..report.ranks)
                    .map(|r| {
                        let mut p = ProcStats {
                            rank: r as u32,
                            node: resources.node_of_rank(r as u32, machine),
                            elapsed_s: acc.elapsed_per_rank_s[r],
                            ..Default::default()
                        };
                        for c in &acc.cpus[r] {
                            p.useful_s += c.useful_s;
                            p.mpi_s += c.mpi_s;
                            p.mpi_worker_idle_s += c.mpi_worker_idle_s;
                            p.omp_serialization_s += c.omp_serialization_s;
                            p.omp_scheduling_s += c.omp_scheduling_s;
                            p.omp_barrier_s += c.omp_barrier_s;
                            p.useful_instructions += c.useful_instructions;
                            p.useful_cycles += c.useful_cycles;
                        }
                        p
                    })
                    .collect();
                RegionData {
                    name: name.clone(),
                    elapsed_s: acc.elapsed_s(),
                    visits: acc.visits,
                    procs,
                }
            })
            .collect();
        RunData {
            dlb_version: DLB_VERSION.to_string(),
            app: app.to_string(),
            machine: machine.name.clone(),
            timestamp,
            ranks: report.ranks as u32,
            threads: report.threads as u32,
            nodes: resources.nodes_used(machine),
            regions,
            git: None,
        }
    }

    pub fn resources(&self) -> ResourceConfig {
        ResourceConfig::new(self.ranks, self.threads)
    }

    pub fn region(&self, name: &str) -> Option<&RegionData> {
        self.regions.iter().find(|r| r.name == name)
    }

    /// The timestamp TALP-Pages plots against: git commit time when the
    /// metadata wrapper ran, execution end time otherwise (paper
    /// §Time-evolution plots).
    pub fn effective_timestamp(&self) -> i64 {
        self.git
            .as_ref()
            .map(|g| g.commit_timestamp)
            .unwrap_or(self.timestamp)
    }

    // ---------- JSON ----------
    //
    // Two symmetric codecs share the schema:
    // * the tree pair `to_json`/`from_json` (tests, tools, callers
    //   that already hold a `Json`);
    // * the streaming pair `write_to`/`from_slice` — the hot path for
    //   the scanner and store ingest, which decode straight from the
    //   artifact bytes and encode straight into the output buffer
    //   without materializing a tree.
    // `streaming_encoder_matches_tree` / `from_slice_matches_from_json`
    // below pin the two pairs byte/semantics-identical.

    /// Serialize into `w` (the exact document `to_json` builds).
    pub fn write_to(&self, w: &mut JsonWriter) {
        w.begin_obj();
        w.key("dlb_version");
        w.str_val(&self.dlb_version);
        w.key("app");
        w.str_val(&self.app);
        w.key("machine");
        w.str_val(&self.machine);
        w.key("timestamp");
        w.str_val(&timefmt::to_iso8601(self.timestamp));
        w.key("resources");
        w.begin_obj();
        w.key("num_mpi_ranks");
        w.num(self.ranks as f64);
        w.key("num_omp_threads");
        w.num(self.threads as f64);
        w.key("num_cpus");
        w.num((self.ranks * self.threads) as f64);
        w.key("num_nodes");
        w.num(self.nodes as f64);
        w.end_obj();
        w.key("regions");
        w.begin_obj();
        for reg in &self.regions {
            w.key(&reg.name);
            w.begin_obj();
            w.key("elapsed_time_ns");
            w.num(ns_f(reg.elapsed_s));
            w.key("visits");
            w.num(reg.visits as f64);
            w.key("processes");
            w.begin_arr();
            for p in &reg.procs {
                w.begin_obj();
                w.key("rank");
                w.num(p.rank as f64);
                w.key("node");
                w.num(p.node as f64);
                w.key("elapsed_time_ns");
                w.num(ns_f(p.elapsed_s));
                w.key("useful_time_ns");
                w.num(ns_f(p.useful_s));
                w.key("mpi_time_ns");
                w.num(ns_f(p.mpi_s));
                w.key("mpi_worker_idle_time_ns");
                w.num(ns_f(p.mpi_worker_idle_s));
                w.key("omp_serialization_time_ns");
                w.num(ns_f(p.omp_serialization_s));
                w.key("omp_scheduling_time_ns");
                w.num(ns_f(p.omp_scheduling_s));
                w.key("omp_load_balance_time_ns");
                w.num(ns_f(p.omp_barrier_s));
                w.key("useful_instructions");
                w.num(p.useful_instructions as f64);
                w.key("useful_cycles");
                w.num(p.useful_cycles as f64);
                w.end_obj();
            }
            w.end_arr();
            w.end_obj();
        }
        w.end_obj();
        if let Some(g) = &self.git {
            w.key("git");
            w.begin_obj();
            w.key("commit");
            w.str_val(&g.commit);
            w.key("branch");
            w.str_val(&g.branch);
            w.key("commit_timestamp");
            w.str_val(&timefmt::to_iso8601(g.commit_timestamp));
            w.key("message");
            w.str_val(&g.message);
            w.end_obj();
        }
        w.end_obj();
    }

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.push_field("dlb_version", Json::Str(self.dlb_version.clone()));
        root.push_field("app", Json::Str(self.app.clone()));
        root.push_field("machine", Json::Str(self.machine.clone()));
        root.push_field(
            "timestamp",
            Json::Str(timefmt::to_iso8601(self.timestamp)),
        );
        root.push_field(
            "resources",
            Json::from_pairs(vec![
                ("num_mpi_ranks", Json::Num(self.ranks as f64)),
                ("num_omp_threads", Json::Num(self.threads as f64)),
                (
                    "num_cpus",
                    Json::Num((self.ranks * self.threads) as f64),
                ),
                ("num_nodes", Json::Num(self.nodes as f64)),
            ]),
        );
        let mut regions = Json::obj();
        for reg in &self.regions {
            let procs: Vec<Json> = reg
                .procs
                .iter()
                .map(|p| {
                    Json::from_pairs(vec![
                        ("rank", Json::Num(p.rank as f64)),
                        ("node", Json::Num(p.node as f64)),
                        ("elapsed_time_ns", ns(p.elapsed_s)),
                        ("useful_time_ns", ns(p.useful_s)),
                        ("mpi_time_ns", ns(p.mpi_s)),
                        ("mpi_worker_idle_time_ns", ns(p.mpi_worker_idle_s)),
                        (
                            "omp_serialization_time_ns",
                            ns(p.omp_serialization_s),
                        ),
                        ("omp_scheduling_time_ns", ns(p.omp_scheduling_s)),
                        ("omp_load_balance_time_ns", ns(p.omp_barrier_s)),
                        (
                            "useful_instructions",
                            Json::Num(p.useful_instructions as f64),
                        ),
                        ("useful_cycles", Json::Num(p.useful_cycles as f64)),
                    ])
                })
                .collect();
            regions.set(
                &reg.name,
                Json::from_pairs(vec![
                    ("elapsed_time_ns", ns(reg.elapsed_s)),
                    ("visits", Json::Num(reg.visits as f64)),
                    ("processes", Json::Arr(procs)),
                ]),
            );
        }
        root.push_field("regions", regions);
        if let Some(g) = &self.git {
            root.push_field(
                "git",
                Json::from_pairs(vec![
                    ("commit", Json::Str(g.commit.clone())),
                    ("branch", Json::Str(g.branch.clone())),
                    (
                        "commit_timestamp",
                        Json::Str(timefmt::to_iso8601(g.commit_timestamp)),
                    ),
                    ("message", Json::Str(g.message.clone())),
                ]),
            );
        }
        root
    }

    pub fn from_json(j: &Json) -> Result<RunData> {
        let res = j.get("resources").context("missing resources")?;
        let ranks = res
            .get("num_mpi_ranks")
            .and_then(Json::as_u64)
            .context("missing num_mpi_ranks")? as u32;
        let threads = res
            .get("num_omp_threads")
            .and_then(Json::as_u64)
            .context("missing num_omp_threads")? as u32;
        if ranks == 0 || threads == 0 {
            bail!("resources must be positive ({ranks}x{threads})");
        }
        let nodes =
            res.get("num_nodes").and_then(Json::as_u64).unwrap_or(1) as u32;
        let timestamp = j
            .get("timestamp")
            .and_then(Json::as_str)
            .and_then(timefmt::from_iso8601)
            .context("missing/bad timestamp")?;
        let mut regions = Vec::new();
        let regs = j
            .get("regions")
            .and_then(Json::as_obj)
            .context("missing regions")?;
        for (name, rj) in regs {
            let mut procs = Vec::new();
            for pj in rj
                .get("processes")
                .and_then(Json::as_arr)
                .context("missing processes")?
            {
                // Fields arrive in serialization order, so the cursor
                // memo turns eleven O(n) scans per process into one
                // comparison each.
                let mut pc = FieldCursor::new(pj);
                procs.push(ProcStats {
                    rank: pc.num_or("rank", 0.0) as u32,
                    node: pc.num_or("node", 0.0) as u32,
                    elapsed_s: pc.num_or("elapsed_time_ns", 0.0) / NS,
                    useful_s: pc.num_or("useful_time_ns", 0.0) / NS,
                    mpi_s: pc.num_or("mpi_time_ns", 0.0) / NS,
                    mpi_worker_idle_s: pc
                        .num_or("mpi_worker_idle_time_ns", 0.0)
                        / NS,
                    omp_serialization_s: pc
                        .num_or("omp_serialization_time_ns", 0.0)
                        / NS,
                    omp_scheduling_s: pc
                        .num_or("omp_scheduling_time_ns", 0.0)
                        / NS,
                    omp_barrier_s: pc.num_or("omp_load_balance_time_ns", 0.0)
                        / NS,
                    useful_instructions: pc.u64_or("useful_instructions", 0),
                    useful_cycles: pc.u64_or("useful_cycles", 0),
                });
            }
            if procs.len() != ranks as usize {
                bail!(
                    "region '{name}': {} processes for {ranks} ranks",
                    procs.len()
                );
            }
            regions.push(RegionData {
                name: name.clone(),
                elapsed_s: rj.num_or("elapsed_time_ns", 0.0) / NS,
                visits: rj.get("visits").and_then(Json::as_u64).unwrap_or(1),
                procs,
            });
        }
        if regions.is_empty() {
            bail!("no regions in TALP json");
        }
        let git = j.get("git").map(|g| GitMeta {
            commit: g.str_or("commit", "").to_string(),
            branch: g.str_or("branch", "").to_string(),
            commit_timestamp: g
                .get("commit_timestamp")
                .and_then(Json::as_str)
                .and_then(timefmt::from_iso8601)
                .unwrap_or(timestamp),
            message: g.str_or("message", "").to_string(),
        });
        Ok(RunData {
            dlb_version: j.str_or("dlb_version", "unknown").to_string(),
            app: j.str_or("app", "unknown").to_string(),
            machine: j.str_or("machine", "unknown").to_string(),
            timestamp,
            ranks,
            threads,
            nodes,
            regions,
            git,
        })
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        // Pre-size roughly: ~470 pretty-printed bytes per process plus
        // headroom for metadata — avoids re-allocation churn while the
        // document streams into the buffer.
        let procs: usize = self.regions.iter().map(|r| r.procs.len()).sum();
        let mut w = JsonWriter::with_capacity(1024 + procs * 470, true);
        self.write_to(&mut w);
        w.newline();
        std::fs::write(path, w.into_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Parse artifact text, attributing errors to `path` (kept for
    /// callers that already hold a `&str`; byte-level callers use the
    /// faster [`RunData::from_slice`]).
    pub fn parse_str(text: &str, path: &std::path::Path) -> Result<RunData> {
        RunData::from_slice(text.as_bytes(), path)
    }

    /// Decode a TALP artifact straight from its raw bytes through the
    /// streaming event reader: no `Json` tree is built, strings with
    /// no escapes are borrowed rather than copied, and UTF-8 is
    /// validated only inside string literals — so the scanner and
    /// store ingest skip the whole-buffer `String::from_utf8` pass.
    /// Accepts and rejects the same documents as `Json::parse` +
    /// [`RunData::from_json`], including first-occurrence-wins for
    /// duplicated top-level keys (the one duplicate-key case where the
    /// outcome could differ structurally; no TALP producer emits
    /// duplicate keys at all).
    pub fn from_slice(bytes: &[u8], path: &std::path::Path) -> Result<RunData> {
        decode_run(bytes)
            .with_context(|| format!("parsing {}", path.display()))
    }

    pub fn read_file(path: &std::path::Path) -> Result<RunData> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        RunData::from_slice(&bytes, path)
    }
}

/// One region mid-decode: `procs` stays `None` until a `processes`
/// array is seen, so the "missing processes" check can run after the
/// whole document is read (field order is arbitrary).
struct PendingRegion {
    name: String,
    elapsed_s: f64,
    visits: u64,
    procs: Option<Vec<ProcStats>>,
}

/// Single-pass event decode of a TALP artifact.  Validation that
/// spans fields (process count vs ranks, git timestamp fallback) is
/// deferred to the end so key order never matters — the tree decoder
/// is order-insensitive and this one must match it.
fn decode_run(bytes: &[u8]) -> Result<RunData> {
    let mut r = JsonReader::new(bytes);
    match r.next()? {
        Event::ObjStart => {}
        _ => bail!("TALP json root is not an object"),
    }
    let mut dlb_version: Option<String> = None;
    let mut app: Option<String> = None;
    let mut machine: Option<String> = None;
    let mut saw_timestamp = false;
    let mut timestamp: Option<i64> = None;
    let mut saw_resources = false;
    let mut ranks: Option<u64> = None;
    let mut threads: Option<u64> = None;
    let mut nodes: u64 = 1;
    let mut saw_regions = false;
    let mut pending: Vec<PendingRegion> = Vec::new();
    // (commit, branch, commit_timestamp, message): present iff a `git`
    // key was seen, timestamp resolved after the full pass.
    let mut saw_git = false;
    let mut git: Option<(String, String, Option<i64>, String)> = None;
    loop {
        match r.next()? {
            Event::ObjEnd => break,
            Event::Key(k) => match k.as_ref() {
                // Duplicate top-level keys: `Json::get` takes the
                // first occurrence, so the single-pass decoder must
                // too — a failed guard falls through to the final
                // `skip_value` arm.  The structural fields use `saw_*`
                // flags so even a mistyped first occurrence claims the
                // key; the three metadata strings settle for
                // `is_none`, whose only divergence (mistyped first +
                // valid second) still decodes a valid run.
                "dlb_version" if dlb_version.is_none() => {
                    dlb_version = r.str_opt()?.map(|s| s.into_owned())
                }
                "app" if app.is_none() => {
                    app = r.str_opt()?.map(|s| s.into_owned())
                }
                "machine" if machine.is_none() => {
                    machine = r.str_opt()?.map(|s| s.into_owned())
                }
                "timestamp" if !saw_timestamp => {
                    saw_timestamp = true;
                    timestamp = r
                        .str_opt()?
                        .as_deref()
                        .and_then(timefmt::from_iso8601);
                }
                "resources" if !saw_resources => {
                    saw_resources = true;
                    match r.next()? {
                        Event::ObjStart => loop {
                            match r.next()? {
                                Event::ObjEnd => break,
                                Event::Key(rk) => match rk.as_ref() {
                                    "num_mpi_ranks" => ranks = r.u64_opt()?,
                                    "num_omp_threads" => {
                                        threads = r.u64_opt()?
                                    }
                                    "num_nodes" => {
                                        nodes = r.u64_opt()?.unwrap_or(1)
                                    }
                                    _ => r.skip_value()?,
                                },
                                _ => unreachable!("object events"),
                            }
                        },
                        Event::ArrStart => r.skip_value_rest()?,
                        _ => {}
                    }
                }
                "regions" if !saw_regions => {
                    saw_regions = true;
                    match r.next()? {
                        Event::ObjStart => loop {
                            match r.next()? {
                                Event::ObjEnd => break,
                                Event::Key(name) => {
                                    let name = name.into_owned();
                                    pending
                                        .push(decode_region(&mut r, name)?);
                                }
                                _ => unreachable!("object events"),
                            }
                        },
                        Event::ArrStart => r.skip_value_rest()?,
                        _ => {}
                    }
                }
                "git" if !saw_git => {
                    saw_git = true;
                    match r.next()? {
                        Event::ObjStart => {
                            let mut commit = String::new();
                            let mut branch = String::new();
                            let mut ts: Option<i64> = None;
                            let mut message = String::new();
                            loop {
                                match r.next()? {
                                    Event::ObjEnd => break,
                                    Event::Key(gk) => match gk.as_ref() {
                                        "commit" => {
                                            commit = r
                                                .str_opt()?
                                                .map(|s| s.into_owned())
                                                .unwrap_or_default()
                                        }
                                        "branch" => {
                                            branch = r
                                                .str_opt()?
                                                .map(|s| s.into_owned())
                                                .unwrap_or_default()
                                        }
                                        "commit_timestamp" => {
                                            ts = r
                                                .str_opt()?
                                                .as_deref()
                                                .and_then(timefmt::from_iso8601);
                                        }
                                        "message" => {
                                            message = r
                                                .str_opt()?
                                                .map(|s| s.into_owned())
                                                .unwrap_or_default()
                                        }
                                        _ => r.skip_value()?,
                                    },
                                    _ => unreachable!("object events"),
                                }
                            }
                            git = Some((commit, branch, ts, message));
                        }
                        // Any non-object `git` value mirrors the tree
                        // decoder: a defaulted GitMeta, never an error.
                        Event::ArrStart => {
                            r.skip_value_rest()?;
                            git = Some(Default::default());
                        }
                        _ => git = Some(Default::default()),
                    }
                }
                _ => r.skip_value()?,
            },
            _ => unreachable!("object events"),
        }
    }
    r.finish()?;

    // Cross-field validation, in the tree decoder's order.
    if !saw_resources {
        bail!("missing resources");
    }
    let ranks = ranks.context("missing num_mpi_ranks")? as u32;
    let threads = threads.context("missing num_omp_threads")? as u32;
    if ranks == 0 || threads == 0 {
        bail!("resources must be positive ({ranks}x{threads})");
    }
    let timestamp = timestamp.context("missing/bad timestamp")?;
    let mut regions = Vec::with_capacity(pending.len());
    for reg in pending {
        let PendingRegion { name, elapsed_s, visits, procs } = reg;
        let procs = procs
            .with_context(|| format!("region '{name}': missing processes"))?;
        if procs.len() != ranks as usize {
            bail!(
                "region '{name}': {} processes for {ranks} ranks",
                procs.len()
            );
        }
        regions.push(RegionData { name, elapsed_s, visits, procs });
    }
    if regions.is_empty() {
        bail!("no regions in TALP json");
    }
    let git = git.map(|(commit, branch, ts, message)| GitMeta {
        commit,
        branch,
        commit_timestamp: ts.unwrap_or(timestamp),
        message,
    });
    Ok(RunData {
        dlb_version: dlb_version.unwrap_or_else(|| "unknown".to_string()),
        app: app.unwrap_or_else(|| "unknown".to_string()),
        machine: machine.unwrap_or_else(|| "unknown".to_string()),
        timestamp,
        ranks,
        threads,
        nodes: nodes as u32,
        regions,
        git,
    })
}

/// Decode one region's value (the reader sits right after its key).
fn decode_region(r: &mut JsonReader<'_>, name: String) -> Result<PendingRegion> {
    let mut reg = PendingRegion { name, elapsed_s: 0.0, visits: 1, procs: None };
    match r.next()? {
        Event::ObjStart => {}
        Event::ArrStart => {
            r.skip_value_rest()?;
            return Ok(reg);
        }
        // A scalar region value has no processes — caught at the end.
        _ => return Ok(reg),
    }
    loop {
        match r.next()? {
            Event::ObjEnd => break,
            Event::Key(k) => match k.as_ref() {
                "elapsed_time_ns" => {
                    reg.elapsed_s = r.f64_opt()?.unwrap_or(0.0) / NS
                }
                "visits" => reg.visits = r.u64_opt()?.unwrap_or(1),
                "processes" => match r.next()? {
                    Event::ArrStart => {
                        let mut procs = Vec::new();
                        loop {
                            match r.next()? {
                                Event::ArrEnd => break,
                                Event::ObjStart => {
                                    procs.push(decode_proc(r)?)
                                }
                                Event::ArrStart => {
                                    // Mirror the tree decoder: a non-
                                    // object entry is a defaulted
                                    // process record.
                                    r.skip_value_rest()?;
                                    procs.push(ProcStats::default());
                                }
                                _ => procs.push(ProcStats::default()),
                            }
                        }
                        reg.procs = Some(procs);
                    }
                    Event::ObjStart => r.skip_value_rest()?,
                    _ => {}
                },
                _ => r.skip_value()?,
            },
            _ => unreachable!("object events"),
        }
    }
    Ok(reg)
}

/// Decode one process record (the reader sits just past its `{`).
fn decode_proc(r: &mut JsonReader<'_>) -> Result<ProcStats> {
    let mut p = ProcStats::default();
    loop {
        match r.next()? {
            Event::ObjEnd => return Ok(p),
            Event::Key(k) => match k.as_ref() {
                "rank" => p.rank = r.f64_opt()?.unwrap_or(0.0) as u32,
                "node" => p.node = r.f64_opt()?.unwrap_or(0.0) as u32,
                "elapsed_time_ns" => {
                    p.elapsed_s = r.f64_opt()?.unwrap_or(0.0) / NS
                }
                "useful_time_ns" => {
                    p.useful_s = r.f64_opt()?.unwrap_or(0.0) / NS
                }
                "mpi_time_ns" => p.mpi_s = r.f64_opt()?.unwrap_or(0.0) / NS,
                "mpi_worker_idle_time_ns" => {
                    p.mpi_worker_idle_s = r.f64_opt()?.unwrap_or(0.0) / NS
                }
                "omp_serialization_time_ns" => {
                    p.omp_serialization_s = r.f64_opt()?.unwrap_or(0.0) / NS
                }
                "omp_scheduling_time_ns" => {
                    p.omp_scheduling_s = r.f64_opt()?.unwrap_or(0.0) / NS
                }
                "omp_load_balance_time_ns" => {
                    p.omp_barrier_s = r.f64_opt()?.unwrap_or(0.0) / NS
                }
                "useful_instructions" => {
                    p.useful_instructions = r.u64_opt()?.unwrap_or(0)
                }
                "useful_cycles" => {
                    p.useful_cycles = r.u64_opt()?.unwrap_or(0)
                }
                _ => r.skip_value()?,
            },
            _ => unreachable!("object events"),
        }
    }
}

fn ns_f(secs: f64) -> f64 {
    (secs * NS).round()
}

fn ns(secs: f64) -> Json {
    Json::Num(ns_f(secs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunData {
        RunData {
            dlb_version: DLB_VERSION.into(),
            app: "tealeaf".into(),
            machine: "mn5".into(),
            timestamp: 1_721_046_896,
            ranks: 2,
            threads: 4,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 10.0,
                visits: 1,
                procs: vec![
                    ProcStats {
                        rank: 0,
                        node: 0,
                        elapsed_s: 10.0,
                        useful_s: 36.0,
                        mpi_s: 1.0,
                        mpi_worker_idle_s: 3.0,
                        omp_serialization_s: 0.5,
                        omp_scheduling_s: 0.2,
                        omp_barrier_s: 0.3,
                        useful_instructions: 1_000_000,
                        useful_cycles: 500_000,
                    },
                    ProcStats {
                        rank: 1,
                        node: 0,
                        elapsed_s: 10.0,
                        useful_s: 34.0,
                        mpi_s: 2.0,
                        mpi_worker_idle_s: 6.0,
                        omp_serialization_s: 0.7,
                        omp_scheduling_s: 0.4,
                        omp_barrier_s: 0.9,
                        useful_instructions: 900_000,
                        useful_cycles: 450_000,
                    },
                ],
            }],
            git: Some(GitMeta {
                commit: "9dc04ca0".into(),
                branch: "main".into(),
                commit_timestamp: 1_721_000_000,
                message: "fix scaling bug".into(),
            }),
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let r = sample();
        let j = r.to_json();
        let back = RunData::from_json(&j).unwrap();
        assert_eq!(back.app, "tealeaf");
        assert_eq!(back.ranks, 2);
        assert_eq!(back.threads, 4);
        assert_eq!(back.timestamp, r.timestamp);
        let g = back.region("Global").unwrap();
        assert_eq!(g.procs.len(), 2);
        assert!((g.procs[1].useful_s - 34.0).abs() < 1e-6);
        assert_eq!(g.procs[0].useful_instructions, 1_000_000);
        let git = back.git.unwrap();
        assert_eq!(git.commit, "9dc04ca0");
        assert_eq!(git.commit_timestamp, 1_721_000_000);
    }

    #[test]
    fn effective_timestamp_prefers_git() {
        let mut r = sample();
        assert_eq!(r.effective_timestamp(), 1_721_000_000);
        r.git = None;
        assert_eq!(r.effective_timestamp(), 1_721_046_896);
    }

    #[test]
    fn file_roundtrip() {
        let td = crate::util::fs::TempDir::new("talpjson").unwrap();
        let path = td.path().join("sub/talp_2x4.json");
        let r = sample();
        r.write_file(&path).unwrap();
        let back = RunData::read_file(&path).unwrap();
        assert_eq!(back.resources().label(), "2x4");
    }

    #[test]
    fn rejects_malformed() {
        for text in [
            "{}",
            r#"{"resources":{"num_mpi_ranks":0,"num_omp_threads":1}}"#,
            r#"{"resources":{"num_mpi_ranks":1,"num_omp_threads":1},
                "timestamp":"2024-01-01T00:00:00Z","regions":{}}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(RunData::from_json(&j).is_err(), "{text}");
        }
    }

    #[test]
    fn rejects_process_count_mismatch() {
        let mut r = sample();
        r.regions[0].procs.pop();
        let j = r.to_json();
        assert!(RunData::from_json(&j).is_err());
    }

    // ---------- streaming codec vs tree codec ----------

    #[test]
    fn streaming_encoder_matches_tree() {
        let r = sample();
        let tree = r.to_json().to_string_pretty();
        let mut w = JsonWriter::pretty();
        r.write_to(&mut w);
        w.newline();
        assert_eq!(w.into_string(), tree, "pretty output must be identical");

        let tree = r.to_json().to_string_compact();
        let mut w = JsonWriter::compact();
        r.write_to(&mut w);
        assert_eq!(w.into_string(), tree, "compact output must be identical");
    }

    #[test]
    fn from_slice_matches_from_json() {
        let path = std::path::Path::new("x.json");
        let text = sample().to_json().to_string_pretty();
        let a = RunData::from_slice(text.as_bytes(), path).unwrap();
        let b = RunData::from_json(&Json::parse(&text).unwrap()).unwrap();
        // Same decode — compare via the canonical serialization.
        assert_eq!(
            a.to_json().to_string_compact(),
            b.to_json().to_string_compact()
        );
        assert_eq!(a.git, b.git);
    }

    #[test]
    fn from_slice_handles_reordered_and_unknown_keys() {
        // The streaming decoder is single-pass but must stay key-order
        // independent like the tree decoder: resources *after* regions,
        // unknown keys everywhere.
        let text = r#"{
            "unknown_top": {"deep": [1, 2, {"x": "y"}]},
            "regions": {
                "Global": {
                    "processes": [
                        {"rank": 0, "useful_time_ns": 1e9, "mystery": [1]},
                        {"rank": 1, "useful_time_ns": 2e9}
                    ],
                    "elapsed_time_ns": 3e9,
                    "visits": 2
                }
            },
            "timestamp": "2024-07-15T12:34:56Z",
            "resources": {"num_omp_threads": 1, "num_mpi_ranks": 2}
        }"#;
        let path = std::path::Path::new("reordered.json");
        let a = RunData::from_slice(text.as_bytes(), path).unwrap();
        let b = RunData::parse_str(text, path).unwrap();
        assert_eq!(a.ranks, 2);
        assert_eq!(a.threads, 1);
        assert_eq!(a.regions[0].visits, 2);
        assert!((a.regions[0].elapsed_s - 3.0).abs() < 1e-9);
        assert!((a.regions[0].procs[1].useful_s - 2.0).abs() < 1e-9);
        assert_eq!(
            a.to_json().to_string_compact(),
            b.to_json().to_string_compact()
        );
    }

    #[test]
    fn from_slice_duplicate_top_level_keys_first_wins_like_tree() {
        // `Json::get` returns the first occurrence of a duplicated
        // key; the single-pass streaming decoder must agree — the
        // second `regions` block below must not add regions, and the
        // second (invalid) `resources` block must not shadow the
        // first valid one.
        let text = r#"{
            "resources": {"num_mpi_ranks": 1, "num_omp_threads": 1},
            "timestamp": "2024-01-01T00:00:00Z",
            "regions": {
                "Global": {"processes": [{"rank": 0}]}
            },
            "resources": {"num_mpi_ranks": 0, "num_omp_threads": 1},
            "regions": {
                "Global": {"processes": [{"rank": 0}]},
                "Extra": {"processes": [{"rank": 0}]}
            },
            "timestamp": "not a timestamp"
        }"#;
        let path = std::path::Path::new("dup.json");
        let a = RunData::from_slice(text.as_bytes(), path).unwrap();
        let b = RunData::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(a.ranks, 1);
        assert_eq!(a.regions.len(), 1, "second regions block ignored");
        assert_eq!(a.timestamp, b.timestamp);
        assert_eq!(
            a.to_json().to_string_compact(),
            b.to_json().to_string_compact()
        );
    }

    #[test]
    fn from_slice_rejects_what_from_json_rejects() {
        let path = std::path::Path::new("bad.json");
        for text in [
            "{}",
            "[1,2]",
            "not json at all",
            r#"{"resources":{"num_mpi_ranks":0,"num_omp_threads":1}}"#,
            r#"{"resources":{"num_mpi_ranks":1,"num_omp_threads":1},
                "timestamp":"2024-01-01T00:00:00Z","regions":{}}"#,
            // Region without processes.
            r#"{"resources":{"num_mpi_ranks":1,"num_omp_threads":1},
                "timestamp":"2024-01-01T00:00:00Z",
                "regions":{"g":{"elapsed_time_ns":1}}}"#,
            // Truncated mid-document.
            r#"{"resources": {"num_mpi_ranks": 2,"#,
        ] {
            assert!(RunData::from_slice(text.as_bytes(), path).is_err(), "{text}");
        }
        // Invalid UTF-8 is an error, not a panic.
        let mut bad = br#"{"app":""#.to_vec();
        bad.push(0xff);
        bad.extend_from_slice(br#""}"#);
        assert!(RunData::from_slice(&bad, path).is_err());
    }
}
