//! TALP: on-the-fly collection of POP raw measurements (the DLB module
//! the paper builds on, reimplemented as a simulator `EventSink`).
//!
//! * [`accum`]   — per-(region, cpu) running timers.
//! * [`monitor`] — the live monitor + its DLB-like cost model.
//! * [`json`]    — the TALP JSON schema and the parsed [`json::RunData`].

pub mod accum;
pub mod json;
pub mod monitor;

pub use json::{GitMeta, ProcStats, RegionData, RunData};
pub use monitor::{TalpMonitor, TalpReport, TALP_COST};
