//! Single-writer store coordination: the `.talp-store.lock` file.
//!
//! Shard appends are atomic per write, but two concurrent writers
//! (say, a CLI `ingest` racing a resident `talp-pages serve`) could
//! interleave appends to one shard and leave the manifest describing
//! neither of them.  Every mutating entry point therefore takes this
//! advisory lock first: a JSON lockfile in the store root created with
//! `O_EXCL` semantics ([`std::fs::OpenOptions::create_new`]), carrying
//! the holder's pid and acquisition timestamp.
//!
//! Read paths (`report --store`, `gate --store`, `store stats/query`,
//! `check`) never take the lock — corruption-tolerant loading already
//! handles reading concurrently with a writer's append, and a resident
//! server must stay curl-able while batch reports run beside it.
//!
//! Stale locks: a crashed writer leaves its lockfile behind.  On
//! Linux, liveness is checked directly (`/proc/<pid>`); elsewhere a
//! lock older than [`STALE_LOCK_SECS`] is presumed abandoned.  A stale
//! lock is taken over (removed, then re-created); a live one is a hard
//! error naming the holder.  `talp-pages check` surfaces an orphaned
//! lock as the TP019 diagnostic.

use std::fs::OpenOptions;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::failpoint;
use crate::util::json::Json;
use crate::util::timefmt;

/// Lockfile name, in the store root next to the manifest.
pub const LOCK_FILE_NAME: &str = ".talp-store.lock";

/// Without `/proc` liveness (non-Linux), a lock this old is presumed
/// abandoned and taken over.
pub const STALE_LOCK_SECS: i64 = 24 * 3600;

/// Decoded lockfile contents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LockInfo {
    pub pid: u32,
    /// Acquisition time (unix seconds).
    pub timestamp: i64,
}

impl LockInfo {
    /// Parse a lockfile body; `None` for damaged content (treated as
    /// stale — garbage must not brick the store).
    pub fn parse(text: &str) -> Option<LockInfo> {
        let doc = Json::parse(text).ok()?;
        Some(LockInfo {
            pid: doc.get("pid").and_then(Json::as_u64)? as u32,
            timestamp: doc.get("timestamp").and_then(Json::as_u64)? as i64,
        })
    }

    /// Is the holding process still alive?  Linux asks `/proc`
    /// directly; elsewhere the age fallback applies (a long-lived
    /// server keeps its lock on Linux, where liveness is exact).
    pub fn holder_alive(&self, now: i64) -> bool {
        #[cfg(target_os = "linux")]
        {
            let _ = now;
            Path::new("/proc").join(self.pid.to_string()).exists()
        }
        #[cfg(not(target_os = "linux"))]
        {
            now - self.timestamp <= STALE_LOCK_SECS
        }
    }
}

/// RAII writer lock on a run store: holds `.talp-store.lock` from
/// [`StoreLock::acquire`] until drop (or an explicit
/// [`StoreLock::release`]).
#[derive(Debug)]
pub struct StoreLock {
    path: PathBuf,
}

impl StoreLock {
    /// Acquire the writer lock for the store at `root`, creating the
    /// root directory if needed.  A live holder is an error; a stale
    /// lock (dead pid, or over-age where liveness is unknowable) is
    /// taken over.
    pub fn acquire(root: &Path) -> Result<StoreLock> {
        std::fs::create_dir_all(root).with_context(|| {
            format!("creating store root {}", root.display())
        })?;
        let path = root.join(LOCK_FILE_NAME);
        // One takeover round at most: first attempt, stale cleanup,
        // second attempt.  Losing the re-create race to another writer
        // is a legitimate contention error, not a retry loop.
        for takeover in [false, true] {
            failpoint::check("store::lock", "create").with_context(
                || format!("creating lock {}", path.display()),
            )?;
            match OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    let body = Json::from_pairs(vec![
                        (
                            "pid",
                            Json::Num(f64::from(std::process::id())),
                        ),
                        (
                            "timestamp",
                            Json::Num(timefmt::now_unix() as f64),
                        ),
                    ])
                    .to_string_compact();
                    f.write_all(body.as_bytes()).with_context(|| {
                        format!("writing lock {}", path.display())
                    })?;
                    // A torn lock body parses as damaged and is
                    // treated as stale, so this fsync is about
                    // honesty (the pid a crashed writer leaves
                    // behind), not correctness.
                    f.sync_data().with_context(|| {
                        format!("flushing lock {}", path.display())
                    })?;
                    return Ok(StoreLock { path });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::AlreadyExists =>
                {
                    let held = std::fs::read_to_string(&path)
                        .ok()
                        .and_then(|t| LockInfo::parse(&t));
                    match held {
                        Some(info)
                            if info
                                .holder_alive(timefmt::now_unix()) =>
                        {
                            bail!(
                                "store {} is locked by a running \
                                 writer (pid {}, since {}); wait for \
                                 it or remove {} if it is not a \
                                 talp-pages process",
                                root.display(),
                                info.pid,
                                timefmt::to_iso8601(info.timestamp),
                                path.display()
                            );
                        }
                        _ if takeover => bail!(
                            "store {} lock reappeared during \
                             stale-lock takeover — another writer won \
                             the race; retry",
                            root.display()
                        ),
                        _ => {
                            // Dead holder or unreadable lock: take it
                            // over and loop into the second attempt.
                            std::fs::remove_file(&path).with_context(
                                || {
                                    format!(
                                        "removing stale lock {}",
                                        path.display()
                                    )
                                },
                            )?;
                        }
                    }
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("creating lock {}", path.display())
                    })
                }
            }
        }
        unreachable!("second create_new attempt returns or bails");
    }

    /// The lockfile path (for messages and tests).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Release explicitly, surfacing removal errors (drop is
    /// best-effort and silent).
    pub fn release(self) -> Result<()> {
        let path = self.path.clone();
        std::mem::forget(self);
        failpoint::check("store::lock", "release").with_context(
            || format!("releasing lock {}", path.display()),
        )?;
        std::fs::remove_file(&path).with_context(|| {
            format!("releasing lock {}", path.display())
        })
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    /// A pid far above any real `pid_max` — never alive.
    const DEAD_PID: u32 = 4_000_000_000;

    fn write_lock(root: &Path, pid: u32, timestamp: i64) {
        std::fs::create_dir_all(root).unwrap();
        std::fs::write(
            root.join(LOCK_FILE_NAME),
            format!("{{\"pid\":{pid},\"timestamp\":{timestamp}}}"),
        )
        .unwrap();
    }

    #[test]
    fn acquire_writes_and_drop_removes() {
        let td = TempDir::new("lock-cycle").unwrap();
        let root = td.path().join("store");
        let lock = StoreLock::acquire(&root).unwrap();
        let text = std::fs::read_to_string(lock.path()).unwrap();
        let info = LockInfo::parse(&text).unwrap();
        assert_eq!(info.pid, std::process::id());
        assert!(info.timestamp > 0);
        let path = lock.path().to_path_buf();
        drop(lock);
        assert!(!path.exists(), "drop releases the lock");
        // Explicit release works too.
        let lock = StoreLock::acquire(&root).unwrap();
        lock.release().unwrap();
        assert!(!root.join(LOCK_FILE_NAME).exists());
    }

    #[test]
    fn live_holder_blocks_second_writer() {
        let td = TempDir::new("lock-live").unwrap();
        let root = td.path().join("store");
        // Our own pid is definitionally alive.
        write_lock(&root, std::process::id(), timefmt::now_unix());
        let err = StoreLock::acquire(&root).unwrap_err();
        assert!(err.to_string().contains("locked by a running writer"));
        assert!(root.join(LOCK_FILE_NAME).exists(), "lock untouched");
    }

    #[test]
    fn stale_and_corrupt_locks_are_taken_over() {
        let td = TempDir::new("lock-stale").unwrap();
        let root = td.path().join("store");
        write_lock(&root, DEAD_PID, 1_700_000_000);
        let lock = StoreLock::acquire(&root).unwrap();
        let info = LockInfo::parse(
            &std::fs::read_to_string(lock.path()).unwrap(),
        )
        .unwrap();
        assert_eq!(info.pid, std::process::id(), "takeover re-stamps");
        drop(lock);

        // Unparsable garbage is stale too.
        std::fs::write(root.join(LOCK_FILE_NAME), "][ not json").unwrap();
        let lock = StoreLock::acquire(&root).unwrap();
        drop(lock);
        assert!(!root.join(LOCK_FILE_NAME).exists());
    }

    #[test]
    fn holder_liveness_matches_proc() {
        let now = timefmt::now_unix();
        let live = LockInfo { pid: std::process::id(), timestamp: now };
        assert!(live.holder_alive(now));
        let dead = LockInfo { pid: DEAD_PID, timestamp: now };
        assert!(!dead.holder_alive(now));
    }
}
