//! Unified admission: Fig. 2 artifact folder → [`RunStore`], through
//! the adapter registry.
//!
//! [`Admission`] is the one ingestion path every entry point routes
//! through — CLI `ingest`, `serve` (`POST /ingest` and `--watch`),
//! and the in-process CI runner — parameterized by worker count,
//! commit stamp and format.  The default format is auto-detection
//! over [`crate::adapters::registry`]; a document claimed by more
//! than one adapter is a *hard error* (the whole pass fails rather
//! than guessing), while an unrecognized or unparsable file degrades
//! to a skip-warning like the tolerant scanner.
//!
//! The store is content-addressed, so ingest is O(changed): every
//! artifact file is read and hashed (cheap), but only files whose
//! `(path, content hash)` identity is not already stored go through
//! an adapter and the POP reduction.  Multi-run formats (BeeSwarm)
//! expand one file into several records with `#<RxT>`-suffixed
//! sources; the file-level check ([`RunStore::contains_file`]) strips
//! the suffix, so a warm re-ingest of an unchanged folder parses zero
//! artifacts no matter the format — the property `talp-pages ingest`
//! prints and the store tests assert.
//!
//! Commit metadata: runs that already carry [`GitMeta`] (stamped by
//! `talp-pages metadata` / `ci::gitmeta` in their pipeline) keep it;
//! runs without it can be stamped at admission time via
//! [`Admission::commit`], so history ordering stays commit-based even
//! for artifacts that skipped the stamping step.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{bail, Result};

use crate::adapters::{self, Adapter, Detection};
use crate::pages::cache::content_hash;
use crate::pages::scanner;
use crate::pop::RunMetrics;
use crate::talp::GitMeta;
use crate::util::par::parallel_map;

use super::RunStore;

/// What one admission pass did.
#[derive(Debug, Default)]
pub struct IngestReport {
    /// Artifact files discovered under the input root.
    pub scanned: usize,
    /// Files whose content went through an adapter (not stored yet).
    pub parsed: usize,
    /// Records appended to the store.
    pub stored: usize,
    /// Files skipped because their (path, content hash) identity was
    /// already stored.
    pub already_stored: usize,
    /// Runs parsed per adapter, keyed by registry name — the serve
    /// `/statsz` per-format counters and the CLI breakdown line.
    pub formats: BTreeMap<&'static str, usize>,
    /// Experiments the freshly parsed records belong to (deduped).
    /// Resident consumers use this as the dirty set for incremental
    /// re-analysis; it can over-approximate by an experiment whose
    /// only fresh record was a within-batch duplicate, which merely
    /// costs one redundant re-analysis.
    pub stored_experiments: BTreeSet<String>,
    /// Unparsable files (skipped, like the scanner does).
    pub warnings: Vec<String>,
}

/// Builder for one ingestion pass — the entry point the CLI, the
/// serve loop and the CI runner all share.
///
/// ```no_run
/// use talp_pages::store::{Admission, RunStore};
///
/// fn main() -> anyhow::Result<()> {
///     let mut store = RunStore::create_or_open("store".as_ref())?;
///     let report = Admission::new()
///         .jobs(4)
///         .ingest_dir(&mut store, "artifacts".as_ref())?;
///     println!("{} stored, formats {:?}", report.stored, report.formats);
///     Ok(())
/// }
/// ```
#[derive(Clone, Copy, Default)]
pub struct Admission<'a> {
    jobs: usize,
    commit: Option<&'a GitMeta>,
    format: Option<&'static dyn Adapter>,
}

impl<'a> Admission<'a> {
    /// Auto-detected format, auto worker count, no commit stamp.
    pub fn new() -> Admission<'a> {
        Admission::default()
    }

    /// Worker threads for hash + parse (0 = auto).
    pub fn jobs(mut self, jobs: usize) -> Admission<'a> {
        self.jobs = jobs;
        self
    }

    /// Stamp `commit` into admitted runs that carry no git metadata.
    pub fn commit(mut self, commit: Option<&'a GitMeta>) -> Admission<'a> {
        self.commit = commit;
        self
    }

    /// Pin every file to one adapter instead of auto-detecting.
    pub fn format(mut self, adapter: &'static dyn Adapter) -> Admission<'a> {
        self.format = Some(adapter);
        self
    }

    /// Ingest every artifact under `root` into `store`.  Files whose
    /// (path, content hash) identity is already stored are skipped
    /// without parsing; fresh files go through their adapter in
    /// parallel and append in deterministic discover order.  An
    /// ambiguously-detected file fails the whole pass.
    pub fn ingest_dir(
        &self,
        store: &mut RunStore,
        root: &Path,
    ) -> Result<IngestReport> {
        enum Outcome {
            AlreadyStored,
            Fresh(&'static str, String, Vec<RunMetrics>),
            Bad(String),
            /// Auto-detection matched several adapters: hard error.
            Refused(String),
        }

        let found = scanner::discover(root)?;
        let all: Vec<(String, std::path::PathBuf)> = found
            .iter()
            .flat_map(|(_, fs)| {
                fs.iter().map(|p| (scanner::rel_str(root, p), p.clone()))
            })
            .collect();

        let fixed = self.format;
        let snapshot: &RunStore = store;
        let outcomes: Vec<Outcome> =
            parallel_map(&all, self.jobs, |(rel, path)| {
                let bytes = match std::fs::read(path) {
                    Ok(b) => b,
                    Err(e) => {
                        return Outcome::Bad(format!(
                            "skipping {}: {e}",
                            path.display()
                        ))
                    }
                };
                let hash = content_hash(&bytes);
                if snapshot.contains_file(rel, &hash) {
                    return Outcome::AlreadyStored;
                }
                let adapter = match fixed {
                    Some(a) => a,
                    None => match adapters::detect(&bytes) {
                        Detection::Match(a) => a,
                        Detection::Ambiguous(a, b) => {
                            return Outcome::Refused(format!(
                                "{}: ambiguous format — detected as both \
                                 '{a}' and '{b}'; pass an explicit format",
                                path.display()
                            ))
                        }
                        Detection::Unknown => {
                            return Outcome::Bad(format!(
                                "skipping {}: no registered adapter ({}) \
                                 recognizes this file",
                                path.display(),
                                adapters::names()
                            ))
                        }
                    },
                };
                match adapter.parse(&bytes, rel) {
                    Ok(runs) => Outcome::Fresh(adapter.name(), hash, runs),
                    Err(e) => Outcome::Bad(format!(
                        "skipping {}: {e:#}",
                        path.display()
                    )),
                }
            });

        let mut report =
            IngestReport { scanned: all.len(), ..Default::default() };
        let mut fresh: Vec<(String, String, RunMetrics)> = Vec::new();
        let mut next = outcomes.into_iter();
        for (id, files) in &found {
            for _ in files {
                match next.next().expect("ingest worker skipped a file") {
                    Outcome::AlreadyStored => report.already_stored += 1,
                    Outcome::Fresh(format, hash, runs) => {
                        report.parsed += 1;
                        *report.formats.entry(format).or_default() +=
                            runs.len();
                        for mut run in runs {
                            if run.git.is_none() {
                                run.git = self.commit.cloned();
                            }
                            fresh.push((id.clone(), hash.clone(), run));
                        }
                    }
                    Outcome::Bad(w) => report.warnings.push(w),
                    Outcome::Refused(e) => bail!(e),
                }
            }
        }
        report.stored_experiments =
            fresh.iter().map(|(id, _, _)| id.clone()).collect();
        let parsed_runs = fresh.len();
        // One batched append: each touched shard opens once, and a
        // duplicate identity within the batch (possible only if the
        // same path was discovered twice) dedups here.
        report.stored = store.append_all(fresh)?;
        report.already_stored += parsed_runs - report.stored;
        if report.stored == 0 {
            report.stored_experiments.clear();
        }
        Ok(report)
    }
}

/// Thin wrapper: [`Admission`] with every default (auto format, auto
/// workers, no commit stamp).
pub fn ingest_dir(store: &mut RunStore, root: &Path) -> Result<IngestReport> {
    Admission::new().ingest_dir(store, root)
}

#[cfg(test)]
mod tests {
    use super::super::tests::run_metrics;
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::util::fs::TempDir;

    fn build_tree(td: &TempDir, runs: usize) {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        for i in 0..runs {
            let mut app = Genex::salpha(1, CodeVersion::fixed());
            app.timesteps = 2;
            let (d, _) = run_with_talp(&app, &machine, &res, 10 + i as u64, 0);
            d.write_file(
                &td.path().join(format!("salpha/res_1/run_{i}.json")),
            )
            .unwrap();
        }
    }

    fn beeswarm_doc() -> &'static str {
        r#"{"application": "lulesh", "machine": "mn5",
            "timestamp": "2026-02-01T08:00:00Z",
            "scales": [
              {"processes": 1, "threads": 2, "time_s": 10.0,
               "efficiency": 1.0},
              {"processes": 2, "threads": 2, "time_s": 5.5,
               "efficiency": 0.91}]}"#
    }

    #[test]
    fn cold_then_warm_ingest() {
        let td = TempDir::new("ingest").unwrap();
        build_tree(&td, 3);
        let root = td.path().join("store");
        let mut store = RunStore::create_or_open(&root).unwrap();

        let cold = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(cold.scanned, 3);
        assert_eq!(cold.parsed, 3);
        assert_eq!(cold.stored, 3);
        assert_eq!(cold.already_stored, 0);
        assert!(cold.warnings.is_empty());
        assert_eq!(cold.formats.get("talp"), Some(&3));
        assert_eq!(
            cold.stored_experiments.iter().collect::<Vec<_>>(),
            ["salpha/res_1"]
        );

        // Warm re-ingest: everything hashes, nothing parses.
        let warm = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(warm.scanned, 3);
        assert_eq!(warm.parsed, 0, "warm ingest must parse zero artifacts");
        assert_eq!(warm.stored, 0);
        assert_eq!(warm.already_stored, 3);
        assert!(warm.formats.is_empty());
        assert!(warm.stored_experiments.is_empty());

        // One new file: exactly one parse.
        build_tree(&td, 4);
        let incr = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(incr.parsed, 1);
        assert_eq!(incr.stored, 1);
        assert_eq!(incr.already_stored, 3);
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn mixed_formats_admit_into_one_store_and_warm_skip() {
        let td = TempDir::new("ingest-mixed").unwrap();
        build_tree(&td, 2);
        std::fs::write(
            td.path().join("salpha/res_1/sweep.json"),
            beeswarm_doc(),
        )
        .unwrap();
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        let cold = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(cold.scanned, 3);
        assert_eq!(cold.parsed, 3, "all three files parse");
        assert_eq!(cold.stored, 4, "beeswarm file expands to 2 records");
        assert_eq!(cold.formats.get("talp"), Some(&2));
        assert_eq!(cold.formats.get("beeswarm"), Some(&2));
        assert_eq!(store.len(), 4);
        // Warm: the multi-run file skips at the hash level too.
        let warm = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(warm.parsed, 0, "multi-run file must warm-skip");
        assert_eq!(warm.already_stored, 3);
    }

    #[test]
    fn ambiguous_detection_is_a_hard_error() {
        let td = TempDir::new("ingest-ambig").unwrap();
        build_tree(&td, 1);
        std::fs::write(
            td.path().join("salpha/res_1/both.json"),
            r#"{"scales": [], "context": {}, "benchmarks": []}"#,
        )
        .unwrap();
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        let err = ingest_dir(&mut store, td.path()).unwrap_err();
        assert!(err.to_string().contains("ambiguous format"), "{err:#}");
        assert_eq!(store.len(), 0, "nothing admitted from a refused pass");
        // Pinning the format turns the refusal into an ordinary
        // parse-or-skip decision.
        let rep = Admission::new()
            .format(crate::adapters::by_name("talp").unwrap())
            .ingest_dir(&mut store, td.path())
            .unwrap();
        assert_eq!(rep.stored, 1, "the talp file");
        assert_eq!(rep.warnings.len(), 1, "the crafted file skips");
    }

    #[test]
    fn restamped_files_supersede_not_duplicate() {
        // The shipped `metadata` command rewrites artifacts in place;
        // ingest-after-stamp must replace the unstamped versions, not
        // double every history point.
        let td = TempDir::new("ingest-restamp").unwrap();
        build_tree(&td, 2);
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(store.len(), 2);

        let repo = crate::ci::Repo::genex_history(1, 0, 3, 9_000);
        crate::ci::gitmeta::stamp_tree(td.path(), &repo.commits[0])
            .unwrap();
        let re = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(re.parsed, 2, "stamped bytes are new content");
        assert_eq!(re.stored, 2);
        assert_eq!(store.len(), 2, "superseded, not duplicated");
        let scan = RunStore::open(store.root()).unwrap().into_scan();
        assert_eq!(scan.experiments[0].runs.len(), 2);
        assert!(scan.experiments[0].runs.iter().all(|r| r.git.is_some()));
    }

    #[test]
    fn corrupt_artifact_warns_and_survives() {
        let td = TempDir::new("ingest-bad").unwrap();
        build_tree(&td, 2);
        std::fs::write(td.path().join("salpha/res_1/bad.json"), "][")
            .unwrap();
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        let rep = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(rep.stored, 2);
        assert_eq!(rep.warnings.len(), 1);
        assert!(rep.warnings[0].contains("bad.json"));
        // The corrupt file is not stored: re-ingest warns again but
        // still parses nothing valid.
        let rep2 = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(rep2.parsed, 0);
        assert_eq!(rep2.warnings.len(), 1);
    }

    #[test]
    fn commit_metadata_stamped_only_when_absent() {
        let td = TempDir::new("ingest-meta").unwrap();
        build_tree(&td, 1); // simulator runs carry no git meta
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        let meta = GitMeta {
            commit: "feedc0de".into(),
            branch: "main".into(),
            commit_timestamp: 4_242,
            message: "ingest-time stamp".into(),
        };
        Admission::new()
            .commit(Some(&meta))
            .ingest_dir(&mut store, td.path())
            .unwrap();
        let scan = RunStore::open(store.root()).unwrap().into_scan();
        let run = &scan.experiments[0].runs[0];
        assert_eq!(run.git.as_ref().unwrap().commit, "feedc0de");
        assert_eq!(run.effective_timestamp(), 4_242);

        // A run that is already stamped keeps its own metadata.
        let pre = run_metrics("pre.json", 2, 77);
        let mut store2 =
            RunStore::create_or_open(&td.path().join("store2")).unwrap();
        store2.append("exp", "hh", pre).unwrap();
        let scan2 = RunStore::open(store2.root()).unwrap().into_scan();
        assert_eq!(
            scan2.experiments[0].runs[0].git.as_ref().unwrap().commit,
            "c000004d"
        );
    }

    #[test]
    fn missing_root_is_an_error() {
        let td = TempDir::new("ingest-missing").unwrap();
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        assert!(ingest_dir(&mut store, &td.path().join("nope")).is_err());
    }
}
