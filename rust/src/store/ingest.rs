//! Incremental ingest: Fig. 2 artifact folder → [`RunStore`].
//!
//! The store is content-addressed, so ingest is O(changed): every
//! artifact file is read and hashed (cheap), but only files whose
//! `(path, content hash)` identity is not already stored go through
//! the JSON parser and the POP reduction.  A warm re-ingest of an
//! unchanged folder parses zero artifacts — the property `talp-pages
//! ingest` prints and the store tests assert.
//!
//! Commit metadata: runs that already carry [`GitMeta`] (stamped by
//! `talp-pages metadata` / `ci::gitmeta` in their pipeline) keep it;
//! runs without it can be stamped at ingest time via the optional
//! `commit` argument, so history ordering stays commit-based even for
//! artifacts that skipped the stamping step.

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::Result;

use crate::pages::cache::content_hash;
use crate::pages::scanner;
use crate::pop::RunMetrics;
use crate::talp::{GitMeta, RunData};
use crate::util::par::parallel_map;

use super::RunStore;

/// What one [`ingest_dir`] pass did.
#[derive(Debug, Default)]
pub struct IngestReport {
    /// Artifact files discovered under the input root.
    pub scanned: usize,
    /// Files whose content went through parse + reduce (not stored yet).
    pub parsed: usize,
    /// Records appended to the store.
    pub stored: usize,
    /// Files skipped because their (path, content hash) identity was
    /// already stored.
    pub already_stored: usize,
    /// Experiments the freshly parsed records belong to (deduped).
    /// Resident consumers use this as the dirty set for incremental
    /// re-analysis; it can over-approximate by an experiment whose
    /// only fresh record was a within-batch duplicate, which merely
    /// costs one redundant re-analysis.
    pub stored_experiments: BTreeSet<String>,
    /// Unparsable files (skipped, like the scanner does).
    pub warnings: Vec<String>,
}

/// Ingest every artifact under `root` into `store` on up to `jobs`
/// workers (0 = auto).  Files whose (path, content hash) identity is
/// already stored are skipped without parsing; fresh files parse +
/// reduce in parallel and append in deterministic discover order.
/// `commit`, when given, is stamped into ingested runs that carry no
/// git metadata.
pub fn ingest_dir(
    store: &mut RunStore,
    root: &Path,
    jobs: usize,
    commit: Option<&GitMeta>,
) -> Result<IngestReport> {
    enum Outcome {
        AlreadyStored,
        Fresh(String, RunMetrics),
        Bad(String),
    }

    let found = scanner::discover(root)?;
    let all: Vec<(String, std::path::PathBuf)> = found
        .iter()
        .flat_map(|(_, fs)| {
            fs.iter().map(|p| (scanner::rel_str(root, p), p.clone()))
        })
        .collect();

    let snapshot: &RunStore = store;
    let outcomes: Vec<Outcome> = parallel_map(&all, jobs, |(rel, path)| {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) => {
                return Outcome::Bad(format!(
                    "skipping {}: {e}",
                    path.display()
                ))
            }
        };
        let hash = content_hash(&bytes);
        if snapshot.contains(rel, &hash) {
            return Outcome::AlreadyStored;
        }
        // Streaming decode straight from the bytes just hashed — no
        // UTF-8 revalidation pass, no Json tree.
        match RunData::from_slice(&bytes, path) {
            Ok(data) => Outcome::Fresh(hash, RunMetrics::from_run(&data, rel)),
            Err(e) => {
                Outcome::Bad(format!("skipping {}: {e:#}", path.display()))
            }
        }
    });

    let mut report = IngestReport { scanned: all.len(), ..Default::default() };
    let mut fresh: Vec<(String, String, RunMetrics)> = Vec::new();
    let mut next = outcomes.into_iter();
    for (id, files) in &found {
        for _ in files {
            match next.next().expect("ingest worker skipped a file") {
                Outcome::AlreadyStored => report.already_stored += 1,
                Outcome::Fresh(hash, mut run) => {
                    report.parsed += 1;
                    if run.git.is_none() {
                        run.git = commit.cloned();
                    }
                    fresh.push((id.clone(), hash, run));
                }
                Outcome::Bad(w) => report.warnings.push(w),
            }
        }
    }
    report.stored_experiments =
        fresh.iter().map(|(id, _, _)| id.clone()).collect();
    // One batched append: each touched shard opens once, and a
    // duplicate identity within the batch (possible only if the same
    // path was discovered twice) dedups here.
    report.stored = store.append_all(fresh)?;
    report.already_stored += report.parsed - report.stored;
    if report.stored == 0 {
        report.stored_experiments.clear();
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::super::tests::run_metrics;
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::util::fs::TempDir;

    fn build_tree(td: &TempDir, runs: usize) {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        for i in 0..runs {
            let mut app = Genex::salpha(1, CodeVersion::fixed());
            app.timesteps = 2;
            let (d, _) = run_with_talp(&app, &machine, &res, 10 + i as u64, 0);
            d.write_file(
                &td.path().join(format!("salpha/res_1/run_{i}.json")),
            )
            .unwrap();
        }
    }

    #[test]
    fn cold_then_warm_ingest() {
        let td = TempDir::new("ingest").unwrap();
        build_tree(&td, 3);
        let root = td.path().join("store");
        let mut store = RunStore::create_or_open(&root).unwrap();

        let cold = ingest_dir(&mut store, td.path(), 0, None).unwrap();
        assert_eq!(cold.scanned, 3);
        assert_eq!(cold.parsed, 3);
        assert_eq!(cold.stored, 3);
        assert_eq!(cold.already_stored, 0);
        assert!(cold.warnings.is_empty());
        assert_eq!(
            cold.stored_experiments.iter().collect::<Vec<_>>(),
            ["salpha/res_1"]
        );

        // Warm re-ingest: everything hashes, nothing parses.
        let warm = ingest_dir(&mut store, td.path(), 0, None).unwrap();
        assert_eq!(warm.scanned, 3);
        assert_eq!(warm.parsed, 0, "warm ingest must parse zero artifacts");
        assert_eq!(warm.stored, 0);
        assert_eq!(warm.already_stored, 3);
        assert!(warm.stored_experiments.is_empty());

        // One new file: exactly one parse.
        build_tree(&td, 4);
        let incr = ingest_dir(&mut store, td.path(), 0, None).unwrap();
        assert_eq!(incr.parsed, 1);
        assert_eq!(incr.stored, 1);
        assert_eq!(incr.already_stored, 3);
        assert_eq!(store.len(), 4);
    }

    #[test]
    fn restamped_files_supersede_not_duplicate() {
        // The shipped `metadata` command rewrites artifacts in place;
        // ingest-after-stamp must replace the unstamped versions, not
        // double every history point.
        let td = TempDir::new("ingest-restamp").unwrap();
        build_tree(&td, 2);
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        ingest_dir(&mut store, td.path(), 0, None).unwrap();
        assert_eq!(store.len(), 2);

        let repo = crate::ci::Repo::genex_history(1, 0, 3, 9_000);
        crate::ci::gitmeta::stamp_tree(td.path(), &repo.commits[0])
            .unwrap();
        let re = ingest_dir(&mut store, td.path(), 0, None).unwrap();
        assert_eq!(re.parsed, 2, "stamped bytes are new content");
        assert_eq!(re.stored, 2);
        assert_eq!(store.len(), 2, "superseded, not duplicated");
        let scan = RunStore::open(store.root()).unwrap().into_scan();
        assert_eq!(scan.experiments[0].runs.len(), 2);
        assert!(scan.experiments[0].runs.iter().all(|r| r.git.is_some()));
    }

    #[test]
    fn corrupt_artifact_warns_and_survives() {
        let td = TempDir::new("ingest-bad").unwrap();
        build_tree(&td, 2);
        std::fs::write(td.path().join("salpha/res_1/bad.json"), "][")
            .unwrap();
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        let rep = ingest_dir(&mut store, td.path(), 0, None).unwrap();
        assert_eq!(rep.stored, 2);
        assert_eq!(rep.warnings.len(), 1);
        assert!(rep.warnings[0].contains("bad.json"));
        // The corrupt file is not stored: re-ingest warns again but
        // still parses nothing valid.
        let rep2 = ingest_dir(&mut store, td.path(), 0, None).unwrap();
        assert_eq!(rep2.parsed, 0);
        assert_eq!(rep2.warnings.len(), 1);
    }

    #[test]
    fn commit_metadata_stamped_only_when_absent() {
        let td = TempDir::new("ingest-meta").unwrap();
        build_tree(&td, 1); // simulator runs carry no git meta
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        let meta = GitMeta {
            commit: "feedc0de".into(),
            branch: "main".into(),
            commit_timestamp: 4_242,
            message: "ingest-time stamp".into(),
        };
        ingest_dir(&mut store, td.path(), 0, Some(&meta)).unwrap();
        let scan = RunStore::open(store.root()).unwrap().into_scan();
        let run = &scan.experiments[0].runs[0];
        assert_eq!(run.git.as_ref().unwrap().commit, "feedc0de");
        assert_eq!(run.effective_timestamp(), 4_242);

        // A run that is already stamped keeps its own metadata.
        let pre = run_metrics("pre.json", 2, 77);
        let mut store2 =
            RunStore::create_or_open(&td.path().join("store2")).unwrap();
        store2.append("exp", "hh", pre).unwrap();
        let scan2 = RunStore::open(store2.root()).unwrap().into_scan();
        assert_eq!(
            scan2.experiments[0].runs[0].git.as_ref().unwrap().commit,
            "c000004d"
        );
    }

    #[test]
    fn missing_root_is_an_error() {
        let td = TempDir::new("ingest-missing").unwrap();
        let mut store =
            RunStore::create_or_open(&td.path().join("store")).unwrap();
        assert!(
            ingest_dir(&mut store, &td.path().join("nope"), 0, None).is_err()
        );
    }
}
