//! `store fsck`: crash-recovery scan and repair for a run store.
//!
//! The store's writers are crash-consistent (every mutation goes
//! through [`crate::util::fs::durable_append`] /
//! [`crate::util::fs::durable_write_atomic`]), so a killed writer can
//! only ever leave *recognisable* residue behind: an orphan `.tmp`
//! staging file, an empty just-created shard, a torn final record, a
//! manifest older than the shard bytes it describes, a stale or orphan
//! index sidecar, or the dead writer's lockfile.  [`fsck`] replays the
//! same corruption-tolerant decoder the loader uses and cross-checks
//! the manifest and every sidecar against the shards, reporting each
//! finding as a structured [`Diagnostic`]:
//!
//! * **TP025** (error) — fsck-detectable store damage: a torn or
//!   unterminated final record, or a manifest that no longer matches
//!   the decoded shard contents.
//! * **TP026** (warning) — interrupted-operation residue: orphan
//!   `.tmp` files, empty shard files, orphan or stale index sidecars.
//! * **TP012/TP013/TP019** — reused verbatim from the loader and
//!   `check`: interior corrupt records, unreadable shards, orphaned
//!   writer locks.
//!
//! Dry-run by default; [`FsckOptions::repair`] heals everything
//! healable while holding the writer lock: residue is removed, torn
//! tails are truncated back to the last record boundary (an
//! unterminated-but-decodable tail gets its newline instead), the
//! manifest is rewritten from the decoded truth, and sidecars are
//! refreshed.  Repair is idempotent — it re-derives every fix from the
//! on-disk state, so running it twice (or on a healthy store) changes
//! nothing.  Interior corrupt lines are deliberately *not* rewritten:
//! that is `store compact`'s job, and doing it here would move
//! surviving records' byte offsets — fsck's contract is that recovery
//! lands byte-identical to the state just before or just after the
//! interrupted operation, never a third state.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::check::{CheckReport, Diagnostic, Severity};
use crate::util::timefmt;

use super::{
    shard_files_at, trim_line, validate_manifest, LockInfo, RunStore,
    ShardIndex, StoreLock, StoredRun, LOCK_FILE_NAME, MANIFEST_FILE_NAME,
    SHARDS_DIR,
};

/// How [`fsck`] runs.
#[derive(Debug, Clone, Copy, Default)]
pub struct FsckOptions {
    /// Heal findings (holding the writer lock) instead of only
    /// reporting them.
    pub repair: bool,
    /// Worker count for the shard decode passes (0 = auto).
    pub jobs: usize,
}

/// What [`fsck`] found and (with `--repair`) did.
#[derive(Debug)]
pub struct FsckReport {
    /// Findings from the initial scan, in `check`'s sort order.
    pub diagnostics: Vec<Diagnostic>,
    /// Human-readable repair actions performed (empty on a dry run).
    pub repairs: Vec<String>,
    /// Findings still present after repair; on a dry run this is the
    /// initial scan unchanged.
    pub remaining: Vec<Diagnostic>,
    /// Whether a repair pass ran.
    pub repaired: bool,
}

impl FsckReport {
    /// Errors still standing — what the CLI exit code keys off.
    pub fn errors_remaining(&self) -> usize {
        count(&self.remaining, Severity::Error)
    }

    /// One line per finding (hint-indented, like `check`), the repair
    /// log, any findings that survived repair, and a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{}: {d}\n", d.severity.id()));
            if let Some(h) = &d.hint {
                out.push_str(&format!("  hint: {h}\n"));
            }
        }
        if !self.repairs.is_empty() {
            out.push_str("repaired:\n");
            for r in &self.repairs {
                out.push_str(&format!("  - {r}\n"));
            }
        }
        if self.repaired && !self.remaining.is_empty() {
            out.push_str("remaining after repair:\n");
            for d in &self.remaining {
                out.push_str(&format!("  {}: {d}\n", d.severity.id()));
            }
        }
        out.push_str(&self.summary_line());
        out.push('\n');
        out
    }

    /// `fsck: N finding(s) (E error(s), W warning(s)) — ...` with the
    /// dry-run/repair outcome.
    pub fn summary_line(&self) -> String {
        let head = format!(
            "fsck: {} finding(s) ({} error(s), {} warning(s))",
            self.diagnostics.len(),
            count(&self.diagnostics, Severity::Error),
            count(&self.diagnostics, Severity::Warning),
        );
        if self.repaired {
            format!(
                "{head} — {} repair(s) applied, {} finding(s) remaining \
                 ({} error(s))",
                self.repairs.len(),
                self.remaining.len(),
                self.errors_remaining(),
            )
        } else if self.diagnostics.is_empty() {
            format!("{head} — store is clean")
        } else {
            format!("{head} — dry run; `--repair` heals what it can")
        }
    }
}

fn count(diags: &[Diagnostic], sev: Severity) -> usize {
    diags.iter().filter(|d| d.severity == sev).count()
}

/// Scan-then-heal entry point.  A missing, unparsable or
/// wrong-version manifest is a hard error (the store is the durable
/// record — fsck will not guess at a format it cannot verify); with
/// [`FsckOptions::repair`] the repair pass runs unconditionally (it is
/// a no-op on a healthy store) and the store is re-scanned into
/// [`FsckReport::remaining`].
pub fn fsck(root: &Path, opts: &FsckOptions) -> Result<FsckReport> {
    validate_manifest(root)?;
    let diagnostics = scan(root, opts.jobs)?;
    let mut repairs = Vec::new();
    let remaining = if opts.repair {
        repair(root, opts.jobs, &mut repairs)?;
        scan(root, opts.jobs)?
    } else {
        diagnostics.clone()
    };
    Ok(FsckReport {
        diagnostics,
        repairs,
        remaining,
        repaired: opts.repair,
    })
}

/// All `.tmp` staging files in the store root and `shards/`, sorted.
fn tmp_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    for dir in [root.to_path_buf(), root.join(SHARDS_DIR)] {
        let Ok(rd) = std::fs::read_dir(&dir) else { continue };
        out.extend(rd.flatten().map(|e| e.path()).filter(|p| {
            p.is_file()
                && p.extension().and_then(|e| e.to_str()) == Some("tmp")
        }));
    }
    out.sort();
    out
}

/// All `.idx` sidecars under `shards/`, sorted.
fn sidecar_files(root: &Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(root.join(SHARDS_DIR))
        .map(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .filter(|p| {
                    p.extension().and_then(|e| e.to_str()) == Some("idx")
                })
                .collect()
        })
        .unwrap_or_default();
    out.sort();
    out
}

/// Is the lockfile at `root` present but held by a dead (or
/// unidentifiable) writer?
fn lock_is_orphaned(root: &Path) -> bool {
    match std::fs::read_to_string(root.join(LOCK_FILE_NAME)) {
        Ok(text) => !LockInfo::parse(&text)
            .map(|i| i.holder_alive(timefmt::now_unix()))
            .unwrap_or(false),
        Err(_) => false,
    }
}

/// The read-only finding pass: every check is re-derived from the
/// on-disk bytes so scan → repair → scan converges.
fn scan(root: &Path, jobs: usize) -> Result<Vec<Diagnostic>> {
    let mut rep = CheckReport::new();

    for p in tmp_files(root) {
        rep.push(
            Diagnostic::warning(
                "TP026",
                p.display().to_string(),
                "orphan temp file left by an interrupted write",
            )
            .with_hint("`talp-pages store fsck --repair` removes it"),
        );
    }

    for shard in shard_files_at(root) {
        let disp = shard.display().to_string();
        let bytes = match std::fs::read(&shard) {
            Ok(b) => b,
            Err(e) => {
                rep.push(Diagnostic::warning(
                    "TP013",
                    disp,
                    format!("unreadable ({e}) — skipped"),
                ));
                continue;
            }
        };
        if bytes.is_empty() {
            rep.push(
                Diagnostic::warning(
                    "TP026",
                    disp,
                    "empty shard file left by an interrupted append",
                )
                .with_hint(
                    "`talp-pages store fsck --repair` removes it",
                ),
            );
            continue;
        }
        let ends_nl = bytes.last() == Some(&b'\n');
        let fragments = bytes.split(|&b| b == b'\n').count();
        let mut lineno = 0usize;
        for (i, line) in bytes.split(|&b| b == b'\n').enumerate() {
            lineno += 1;
            let line = trim_line(line);
            if line.is_empty() {
                continue;
            }
            let is_tail = !ends_nl && i == fragments - 1;
            match StoredRun::from_line(line) {
                Ok(_) if is_tail => rep.push(
                    Diagnostic::error(
                        "TP025",
                        disp.clone(),
                        format!(
                            "final record at line {lineno} has no \
                             terminating newline — the next append \
                             would merge with and corrupt it"
                        ),
                    )
                    .with_hint(
                        "`talp-pages store fsck --repair` terminates \
                         the line",
                    ),
                ),
                Ok(_) => {}
                Err(e) if is_tail => rep.push(
                    Diagnostic::error(
                        "TP025",
                        disp.clone(),
                        format!(
                            "torn final record at line {lineno} ({e:#}) \
                             — an append was interrupted mid-write"
                        ),
                    )
                    .with_hint(
                        "`talp-pages store fsck --repair` truncates \
                         the shard back to the last record boundary",
                    ),
                ),
                Err(e) => rep.push(
                    Diagnostic::warning(
                        "TP012",
                        disp.clone(),
                        format!(
                            "corrupt record at line {lineno} ({e:#}) — \
                             the loader skips it"
                        ),
                    )
                    .with_hint(
                        "`talp-pages store compact` rewrites shards \
                         without corrupt lines",
                    ),
                ),
            }
        }
    }

    for sc in sidecar_files(root) {
        let shard = sc.with_extension("");
        let disp = sc.display().to_string();
        if !shard.exists() {
            rep.push(
                Diagnostic::warning(
                    "TP026",
                    disp,
                    "orphan index sidecar — its companion shard does \
                     not exist",
                )
                .with_hint(
                    "`talp-pages store fsck --repair` removes it",
                ),
            );
            continue;
        }
        match ShardIndex::load(&shard) {
            Err(e) => rep.push(
                Diagnostic::warning(
                    "TP026",
                    disp,
                    format!("unparsable index sidecar ({e:#})"),
                )
                .with_hint(
                    "`talp-pages store fsck --repair` rebuilds it",
                ),
            ),
            Ok(Some(idx)) if !idx.is_fresh_for(&shard) => rep.push(
                Diagnostic::warning(
                    "TP026",
                    disp,
                    "stale index sidecar — built from a different \
                     shard size",
                )
                .with_hint(
                    "`talp-pages store fsck --repair` rebuilds it",
                ),
            ),
            Ok(_) => {}
        }
    }

    // Manifest cross-check: the manifest a clean writer leaves behind
    // is byte-for-byte what `manifest_doc` derives from the decoded
    // shards; anything else means a crash landed between a shard
    // mutation and the manifest rewrite.
    let store = RunStore::open_with_jobs(root, jobs)?;
    let manifest = root.join(MANIFEST_FILE_NAME);
    let expected = store.manifest_doc().to_string_pretty();
    let actual = std::fs::read_to_string(&manifest).unwrap_or_default();
    if actual != expected {
        rep.push(
            Diagnostic::error(
                "TP025",
                manifest.display().to_string(),
                "manifest does not match the decoded shard contents \
                 (a writer crashed between a shard write and the \
                 manifest rewrite)",
            )
            .with_hint(
                "`talp-pages store fsck --repair` rewrites it from \
                 the shards",
            ),
        );
    }

    if lock_is_orphaned(root) {
        rep.push(
            Diagnostic::warning(
                "TP019",
                root.join(LOCK_FILE_NAME).display().to_string(),
                "orphaned writer lock (holder is not running)",
            )
            .with_hint(
                "`talp-pages store fsck --repair` takes it over and \
                 releases it",
            ),
        );
    }

    rep.sort();
    Ok(rep.diagnostics)
}

/// The healing pass, under the writer lock (a live writer is a hard
/// error; a stale lock is taken over, which is itself the heal for
/// TP019).  Every fix is re-derived from disk, so the pass is
/// idempotent and safe to run on a healthy store.
fn repair(
    root: &Path,
    jobs: usize,
    repairs: &mut Vec<String>,
) -> Result<()> {
    let had_orphan_lock = lock_is_orphaned(root);
    let lock = StoreLock::acquire(root)?;
    if had_orphan_lock {
        repairs
            .push("took over and released an orphaned writer lock".into());
    }

    for p in tmp_files(root) {
        std::fs::remove_file(&p).with_context(|| {
            format!("removing orphan temp file {}", p.display())
        })?;
        repairs.push(format!(
            "removed orphan temp file {}",
            p.display()
        ));
    }

    for shard in shard_files_at(root) {
        let Ok(bytes) = std::fs::read(&shard) else { continue };
        if bytes.is_empty() {
            std::fs::remove_file(&shard).with_context(|| {
                format!("removing empty shard {}", shard.display())
            })?;
            repairs.push(format!(
                "removed empty shard {}",
                shard.display()
            ));
            continue;
        }
        if bytes.last() == Some(&b'\n') {
            continue;
        }
        let tail_start = bytes
            .iter()
            .rposition(|&b| b == b'\n')
            .map(|i| i + 1)
            .unwrap_or(0);
        let tail = trim_line(&bytes[tail_start..]);
        if tail.is_empty() {
            // Whitespace-only tail: harmless (a future appended line
            // trims its leading whitespace away).
            continue;
        }
        if StoredRun::from_line(tail).is_ok() {
            // Decodable but unterminated: give it its newline so the
            // next append cannot merge with it.
            crate::util::fs::durable_append(
                &shard,
                b"\n",
                "store::fsck",
            )
            .with_context(|| {
                format!(
                    "terminating final record of {}",
                    shard.display()
                )
            })?;
            repairs.push(format!(
                "terminated the final record of {}",
                shard.display()
            ));
        } else {
            // Torn tail: truncate back to the last record boundary.
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(&shard)
                .with_context(|| {
                    format!("opening {} for repair", shard.display())
                })?;
            f.set_len(tail_start as u64).with_context(|| {
                format!("truncating {}", shard.display())
            })?;
            f.sync_data().with_context(|| {
                format!("flushing {}", shard.display())
            })?;
            repairs.push(format!(
                "truncated the torn tail of {} ({} byte(s))",
                shard.display(),
                bytes.len() - tail_start
            ));
        }
    }

    for sc in sidecar_files(root) {
        if !sc.with_extension("").exists() {
            std::fs::remove_file(&sc).with_context(|| {
                format!(
                    "removing orphan sidecar {}",
                    sc.display()
                )
            })?;
            repairs.push(format!(
                "removed orphan index sidecar {}",
                sc.display()
            ));
        }
    }

    // Shards are clean now: re-derive the manifest and sidecars from
    // the decoded truth.  Both serializations are deterministic, which
    // is what lands recovery byte-identical to a clean writer's state.
    let store = RunStore::open_with_jobs(root, jobs)?;
    let manifest = root.join(MANIFEST_FILE_NAME);
    let expected = store.manifest_doc().to_string_pretty();
    let actual =
        std::fs::read_to_string(&manifest).unwrap_or_default();
    if actual != expected {
        store.save_manifest()?;
        repairs.push(
            "rewrote the manifest from the decoded shard contents"
                .into(),
        );
    }
    let refreshed = store.refresh_indexes()?;
    if refreshed > 0 {
        repairs.push(format!(
            "refreshed {refreshed} index sidecar(s)"
        ));
    }
    lock.release()
}

#[cfg(test)]
mod tests {
    use super::super::tests::run_metrics;
    use super::*;
    use crate::util::fs::TempDir;

    fn seeded(root: &Path) -> RunStore {
        let mut s = RunStore::create_or_open(root).unwrap();
        s.append("exp", "h1", run_metrics("a.json", 2, 1)).unwrap();
        s.append("exp", "h2", run_metrics("b.json", 2, 2)).unwrap();
        s.refresh_indexes().unwrap();
        s
    }

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_store_is_clean() {
        let td = TempDir::new("fsck-clean").unwrap();
        let root = td.path().join("store");
        seeded(&root);
        let rep =
            fsck(&root, &FsckOptions::default()).unwrap();
        assert!(rep.diagnostics.is_empty(), "{rep:?}");
        assert_eq!(rep.errors_remaining(), 0);
        assert!(rep.summary_line().contains("clean"));

        // Repair on a healthy store is a no-op.
        let rep = fsck(
            &root,
            &FsckOptions { repair: true, jobs: 0 },
        )
        .unwrap();
        assert!(rep.repairs.is_empty(), "{rep:?}");
        assert!(rep.remaining.is_empty(), "{rep:?}");
    }

    #[test]
    fn non_store_is_a_hard_error() {
        let td = TempDir::new("fsck-nostore").unwrap();
        let err = fsck(td.path(), &FsckOptions::default())
            .unwrap_err();
        assert!(
            err.to_string().contains("not a run store"),
            "{err:#}"
        );
    }

    #[test]
    fn torn_tail_is_truncated_back_to_the_last_record() {
        let td = TempDir::new("fsck-torn").unwrap();
        let root = td.path().join("store");
        seeded(&root);
        let shard =
            root.join(SHARDS_DIR).join("exp__2x2.jsonl");
        let before = std::fs::read(&shard).unwrap();
        // A half-written record with no terminating newline — what a
        // crash mid-`write` leaves behind.
        let mut torn = before.clone();
        torn.extend_from_slice(b"{\"hash\":\"h9\",\"exper");
        std::fs::write(&shard, &torn).unwrap();

        let rep =
            fsck(&root, &FsckOptions::default()).unwrap();
        assert!(
            codes(&rep.diagnostics).contains(&"TP025"),
            "{rep:?}"
        );
        assert!(rep.errors_remaining() > 0);

        let rep = fsck(
            &root,
            &FsckOptions { repair: true, jobs: 0 },
        )
        .unwrap();
        assert!(
            rep.repairs.iter().any(|r| r.contains("truncated")),
            "{rep:?}"
        );
        assert!(rep.remaining.is_empty(), "{rep:?}");
        assert_eq!(
            std::fs::read(&shard).unwrap(),
            before,
            "truncation restores the pre-append bytes"
        );
    }

    #[test]
    fn unterminated_final_record_gets_its_newline() {
        let td = TempDir::new("fsck-unterm").unwrap();
        let root = td.path().join("store");
        seeded(&root);
        let shard =
            root.join(SHARDS_DIR).join("exp__2x2.jsonl");
        let mut bytes = std::fs::read(&shard).unwrap();
        assert_eq!(bytes.pop(), Some(b'\n'));
        std::fs::write(&shard, &bytes).unwrap();

        let rep = fsck(
            &root,
            &FsckOptions { repair: true, jobs: 0 },
        )
        .unwrap();
        assert!(
            rep.diagnostics
                .iter()
                .any(|d| d.code == "TP025"
                    && d.message.contains("no terminating newline")),
            "{rep:?}"
        );
        assert!(rep.remaining.is_empty(), "{rep:?}");
        assert_eq!(
            std::fs::read(&shard).unwrap().last(),
            Some(&b'\n')
        );
    }

    #[test]
    fn residue_and_drift_are_found_and_healed() {
        let td = TempDir::new("fsck-residue").unwrap();
        let root = td.path().join("store");
        let mut s = seeded(&root);
        // Orphan temp files in both directories.
        std::fs::write(
            root.join(".talp-store.json.tmp"),
            b"{}",
        )
        .unwrap();
        std::fs::write(
            root.join(SHARDS_DIR).join("exp__2x2.jsonl.tmp"),
            b"junk",
        )
        .unwrap();
        // Empty shard (a crash immediately after create).
        std::fs::write(
            root.join(SHARDS_DIR).join("late__4x4.jsonl"),
            b"",
        )
        .unwrap();
        // Orphan sidecar.
        std::fs::write(
            root.join(SHARDS_DIR).join("ghost__1x1.jsonl.idx"),
            b"junk",
        )
        .unwrap();
        // Manifest drift: append bypassing the store API.
        let shard =
            root.join(SHARDS_DIR).join("exp__2x2.jsonl");
        let extra = super::super::StoredRun {
            experiment: "exp".into(),
            hash: "h3".into(),
            run: run_metrics("c.json", 2, 3),
        };
        crate::util::fs::durable_append(
            &shard,
            format!("{}\n", extra.to_line()).as_bytes(),
            "store::fsck",
        )
        .unwrap();
        // Dead writer's lockfile.
        std::fs::write(
            root.join(LOCK_FILE_NAME),
            "{\"pid\":4000000000,\"timestamp\":1700000000}",
        )
        .unwrap();
        drop(s.refresh_indexes()); // pre-drift sidecar is now stale

        let rep =
            fsck(&root, &FsckOptions::default()).unwrap();
        let found = codes(&rep.diagnostics);
        for code in ["TP019", "TP025", "TP026"] {
            assert!(found.contains(&code), "{found:?}");
        }
        assert_eq!(
            rep.remaining.len(),
            rep.diagnostics.len(),
            "dry run repairs nothing"
        );

        let rep = fsck(
            &root,
            &FsckOptions { repair: true, jobs: 0 },
        )
        .unwrap();
        assert!(rep.remaining.is_empty(), "{}", rep.render_text());
        assert!(!root.join(LOCK_FILE_NAME).exists());
        assert!(
            !root.join(".talp-store.json.tmp").exists()
                && !root
                    .join(SHARDS_DIR)
                    .join("exp__2x2.jsonl.tmp")
                    .exists()
        );
        // The healed store loads and serves all three records.
        let healed = RunStore::open(&root).unwrap();
        assert_eq!(healed.len(), 3);
        assert!(healed.warnings().is_empty());
        // ... and a second repair changes nothing.
        let rep = fsck(
            &root,
            &FsckOptions { repair: true, jobs: 0 },
        )
        .unwrap();
        assert!(rep.repairs.is_empty(), "{rep:?}");
    }

    #[test]
    fn interior_corruption_is_reported_not_rewritten() {
        let td = TempDir::new("fsck-interior").unwrap();
        let root = td.path().join("store");
        let s = seeded(&root);
        drop(s);
        let shard =
            root.join(SHARDS_DIR).join("exp__2x2.jsonl");
        let text = std::fs::read_to_string(&shard).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        lines.insert(1, "][ not a record");
        let damaged = format!("{}\n", lines.join("\n"));
        std::fs::write(&shard, &damaged).unwrap();

        let rep = fsck(
            &root,
            &FsckOptions { repair: true, jobs: 0 },
        )
        .unwrap();
        // The corrupt line (TP012) and the manifest drift it causes
        // (TP025) are both found; repair rewrites the manifest but
        // leaves the shard bytes alone — rewriting is compact's job.
        assert!(codes(&rep.diagnostics).contains(&"TP012"));
        assert!(
            std::fs::read_to_string(&shard).unwrap() == damaged,
            "fsck must not rewrite interior lines"
        );
        assert_eq!(codes(&rep.remaining), ["TP012"], "{rep:?}");
        assert_eq!(rep.errors_remaining(), 0);
    }
}
