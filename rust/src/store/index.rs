//! Per-shard byte-offset index sidecars — the accelerator that makes
//! [`super::RunStore::query`] sub-linear in store size.
//!
//! Every shard `<name>.jsonl` may carry a sidecar `<name>.jsonl.idx`
//! describing where each record line starts, how long it is, and the
//! selection metadata a query filters on (hash, experiment, config,
//! source, effective timestamp, commit) — everything needed to decide
//! *which* lines to decode without decoding any of them.  The sidecar
//! is JSONL like the shard itself: a header line
//!
//! ```json
//! {"index_version":1,"shard_bytes":12345,"corrupt_lines":0}
//! ```
//!
//! followed by one line per indexed record:
//!
//! ```json
//! {"off":0,"len":931,"hash":"…","experiment":"…","config":"2x2",
//!  "source":"exp/run_0.json","ts":1700000000,"commit":"…"}
//! ```
//!
//! Contract (the tentpole rule): the index is an accelerator, **never
//! a second source of truth**.  `shard_bytes` pins the exact shard
//! size the index was built from — any append invalidates it wholesale
//! ([`ShardIndex::is_fresh_for`]) — and every decoded record is
//! re-validated against its entry (hash/source/experiment) by the
//! query engine, which degrades to the sequential
//! [`super::StoredRun::from_line`] scan of the whole shard on any
//! mismatch.  A corrupt or stale sidecar therefore costs a warning and
//! a rebuild, never a wrong result.  Writes are atomic
//! (temp-file + rename), same as shard compaction.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{Event, JsonReader, JsonWriter};

use super::trim_line;

/// Sidecar format version; bump on any shape change.  Unlike the store
/// manifest this is *not* strict: an unknown index version is treated
/// as a stale index (rebuild), because the shard itself is the truth.
pub const INDEX_VERSION: u64 = 1;

/// Sidecar file for a shard: the shard path with a literal `.idx`
/// appended (`exp__2x2.jsonl` → `exp__2x2.jsonl.idx`).  The extra
/// extension keeps sidecars out of [`super::RunStore`]'s `.jsonl`
/// shard enumeration.
pub fn sidecar_path(shard: &Path) -> PathBuf {
    let mut os = shard.as_os_str().to_os_string();
    os.push(".idx");
    PathBuf::from(os)
}

/// One indexed record line: where it lives in the shard plus the
/// metadata a [`super::QuerySpec`] selects on.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexEntry {
    /// Byte offset of the trimmed record line inside the shard.
    pub offset: usize,
    /// Trimmed line length in bytes (what `from_line` decodes).
    pub len: usize,
    pub hash: String,
    pub experiment: String,
    /// Resource-configuration label (`<ranks>x<threads>`).
    pub config: String,
    pub source: String,
    /// Effective timestamp (commit timestamp when stamped, run
    /// timestamp otherwise) — what history ordering uses.
    pub ts: i64,
    /// Commit sha, empty when the run carries no git metadata.
    pub commit: String,
}

/// A whole sidecar: the shard size it was built from, how many lines
/// the builder could not decode, and one entry per decoded record.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardIndex {
    /// Exact shard file size the index describes; any other size means
    /// the index is stale.
    pub shard_bytes: u64,
    /// Undecodable lines the builder skipped (mirrors the loader's
    /// TP012 tolerance, so a healed-by-rebuild index is honest about
    /// damage).
    pub corrupt_lines: u64,
    pub entries: Vec<IndexEntry>,
}

impl ShardIndex {
    /// Is this index fresh for the shard on disk right now?  Freshness
    /// is exact-size equality: appends grow the shard, compaction
    /// rewrites it, and both invalidate every recorded offset.
    pub fn is_fresh_for(&self, shard: &Path) -> bool {
        std::fs::metadata(shard)
            .map(|m| m.len() == self.shard_bytes)
            .unwrap_or(false)
    }

    /// Render the sidecar as JSONL (header line + one line per entry).
    pub fn render(&self) -> String {
        let mut w = JsonWriter::with_capacity(
            64 + self.entries.len() * 192,
            false,
        );
        w.begin_obj();
        w.key("index_version");
        w.num(INDEX_VERSION as f64);
        w.key("shard_bytes");
        w.num(self.shard_bytes as f64);
        w.key("corrupt_lines");
        w.num(self.corrupt_lines as f64);
        w.end_obj();
        w.newline();
        for e in &self.entries {
            w.begin_obj();
            w.key("off");
            w.num(e.offset as f64);
            w.key("len");
            w.num(e.len as f64);
            w.key("hash");
            w.str_val(&e.hash);
            w.key("experiment");
            w.str_val(&e.experiment);
            w.key("config");
            w.str_val(&e.config);
            w.key("source");
            w.str_val(&e.source);
            w.key("ts");
            w.num(e.ts as f64);
            w.key("commit");
            w.str_val(&e.commit);
            w.end_obj();
            w.newline();
        }
        w.into_string()
    }

    /// Parse a sidecar.  Every structural problem is a hard `Err` —
    /// the caller treats a broken sidecar as "no usable index" and
    /// rebuilds; tolerating damage here would defeat the validation.
    pub fn parse(bytes: &[u8]) -> Result<ShardIndex> {
        let mut lines =
            bytes.split(|&b| b == b'\n').map(trim_line).filter(|l| {
                !l.is_empty()
            });
        let header =
            lines.next().context("index sidecar is empty")?;
        let (version, shard_bytes, corrupt_lines) = parse_header(header)
            .context("corrupt index header")?;
        if version != INDEX_VERSION {
            bail!(
                "index version {version}; this build understands only \
                 version {INDEX_VERSION}"
            );
        }
        let mut idx = ShardIndex {
            shard_bytes,
            corrupt_lines,
            entries: Vec::new(),
        };
        let mut lineno = 1usize;
        for line in lines {
            lineno += 1;
            let e = parse_entry(line).with_context(|| {
                format!("corrupt index entry at line {lineno}")
            })?;
            if let Some(prev) = idx.entries.last() {
                if e.offset <= prev.offset {
                    bail!(
                        "index entry at line {lineno} is out of order \
                         (offset {} after {})",
                        e.offset,
                        prev.offset
                    );
                }
            }
            if (e.offset + e.len) as u64 > shard_bytes {
                bail!(
                    "index entry at line {lineno} points past the end \
                     of the shard ({}+{} > {shard_bytes})",
                    e.offset,
                    e.len
                );
            }
            idx.entries.push(e);
        }
        Ok(idx)
    }

    /// Load the sidecar for `shard`.  `Ok(None)` means "no sidecar"
    /// (an ordinary un-indexed shard); `Err` means the sidecar exists
    /// but is unusable (the caller warns and rebuilds).
    pub fn load(shard: &Path) -> Result<Option<ShardIndex>> {
        let path = sidecar_path(shard);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                bail!("unreadable index {}: {e}", path.display())
            }
        };
        ShardIndex::parse(&bytes)
            .map(Some)
            .with_context(|| format!("index {}", path.display()))
    }

    /// Write the sidecar atomically and durably (temp-file + fsync +
    /// rename + directory fsync), so a killed writer can never leave
    /// a truncated index that would *parse* but lie about the shard.
    pub fn write_atomic(&self, shard: &Path) -> Result<()> {
        let path = sidecar_path(shard);
        crate::util::fs::durable_write_atomic(
            &path,
            self.render().as_bytes(),
            "store::index",
        )
        .with_context(|| {
            format!("replacing index {}", path.display())
        })
    }
}

/// Decode the header line: `(index_version, shard_bytes,
/// corrupt_lines)`.
fn parse_header(line: &[u8]) -> Result<(u64, u64, u64)> {
    let mut r = JsonReader::new(line);
    match r.next()? {
        Event::ObjStart => {}
        _ => bail!("header is not an object"),
    }
    let mut version: Option<u64> = None;
    let mut shard_bytes: Option<u64> = None;
    let mut corrupt_lines: Option<u64> = None;
    loop {
        match r.next()? {
            Event::ObjEnd => break,
            Event::Key(k) => match k.as_ref() {
                "index_version" => version = r.u64_opt()?,
                "shard_bytes" => shard_bytes = r.u64_opt()?,
                "corrupt_lines" => corrupt_lines = r.u64_opt()?,
                _ => r.skip_value()?,
            },
            _ => unreachable!("object events"),
        }
    }
    r.finish()?;
    Ok((
        version.context("header without index_version")?,
        shard_bytes.context("header without shard_bytes")?,
        corrupt_lines.unwrap_or(0),
    ))
}

/// Decode one entry line.
fn parse_entry(line: &[u8]) -> Result<IndexEntry> {
    let mut r = JsonReader::new(line);
    match r.next()? {
        Event::ObjStart => {}
        _ => bail!("entry is not an object"),
    }
    let mut off: Option<u64> = None;
    let mut len: Option<u64> = None;
    let mut hash: Option<String> = None;
    let mut experiment: Option<String> = None;
    let mut config: Option<String> = None;
    let mut source: Option<String> = None;
    let mut ts: Option<i64> = None;
    let mut commit: Option<String> = None;
    loop {
        match r.next()? {
            Event::ObjEnd => break,
            Event::Key(k) => match k.as_ref() {
                "off" => off = r.u64_opt()?,
                "len" => len = r.u64_opt()?,
                "hash" => hash = r.str_opt()?.map(|s| s.into_owned()),
                "experiment" => {
                    experiment = r.str_opt()?.map(|s| s.into_owned())
                }
                "config" => {
                    config = r.str_opt()?.map(|s| s.into_owned())
                }
                "source" => {
                    source = r.str_opt()?.map(|s| s.into_owned())
                }
                "ts" => ts = r.f64_opt()?.map(|n| n as i64),
                "commit" => {
                    commit = r.str_opt()?.map(|s| s.into_owned())
                }
                _ => r.skip_value()?,
            },
            _ => unreachable!("object events"),
        }
    }
    r.finish()?;
    Ok(IndexEntry {
        offset: off.context("entry without off")? as usize,
        len: len.context("entry without len")? as usize,
        hash: hash.context("entry without hash")?,
        experiment: experiment.context("entry without experiment")?,
        config: config.context("entry without config")?,
        source: source.context("entry without source")?,
        ts: ts.context("entry without ts")?,
        commit: commit.unwrap_or_default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn entry(off: usize, src: &str) -> IndexEntry {
        IndexEntry {
            offset: off,
            len: 10,
            hash: format!("h{off}"),
            experiment: "exp/α".into(),
            config: "2x2".into(),
            source: src.into(),
            ts: 1_700_000_000 + off as i64,
            commit: format!("c{off:07x}"),
        }
    }

    #[test]
    fn render_parse_roundtrip() {
        let idx = ShardIndex {
            shard_bytes: 1000,
            corrupt_lines: 2,
            entries: vec![entry(0, "a.json"), entry(500, "b.json")],
        };
        let back = ShardIndex::parse(idx.render().as_bytes()).unwrap();
        assert_eq!(back, idx);
    }

    #[test]
    fn sidecar_path_keeps_full_shard_name() {
        assert_eq!(
            sidecar_path(Path::new("shards/exp__2x2.jsonl")),
            Path::new("shards/exp__2x2.jsonl.idx")
        );
    }

    #[test]
    fn structural_damage_is_a_hard_error() {
        // Empty, bad header, future version.
        assert!(ShardIndex::parse(b"").is_err());
        assert!(ShardIndex::parse(b"[1,2]\n").is_err());
        let future = "{\"index_version\":9,\"shard_bytes\":10}\n";
        let err =
            ShardIndex::parse(future.as_bytes()).unwrap_err().to_string();
        assert!(err.contains("index version 9"), "{err}");

        // Out-of-order and out-of-bounds entries.
        let base = ShardIndex {
            shard_bytes: 100,
            corrupt_lines: 0,
            entries: vec![entry(50, "a.json"), entry(0, "b.json")],
        };
        let err =
            ShardIndex::parse(base.render().as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("out of order"), "{err:#}");
        let oob = ShardIndex {
            shard_bytes: 40,
            corrupt_lines: 0,
            entries: vec![entry(35, "a.json")],
        };
        let err = ShardIndex::parse(oob.render().as_bytes()).unwrap_err();
        assert!(
            format!("{err:#}").contains("past the end"),
            "{err:#}"
        );

        // A truncated entry line.
        let mut text = ShardIndex {
            shard_bytes: 100,
            corrupt_lines: 0,
            entries: vec![entry(0, "a.json")],
        }
        .render();
        text.push_str("{\"off\":20,\"len\":");
        let err = ShardIndex::parse(text.as_bytes()).unwrap_err();
        assert!(
            format!("{err:#}").contains("line 3"),
            "{err:#}"
        );
    }

    #[test]
    fn load_distinguishes_missing_from_corrupt() {
        let td = TempDir::new("idx-load").unwrap();
        let shard = td.path().join("exp__2x2.jsonl");
        std::fs::write(&shard, "x".repeat(30)).unwrap();
        assert!(ShardIndex::load(&shard).unwrap().is_none(), "no sidecar");

        let idx = ShardIndex {
            shard_bytes: 30,
            corrupt_lines: 0,
            entries: vec![entry(0, "a.json")],
        };
        idx.write_atomic(&shard).unwrap();
        let back = ShardIndex::load(&shard).unwrap().expect("sidecar");
        assert_eq!(back, idx);
        assert!(back.is_fresh_for(&shard));

        // Growing the shard makes the index stale, not corrupt.
        std::fs::write(&shard, "x".repeat(40)).unwrap();
        assert!(!ShardIndex::load(&shard)
            .unwrap()
            .unwrap()
            .is_fresh_for(&shard));

        // Corrupting the sidecar is an error, not a silent None.
        std::fs::write(sidecar_path(&shard), "{\"index_version\": ")
            .unwrap();
        assert!(ShardIndex::load(&shard).is_err());
    }
}
