//! Indexed queries over a [`super::RunStore`] — select and decode
//! only the lines a question needs.
//!
//! [`super::RunStore::open_with_jobs`] decodes every line of every
//! shard ever written; for "the last 200 runs of experiment X" on a
//! 50k-run corpus that is 50k decodes to use 200.  This module answers
//! the same questions from the per-shard sidecar indexes
//! ([`super::index`]): it loads the (small) entry tables, replays the
//! loader's exact supersede/duplicate resolution *over the entries*,
//! applies the [`QuerySpec`] filters, and seeks-and-decodes only the
//! selected lines.
//!
//! Correctness contract (the tentpole rule): the corruption-tolerant
//! [`super::StoredRun::from_line`] decoder stays the single read path,
//! and the index is never trusted blindly —
//!
//! * a missing, stale or unparsable sidecar is rebuilt from a full
//!   sequential decode of its shard (a warning when it was corrupt,
//!   silently when merely missing/stale);
//! * every record decoded through an index entry is validated against
//!   the entry (hash, source, experiment, config, timestamp); any
//!   mismatch distrusts that shard's index entirely, re-decodes the
//!   shard sequentially, heals the sidecar and re-runs the selection —
//!   a bad index entry costs time and a warning, never a wrong result.
//!
//! [`query_full_scan`] is the control: the same [`QuerySpec`] applied
//! in memory to a fully loaded store.  Both paths share one selection
//! function over one metadata shape, so their results are identical by
//! construction — the property the `store_query` acceptance tests and
//! the CI `store-scale` job pin.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::io::{Read as _, Seek as _, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::check::Diagnostic;
use crate::gate::policy::pat_match;
use crate::util::par::parallel_map;

use super::index::{IndexEntry, ShardIndex};
use super::{decode_shard, shard_files_at, RunStore, StoredRun};

/// What to select: every field is optional and they compose with AND.
/// The default spec matches everything.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QuerySpec {
    /// Experiment-id pattern (exact, `*`, or trailing-`*` prefix —
    /// the gate policy's matcher).
    pub experiment: Option<String>,
    /// Resource-configuration pattern (`2x8`, `4x*`, ...).
    pub config: Option<String>,
    /// Keep runs at or after the newest stored run whose commit sha
    /// starts with this prefix (errors when no stored commit matches).
    pub since_commit: Option<String>,
    /// Keep runs with effective timestamp >= this (unix seconds).
    pub since: Option<i64>,
    /// Keep runs with effective timestamp <= this (unix seconds).
    pub until: Option<i64>,
    /// Keep only the last N runs of each matched (experiment, config)
    /// history, in (timestamp, source) order — "the recent window".
    pub last: Option<usize>,
}

impl QuerySpec {
    /// Does this spec select every stored run?  (The session layer
    /// routes match-all store scans through the classic full loader,
    /// preserving its per-line corruption warnings.)
    pub fn is_match_all(&self) -> bool {
        *self == QuerySpec::default()
    }
}

/// Work and coverage counters for one query — the observability the
/// `store stats`/`store query` CLI and the CI `store-scale` job print.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct QueryStats {
    /// Shard files considered.
    pub shards: usize,
    /// Index entries loaded across all shards.
    pub indexed_lines: usize,
    /// Live runs after supersede/duplicate replay.
    pub live_runs: usize,
    /// Runs matching the spec.
    pub matched_runs: usize,
    /// `from_line` decode attempts — THE sub-linearity witness: with
    /// fresh indexes this equals `matched_runs`, not the store size.
    pub decoded_lines: usize,
    /// Shards whose sidecar was fresh (seek-decode path).
    pub indexes_fresh: usize,
    /// Shards decoded sequentially (sidecar missing/stale/corrupt or
    /// distrusted after a validation failure).
    pub indexes_rebuilt: usize,
}

/// A query's result: matching records in deterministic
/// (experiment, effective timestamp, source) order, plus stats and
/// structured warnings.
#[derive(Debug)]
pub struct QueryOutcome {
    pub records: Vec<StoredRun>,
    pub stats: QueryStats,
    pub warnings: Vec<Diagnostic>,
}

/// The per-record metadata the selection runs on.  Both the indexed
/// path (from [`IndexEntry`]) and the full-scan control (from decoded
/// [`StoredRun`]s) reduce to this shape, so one [`select`] serves
/// both and they cannot diverge.
struct RecordMeta {
    experiment: String,
    config: String,
    source: String,
    commit: String,
    ts: i64,
}

impl RecordMeta {
    fn of_entry(e: &IndexEntry) -> RecordMeta {
        RecordMeta {
            experiment: e.experiment.clone(),
            config: e.config.clone(),
            source: e.source.clone(),
            commit: e.commit.clone(),
            ts: e.ts,
        }
    }

    fn of_record(r: &StoredRun) -> RecordMeta {
        RecordMeta {
            experiment: r.experiment.clone(),
            config: r.run.resources().label(),
            source: r.run.source.clone(),
            commit: r
                .run
                .git
                .as_ref()
                .map(|g| g.commit.clone())
                .unwrap_or_default(),
            ts: r.run.effective_timestamp(),
        }
    }
}

/// Apply `spec` to `metas`; returns the selected indices (order
/// preserved).  Errors only for an unanswerable spec (`since_commit`
/// naming a commit the store has never seen).
fn select(metas: &[RecordMeta], spec: &QuerySpec) -> Result<Vec<usize>> {
    let mut idx: Vec<usize> = (0..metas.len()).collect();
    if let Some(pat) = &spec.experiment {
        idx.retain(|&i| pat_match(pat, &metas[i].experiment));
    }
    if let Some(pat) = &spec.config {
        idx.retain(|&i| pat_match(pat, &metas[i].config));
    }
    if let Some(prefix) = &spec.since_commit {
        // The anchor is the *newest* stored run of that commit (a
        // commit can be re-run); searched across the whole live set so
        // an experiment filter can't silently unanchor it.
        let anchor = metas
            .iter()
            .filter(|m| {
                !m.commit.is_empty() && m.commit.starts_with(prefix.as_str())
            })
            .map(|m| m.ts)
            .max()
            .with_context(|| {
                format!(
                    "no stored run's commit starts with '{prefix}' — \
                     cannot anchor --since-commit"
                )
            })?;
        idx.retain(|&i| metas[i].ts >= anchor);
    }
    if let Some(s) = spec.since {
        idx.retain(|&i| metas[i].ts >= s);
    }
    if let Some(u) = spec.until {
        idx.retain(|&i| metas[i].ts <= u);
    }
    if let Some(n) = spec.last {
        // Last N per (experiment, config) history in the exact order
        // histories are plotted: (timestamp, source).
        let mut groups: BTreeMap<(&str, &str), Vec<usize>> =
            BTreeMap::new();
        for &i in &idx {
            groups
                .entry((
                    metas[i].experiment.as_str(),
                    metas[i].config.as_str(),
                ))
                .or_default()
                .push(i);
        }
        let mut keep: HashSet<usize> = HashSet::new();
        for (_, mut g) in groups {
            g.sort_by(|&a, &b| {
                metas[a]
                    .ts
                    .cmp(&metas[b].ts)
                    .then_with(|| metas[a].source.cmp(&metas[b].source))
            });
            keep.extend(g.iter().rev().take(n));
        }
        idx.retain(|i| keep.contains(i));
    }
    Ok(idx)
}

/// One shard's entry table for the query replay: either a fresh
/// sidecar (records decoded lazily, by seek) or a full sequential
/// decode (records already in memory).
struct ShardTable {
    path: PathBuf,
    entries: Vec<IndexEntry>,
    /// Parallel to `entries` when the shard was sequentially decoded.
    records: Option<Vec<StoredRun>>,
    fresh: bool,
    /// `from_line` attempts spent building this table (0 when fresh).
    decoded: usize,
    /// Shard file size the table describes (from the index header when
    /// fresh, from the decode pass otherwise).
    bytes: u64,
    corrupt_lines: u64,
    warnings: Vec<Diagnostic>,
}

/// Sequentially decode `path` and build its table, healing the
/// sidecar on disk (best-effort — a read-only store must still
/// query).
fn rebuild_table(path: &Path, mut warnings: Vec<Diagnostic>) -> ShardTable {
    let dec = decode_shard(path);
    let entries: Vec<IndexEntry> = dec
        .records
        .iter()
        .map(|(rec, offset, len)| entry_of(rec, *offset, *len))
        .collect();
    let idx = ShardIndex {
        shard_bytes: dec.bytes,
        corrupt_lines: dec.corrupt_lines,
        entries: entries.clone(),
    };
    let _ = idx.write_atomic(path);
    if dec.corrupt_lines > 0 {
        warnings.push(corrupt_lines_warning(path, dec.corrupt_lines));
    }
    warnings.extend(
        dec.warnings.into_iter().filter(|d| d.code == "TP013"),
    );
    ShardTable {
        path: path.to_path_buf(),
        entries,
        decoded: dec.records.len(),
        records: Some(dec.records.into_iter().map(|(r, _, _)| r).collect()),
        fresh: false,
        bytes: dec.bytes,
        corrupt_lines: dec.corrupt_lines,
        warnings,
    }
}

/// Build one index entry from a decoded record and its line location.
pub(super) fn entry_of(
    rec: &StoredRun,
    offset: usize,
    len: usize,
) -> IndexEntry {
    IndexEntry {
        offset,
        len,
        hash: rec.hash.clone(),
        experiment: rec.experiment.clone(),
        config: rec.run.resources().label(),
        source: rec.run.source.clone(),
        ts: rec.run.effective_timestamp(),
        commit: rec
            .run
            .git
            .as_ref()
            .map(|g| g.commit.clone())
            .unwrap_or_default(),
    }
}

/// The deterministic per-shard corruption summary both the fresh and
/// the rebuilt path emit (from the index header vs the decode pass),
/// so query warnings do not depend on index temperature.
fn corrupt_lines_warning(path: &Path, n: u64) -> Diagnostic {
    Diagnostic::warning(
        "TP012",
        path.display().to_string(),
        format!("shard has {n} corrupt line(s), skipped"),
    )
    .with_hint("`talp-pages ingest --compact` rewrites damaged shards")
}

/// A sidecar truncated at an entry-line boundary still parses, and its
/// header still matches the shard size — catch it by coverage: with no
/// corrupt lines recorded, the last entry must reach the shard's final
/// newline.  Short coverage demotes the sidecar to stale (silent
/// rebuild) rather than letting it silently hide tail records.
fn covers_shard(idx: &ShardIndex) -> bool {
    if idx.corrupt_lines > 0 {
        // Corrupt tail lines legitimately shorten coverage; the
        // per-record validation still guards every decode.
        return true;
    }
    let covered = idx
        .entries
        .last()
        .map(|e| (e.offset + e.len) as u64)
        .unwrap_or(0);
    idx.shard_bytes <= covered + 1
}

fn load_table(path: &Path) -> ShardTable {
    match ShardIndex::load(path) {
        Ok(Some(idx))
            if idx.is_fresh_for(path) && covers_shard(&idx) =>
        {
            let mut warnings = Vec::new();
            if idx.corrupt_lines > 0 {
                warnings
                    .push(corrupt_lines_warning(path, idx.corrupt_lines));
            }
            ShardTable {
                path: path.to_path_buf(),
                bytes: idx.shard_bytes,
                corrupt_lines: idx.corrupt_lines,
                entries: idx.entries,
                records: None,
                fresh: true,
                decoded: 0,
                warnings,
            }
        }
        // Missing or merely stale: the ordinary post-append state —
        // rebuild silently.
        Ok(_) => rebuild_table(path, Vec::new()),
        // Corrupt sidecar: degrade loudly, then rebuild.
        Err(e) => rebuild_table(
            path,
            vec![Diagnostic::warning(
                "TP017",
                super::index::sidecar_path(path).display().to_string(),
                format!("unusable index sidecar ({e:#}) — rebuilt from \
                         the shard"),
            )],
        ),
    }
}

/// Where one live run lives: `(table index, entry index)`.
#[derive(Clone, Copy)]
struct LiveRef {
    t: usize,
    e: usize,
}

/// Replay the loader's admit rules over the entry tables (sorted shard
/// order, line order within each shard): duplicate `(source, hash)`
/// identities drop (first wins), same-source-different-hash supersedes
/// in place — exactly [`RunStore::open_with_jobs`]'s resolution, so a
/// query and a full load agree on which runs are live.
fn replay_live(tables: &[ShardTable]) -> Vec<LiveRef> {
    let mut keys: HashSet<(String, String)> = HashSet::new();
    let mut by_source: HashMap<String, usize> = HashMap::new();
    let mut live: Vec<LiveRef> = Vec::new();
    for t in 0..tables.len() {
        for e in 0..tables[t].entries.len() {
            let entry = &tables[t].entries[e];
            if !keys
                .insert((entry.source.clone(), entry.hash.clone()))
            {
                continue;
            }
            match by_source.entry(entry.source.clone()) {
                std::collections::hash_map::Entry::Occupied(slot) => {
                    let i = *slot.get();
                    let old = &tables[live[i].t].entries[live[i].e];
                    keys.remove(&(old.source.clone(), old.hash.clone()));
                    live[i] = LiveRef { t, e };
                }
                std::collections::hash_map::Entry::Vacant(slot) => {
                    slot.insert(live.len());
                    live.push(LiveRef { t, e });
                }
            }
        }
    }
    live
}

/// Seek to one indexed line and decode it.
fn decode_at(path: &Path, entry: &IndexEntry) -> Result<StoredRun> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening shard {}", path.display()))?;
    f.seek(SeekFrom::Start(entry.offset as u64))?;
    let mut buf = vec![0u8; entry.len];
    f.read_exact(&mut buf).with_context(|| {
        format!(
            "reading {} byte(s) at offset {} of {}",
            entry.len,
            entry.offset,
            path.display()
        )
    })?;
    StoredRun::from_line(&buf)
}

/// Does a decoded record agree with the index entry that located it?
fn matches_entry(rec: &StoredRun, entry: &IndexEntry) -> bool {
    rec.hash == entry.hash
        && rec.run.source == entry.source
        && rec.experiment == entry.experiment
        && rec.run.resources().label() == entry.config
        && rec.run.effective_timestamp() == entry.ts
}

/// Run `spec` against the store at `root` through the sidecar indexes
/// (see module docs for the degradation contract).
pub(super) fn query(
    root: &Path,
    jobs: usize,
    spec: &QuerySpec,
) -> Result<QueryOutcome> {
    super::validate_manifest(root)?;
    let shards = shard_files_at(root);
    let mut tables: Vec<ShardTable> =
        parallel_map(&shards, jobs, |p| load_table(p));

    let mut stats = QueryStats {
        shards: tables.len(),
        ..Default::default()
    };
    let mut extra_warnings: Vec<Diagnostic> = Vec::new();

    // Selection loop: a validation failure distrusts one shard's
    // index, rebuilds its table and restarts — each shard can be
    // distrusted at most once, so this terminates.
    let records = loop {
        let live = replay_live(&tables);
        let metas: Vec<RecordMeta> = live
            .iter()
            .map(|l| RecordMeta::of_entry(&tables[l.t].entries[l.e]))
            .collect();
        let selected = select(&metas, spec)?;
        stats.live_runs = live.len();
        stats.matched_runs = selected.len();

        let mut out: Vec<StoredRun> = Vec::with_capacity(selected.len());
        let mut distrust: Option<usize> = None;
        for &i in &selected {
            let LiveRef { t, e } = live[i];
            let entry = &tables[t].entries[e];
            let rec = match &tables[t].records {
                Some(records) => records[e].clone(),
                None => {
                    stats.decoded_lines += 1;
                    match decode_at(&tables[t].path, entry) {
                        Ok(rec) if matches_entry(&rec, entry) => rec,
                        Ok(_) => {
                            distrust = Some(t);
                            break;
                        }
                        Err(_) => {
                            distrust = Some(t);
                            break;
                        }
                    }
                }
            };
            out.push(rec);
        }
        let Some(t) = distrust else { break out };
        extra_warnings.push(Diagnostic::warning(
            "TP017",
            super::index::sidecar_path(&tables[t].path)
                .display()
                .to_string(),
            "index entry does not match its shard line — falling back \
             to the sequential scan of this shard"
                .to_string(),
        ));
        let path = tables[t].path.clone();
        tables[t] = rebuild_table(&path, Vec::new());
    };

    for table in &tables {
        stats.indexed_lines += table.entries.len();
        stats.decoded_lines += table.decoded;
        if table.fresh {
            stats.indexes_fresh += 1;
        } else {
            stats.indexes_rebuilt += 1;
        }
    }
    let mut warnings: Vec<Diagnostic> = Vec::new();
    for table in &mut tables {
        warnings.append(&mut table.warnings);
    }
    warnings.extend(extra_warnings);

    let mut records = records;
    sort_records(&mut records);
    Ok(QueryOutcome { records, stats, warnings })
}

/// The control path: load the whole store and apply the same spec in
/// memory.  Byte-identical records to [`query`] by construction
/// (shared [`select`]); linear cost (`decoded_lines` = every line in
/// the store).
pub(super) fn query_full_scan(
    root: &Path,
    jobs: usize,
    spec: &QuerySpec,
) -> Result<QueryOutcome> {
    let store = RunStore::open_with_jobs(root, jobs)?;
    let metas: Vec<RecordMeta> =
        store.records.iter().map(RecordMeta::of_record).collect();
    let selected = select(&metas, spec)?;
    let stats = QueryStats {
        shards: store.shard_meta.len(),
        indexed_lines: 0,
        live_runs: store.records.len(),
        matched_runs: selected.len(),
        decoded_lines: store.decoded_lines,
        indexes_fresh: 0,
        indexes_rebuilt: 0,
    };
    let mut records: Vec<StoredRun> = selected
        .into_iter()
        .map(|i| store.records[i].clone())
        .collect();
    sort_records(&mut records);
    Ok(QueryOutcome {
        records,
        stats,
        warnings: store.warnings.clone(),
    })
}

/// One shard's row in [`RunStore::stats`], aggregated from its entry
/// table — no record is decoded when the sidecar is fresh, which is
/// what lets `store stats` report on a 50k-run corpus in index time.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStat {
    /// Shard file name (`<experiment>__<config>.jsonl`).
    pub file: String,
    /// Live runs after supersede/duplicate replay.
    pub runs: usize,
    /// Indexed lines, live or not.
    pub lines: usize,
    /// Shard file size in bytes.
    pub bytes: u64,
    /// Bytes not owned by a live line: superseded, duplicate, corrupt.
    pub dead_bytes: u64,
    pub corrupt_lines: u64,
    /// Live effective-timestamp range and the commits at its ends
    /// (empty strings when the shard has no live runs).
    pub ts_min: i64,
    pub ts_max: i64,
    pub commit_first: String,
    pub commit_last: String,
    /// `"fresh"` when the sidecar answered as-is; `"rebuilt"` when it
    /// was missing, stale or corrupt (the rebuild also healed it).
    pub index: &'static str,
}

impl ShardStat {
    /// Fraction of the shard the next compaction would drop.
    pub fn dead_ratio(&self) -> f64 {
        self.dead_bytes as f64 / self.bytes.max(1) as f64
    }
}

/// What [`RunStore::stats`] returns: per-shard rows (sorted shard
/// order) plus the same work counters a query reports — on a fully
/// indexed store `stats.decoded_lines` is 0, the number `store stats`
/// prints as the sub-linearity witness.
#[derive(Debug)]
pub struct StoreStats {
    pub shards: Vec<ShardStat>,
    pub stats: QueryStats,
    pub warnings: Vec<Diagnostic>,
}

/// Corpus-shape report from the entry tables alone (see
/// [`StoreStats`]); rebuilds (and heals) any missing/stale/corrupt
/// sidecar it meets along the way.
pub(super) fn stats(root: &Path, jobs: usize) -> Result<StoreStats> {
    super::validate_manifest(root)?;
    let shards = shard_files_at(root);
    let mut tables: Vec<ShardTable> =
        parallel_map(&shards, jobs, |p| load_table(p));
    let live = replay_live(&tables);

    let mut rows: Vec<ShardStat> = tables
        .iter()
        .map(|t| ShardStat {
            file: t
                .path
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_default(),
            runs: 0,
            lines: t.entries.len(),
            bytes: t.bytes,
            // Everything is dead until a live line claims its bytes.
            dead_bytes: t.bytes,
            corrupt_lines: t.corrupt_lines,
            ts_min: 0,
            ts_max: 0,
            commit_first: String::new(),
            commit_last: String::new(),
            index: if t.fresh { "fresh" } else { "rebuilt" },
        })
        .collect();
    for l in &live {
        let e = &tables[l.t].entries[l.e];
        let row = &mut rows[l.t];
        if row.runs == 0 || e.ts < row.ts_min {
            row.ts_min = e.ts;
            row.commit_first = e.commit.clone();
        }
        if row.runs == 0 || e.ts >= row.ts_max {
            row.ts_max = e.ts;
            row.commit_last = e.commit.clone();
        }
        row.runs += 1;
        // A line owns its bytes plus the newline after it.
        row.dead_bytes = row.dead_bytes.saturating_sub(e.len as u64 + 1);
    }

    let mut stats = QueryStats {
        shards: tables.len(),
        live_runs: live.len(),
        ..Default::default()
    };
    let mut warnings: Vec<Diagnostic> = Vec::new();
    for table in &mut tables {
        stats.indexed_lines += table.entries.len();
        stats.decoded_lines += table.decoded;
        if table.fresh {
            stats.indexes_fresh += 1;
        } else {
            stats.indexes_rebuilt += 1;
        }
        warnings.append(&mut table.warnings);
    }
    Ok(StoreStats { shards: rows, stats, warnings })
}

/// Deterministic output order: experiment, then effective timestamp,
/// then source — the exact order [`RunStore::into_scan`] produces, so
/// query results and store scans agree line for line.
fn sort_records(records: &mut [StoredRun]) {
    records.sort_by(|a, b| {
        a.experiment
            .cmp(&b.experiment)
            .then_with(|| {
                a.run
                    .effective_timestamp()
                    .cmp(&b.run.effective_timestamp())
            })
            .then_with(|| a.run.source.cmp(&b.run.source))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(
        exp: &str,
        cfg: &str,
        src: &str,
        commit: &str,
        ts: i64,
    ) -> RecordMeta {
        RecordMeta {
            experiment: exp.into(),
            config: cfg.into(),
            source: src.into(),
            commit: commit.into(),
            ts,
        }
    }

    fn fixture() -> Vec<RecordMeta> {
        vec![
            meta("exp/a", "2x2", "a/r0.json", "aaaa0000", 100),
            meta("exp/a", "2x2", "a/r1.json", "bbbb1111", 200),
            meta("exp/a", "2x2", "a/r2.json", "cccc2222", 300),
            meta("exp/a", "4x2", "a/s0.json", "aaaa0000", 100),
            meta("exp/b", "2x2", "b/r0.json", "bbbb1111", 200),
            meta("exp/b", "2x2", "b/r1.json", "", 250),
        ]
    }

    #[test]
    fn match_all_is_the_default() {
        assert!(QuerySpec::default().is_match_all());
        let spec = QuerySpec { last: Some(5), ..Default::default() };
        assert!(!spec.is_match_all());
        assert_eq!(
            select(&fixture(), &QuerySpec::default()).unwrap().len(),
            6
        );
    }

    #[test]
    fn experiment_and_config_patterns() {
        let m = fixture();
        let spec = QuerySpec {
            experiment: Some("exp/a".into()),
            ..Default::default()
        };
        assert_eq!(select(&m, &spec).unwrap(), [0, 1, 2, 3]);
        let spec = QuerySpec {
            experiment: Some("exp/*".into()),
            config: Some("4x2".into()),
            ..Default::default()
        };
        assert_eq!(select(&m, &spec).unwrap(), [3]);
        let spec = QuerySpec {
            experiment: Some("nope*".into()),
            ..Default::default()
        };
        assert!(select(&m, &spec).unwrap().is_empty());
    }

    #[test]
    fn time_range_and_since_commit() {
        let m = fixture();
        let spec = QuerySpec {
            since: Some(200),
            until: Some(250),
            ..Default::default()
        };
        assert_eq!(select(&m, &spec).unwrap(), [1, 4, 5]);

        // The commit prefix anchors at its newest run's timestamp,
        // across experiments.
        let spec = QuerySpec {
            since_commit: Some("bbbb".into()),
            ..Default::default()
        };
        assert_eq!(select(&m, &spec).unwrap(), [1, 2, 4, 5]);

        // An unknown commit is an error, not an empty result.
        let spec = QuerySpec {
            since_commit: Some("f00d".into()),
            ..Default::default()
        };
        let err = select(&m, &spec).unwrap_err().to_string();
        assert!(err.contains("f00d"), "{err}");

        // Runs without git metadata never anchor a commit.
        let spec = QuerySpec {
            since_commit: Some(String::new()),
            ..Default::default()
        };
        assert_eq!(
            select(&m, &spec).unwrap(),
            [1, 2, 4, 5],
            "empty prefix anchors at the newest stamped run"
        );
    }

    #[test]
    fn last_n_is_per_config_history() {
        let m = fixture();
        let spec = QuerySpec { last: Some(1), ..Default::default() };
        // One per (experiment, config): the newest of each history.
        assert_eq!(select(&m, &spec).unwrap(), [2, 3, 5]);
        let spec = QuerySpec { last: Some(2), ..Default::default() };
        assert_eq!(select(&m, &spec).unwrap(), [1, 2, 3, 4, 5]);
        // Composes with the other filters.
        let spec = QuerySpec {
            experiment: Some("exp/a".into()),
            config: Some("2x2".into()),
            last: Some(2),
            ..Default::default()
        };
        assert_eq!(select(&m, &spec).unwrap(), [1, 2]);
    }
}
