//! # talp-pages-rs
//!
//! A Rust + JAX + Pallas reproduction of *"TALP-Pages: An easy-to-
//! integrate continuous performance monitoring framework"* (Seitz,
//! Trilaksono, Garcia-Gasulla — Parallel Tools Workshop 2024).
//!
//! The crate contains (DESIGN.md has the full inventory):
//!
//! * [`sim`] — the HPC substrate: deterministic phase-level simulator of
//!   hybrid MPI+OpenMP executions (machines, DVFS, caches, collectives),
//!   plus the seeded corpus generator behind `talp-pages sim`
//!   ([`sim::corpus`]): scenario axes — weak/strong scaling, hybrid
//!   region trees, noise regimes, drifting baselines, step regressions
//!   — emitted in any registered adapter's format, byte-reproducible
//!   from a seed.
//! * [`adapters`] — multi-format ingestion: an [`adapters::Adapter`]
//!   registry (`talp`, `root-bench`, `beeswarm`) that detects a
//!   producer's JSON dialect and normalizes it into [`pop::RunMetrics`],
//!   so one store/gate/report/serve/check stack monitors heterogeneous
//!   suites; every ingestion entry point routes through
//!   [`store::Admission`].
//! * [`talp`] — the TALP monitor: on-the-fly POP-factor accumulation and
//!   the DLB-style JSON output.
//! * [`pop`] — fundamental performance factors: the efficiency
//!   hierarchy, weak/strong scaling detection, scaling-efficiency tables.
//! * [`tools`] — the baseline toolchains the paper compares against
//!   (Extrae-like tracer, Score-P-like profiler+tracer, CPT) and their
//!   post-processing pipelines (Dimemas-like replay etc.).
//! * [`pages`] — the TALP-Pages data layer: folder scanner, metrics
//!   cache, time series, change detection, HTML/SVG primitives.
//! * [`session`] — the staged pipeline every consumer routes through:
//!   `Session::scan` → `Scan::analyze` → `Analysis::emit` with
//!   pluggable sources ([`session::ScanSource`]: artifact folder or
//!   run store) and pluggable emitters (HTML site, badges, gate files,
//!   `report.json`).
//! * [`store`] — the persistent cross-commit history store: a
//!   content-addressed, sharded JSONL record of every reduced run,
//!   with incremental ingest (`talp-pages ingest` parses only
//!   artifacts whose content hash is new), corruption-tolerant
//!   loading and compaction.
//! * [`serve`] — the resident monitoring service (`talp-pages serve`):
//!   a std-only HTTP/1.1 server holding a warm session over the run
//!   store, ingesting artifacts (`POST /ingest`, `--watch` drop
//!   directory) and re-analyzing only the affected experiment before
//!   atomically swapping the served snapshot — whose payloads
//!   (`/report.json`, `/gate.json`, `/badges/*.svg`, `/index.html`)
//!   are byte-identical to the batch `report --store` output.
//! * [`ci`] — an in-process GitLab-like CI engine (pipelines, artifact
//!   zips, pages hosting) used to reproduce the paper's CI workflow.
//! * [`gate`] — the regression gate: a declarative policy over the
//!   metrics histories that turns detection into a CI pass/fail
//!   verdict (`gate.json` + markdown + JUnit XML + exit code).
//! * [`check`] — the static analyzer (`talp-pages check`): validates
//!   every input surface — artifact trees, run stores, gate policies,
//!   metrics caches, `report.json`, bench baselines — without running
//!   a report, emitting stable `TP0xx` diagnostics with byte-offset
//!   spans as deterministic text or SARIF 2.1.0
//!   ([`check::sarif`]), with gate-style exit codes (0 clean /
//!   1 warnings / 2 errors).
//! * [`apps`] — workloads: the TeaLeaf CG mini-app (backed by the real
//!   AOT-compiled Pallas kernel through [`runtime`]) and a GENE-X-like
//!   app with the injectable scaling bug of Fig. 7.
//! * [`runtime`] — PJRT loader/executor for `artifacts/*.hlo.txt`
//!   (stubbed unless built with the `pjrt` feature — the offline image
//!   carries no `xla` bindings).
//!
//! # The staged pipeline (session)
//!
//! Scan → analyze → emit, with the paper's Table 2 performance story
//! built into the first two stages:
//!
//! * **Scan** ([`session::Session::scan`]): the Fig. 2 folder walk
//!   reduces every artifact to [`pop::RunMetrics`] through a
//!   content-hash cache (`pages::cache`, FNV-1a-64 over raw bytes) on a
//!   scoped-thread worker pool (`util::par`, `jobs = 0` → auto).  On a
//!   warm CI run only the newest pipeline's fresh artifacts parse;
//!   [`session::EmitSummary::cache_hits`] /
//!   [`session::EmitSummary::cache_misses`] count both sides no matter
//!   which emitters run.
//! * **Analyze** ([`session::Scan::analyze`]): POP tables, Extra-P-style
//!   fits, time series, change detection and the optional gate verdict
//!   — computed once, as data, merged in deterministic scan order so
//!   every `jobs` value yields byte-identical downstream output.
//! * **Emit** ([`session::Analysis::emit`]): any set of
//!   [`session::Emitter`]s — the built-in HTML site, SVG badges, gate
//!   verdict files and the schema-versioned machine-readable
//!   `report.json` ([`session::JsonReport`]) — or your own.
//!
//! Embedding the library without any HTML machinery is two stages and
//! one emitter:
//!
//! ```no_run
//! use talp_pages::session::{AnalyzeOptions, Emitter, JsonReport, Session};
//!
//! fn main() -> anyhow::Result<()> {
//!     // Scan a Fig. 2 folder (with a persistent metrics cache), then
//!     // analyze and write only the machine-readable report.json.
//!     let analysis = Session::new("talp")
//!         .cache("talp/.talp-cache.json")
//!         .scan()?
//!         .analyze(&AnalyzeOptions::default());
//!     let mut emitters: Vec<Box<dyn Emitter>> =
//!         vec![Box::new(JsonReport::new("out"))];
//!     let summary = analysis.emit(&mut emitters)?;
//!     println!(
//!         "{} experiment(s) -> out/report.json ({} cached, {} parsed)",
//!         summary.experiments, summary.cache_hits, summary.cache_misses
//!     );
//!     Ok(())
//! }
//! ```
//!
//! The in-process CI engine (`ci::runner`) points the session cache at
//! its root (outliving per-pipeline work dirs), so pipeline N's report
//! re-parses only the matrix jobs that just ran — the history it merged
//! from pipeline N-1's artifact is served from cache.
//!
//! # The run store (cross-commit history)
//!
//! The cache accelerates one output directory; the [`store`] is the
//! durable record.  Its on-disk layout (version 2):
//!
//! ```text
//! <store root>/
//!   .talp-store.json                 # manifest: {"version": 2, "shards": […]}
//!                                    #   version is strict: unknown or
//!                                    #   older versions are rejected with
//!                                    #   a clear re-ingest message; the
//!                                    #   per-shard summary array is
//!                                    #   advisory (damage tolerated)
//!   shards/
//!     <experiment-slug>__<RxT>.jsonl # one shard per (experiment, config);
//!                                    #   each line is one record:
//!                                    #   {"hash", "experiment", "run"}
//!     <…>.jsonl.idx                  # byte-offset index sidecar: header
//!                                    #   {"index_version", "shard_bytes",
//!                                    #   "corrupt_lines"} + one selection-
//!                                    #   metadata line per record; rebuilt
//!                                    #   on demand when missing or stale
//! ```
//!
//! A record's identity is its (source path, content hash) pair —
//! FNV-1a-64 over the raw bytes, the metrics cache's exact
//! invalidation rule — so `talp-pages ingest` is O(changed):
//! already-stored artifacts are hashed but never parsed, while
//! byte-identical files at different paths stay distinct history
//! points just as a direct scan keeps them.  Changed content at the
//! same path supersedes (latest per path wins, matching the current
//! folder); vanished files stay stored.  Shard loading
//! is corruption-tolerant (a truncated append becomes a warning, not a
//! lost store) and [`store::RunStore::compact`] rewrites shards past
//! the dead-byte threshold ([`store::COMPACT_DEAD_RATIO`]), dropping
//! corrupt, duplicate and superseded lines.  A store-backed session
//! ([`session::Session::from_store`], CLI `report --store` /
//! `gate --store`) runs analyze + emit over thousands of stored runs
//! without opening a single artifact, and its `report.json` is
//! byte-identical to a direct scan over the same runs.
//! [`store::RunStore::query`] (CLI `store query`, and the same filter
//! flags on `report --store`/`gate --store`) uses the sidecars to
//! seek-decode only matching lines — sub-linear in store size, with
//! the sequential scan as the validated fallback so a bad index can
//! cost time, never correctness; `store stats` reports corpus shape,
//! per-shard health and index freshness.
//!
//! # Durability & fault model
//!
//! The store assumes its writer can die at any instruction and
//! promises recovery to a state byte-identical to *before or after*
//! the interrupted operation — never a third state.  Every mutation
//! routes through two `util::fs` primitives: `durable_append`
//! (write → fsync → parent-dir fsync on create) for shard appends and
//! `durable_write_atomic` (temp → write → fsync → rename → parent-dir
//! fsync) for the manifest, sidecars and compaction rewrites.
//! [`store::fsck`] (CLI `store fsck`, dry-run by default, `--repair`
//! to heal) detects and repairs crash residue: orphan temp files,
//! empty or torn shards, manifest drift, stale sidecars, orphaned
//! writer locks; `talp-pages check` reports the same damage
//! statically as `TP025`/`TP026`.  The contract is proved by a
//! kill-point matrix test driven by `util::failpoint` — a
//! deterministic fault-injection layer (cargo feature `failpoints`,
//! activated via `TALP_FAILPOINTS` or the CLI `--failpoints` trailer)
//! guarding every registered write stage, compiled to an inlined
//! no-op in default builds.  [`serve`] shares the discipline at the
//! service level: per-connection timeouts, a bounded connection cap
//! (`503` + `Retry-After`), and a degraded mode that keeps serving
//! the last good snapshot when a refresh fails (flagged on
//! `/healthz`/`/statsz`) instead of dying.
//!
//! # Streaming vs tree JSON
//!
//! The crate has two JSON APIs over one grammar and one formatter
//! (module docs: [`util::json`]):
//!
//! * **Streaming** — [`util::json::JsonReader`] (pull/event parser
//!   over `&[u8]`, zero-copy `Cow<str>` strings, byte-offset errors)
//!   and [`util::json::JsonWriter`] (direct-to-buffer serializer).
//!   This is the hot artifact → store → report path:
//!   [`talp::RunData::from_slice`] / [`talp::RunData::write_to`],
//!   [`pop::RunMetrics::from_events`] / [`pop::RunMetrics::write_to`],
//!   store shard lines, the metrics cache and `report.json` emission
//!   all stream — a warm `report --store` never materializes a
//!   [`util::json::Json`] tree.  Use it when decoding or encoding many
//!   documents of a known schema, where allocation is the cost that
//!   matters.
//! * **Tree** — [`util::json::Json`], the order-preserving value
//!   model.  Use it for configuration files, tests, one-off documents
//!   and anywhere ergonomics beat throughput.  `Json::parse` and
//!   `to_string_compact`/`to_string_pretty` are built *on* the
//!   streaming layer, so the two APIs accept the same documents and
//!   emit identical bytes by construction.

pub mod adapters;
pub mod apps;
pub mod check;
pub mod cli;
pub mod ci;
pub mod gate;
pub mod pages;
pub mod pop;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sim;
pub mod store;
pub mod talp;
pub mod tools;
pub mod util;
