//! # talp-pages-rs
//!
//! A Rust + JAX + Pallas reproduction of *"TALP-Pages: An easy-to-
//! integrate continuous performance monitoring framework"* (Seitz,
//! Trilaksono, Garcia-Gasulla — Parallel Tools Workshop 2024).
//!
//! The crate contains (DESIGN.md has the full inventory):
//!
//! * [`sim`] — the HPC substrate: deterministic phase-level simulator of
//!   hybrid MPI+OpenMP executions (machines, DVFS, caches, collectives).
//! * [`talp`] — the TALP monitor: on-the-fly POP-factor accumulation and
//!   the DLB-style JSON output.
//! * [`pop`] — fundamental performance factors: the efficiency
//!   hierarchy, weak/strong scaling detection, scaling-efficiency tables.
//! * [`tools`] — the baseline toolchains the paper compares against
//!   (Extrae-like tracer, Score-P-like profiler+tracer, CPT) and their
//!   post-processing pipelines (Dimemas-like replay etc.).
//! * [`pages`] — TALP-Pages proper: folder scanner, time-series, HTML
//!   report, SVG badges.
//! * [`ci`] — an in-process GitLab-like CI engine (pipelines, artifact
//!   zips, pages hosting) used to reproduce the paper's CI workflow.
//! * [`apps`] — workloads: the TeaLeaf CG mini-app (backed by the real
//!   AOT-compiled Pallas kernel through [`runtime`]) and a GENE-X-like
//!   app with the injectable scaling bug of Fig. 7.
//! * [`runtime`] — PJRT loader/executor for `artifacts/*.hlo.txt`.

pub mod apps;
pub mod cli;
pub mod ci;
pub mod pages;
pub mod pop;
pub mod runtime;
pub mod sim;
pub mod talp;
pub mod tools;
pub mod util;
