//! # talp-pages-rs
//!
//! A Rust + JAX + Pallas reproduction of *"TALP-Pages: An easy-to-
//! integrate continuous performance monitoring framework"* (Seitz,
//! Trilaksono, Garcia-Gasulla — Parallel Tools Workshop 2024).
//!
//! The crate contains (DESIGN.md has the full inventory):
//!
//! * [`sim`] — the HPC substrate: deterministic phase-level simulator of
//!   hybrid MPI+OpenMP executions (machines, DVFS, caches, collectives).
//! * [`talp`] — the TALP monitor: on-the-fly POP-factor accumulation and
//!   the DLB-style JSON output.
//! * [`pop`] — fundamental performance factors: the efficiency
//!   hierarchy, weak/strong scaling detection, scaling-efficiency tables.
//! * [`tools`] — the baseline toolchains the paper compares against
//!   (Extrae-like tracer, Score-P-like profiler+tracer, CPT) and their
//!   post-processing pipelines (Dimemas-like replay etc.).
//! * [`pages`] — TALP-Pages proper: folder scanner, time-series, HTML
//!   report, SVG badges.
//! * [`ci`] — an in-process GitLab-like CI engine (pipelines, artifact
//!   zips, pages hosting) used to reproduce the paper's CI workflow.
//! * [`gate`] — the regression gate: a declarative policy over the
//!   metrics histories that turns detection into a CI pass/fail
//!   verdict (`gate.json` + markdown + JUnit XML + exit code).
//! * [`apps`] — workloads: the TeaLeaf CG mini-app (backed by the real
//!   AOT-compiled Pallas kernel through [`runtime`]) and a GENE-X-like
//!   app with the injectable scaling bug of Fig. 7.
//! * [`runtime`] — PJRT loader/executor for `artifacts/*.hlo.txt`
//!   (stubbed unless built with the `pjrt` feature — the offline image
//!   carries no `xla` bindings).
//!
//! # The report engine (pages::report)
//!
//! Report generation is parallel and incremental — the paper's Table 2
//! claim ("produce the scaling-efficiency tables faster and under
//! tighter resource constraints") as an architecture:
//!
//! * **Worker pool** (`util::par::parallel_map`): artifact parsing and
//!   per-experiment page rendering fan out over scoped threads; the
//!   `--jobs N` CLI flag (0 = auto) sizes the pool.  Results merge in
//!   deterministic order, so any `--jobs` value produces byte-identical
//!   output.
//! * **Metrics cache** (`pages::cache`): each artifact's reduced
//!   [`pop::RunMetrics`] persists in `<out>/.talp-cache.json`, keyed by
//!   relative path and validated by the FNV-1a-64 **content hash** of
//!   the raw file bytes.  An entry is reused iff the hash matches;
//!   vanished files are pruned; a corrupt or version-mismatched cache
//!   degrades to a cold start.  On a warm CI run only the newest
//!   pipeline's fresh artifacts parse
//!   ([`pages::ReportSummary::cache_hits`] /
//!   [`pages::report::ReportSummary::cache_misses`] count both sides).
//! * **CI integration** (`ci::runner`): the in-process engine points
//!   `ReportOptions::cache_path` at its root (outliving per-pipeline
//!   work dirs), so pipeline N's report re-parses only the matrix jobs
//!   that just ran — the history it merged from pipeline N-1's artifact
//!   is served from cache.

pub mod apps;
pub mod cli;
pub mod ci;
pub mod gate;
pub mod pages;
pub mod pop;
pub mod runtime;
pub mod sim;
pub mod talp;
pub mod tools;
pub mod util;
