//! ROOT-style continuous-benchmark JSON (Google-Benchmark dialect):
//! `{"context": {...}, "benchmarks": [...]}` — the format the ROOT
//! experiment's nightly performance CI publishes (PAPERS.md).
//!
//! Normalization: one file becomes one 1x1 pseudo-run.  Each
//! benchmark entry maps to a region whose elapsed time is
//! `real_time` and whose useful time is `cpu_time` (converted via
//! `time_unit`), so the region's parallel efficiency is exactly the
//! cpu/real utilization ratio the producer measured.  A synthetic
//! `Global` region (sums over all entries) is added when the producer
//! did not emit one, so badges, gates and scaling tables keyed on
//! `Global` work unchanged.
//!
//! The format carries no rank/thread axis — `report --store` shows
//! such runs as a `1x1` configuration; that loss is inherent to the
//! producer, not the adapter.

use anyhow::{bail, Context, Result};

use crate::pop::RunMetrics;
use crate::talp::{GitMeta, ProcStats, RegionData, RunData};
use crate::util::json::Json;
use crate::util::timefmt;

use super::{has_token, Adapter, Confidence};

/// ROOT/Google-Benchmark continuous-benchmark JSON (one pseudo-run
/// per file).
pub struct RootBenchAdapter;

/// Seconds per `time_unit` (Google Benchmark defaults to ns).
fn unit_seconds(unit: &str) -> Result<f64> {
    Ok(match unit {
        "ns" => 1e-9,
        "us" => 1e-6,
        "ms" => 1e-3,
        "s" => 1.0,
        other => bail!("unknown time_unit '{other}'"),
    })
}

impl Adapter for RootBenchAdapter {
    fn name(&self) -> &'static str {
        "root-bench"
    }

    fn description(&self) -> &'static str {
        "ROOT-style continuous-benchmark JSON (context + benchmarks)"
    }

    fn detect(&self, bytes: &[u8]) -> Confidence {
        if has_token(bytes, "\"benchmarks\"") {
            if has_token(bytes, "\"context\"") {
                Confidence::Yes
            } else {
                Confidence::Maybe
            }
        } else {
            Confidence::No
        }
    }

    fn parse(&self, bytes: &[u8], source: &str) -> Result<Vec<RunMetrics>> {
        let text = std::str::from_utf8(bytes)
            .with_context(|| format!("parsing {source}: not UTF-8"))?;
        let j = Json::parse(text)
            .with_context(|| format!("parsing {source}"))?;
        let ctx = j
            .get("context")
            .with_context(|| format!("parsing {source}: missing context"))?;
        let timestamp = ctx
            .get("date")
            .and_then(Json::as_str)
            .and_then(timefmt::from_iso8601)
            .with_context(|| {
                format!("parsing {source}: missing/bad context.date")
            })?;
        let entries = j
            .get("benchmarks")
            .and_then(Json::as_arr)
            .with_context(|| {
                format!("parsing {source}: benchmarks is not a list")
            })?;
        if entries.is_empty() {
            bail!("parsing {source}: no benchmarks");
        }

        let mut regions: Vec<RegionData> = Vec::with_capacity(entries.len());
        let (mut sum_elapsed, mut sum_useful) = (0.0f64, 0.0f64);
        let mut saw_global = false;
        for (i, b) in entries.iter().enumerate() {
            let name = b
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| {
                    format!("parsing {source}: benchmark #{i} has no name")
                })?
                .to_string();
            let unit = unit_seconds(b.str_or("time_unit", "ns"))
                .with_context(|| format!("parsing {source}: '{name}'"))?;
            let real = b.num_or("real_time", f64::NAN) * unit;
            if !real.is_finite() || real < 0.0 {
                bail!("parsing {source}: '{name}' has no real_time");
            }
            // Missing cpu_time degrades to full utilization, like a
            // serial benchmark that never sleeps.
            let mut cpu = b.num_or("cpu_time", f64::NAN) * unit;
            if !cpu.is_finite() {
                cpu = real;
            }
            let cpu = cpu.clamp(0.0, real);
            saw_global |= name == "Global";
            sum_elapsed += real;
            sum_useful += cpu;
            regions.push(RegionData {
                name,
                elapsed_s: real,
                visits: b.get("iterations").and_then(Json::as_u64).unwrap_or(1),
                procs: vec![ProcStats {
                    rank: 0,
                    elapsed_s: real,
                    useful_s: cpu,
                    ..Default::default()
                }],
            });
        }
        if !saw_global {
            regions.insert(
                0,
                RegionData {
                    name: "Global".to_string(),
                    elapsed_s: sum_elapsed,
                    visits: 1,
                    procs: vec![ProcStats {
                        rank: 0,
                        elapsed_s: sum_elapsed,
                        useful_s: sum_useful,
                        ..Default::default()
                    }],
                },
            );
        }

        let git = ctx.get("commit").and_then(Json::as_str).map(|commit| {
            GitMeta {
                commit: commit.to_string(),
                branch: ctx.str_or("branch", "main").to_string(),
                commit_timestamp: ctx
                    .get("commit_date")
                    .and_then(Json::as_str)
                    .and_then(timefmt::from_iso8601)
                    .unwrap_or(timestamp),
                message: ctx.str_or("commit_message", "").to_string(),
            }
        });
        let data = RunData {
            dlb_version: "root-bench".to_string(),
            app: ctx.str_or("executable", "root-bench").to_string(),
            machine: ctx.str_or("host_name", "unknown").to_string(),
            timestamp,
            ranks: 1,
            threads: 1,
            nodes: 1,
            regions,
            git,
        };
        Ok(vec![RunMetrics::from_run(&data, source)])
    }

    fn emit(&self, data: &RunData) -> String {
        let mut ctx = Json::obj();
        ctx.push_field(
            "date",
            Json::Str(timefmt::to_iso8601(data.timestamp)),
        );
        ctx.push_field("executable", Json::Str(data.app.clone()));
        ctx.push_field("host_name", Json::Str(data.machine.clone()));
        ctx.push_field(
            "num_cpus",
            Json::Num((data.ranks * data.threads) as f64),
        );
        if let Some(g) = &data.git {
            ctx.push_field("commit", Json::Str(g.commit.clone()));
            ctx.push_field("branch", Json::Str(g.branch.clone()));
            ctx.push_field(
                "commit_date",
                Json::Str(timefmt::to_iso8601(g.commit_timestamp)),
            );
            ctx.push_field("commit_message", Json::Str(g.message.clone()));
        }
        let ncpus = (data.ranks * data.threads).max(1) as f64;
        let benchmarks: Vec<Json> = data
            .regions
            .iter()
            .map(|reg| {
                let useful: f64 =
                    reg.procs.iter().map(|p| p.useful_s).sum();
                Json::from_pairs(vec![
                    ("name", Json::Str(reg.name.clone())),
                    ("iterations", Json::Num(reg.visits as f64)),
                    (
                        "real_time",
                        Json::Num((reg.elapsed_s * 1e9).round()),
                    ),
                    // Mean useful per cpu keeps the parsed 1x1 run's
                    // parallel efficiency equal to this run's.
                    (
                        "cpu_time",
                        Json::Num((useful / ncpus * 1e9).round()),
                    ),
                    ("time_unit", Json::Str("ns".to_string())),
                ])
            })
            .collect();
        let mut root = Json::obj();
        root.push_field("context", ctx);
        root.push_field("benchmarks", Json::Arr(benchmarks));
        root.to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc() -> &'static str {
        r#"{
  "context": {
    "date": "2026-01-05T12:00:00Z",
    "executable": "tree-io",
    "host_name": "runner-7",
    "num_cpus": 8,
    "commit": "feedc0defeedc0de",
    "branch": "main",
    "commit_date": "2026-01-05T11:00:00Z",
    "commit_message": "speed up basket reads"
  },
  "benchmarks": [
    {"name": "BM_Read", "iterations": 50, "real_time": 2.0e9,
     "cpu_time": 1.5e9, "time_unit": "ns"},
    {"name": "BM_Write", "iterations": 20, "real_time": 1.0e9,
     "cpu_time": 0.9e9, "time_unit": "ns"}
  ]
}"#
    }

    #[test]
    fn detects_and_parses_with_synthetic_global() {
        let bytes = doc().as_bytes();
        assert_eq!(RootBenchAdapter.detect(bytes), Confidence::Yes);
        let runs =
            RootBenchAdapter.parse(bytes, "ci/bench.json").unwrap();
        assert_eq!(runs.len(), 1);
        let run = &runs[0];
        assert_eq!(run.source, "ci/bench.json");
        assert_eq!((run.ranks, run.threads), (1, 1));
        assert_eq!(run.app, "tree-io");
        assert_eq!(run.machine, "runner-7");
        let names: Vec<&str> =
            run.regions.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, ["Global", "BM_Read", "BM_Write"]);
        // Global sums: 3s elapsed, 2.4s useful → PE 0.8.
        let g = run.region("Global").unwrap();
        assert!((g.metrics.elapsed_s - 3.0).abs() < 1e-9);
        assert!((g.metrics.parallel_efficiency - 0.8).abs() < 1e-9);
        // cpu/real per entry: BM_Read PE = 0.75.
        let r = run.region("BM_Read").unwrap();
        assert!((r.metrics.parallel_efficiency - 0.75).abs() < 1e-9);
        assert_eq!(r.visits, 50);
        let git = run.git.as_ref().unwrap();
        assert_eq!(git.commit, "feedc0defeedc0de");
        assert_eq!(run.effective_timestamp(), git.commit_timestamp);
    }

    #[test]
    fn time_units_convert() {
        let text = r#"{"context": {"date": "2026-01-01T00:00:00Z"},
            "benchmarks": [
              {"name": "Global", "real_time": 1500.0,
               "cpu_time": 750.0, "time_unit": "ms"}]}"#;
        let runs =
            RootBenchAdapter.parse(text.as_bytes(), "b.json").unwrap();
        let g = runs[0].region("Global").unwrap();
        assert!((g.metrics.elapsed_s - 1.5).abs() < 1e-9);
        assert!((g.metrics.parallel_efficiency - 0.5).abs() < 1e-9);
    }

    #[test]
    fn rejects_malformed() {
        for text in [
            "{}",
            r#"{"context": {}, "benchmarks": []}"#,
            r#"{"context": {"date": "2026-01-01T00:00:00Z"},
                "benchmarks": [{"iterations": 1}]}"#,
            r#"{"context": {"date": "2026-01-01T00:00:00Z"},
                "benchmarks": [{"name": "x", "real_time": 1,
                                "time_unit": "fortnights"}]}"#,
            r#"{"context": {"date": "nope"}, "benchmarks": [
                {"name": "x", "real_time": 1}]}"#,
        ] {
            assert!(
                RootBenchAdapter.parse(text.as_bytes(), "b.json").is_err(),
                "{text}"
            );
        }
    }

    #[test]
    fn emit_parse_round_trip_preserves_efficiency() {
        let runs =
            RootBenchAdapter.parse(doc().as_bytes(), "a.json").unwrap();
        // Re-emit from a canonical RunData and parse again: the
        // Global PE must survive the lossy round trip.
        let data = RunData {
            dlb_version: "x".into(),
            app: "tree-io".into(),
            machine: "runner-7".into(),
            timestamp: 1_700_000_000,
            ranks: 2,
            threads: 4,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 10.0,
                visits: 1,
                procs: (0..2)
                    .map(|r| ProcStats {
                        rank: r,
                        elapsed_s: 10.0,
                        useful_s: 30.0, // PE = 60 / (8*10) = 0.75
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: None,
        };
        let emitted = RootBenchAdapter.emit(&data);
        let back = RootBenchAdapter
            .parse(emitted.as_bytes(), "b.json")
            .unwrap();
        let pe_before = runs[0]
            .region("Global")
            .unwrap()
            .metrics
            .parallel_efficiency;
        assert!((pe_before - 0.8).abs() < 1e-9);
        let pe = back[0]
            .region("Global")
            .unwrap()
            .metrics
            .parallel_efficiency;
        assert!((pe - 0.75).abs() < 1e-9, "{pe}");
        assert!(emitted.ends_with('\n'));
    }
}
