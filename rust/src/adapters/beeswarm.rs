//! BeeSwarm-style CI scalability-test output: one JSON per sweep,
//! with a `scales` array of `{processes, threads, time_s,
//! efficiency}` points (PAPERS.md).  This is the multi-run format —
//! one file expands into one record per scale point, each suffixed
//! `#<RxT>` so the store keeps every configuration as its own
//! history.
//!
//! Normalization: each scale point becomes a single-`Global`-region
//! run whose per-rank useful time is `time_s * efficiency * threads`,
//! so the computed parallel efficiency equals the producer's reported
//! efficiency exactly (`PE = Σu_p / (ncpu·E)`); the region tree and
//! MPI/OpenMP split are lost, which is inherent to the producer.

use anyhow::{bail, Context, Result};

use crate::pop::RunMetrics;
use crate::talp::{GitMeta, ProcStats, RegionData, RunData};
use crate::util::json::Json;
use crate::util::timefmt;

use super::{has_token, Adapter, Confidence};

/// BeeSwarm-style scalability sweep JSON (one run per scale point).
pub struct BeeSwarmAdapter;

impl Adapter for BeeSwarmAdapter {
    fn name(&self) -> &'static str {
        "beeswarm"
    }

    fn description(&self) -> &'static str {
        "BeeSwarm-style CI scalability sweep (one run per scale point)"
    }

    fn detect(&self, bytes: &[u8]) -> Confidence {
        if has_token(bytes, "\"scales\"") {
            Confidence::Yes
        } else {
            Confidence::No
        }
    }

    fn parse(&self, bytes: &[u8], source: &str) -> Result<Vec<RunMetrics>> {
        let text = std::str::from_utf8(bytes)
            .with_context(|| format!("parsing {source}: not UTF-8"))?;
        let j = Json::parse(text)
            .with_context(|| format!("parsing {source}"))?;
        let timestamp = j
            .get("timestamp")
            .and_then(Json::as_str)
            .and_then(timefmt::from_iso8601)
            .with_context(|| {
                format!("parsing {source}: missing/bad timestamp")
            })?;
        let app = j.str_or("application", "beeswarm").to_string();
        let machine = j.str_or("machine", "unknown").to_string();
        let git = j.get("commit").and_then(Json::as_str).map(|commit| {
            GitMeta {
                commit: commit.to_string(),
                branch: j.str_or("branch", "main").to_string(),
                commit_timestamp: j
                    .get("commit_date")
                    .and_then(Json::as_str)
                    .and_then(timefmt::from_iso8601)
                    .unwrap_or(timestamp),
                message: j.str_or("commit_message", "").to_string(),
            }
        });
        let scales = j
            .get("scales")
            .and_then(Json::as_arr)
            .with_context(|| {
                format!("parsing {source}: scales is not a list")
            })?;
        if scales.is_empty() {
            bail!("parsing {source}: no scale points");
        }

        let mut runs = Vec::with_capacity(scales.len());
        for (i, s) in scales.iter().enumerate() {
            let ranks = s
                .get("processes")
                .and_then(Json::as_u64)
                .with_context(|| {
                    format!("parsing {source}: scale #{i} has no processes")
                })? as u32;
            let threads =
                s.get("threads").and_then(Json::as_u64).unwrap_or(1) as u32;
            if ranks == 0 || threads == 0 {
                bail!(
                    "parsing {source}: scale #{i} resources must be \
                     positive ({ranks}x{threads})"
                );
            }
            let time_s = s.num_or("time_s", f64::NAN);
            if !time_s.is_finite() || time_s <= 0.0 {
                bail!("parsing {source}: scale #{i} has no time_s");
            }
            let efficiency = s.num_or("efficiency", f64::NAN);
            if !efficiency.is_finite() {
                bail!("parsing {source}: scale #{i} has no efficiency");
            }
            let efficiency = efficiency.clamp(0.0, 1.0);
            let nodes =
                s.get("nodes").and_then(Json::as_u64).unwrap_or(1) as u32;
            let data = RunData {
                dlb_version: "beeswarm".to_string(),
                app: app.clone(),
                machine: machine.clone(),
                timestamp,
                ranks,
                threads,
                nodes,
                regions: vec![RegionData {
                    name: "Global".to_string(),
                    elapsed_s: time_s,
                    visits: 1,
                    procs: (0..ranks)
                        .map(|rank| ProcStats {
                            rank,
                            elapsed_s: time_s,
                            // Σ useful = ranks·threads·time·eff, so the
                            // computed PE is exactly `efficiency`.
                            useful_s: time_s * efficiency * threads as f64,
                            ..Default::default()
                        })
                        .collect(),
                }],
                git: git.clone(),
            };
            let run_source = format!("{source}#{ranks}x{threads}");
            runs.push(RunMetrics::from_run(&data, &run_source));
        }
        Ok(runs)
    }

    fn emit(&self, data: &RunData) -> String {
        let mut root = Json::obj();
        root.push_field("application", Json::Str(data.app.clone()));
        root.push_field("machine", Json::Str(data.machine.clone()));
        root.push_field(
            "timestamp",
            Json::Str(timefmt::to_iso8601(data.timestamp)),
        );
        if let Some(g) = &data.git {
            root.push_field("commit", Json::Str(g.commit.clone()));
            root.push_field("branch", Json::Str(g.branch.clone()));
            root.push_field(
                "commit_date",
                Json::Str(timefmt::to_iso8601(g.commit_timestamp)),
            );
            root.push_field(
                "commit_message",
                Json::Str(g.message.clone()),
            );
        }
        // One emitted run is one scale point; the simulator merges
        // points by concatenating `scales` arrays before writing.
        let global = data.region("Global").or(data.regions.first());
        let (time_s, efficiency) = match global {
            Some(reg) => {
                let useful: f64 =
                    reg.procs.iter().map(|p| p.useful_s).sum();
                let ncpu = (data.ranks * data.threads).max(1) as f64;
                let pe = if reg.elapsed_s > 0.0 {
                    (useful / (ncpu * reg.elapsed_s)).clamp(0.0, 1.0)
                } else {
                    0.0
                };
                (reg.elapsed_s, pe)
            }
            None => (0.0, 0.0),
        };
        root.push_field(
            "scales",
            Json::Arr(vec![Json::from_pairs(vec![
                ("processes", Json::Num(data.ranks as f64)),
                ("threads", Json::Num(data.threads as f64)),
                ("nodes", Json::Num(data.nodes as f64)),
                ("time_s", Json::Num(time_s)),
                ("efficiency", Json::Num(efficiency)),
            ])]),
        );
        root.to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc() -> &'static str {
        r#"{
  "application": "lulesh",
  "machine": "cluster-a",
  "timestamp": "2026-02-01T08:00:00Z",
  "commit": "0123456789abcdef",
  "branch": "main",
  "commit_date": "2026-02-01T07:30:00Z",
  "commit_message": "tune halo exchange",
  "scales": [
    {"processes": 1, "threads": 4, "time_s": 40.0, "efficiency": 1.0},
    {"processes": 2, "threads": 4, "time_s": 21.0, "efficiency": 0.95},
    {"processes": 4, "threads": 4, "time_s": 11.5, "efficiency": 0.87}
  ]
}"#
    }

    #[test]
    fn detects_and_expands_one_run_per_scale() {
        let bytes = doc().as_bytes();
        assert_eq!(BeeSwarmAdapter.detect(bytes), Confidence::Yes);
        let runs =
            BeeSwarmAdapter.parse(bytes, "exp/sweep.json").unwrap();
        assert_eq!(runs.len(), 3);
        let sources: Vec<&str> =
            runs.iter().map(|r| r.source.as_str()).collect();
        assert_eq!(
            sources,
            [
                "exp/sweep.json#1x4",
                "exp/sweep.json#2x4",
                "exp/sweep.json#4x4"
            ]
        );
        let labels: Vec<String> =
            runs.iter().map(|r| r.resources().label()).collect();
        assert_eq!(labels, ["1x4", "2x4", "4x4"]);
        // Reported efficiency is reproduced exactly as PE.
        for (run, want) in runs.iter().zip([1.0, 0.95, 0.87]) {
            let pe = run
                .region("Global")
                .unwrap()
                .metrics
                .parallel_efficiency;
            assert!((pe - want).abs() < 1e-9, "{pe} vs {want}");
            assert_eq!(run.app, "lulesh");
            assert_eq!(
                run.git.as_ref().unwrap().commit,
                "0123456789abcdef"
            );
        }
    }

    #[test]
    fn rejects_malformed() {
        for text in [
            "{}",
            r#"{"timestamp": "2026-01-01T00:00:00Z", "scales": []}"#,
            r#"{"timestamp": "2026-01-01T00:00:00Z",
                "scales": [{"threads": 2, "time_s": 1,
                            "efficiency": 0.5}]}"#,
            r#"{"timestamp": "2026-01-01T00:00:00Z",
                "scales": [{"processes": 0, "time_s": 1,
                            "efficiency": 0.5}]}"#,
            r#"{"timestamp": "2026-01-01T00:00:00Z",
                "scales": [{"processes": 2, "efficiency": 0.5}]}"#,
            r#"{"timestamp": "2026-01-01T00:00:00Z",
                "scales": [{"processes": 2, "time_s": 3}]}"#,
            r#"{"scales": [{"processes": 2, "time_s": 3,
                            "efficiency": 0.5}]}"#,
        ] {
            assert!(
                BeeSwarmAdapter.parse(text.as_bytes(), "s.json").is_err(),
                "{text}"
            );
        }
    }

    #[test]
    fn emit_parse_round_trip_preserves_scale_and_efficiency() {
        let data = RunData {
            dlb_version: "x".into(),
            app: "lulesh".into(),
            machine: "cluster-a".into(),
            timestamp: 1_750_000_000,
            ranks: 4,
            threads: 2,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 8.0,
                visits: 1,
                procs: (0..4)
                    .map(|rank| ProcStats {
                        rank,
                        elapsed_s: 8.0,
                        useful_s: 8.0 * 0.9 * 2.0,
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: None,
        };
        let emitted = BeeSwarmAdapter.emit(&data);
        assert!(emitted.ends_with('\n'));
        let back = BeeSwarmAdapter
            .parse(emitted.as_bytes(), "s.json")
            .unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].source, "s.json#4x2");
        let g = back[0].region("Global").unwrap();
        assert!((g.metrics.elapsed_s - 8.0).abs() < 1e-9);
        assert!((g.metrics.parallel_efficiency - 0.9).abs() < 1e-9);
    }
}
