//! Multi-format ingestion adapters (ROADMAP open item 3).
//!
//! Only TALP artifacts parsed before this layer existed; real projects
//! run heterogeneous suites, so the store/gate/report stack gains a
//! pluggable front end.  An [`Adapter`] recognizes a producer's JSON
//! dialect ([`Adapter::detect`]) and normalizes each document into one
//! or more [`pop::RunMetrics`](crate::pop::RunMetrics) — the form
//! every downstream consumer (store, gate, report, serve, check)
//! already speaks — so nothing after admission changes per format.
//!
//! The registry holds three adapters:
//!
//! | name         | producer                                   | detection tokens             |
//! |--------------|--------------------------------------------|------------------------------|
//! | `talp`       | DLB/TALP artifact (the native format)      | `"resources"` + `"regions"`  |
//! | `root-bench` | ROOT-style continuous-benchmark JSON       | `"context"` + `"benchmarks"` |
//! | `beeswarm`   | BeeSwarm-style CI scalability-test output  | `"scales"`                   |
//!
//! Detection is intentionally dumb — token presence over the raw
//! bytes, no parse — so it is O(bytes) and cannot fail; a document
//! claimed by more than one adapter is [`Detection::Ambiguous`], which
//! the admission path turns into a hard error rather than guessing.
//!
//! Every adapter can also *emit* its format from the canonical
//! [`RunData`] interchange form ([`Adapter::emit`]), which is how the
//! deterministic workload simulator (`talp-pages sim`,
//! [`crate::sim::corpus`]) writes corpora in any registered format.
//! Lossy formats round-trip lossily by design: `root-bench` flattens
//! to one 1x1 pseudo-run per file (preserving the efficiency ratio as
//! cpu_time/real_time), `beeswarm` keeps only per-scale totals.
//!
//! Multi-run documents (one BeeSwarm file holds a whole scaling
//! sweep) expand into one record per entry with the source suffixed
//! `#<RxT>`, e.g. `exp/sweep.json#4x2`; the store's file-level
//! identity ([`crate::store::RunStore::contains_file`]) strips the
//! suffix so warm re-ingest still hashes-and-skips whole files.

use anyhow::Result;

use crate::pop::RunMetrics;
use crate::talp::RunData;

mod beeswarm;
mod root_bench;
mod talp;

pub use beeswarm::BeeSwarmAdapter;
pub use root_bench::RootBenchAdapter;
pub use talp::TalpAdapter;

/// How strongly an adapter claims a document.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Confidence {
    /// The document is definitely not this format.
    No,
    /// Weak structural hints (e.g. one of two expected tokens).
    Maybe,
    /// The format's distinguishing tokens are all present.
    Yes,
}

/// One ingestion format: recognize, normalize, emit.
///
/// `Sync` because the registry is a `static` shared across ingest
/// worker threads.
pub trait Adapter: Sync {
    /// Registry name (`--format <name>`, `format=` query param).
    fn name(&self) -> &'static str;

    /// One-line description for `--help` and the README format table.
    fn description(&self) -> &'static str;

    /// Cheap, infallible format sniff over the raw bytes.
    fn detect(&self, bytes: &[u8]) -> Confidence;

    /// Normalize one document into run records.  `source` is the
    /// scan-root-relative path of the file; single-run formats return
    /// one record with `run.source == source`, multi-run formats
    /// suffix each record `#<RxT>`.  Every returned run's `source`
    /// must start with `source`.
    fn parse(&self, bytes: &[u8], source: &str) -> Result<Vec<RunMetrics>>;

    /// Render one canonical run in this adapter's on-disk format
    /// (pretty-printed, trailing newline) — the simulator's writer.
    fn emit(&self, data: &RunData) -> String;
}

/// All registered adapters, in detection order (`talp` first — the
/// native format wins name lookups and docs list it first).
pub fn registry() -> &'static [&'static dyn Adapter] {
    static REGISTRY: [&'static dyn Adapter; 3] =
        [&TalpAdapter, &RootBenchAdapter, &BeeSwarmAdapter];
    &REGISTRY
}

/// Look an adapter up by its registry name.
pub fn by_name(name: &str) -> Option<&'static dyn Adapter> {
    registry().iter().copied().find(|a| a.name() == name)
}

/// Comma-separated registry names (error messages, usage text).
pub fn names() -> String {
    registry()
        .iter()
        .map(|a| a.name())
        .collect::<Vec<_>>()
        .join("|")
}

/// Outcome of registry auto-detection over one document.
#[derive(Debug, Clone, Copy)]
pub enum Detection {
    /// Exactly one adapter claims the document at the highest
    /// confidence present.
    Match(&'static dyn Adapter),
    /// More than one adapter claims it equally — admission refuses to
    /// guess (hard error).
    Ambiguous(&'static str, &'static str),
    /// No adapter recognizes the document.
    Unknown,
}

/// Auto-detect the format of `bytes` against the whole registry.
///
/// `Yes` claims beat `Maybe` claims; two claims at the same winning
/// confidence are [`Detection::Ambiguous`].  A document that is not
/// even a JSON object is [`Detection::Unknown`] without consulting
/// any adapter.
pub fn detect(bytes: &[u8]) -> Detection {
    let starts_like_json = bytes
        .iter()
        .find(|b| !b" \t\r\n".contains(b))
        .map(|&b| b == b'{')
        .unwrap_or(false);
    if !starts_like_json {
        return Detection::Unknown;
    }
    for want in [Confidence::Yes, Confidence::Maybe] {
        let mut claims = registry()
            .iter()
            .copied()
            .filter(|a| a.detect(bytes) == want);
        if let Some(first) = claims.next() {
            return match claims.next() {
                Some(second) => {
                    Detection::Ambiguous(first.name(), second.name())
                }
                None => Detection::Match(first),
            };
        }
    }
    Detection::Unknown
}

/// `true` if the quoted JSON key (`"token"`) appears anywhere in the
/// document bytes — the detection primitive shared by the adapters.
pub(crate) fn has_token(bytes: &[u8], token: &str) -> bool {
    debug_assert!(token.starts_with('"') && token.ends_with('"'));
    let t = token.as_bytes();
    t.len() <= bytes.len()
        && bytes.windows(t.len()).any(|w| w == t)
}

/// Strip a multi-run record's `#<RxT>` suffix back to the file path
/// the record came from (identity for single-run sources).
pub fn file_of(source: &str) -> &str {
    match source.find('#') {
        Some(i) => &source[..i],
        None => source,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MachineSpec, ResourceConfig};

    pub(crate) fn talp_doc() -> Vec<u8> {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 4);
        let mut app =
            crate::apps::Genex::salpha(1, crate::apps::CodeVersion::fixed());
        app.timesteps = 2;
        let (data, _) =
            crate::apps::run_with_talp(&app, &machine, &res, 11, 1_700_000_000);
        TalpAdapter.emit(&data).into_bytes()
    }

    #[test]
    fn registry_names_are_stable_and_unique() {
        let names: Vec<&str> =
            registry().iter().map(|a| a.name()).collect();
        assert_eq!(names, ["talp", "root-bench", "beeswarm"]);
        assert!(by_name("talp").is_some());
        assert!(by_name("root-bench").is_some());
        assert!(by_name("beeswarm").is_some());
        assert!(by_name("nope").is_none());
        assert_eq!(names(), "talp|root-bench|beeswarm");
    }

    #[test]
    fn each_emitted_doc_detects_as_exactly_its_own_adapter() {
        let data = RunData::from_slice(
            &talp_doc(),
            std::path::Path::new("t.json"),
        )
        .unwrap();
        for adapter in registry() {
            let doc = adapter.emit(&data);
            match detect(doc.as_bytes()) {
                Detection::Match(a) => assert_eq!(
                    a.name(),
                    adapter.name(),
                    "emitted {} doc must detect as itself",
                    adapter.name()
                ),
                other => panic!(
                    "{} doc detected as {other:?}",
                    adapter.name()
                ),
            }
        }
    }

    #[test]
    fn ambiguous_and_unknown_detection() {
        // Tokens of two formats in one document: refuse to guess.
        let doc = br#"{"scales": [], "context": {}, "benchmarks": []}"#;
        match detect(doc) {
            Detection::Ambiguous(a, b) => {
                assert_ne!(a, b);
            }
            other => panic!("expected ambiguous, got {other:?}"),
        }
        assert!(matches!(detect(b"{\"app\": 1}"), Detection::Unknown));
        assert!(matches!(detect(b"]["), Detection::Unknown));
        assert!(matches!(detect(b""), Detection::Unknown));
        assert!(matches!(detect(b"[1, 2]"), Detection::Unknown));
    }

    #[test]
    fn maybe_claims_resolve_only_without_yes() {
        // "benchmarks" alone is a Maybe for root-bench; with no Yes
        // claim anywhere it resolves to root-bench.
        match detect(br#"{"benchmarks": []}"#) {
            Detection::Match(a) => assert_eq!(a.name(), "root-bench"),
            other => panic!("{other:?}"),
        }
        // A Yes claim (beeswarm's "scales") outranks the Maybe.
        match detect(br#"{"benchmarks": 0, "scales": []}"#) {
            Detection::Match(a) => assert_eq!(a.name(), "beeswarm"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn file_of_strips_multi_run_suffix() {
        assert_eq!(file_of("exp/sweep.json#4x2"), "exp/sweep.json");
        assert_eq!(file_of("exp/run.json"), "exp/run.json");
        assert_eq!(file_of("a#b#c"), "a");
    }
}
