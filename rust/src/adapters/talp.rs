//! The native format as an adapter: a thin wrapper over the existing
//! streaming [`RunData::from_slice`] decoder and [`RunData::write_to`]
//! encoder, so the unified admission path has no special case for
//! TALP and the simulator emits byte-identical artifacts to
//! [`RunData::write_file`].

use std::path::Path;

use anyhow::Result;

use crate::pop::RunMetrics;
use crate::talp::RunData;
use crate::util::json::JsonWriter;

use super::{has_token, Adapter, Confidence};

/// DLB/TALP artifact JSON (one run per file).
pub struct TalpAdapter;

impl Adapter for TalpAdapter {
    fn name(&self) -> &'static str {
        "talp"
    }

    fn description(&self) -> &'static str {
        "DLB/TALP artifact JSON (native format, one run per file)"
    }

    fn detect(&self, bytes: &[u8]) -> Confidence {
        if has_token(bytes, "\"resources\"") && has_token(bytes, "\"regions\"")
        {
            Confidence::Yes
        } else if has_token(bytes, "\"dlb_version\"") {
            Confidence::Maybe
        } else {
            Confidence::No
        }
    }

    fn parse(&self, bytes: &[u8], source: &str) -> Result<Vec<RunMetrics>> {
        let data = RunData::from_slice(bytes, Path::new(source))?;
        Ok(vec![RunMetrics::from_run(&data, source)])
    }

    fn emit(&self, data: &RunData) -> String {
        // The exact bytes `RunData::write_file` puts on disk.
        let procs: usize = data.regions.iter().map(|r| r.procs.len()).sum();
        let mut w = JsonWriter::with_capacity(1024 + procs * 470, true);
        data.write_to(&mut w);
        w.newline();
        w.into_string()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::talp_doc;
    use super::*;

    #[test]
    fn detects_and_parses_native_artifacts() {
        let doc = talp_doc();
        assert_eq!(TalpAdapter.detect(&doc), Confidence::Yes);
        let runs = TalpAdapter.parse(&doc, "exp/a.json").unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].source, "exp/a.json");
        assert_eq!(runs[0].resources().label(), "2x4");
    }

    #[test]
    fn parse_matches_direct_scan_reduction() {
        // The adapter path must be the identical reduction the folder
        // scanner performs — same decoder, same `from_run`.
        let doc = talp_doc();
        let data =
            RunData::from_slice(&doc, Path::new("exp/a.json")).unwrap();
        let direct = RunMetrics::from_run(&data, "exp/a.json");
        let adapted =
            TalpAdapter.parse(&doc, "exp/a.json").unwrap().remove(0);
        assert_eq!(
            adapted.to_json().to_string_compact(),
            direct.to_json().to_string_compact()
        );
    }

    #[test]
    fn emit_round_trips_byte_identically() {
        let doc = talp_doc();
        let data =
            RunData::from_slice(&doc, Path::new("x.json")).unwrap();
        assert_eq!(TalpAdapter.emit(&data).as_bytes(), &doc[..]);
    }

    #[test]
    fn rejects_non_talp() {
        assert_eq!(
            TalpAdapter.detect(br#"{"benchmarks": []}"#),
            Confidence::No
        );
        assert!(TalpAdapter.parse(b"{}", "x.json").is_err());
    }
}
