//! Cache / IPC model.
//!
//! Maps a phase's per-thread working set to (ipc, stall_fraction).  The
//! interesting regime for the paper's tables is the LLC boundary: the
//! TeaLeaf strong-scaling experiment halves the per-thread working set
//! from ~2x the LLC share to well under it, which is what produces the
//! super-linear IPC scalability (~3.1x) in Table 7, while weak scaling
//! keeps the per-thread set constant and IPC flat (Table 6).
//!
//! The transition is a logistic in log(working set / capacity) — smooth,
//! monotone, and deliberately simple: TALP only ever sees the resulting
//! aggregate counters.

use super::machine::MachineSpec;

/// Result of the cache model for one phase.
#[derive(Debug, Clone, Copy)]
pub struct CacheEffect {
    pub ipc: f64,
    /// Fraction of cycles stalled on memory (feeds the DVFS model).
    pub stall_fraction: f64,
}

/// `threads_on_socket` matters because the LLC is shared: each thread's
/// effective slice is llc / threads.
pub fn effect(
    m: &MachineSpec,
    working_set_bytes: f64,
    threads_on_socket: u32,
) -> CacheEffect {
    let llc_share =
        m.llc_bytes as f64 / threads_on_socket.max(1) as f64;
    // Blend between L2-resident (best), LLC-resident (good) and
    // DRAM-bound (floor).
    let fit_l2 = fit_fraction(working_set_bytes, m.l2_bytes as f64);
    let fit_llc = fit_fraction(working_set_bytes, llc_share);
    // Weight: L2 hit is full speed; LLC hit ~95% of peak IPC; DRAM floor.
    let cache_quality = fit_l2 + (1.0 - fit_l2) * 0.95 * fit_llc;
    let ipc = m.ipc_mem + (m.ipc_cache - m.ipc_mem) * cache_quality;
    let stall = 1.0 - cache_quality;
    CacheEffect { ipc, stall_fraction: stall.clamp(0.0, 1.0) }
}

/// Logistic "does `ws` fit in `capacity`" in log2 space: ~1 when
/// ws << capacity, ~0 when ws >> capacity, 0.5 at ws == capacity.
fn fit_fraction(ws: f64, capacity: f64) -> f64 {
    if ws <= 0.0 {
        return 1.0;
    }
    let x = (capacity.max(1.0) / ws).log2();
    // Steep transition: caches either capture a stencil sweep's reuse or
    // they don't; the half-octave blur models partial-line/halo effects.
    1.0 / (1.0 + (-5.0 * x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_working_set_hits_peak_ipc() {
        let m = MachineSpec::marenostrum5();
        let e = effect(&m, 64.0 * 1024.0, 56);
        assert!(e.ipc > 0.9 * m.ipc_cache, "ipc {}", e.ipc);
        assert!(e.stall_fraction < 0.15);
    }

    #[test]
    fn huge_working_set_hits_memory_floor() {
        let m = MachineSpec::marenostrum5();
        let e = effect(&m, 4e9, 56);
        assert!(e.ipc < 1.3 * m.ipc_mem, "ipc {}", e.ipc);
        assert!(e.stall_fraction > 0.8);
    }

    #[test]
    fn monotone_in_working_set() {
        let m = MachineSpec::marenostrum5();
        let mut last = f64::INFINITY;
        for ws in [1e4, 1e5, 1e6, 1e7, 1e8, 1e9] {
            let e = effect(&m, ws, 56);
            assert!(e.ipc <= last + 1e-12, "not monotone at {ws}");
            last = e.ipc;
        }
    }

    #[test]
    fn llc_sharing_penalizes_dense_threads() {
        let m = MachineSpec::marenostrum5();
        let ws = 3e6; // ~LLC-share scale
        let sparse = effect(&m, ws, 8);
        let dense = effect(&m, ws, 56);
        assert!(sparse.ipc > dense.ipc);
    }

    #[test]
    fn tealeaf_strong_scaling_ipc_jump() {
        // 4000^2 grid, ~5 f64 arrays (TeaLeaf CG state): per-thread
        // slice at 2x56 vs 4x56 straddles the combined cache share.
        let m = MachineSpec::marenostrum5();
        let cells = 4000.0 * 4000.0;
        let bytes = cells * 5.0 * 8.0;
        let ws_2x56 = bytes / 112.0;
        let ws_4x56 = bytes / 224.0;
        let e2 = effect(&m, ws_2x56, 56);
        let e4 = effect(&m, ws_4x56, 56);
        let scal = e4.ipc / e2.ipc;
        assert!(
            (1.8..4.0).contains(&scal),
            "IPC scalability {scal} outside the Table-7 band (paper: 3.1-3.7)"
        );
    }
}
