//! Seeded corpus generator behind `talp-pages sim` — the simulator's
//! batch front end.
//!
//! One [`CorpusSpec`] describes a whole artifact tree: which scenario
//! [`Axis`] directories to emit, how many runs per axis, the machine,
//! the seed.  Everything downstream of the seed is deterministic —
//! same spec, byte-identical corpus — so fixtures, CI jobs and bug
//! reports can name a corpus by `(seed, axes, runs)` instead of
//! shipping files.  Each axis becomes one experiment directory (the
//! folder scanner groups by parent dir), and every run is a *real*
//! simulated execution ([`crate::apps::run_with_talp`]) whose POP
//! factors respond to the scenario, not hand-written numbers:
//!
//! | axis             | what varies run-to-run                          |
//! |------------------|-------------------------------------------------|
//! | `weak-scaling`   | resolution grows with the rank count            |
//! | `strong-scaling` | fixed problem, rank count grows                 |
//! | `hybrid`         | fixed ranks, OpenMP thread count grows          |
//! | `noise`          | calm / typical / noisy platform regimes         |
//! | `drift`          | compute slowdown creeping up 2% per run         |
//! | `step`           | a 35% slowdown landing at the history midpoint  |
//!
//! Corpora can be written in any registered adapter's format
//! ([`write_corpus`] takes the [`Adapter`]), which is how the CI
//! store-scale job exercises ROOT-bench and BeeSwarm ingestion
//! without real producers.  [`synth_batch`] is the store-records
//! variant behind `store synth`: same simulator, but fanned out into
//! pre-reduced [`RunMetrics`] records for scale testing.

use std::path::Path;

use anyhow::{bail, Result};

use crate::adapters::Adapter;
use crate::apps::{
    run_with_talp, run_with_talp_noise, CodeVersion, Genex,
};
use crate::pop::RunMetrics;
use crate::talp::{GitMeta, RunData};

use super::{MachineSpec, NoiseModel, ResourceConfig};

/// One scenario dimension a generated corpus can cover.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// Problem size grows with the rank count (efficiency should hold).
    WeakScaling,
    /// Fixed problem, rank count grows (efficiency decays).
    StrongScaling,
    /// Fixed MPI ranks, OpenMP thread count grows — hybrid region
    /// trees with thread-level factors in play.
    Hybrid,
    /// Same configuration under calm / typical / noisy platforms.
    Noise,
    /// A baseline drifting slower by 2% compute per run.
    Drift,
    /// A clean history with a 35% step regression at the midpoint.
    Step,
}

impl Axis {
    /// Every axis, in the order `sim` emits them.
    pub fn all() -> [Axis; 6] {
        [
            Axis::WeakScaling,
            Axis::StrongScaling,
            Axis::Hybrid,
            Axis::Noise,
            Axis::Drift,
            Axis::Step,
        ]
    }

    /// Directory / CLI name of the axis.
    pub fn label(&self) -> &'static str {
        match self {
            Axis::WeakScaling => "weak-scaling",
            Axis::StrongScaling => "strong-scaling",
            Axis::Hybrid => "hybrid",
            Axis::Noise => "noise",
            Axis::Drift => "drift",
            Axis::Step => "step",
        }
    }

    /// Inverse of [`Axis::label`] (CLI `--axes` parsing).
    pub fn parse(name: &str) -> Option<Axis> {
        Axis::all().into_iter().find(|a| a.label() == name)
    }

    /// Comma-free list of every label for usage/error text.
    pub fn labels() -> String {
        Axis::all()
            .iter()
            .map(|a| a.label())
            .collect::<Vec<_>>()
            .join("|")
    }
}

/// A whole corpus, named by its parameters.  Two equal specs generate
/// byte-identical trees.
pub struct CorpusSpec {
    /// Master seed; every run's seed derives from it arithmetically.
    pub seed: u64,
    /// Runs per axis directory.
    pub runs: usize,
    /// Scenario directories to emit (order preserved).
    pub axes: Vec<Axis>,
    /// Simulated machine.
    pub machine: MachineSpec,
    /// Timestamp of each axis's first run; consecutive runs are one
    /// hour apart.  Fixed (never wall clock) so corpora reproduce.
    pub base_timestamp: i64,
}

impl CorpusSpec {
    /// All six axes, 6 runs each, MareNostrum 5, a fixed epoch.
    pub fn new(seed: u64) -> CorpusSpec {
        CorpusSpec {
            seed,
            runs: 6,
            axes: Axis::all().to_vec(),
            machine: MachineSpec::marenostrum5(),
            base_timestamp: 1_700_000_000,
        }
    }
}

/// What one run of an axis should simulate.
struct RunPlan {
    resolution: u32,
    config: ResourceConfig,
    version: CodeVersion,
    noise: Option<NoiseModel>,
}

fn plan(axis: Axis, i: usize, runs: usize) -> RunPlan {
    let fixed = CodeVersion::fixed();
    let base = RunPlan {
        resolution: 1,
        config: ResourceConfig::new(2, 8),
        version: fixed,
        noise: None,
    };
    let ranks = [1u32, 2, 4][i % 3];
    match axis {
        Axis::WeakScaling => RunPlan {
            resolution: ranks,
            config: ResourceConfig::new(ranks, 8),
            ..base
        },
        Axis::StrongScaling => RunPlan {
            resolution: 2,
            config: ResourceConfig::new(ranks, 8),
            ..base
        },
        Axis::Hybrid => RunPlan {
            config: ResourceConfig::new(2, [4u32, 8, 16][i % 3]),
            ..base
        },
        Axis::Noise => RunPlan {
            noise: Some(match i % 3 {
                0 => NoiseModel::calm(),
                1 => NoiseModel::typical(),
                _ => NoiseModel::noisy(),
            }),
            ..base
        },
        Axis::Drift => RunPlan {
            version: CodeVersion {
                compute_slowdown: 1.0 + 0.02 * i as f64,
                ..fixed
            },
            ..base
        },
        Axis::Step => RunPlan {
            version: CodeVersion {
                compute_slowdown: if i >= runs / 2 { 1.35 } else { 1.0 },
                ..fixed
            },
            ..base
        },
    }
}

/// Generate the corpus as `(relative path, run)` pairs in
/// deterministic emit order — one directory per axis, `run_<i>.json`
/// inside.  Every run carries deterministic git metadata (commit sha
/// derived from axis and index, timestamps one hour apart) so stored
/// histories order the same way real stamped CI artifacts do.
pub fn generate(spec: &CorpusSpec) -> Vec<(String, RunData)> {
    let mut out = Vec::with_capacity(spec.axes.len() * spec.runs);
    for (axis_i, axis) in spec.axes.iter().enumerate() {
        for i in 0..spec.runs {
            let p = plan(*axis, i, spec.runs);
            let mut app = Genex::salpha(p.resolution, p.version);
            app.timesteps = 2;
            let seed = spec
                .seed
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add((axis_i * 1_000 + i) as u64);
            let ts = spec.base_timestamp + i as i64 * 3_600;
            let (mut data, _) = match p.noise {
                Some(noise) => run_with_talp_noise(
                    &app,
                    &spec.machine,
                    &p.config,
                    seed,
                    ts,
                    noise,
                ),
                None => {
                    run_with_talp(&app, &spec.machine, &p.config, seed, ts)
                }
            };
            data.git = Some(GitMeta {
                commit: format!("{axis_i:02x}{i:06x}ab1e5eed"),
                branch: "main".into(),
                commit_timestamp: ts,
                message: format!("{} run {i}", axis.label()),
            });
            out.push((format!("{}/run_{i}.json", axis.label()), data));
        }
    }
    out
}

/// Generate [`generate`]'s corpus under `out_dir`, each run rendered
/// by `adapter` ([`Adapter::emit`]).  Returns the number of files
/// written.  Same spec + same adapter ⇒ byte-identical tree.
pub fn write_corpus(
    spec: &CorpusSpec,
    out_dir: &Path,
    adapter: &dyn Adapter,
) -> Result<usize> {
    if spec.runs == 0 || spec.axes.is_empty() {
        bail!("corpus spec is empty (no runs or no axes)");
    }
    let runs = generate(spec);
    let n = runs.len();
    for (rel, data) in runs {
        let path = out_dir.join(rel);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&path, adapter.emit(&data))?;
    }
    Ok(n)
}

/// The `store synth` backend: one real simulated run per config, then
/// a metadata-only fan-out across experiments, commits and timestamps
/// — real [`RunMetrics`] payloads at arbitrary scale, which is all a
/// store-scale test observes.  Returns `(experiment, hash, run)`
/// records ready for `RunStore::append_all`.
pub fn synth_batch(
    experiments: usize,
    configs: &[ResourceConfig],
    runs_per_shard: usize,
    seed: u64,
    machine: &MachineSpec,
) -> Vec<(String, String, RunMetrics)> {
    let mut batch =
        Vec::with_capacity(experiments * configs.len() * runs_per_shard);
    for (cfg_i, cfg) in configs.iter().enumerate() {
        let mut app = Genex::salpha(1, CodeVersion::fixed());
        app.timesteps = 2;
        let (base, _) =
            run_with_talp(&app, machine, cfg, seed + cfg_i as u64, 0);
        for exp in 0..experiments {
            for i in 0..runs_per_shard {
                let mut d = base.clone();
                d.timestamp = 1_700_000_000 + i as i64 * 60;
                d.git = Some(GitMeta {
                    commit: format!("{exp:02x}{i:06x}{cfg_i:02x}cccccc"),
                    branch: "main".into(),
                    commit_timestamp: d.timestamp,
                    message: String::new(),
                });
                let source =
                    format!("exp{exp:02}/{}/run_{i}.json", cfg.label());
                let run = RunMetrics::from_run(&d, &source);
                batch.push((
                    format!("exp{exp:02}"),
                    format!("{exp:04x}{cfg_i:02x}{i:08x}"),
                    run,
                ));
            }
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters;

    #[test]
    fn axis_labels_round_trip() {
        for axis in Axis::all() {
            assert_eq!(Axis::parse(axis.label()), Some(axis));
        }
        assert_eq!(Axis::parse("frobnicate"), None);
        assert!(Axis::labels().contains("weak-scaling"));
    }

    fn small_spec(seed: u64) -> CorpusSpec {
        CorpusSpec {
            runs: 3,
            axes: vec![Axis::WeakScaling, Axis::Step],
            ..CorpusSpec::new(seed)
        }
    }

    #[test]
    fn same_seed_is_byte_identical_different_seed_is_not() {
        let talp = adapters::by_name("talp").unwrap();
        let a: Vec<String> = generate(&small_spec(9))
            .iter()
            .map(|(rel, d)| format!("{rel}\n{}", talp.emit(d)))
            .collect();
        let b: Vec<String> = generate(&small_spec(9))
            .iter()
            .map(|(rel, d)| format!("{rel}\n{}", talp.emit(d)))
            .collect();
        assert_eq!(a, b, "same seed must reproduce byte-for-byte");
        let c: Vec<String> = generate(&small_spec(10))
            .iter()
            .map(|(rel, d)| format!("{rel}\n{}", talp.emit(d)))
            .collect();
        assert_ne!(a, c, "a different seed must actually differ");
    }

    #[test]
    fn step_axis_regresses_at_the_midpoint() {
        let spec = CorpusSpec {
            runs: 4,
            axes: vec![Axis::Step],
            ..CorpusSpec::new(3)
        };
        let runs = generate(&spec);
        assert_eq!(runs.len(), 4);
        let elapsed = |d: &RunData| d.region("Global").unwrap().elapsed_s;
        let before = elapsed(&runs[0].1);
        let after = elapsed(&runs[3].1);
        assert!(
            after > before * 1.2,
            "step regression must be visible: {before} -> {after}"
        );
    }

    #[test]
    fn weak_scaling_axis_varies_resources() {
        let spec = CorpusSpec {
            runs: 3,
            axes: vec![Axis::WeakScaling],
            ..CorpusSpec::new(4)
        };
        let labels: Vec<String> = generate(&spec)
            .iter()
            .map(|(_, d)| d.resources().label())
            .collect();
        assert_eq!(labels, ["1x8", "2x8", "4x8"]);
    }

    #[test]
    fn write_corpus_emits_detectable_files_per_adapter() {
        let td = crate::util::fs::TempDir::new("corpus").unwrap();
        let spec = small_spec(5);
        for adapter in adapters::registry() {
            let dir = td.path().join(adapter.name());
            let n = write_corpus(&spec, &dir, *adapter).unwrap();
            assert_eq!(n, 6);
            let doc = std::fs::read(
                dir.join("weak-scaling/run_0.json"),
            )
            .unwrap();
            match adapters::detect(&doc) {
                adapters::Detection::Match(a) => {
                    assert_eq!(a.name(), adapter.name())
                }
                other => panic!("{}: {other:?}", adapter.name()),
            }
        }
        assert!(write_corpus(
            &CorpusSpec { runs: 0, ..small_spec(5) },
            td.path(),
            adapters::by_name("talp").unwrap(),
        )
        .is_err());
    }

    #[test]
    fn synth_batch_shape_matches_parameters() {
        let machine = MachineSpec::marenostrum5();
        let configs =
            [ResourceConfig::new(2, 4), ResourceConfig::new(4, 4)];
        let batch = synth_batch(2, &configs, 3, 7, &machine);
        assert_eq!(batch.len(), 2 * 2 * 3);
        assert_eq!(batch[0].0, "exp00");
        assert_eq!(batch[0].1, "00000000000000");
        assert_eq!(batch[0].2.source, "exp00/2x4/run_0.json");
        // Hashes are unique across the fan-out.
        let hashes: std::collections::HashSet<&str> =
            batch.iter().map(|(_, h, _)| h.as_str()).collect();
        assert_eq!(hashes.len(), batch.len());
    }
}
