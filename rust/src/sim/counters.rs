//! Hardware-counter model: turns a phase's work into (instructions,
//! cycles, seconds) using the cache and DVFS models.
//!
//! The instructions-per-flop constant defaults to the machine spec but
//! is overridden by `runtime::calibrate`, which measures the real AOT
//! stencil executable (HLO op counts per cell) so simulated counters are
//! anchored to the actual compiled kernel rather than a guess.

use super::cache;
use super::dvfs;
use super::machine::MachineSpec;

/// Work description for one thread's compute burst.
#[derive(Debug, Clone, Copy)]
pub struct Work {
    pub flops: f64,
    pub working_set_bytes: f64,
    /// Extra instruction overhead factor (halo recompute, decomposition
    /// surface terms); 1.0 = none.
    pub insn_factor: f64,
}

/// Counter outcome for one thread's compute burst.
#[derive(Debug, Clone, Copy)]
pub struct Burst {
    pub seconds: f64,
    pub instructions: u64,
    pub cycles: u64,
    pub ipc: f64,
    pub freq_ghz: f64,
}

/// Counter model shared by a run.
#[derive(Debug, Clone)]
pub struct CounterModel {
    pub insn_per_flop: f64,
}

impl CounterModel {
    pub fn from_machine(m: &MachineSpec) -> CounterModel {
        CounterModel { insn_per_flop: m.insn_per_flop }
    }

    /// Compute one burst. `active_fraction` and `threads_on_socket`
    /// describe the socket occupancy during the burst.
    pub fn burst(
        &self,
        m: &MachineSpec,
        work: Work,
        active_fraction: f64,
        threads_on_socket: u32,
    ) -> Burst {
        let eff = cache::effect(m, work.working_set_bytes, threads_on_socket);
        let freq =
            dvfs::frequency_ghz(m, active_fraction, eff.stall_fraction, eff.ipc);
        let instructions =
            (work.flops * self.insn_per_flop * work.insn_factor).max(0.0);
        let cycles = instructions / eff.ipc;
        let seconds = cycles / (freq * 1e9);
        Burst {
            seconds,
            instructions: instructions.round() as u64,
            cycles: cycles.round() as u64,
            ipc: eff.ipc,
            freq_ghz: freq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> (MachineSpec, CounterModel) {
        let m = MachineSpec::marenostrum5();
        let c = CounterModel::from_machine(&m);
        (m, c)
    }

    #[test]
    fn burst_is_consistent() {
        let (m, c) = model();
        let b = c.burst(
            &m,
            Work { flops: 1e9, working_set_bytes: 1e8, insn_factor: 1.0 },
            1.0,
            56,
        );
        // time * ipc * freq == instructions (by construction)
        let recon = b.seconds * b.ipc * b.freq_ghz * 1e9;
        assert!((recon / b.instructions as f64 - 1.0).abs() < 1e-6);
        assert!(b.seconds > 0.0);
    }

    #[test]
    fn more_flops_more_time_linear() {
        let (m, c) = model();
        let w = |f| Work { flops: f, working_set_bytes: 1e8, insn_factor: 1.0 };
        let b1 = c.burst(&m, w(1e9), 1.0, 56);
        let b2 = c.burst(&m, w(2e9), 1.0, 56);
        assert!((b2.seconds / b1.seconds - 2.0).abs() < 1e-9);
        assert_eq!(b2.instructions, 2 * b1.instructions);
    }

    #[test]
    fn insn_factor_increases_instructions_not_flops() {
        let (m, c) = model();
        let base = Work { flops: 1e9, working_set_bytes: 1e8, insn_factor: 1.0 };
        let padded = Work { insn_factor: 1.2, ..base };
        let b1 = c.burst(&m, base, 1.0, 56);
        let b2 = c.burst(&m, padded, 1.0, 56);
        assert!((b2.instructions as f64 / b1.instructions as f64 - 1.2).abs() < 1e-6);
    }

    #[test]
    fn cache_fit_speeds_up_superlinearly() {
        let (m, c) = model();
        // Same flops, working set halved across the LLC boundary:
        // time shrinks by much more than 0% (IPC jump), the strong-
        // scaling signature of Table 7.
        let ws_big = 3.0e6 * 2.0;
        let ws_small = 3.0e6 / 2.0;
        let b_big = c.burst(
            &m,
            Work { flops: 1e9, working_set_bytes: ws_big, insn_factor: 1.0 },
            1.0,
            56,
        );
        let b_small = c.burst(
            &m,
            Work { flops: 1e9, working_set_bytes: ws_small, insn_factor: 1.0 },
            1.0,
            56,
        );
        assert!(b_small.seconds < 0.8 * b_big.seconds);
    }
}
