//! Machine model: topology (nodes / sockets / cores), frequency bins,
//! cache capacities and interconnect parameters.
//!
//! Two presets mirror the paper's testbeds: MareNostrum 5 (2 x 56-core
//! Sapphire Rapids per node, 2.15 GHz all-core base with turbo headroom)
//! and Raven at MPCDF (2 x 36-core Ice Lake).  The numbers are public
//! spec-sheet values; they parameterize the DVFS/cache/interconnect
//! models in this module's siblings, they are not measurements.

/// Static description of one machine.
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub name: String,
    pub sockets_per_node: u32,
    pub cores_per_socket: u32,
    /// All-core sustained frequency in GHz (paper's Fig. 3 shows 2.15).
    pub f_allcore_ghz: f64,
    /// Single-core max turbo in GHz.
    pub f_turbo_ghz: f64,
    /// Turbo uplift weight for idle cores (DVFS model).
    pub w_idle: f64,
    /// Turbo uplift weight for memory-stalled cores: stalled pipelines
    /// draw less power, leaving thermal headroom ("license"-style bins).
    pub w_stall: f64,
    /// Frequency penalty per unit of IPC above `ipc_pwr_ref` (cache-
    /// resident code retires more uops/cycle and hits the power limit;
    /// this is what makes Table 7's frequency scalability ~0.88).
    pub k_power: f64,
    pub ipc_pwr_ref: f64,
    /// Per-core L2 in bytes.
    pub l2_bytes: u64,
    /// Shared LLC per socket in bytes.
    pub llc_bytes: u64,
    /// Peak IPC for cache-resident useful code and memory-bound floor.
    pub ipc_cache: f64,
    pub ipc_mem: f64,
    /// Instructions per flop of compiled stencil code (calibrated from
    /// the real XLA executable by runtime::calibrate; this is the
    /// default used when no calibration has run).
    pub insn_per_flop: f64,
    // ---- interconnect (hockney-style) ----
    pub mpi_latency_intra_s: f64,
    pub mpi_latency_inter_s: f64,
    pub mpi_bw_intra_bps: f64,
    pub mpi_bw_inter_bps: f64,
    /// Per-collective software overhead (per log2(P) stage).
    pub coll_stage_s: f64,
    /// Filesystem streaming bandwidth for Io steps.
    pub io_bw_bps: f64,
}

impl MachineSpec {
    pub fn cores_per_node(&self) -> u32 {
        self.sockets_per_node * self.cores_per_socket
    }

    /// MareNostrum 5 general-purpose partition node.
    pub fn marenostrum5() -> MachineSpec {
        MachineSpec {
            name: "mn5".into(),
            sockets_per_node: 2,
            cores_per_socket: 56,
            f_allcore_ghz: 2.15,
            f_turbo_ghz: 3.10,
            w_idle: 0.55,
            w_stall: 0.25,
            k_power: 0.040,
            ipc_pwr_ref: 1.15,
            l2_bytes: 2 * 1024 * 1024,
            // Effective per-socket capacity: 105 MB LLC + 56 x 2 MB
            // private L2 aggregate (the strong-scaling IPC jump in
            // Table 7 happens when per-thread slices drop under the
            // combined share).
            llc_bytes: 220 * 1024 * 1024,
            ipc_cache: 3.8,
            ipc_mem: 1.0,
            insn_per_flop: 1.35,
            mpi_latency_intra_s: 0.4e-6,
            mpi_latency_inter_s: 1.6e-6,
            mpi_bw_intra_bps: 16.0e9,
            mpi_bw_inter_bps: 12.5e9, // ~100 Gb/s NDR shared
            coll_stage_s: 0.9e-6,
            io_bw_bps: 2.0e9,
        }
    }

    /// Raven (MPCDF): 2 x 36-core Ice Lake 8360Y.
    pub fn raven() -> MachineSpec {
        MachineSpec {
            name: "raven".into(),
            sockets_per_node: 2,
            cores_per_socket: 36,
            f_allcore_ghz: 2.40,
            f_turbo_ghz: 3.50,
            w_idle: 0.50,
            w_stall: 0.22,
            k_power: 0.038,
            ipc_pwr_ref: 1.15,
            l2_bytes: 1_280 * 1024,
            // 54 MB LLC + 36 x 1.25 MB L2 aggregate.
            llc_bytes: 100 * 1024 * 1024,
            ipc_cache: 3.4,
            ipc_mem: 1.0,
            insn_per_flop: 1.40,
            mpi_latency_intra_s: 0.5e-6,
            mpi_latency_inter_s: 1.9e-6,
            mpi_bw_intra_bps: 14.0e9,
            mpi_bw_inter_bps: 11.0e9,
            coll_stage_s: 1.0e-6,
            io_bw_bps: 1.5e9,
        }
    }

    pub fn by_name(name: &str) -> Option<MachineSpec> {
        match name {
            "mn5" | "marenostrum5" => Some(MachineSpec::marenostrum5()),
            "raven" => Some(MachineSpec::raven()),
            _ => None,
        }
    }
}

/// A concrete resource configuration for one run: how many MPI ranks,
/// how many OpenMP threads per rank, and the rank->node placement.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceConfig {
    pub n_ranks: u32,
    pub threads_per_rank: u32,
}

impl ResourceConfig {
    pub fn new(n_ranks: u32, threads_per_rank: u32) -> ResourceConfig {
        assert!(n_ranks > 0 && threads_per_rank > 0);
        ResourceConfig { n_ranks, threads_per_rank }
    }

    pub fn total_cpus(&self) -> u32 {
        self.n_ranks * self.threads_per_rank
    }

    /// Paper-style label: "2x56".
    pub fn label(&self) -> String {
        format!("{}x{}", self.n_ranks, self.threads_per_rank)
    }

    pub fn parse_label(s: &str) -> Option<ResourceConfig> {
        let (r, t) = s.split_once('x')?;
        Some(ResourceConfig::new(r.parse().ok()?, t.parse().ok()?))
    }

    /// Number of nodes needed on `m`, packing ranks densely with each
    /// rank's threads pinned to contiguous cores (the paper pins one
    /// rank per socket when threads == cores_per_socket).
    pub fn nodes_used(&self, m: &MachineSpec) -> u32 {
        let cpus = self.total_cpus();
        cpus.div_ceil(m.cores_per_node())
    }

    /// Node index that hosts `rank`.
    pub fn node_of_rank(&self, rank: u32, m: &MachineSpec) -> u32 {
        let ranks_per_node =
            (m.cores_per_node() / self.threads_per_rank).max(1);
        rank / ranks_per_node
    }

    /// Fraction of a node's cores that are active under this config
    /// (on the occupied nodes; clamped by the actual rank count).
    pub fn active_fraction(&self, m: &MachineSpec) -> f64 {
        let ranks_per_node = (m.cores_per_node() / self.threads_per_rank)
            .max(1)
            .min(self.n_ranks);
        let used = (ranks_per_node * self.threads_per_rank) as f64;
        (used / m.cores_per_node() as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mn5_topology() {
        let m = MachineSpec::marenostrum5();
        assert_eq!(m.cores_per_node(), 112);
        assert!(m.f_turbo_ghz > m.f_allcore_ghz);
    }

    #[test]
    fn label_roundtrip() {
        let c = ResourceConfig::new(8, 56);
        assert_eq!(c.label(), "8x56");
        assert_eq!(ResourceConfig::parse_label("8x56"), Some(c));
        assert_eq!(ResourceConfig::parse_label("junk"), None);
        assert_eq!(ResourceConfig::parse_label("8x"), None);
    }

    #[test]
    fn node_packing_mn5() {
        let m = MachineSpec::marenostrum5();
        // paper's TeaLeaf strong scaling: 2x56 = 1 node, 4x56 = 2 nodes
        assert_eq!(ResourceConfig::new(2, 56).nodes_used(&m), 1);
        assert_eq!(ResourceConfig::new(4, 56).nodes_used(&m), 2);
        assert_eq!(ResourceConfig::new(8, 56).nodes_used(&m), 4);
        // MPI-only Fig. 3: 112 ranks = 1 node, 224 = 2 nodes
        assert_eq!(ResourceConfig::new(112, 1).nodes_used(&m), 1);
        assert_eq!(ResourceConfig::new(224, 1).nodes_used(&m), 2);
    }

    #[test]
    fn rank_to_node_mapping() {
        let m = MachineSpec::marenostrum5();
        let c = ResourceConfig::new(4, 56);
        assert_eq!(c.node_of_rank(0, &m), 0);
        assert_eq!(c.node_of_rank(1, &m), 0);
        assert_eq!(c.node_of_rank(2, &m), 1);
        assert_eq!(c.node_of_rank(3, &m), 1);
    }

    #[test]
    fn active_fraction_full_and_partial() {
        let m = MachineSpec::marenostrum5();
        assert!((ResourceConfig::new(2, 56).active_fraction(&m) - 1.0).abs() < 1e-9);
        assert!(ResourceConfig::new(1, 28).active_fraction(&m) < 0.5);
    }

    #[test]
    fn by_name_lookup() {
        assert!(MachineSpec::by_name("mn5").is_some());
        assert!(MachineSpec::by_name("raven").is_some());
        assert!(MachineSpec::by_name("summit").is_none());
    }
}
