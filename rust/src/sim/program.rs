//! The simulator's program IR.
//!
//! Applications (apps::*) compile themselves into a `Program`: a flat,
//! SPMD sequence of steps that every rank executes.  The engine walks the
//! sequence keeping one clock per rank (and per-thread accounting inside
//! ranks), resolving synchronization at MPI steps.  This phase-level IR
//! is exactly the granularity TALP observes — PMPI/OMPT callbacks at
//! phase boundaries — which is why the substrate can feed the real
//! monitor code without a cycle-accurate machine model.

/// How work is spread over the threads of a parallel region.
#[derive(Debug, Clone, PartialEq)]
pub enum Imbalance {
    /// Perfectly balanced.
    None,
    /// Thread `t` gets `1 + skew * t / (T-1)` relative share (linear ramp).
    Linear { skew: f64 },
    /// First `heavy_frac` of threads carry `factor`x the work of the rest
    /// (boundary-rank / surface-term imbalance).
    Block { heavy_frac: f64, factor: f64 },
    /// Multiplicative random jitter per thread with the given sigma.
    Random { sigma: f64 },
}

impl Imbalance {
    /// Relative weight for thread `t` of `n` (mean ~1 by construction;
    /// engine normalizes exactly).
    pub fn weight(&self, t: u32, n: u32, jitter: impl FnMut() -> f64) -> f64 {
        let mut jitter = jitter;
        match self {
            Imbalance::None => 1.0,
            Imbalance::Linear { skew } => {
                if n <= 1 {
                    1.0
                } else {
                    1.0 + skew * t as f64 / (n - 1) as f64
                }
            }
            Imbalance::Block { heavy_frac, factor } => {
                let heavy_n = ((n as f64) * heavy_frac).ceil() as u32;
                if t < heavy_n {
                    *factor
                } else {
                    1.0
                }
            }
            Imbalance::Random { sigma } => {
                let _ = sigma;
                jitter()
            }
        }
    }
}

/// OpenMP loop schedule for a parallel region.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OmpSchedule {
    /// One chunk per thread; imbalance lands on the barrier.
    Static,
    /// `chunks` total chunks dealt dynamically: imbalance is smoothed to
    /// roughly one chunk's worth, but each chunk dispatch costs time and
    /// generates tool events (this is the fine granularity that makes
    /// every tool's overhead explode in Table 1's 4x56 row).
    Dynamic { chunks: u32 },
}

/// MPI collective kinds with distinct cost shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollKind {
    Barrier,
    /// Reductions move a few bytes but pay the full log tree.
    Allreduce,
    Bcast,
    Allgather,
}

impl CollKind {
    pub fn name(&self) -> &'static str {
        match self {
            CollKind::Barrier => "MPI_Barrier",
            CollKind::Allreduce => "MPI_Allreduce",
            CollKind::Bcast => "MPI_Bcast",
            CollKind::Allgather => "MPI_Allgather",
        }
    }
}

/// One step of the SPMD program.
#[derive(Debug, Clone)]
pub enum Step {
    /// Enter a TALP-API-annotated region (paper: `initialize`,
    /// `timestep`); the implicit `Global` region is managed by the
    /// engine itself.
    RegionEnter(String),
    RegionExit(String),
    /// Master-thread-only compute; worker threads sit in OpenMP
    /// serialization time.  `flops` is per rank; `rank_weights` scales
    /// per rank (len 1 = uniform).
    Serial {
        flops: f64,
        working_set_bytes: f64,
        rank_weights: Vec<f64>,
    },
    /// An OpenMP parallel region (worksharing loop) on every rank.
    Parallel {
        /// Total flops across the rank's threads.
        flops: f64,
        /// Per-thread working set in bytes (drives the IPC/cache model).
        working_set_bytes: f64,
        imbalance: Imbalance,
        schedule: OmpSchedule,
        /// Per-rank multiplicative work weights (len 1 = uniform, len
        /// n_ranks = per-rank; drives MPI-level load imbalance).
        rank_weights: Vec<f64>,
        /// Extra instructions-per-flop multiplier (surface/halo overhead
        /// growing with decomposition models instruction-scaling < 1).
        insn_factor: f64,
    },
    /// Blocking collective over all ranks.
    Collective { kind: CollKind, bytes_per_rank: u64 },
    /// Nearest-neighbour halo exchange (1-D decomposition; rank r talks
    /// to r-1 and r+1).
    Exchange { bytes_per_neighbor: u64 },
    /// File I/O. If `parallel` every rank writes its share; otherwise
    /// rank 0 writes everything while others run ahead (the variance
    /// trap §Discussion warns about).
    Io { bytes: u64, parallel: bool },
}

/// A full SPMD program plus bookkeeping the tools need.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub steps: Vec<Step>,
}

impl Program {
    pub fn new() -> Program {
        Program { steps: Vec::new() }
    }

    pub fn push(&mut self, s: Step) -> &mut Self {
        self.steps.push(s);
        self
    }

    pub fn region<F: FnOnce(&mut Self)>(&mut self, name: &str, body: F) -> &mut Self {
        self.steps.push(Step::RegionEnter(name.to_string()));
        body(self);
        self.steps.push(Step::RegionExit(name.to_string()));
        self
    }

    /// Sanity: regions must nest properly.
    pub fn validate(&self) -> Result<(), String> {
        let mut stack: Vec<&str> = Vec::new();
        for s in &self.steps {
            match s {
                Step::RegionEnter(n) => stack.push(n),
                Step::RegionExit(n) => match stack.pop() {
                    Some(top) if top == n => {}
                    Some(top) => {
                        return Err(format!(
                            "region exit '{n}' does not match open '{top}'"
                        ))
                    }
                    None => {
                        return Err(format!("region exit '{n}' with no open region"))
                    }
                },
                _ => {}
            }
        }
        if let Some(open) = stack.pop() {
            return Err(format!("region '{open}' never exited"));
        }
        Ok(())
    }

    /// Rough count of tool-visible events per rank (used in tests and by
    /// tool self-estimates; the engine computes exact counts during the
    /// run).
    pub fn approx_events_per_rank(&self, threads: u32) -> u64 {
        let mut n = 0u64;
        for s in &self.steps {
            n += match s {
                Step::RegionEnter(_) | Step::RegionExit(_) => 1,
                Step::Serial { .. } => 2,
                Step::Parallel { schedule, .. } => match schedule {
                    OmpSchedule::Static => 2 * threads as u64,
                    OmpSchedule::Dynamic { chunks } => 2 * (*chunks as u64),
                },
                Step::Collective { .. } => 2,
                Step::Exchange { .. } => 4,
                Step::Io { .. } => 2,
            };
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_builder_nests() {
        let mut p = Program::new();
        p.region("initialize", |p| {
            p.push(Step::Serial {
                flops: 1e6,
                working_set_bytes: 1e6,
                rank_weights: vec![1.0],
            });
        });
        assert!(p.validate().is_ok());
        assert_eq!(p.steps.len(), 3);
    }

    #[test]
    fn validate_catches_bad_nesting() {
        let mut p = Program::new();
        p.push(Step::RegionEnter("a".into()));
        p.push(Step::RegionExit("b".into()));
        assert!(p.validate().is_err());

        let mut p = Program::new();
        p.push(Step::RegionExit("x".into()));
        assert!(p.validate().is_err());

        let mut p = Program::new();
        p.push(Step::RegionEnter("a".into()));
        assert!(p.validate().is_err());
    }

    #[test]
    fn imbalance_weights() {
        let w0 = Imbalance::None.weight(0, 4, || 1.0);
        assert_eq!(w0, 1.0);
        let lin = Imbalance::Linear { skew: 0.5 };
        assert_eq!(lin.weight(0, 5, || 1.0), 1.0);
        assert_eq!(lin.weight(4, 5, || 1.0), 1.5);
        let blk = Imbalance::Block { heavy_frac: 0.25, factor: 2.0 };
        assert_eq!(blk.weight(0, 4, || 1.0), 2.0);
        assert_eq!(blk.weight(3, 4, || 1.0), 1.0);
    }

    #[test]
    fn event_counts_scale_with_granularity() {
        let mut coarse = Program::new();
        coarse.push(Step::Parallel {
            flops: 1e9,
            working_set_bytes: 1e6,
            imbalance: Imbalance::None,
            schedule: OmpSchedule::Static,
            rank_weights: vec![1.0],
            insn_factor: 1.0,
        });
        let mut fine = Program::new();
        fine.push(Step::Parallel {
            flops: 1e9,
            working_set_bytes: 1e6,
            imbalance: Imbalance::None,
            schedule: OmpSchedule::Dynamic { chunks: 1000 },
            rank_weights: vec![1.0],
            insn_factor: 1.0,
        });
        assert!(
            fine.approx_events_per_rank(8) > coarse.approx_events_per_rank(8)
        );
    }
}
