//! The SPMD phase-level execution engine.
//!
//! Walks a `Program` keeping one clock per rank.  Within a rank, threads
//! are decomposed per step (parallel regions, serialization, barriers);
//! across ranks, MPI steps synchronize clocks and turn imbalance into
//! waiting time.  Every phase is reported to the attached `EventSink`s,
//! and each sink's `CostModel` *perturbs the clocks* — instrumentation
//! overhead is simulated physically, not bolted on afterwards, so
//! Table 1's percentages fall out of the event volume.
//!
//! Determinism: all noise comes from a seeded PRNG forked per rank; two
//! runs with the same `RunConfig` produce identical timelines.

use super::counters::{Burst, CounterModel, Work};
use super::event::{CostModel, Event, EventSink, PhaseKind, RegionMark};
use super::machine::{MachineSpec, ResourceConfig};
use super::mpi;
use super::noise::NoiseModel;
use super::program::{CollKind, Imbalance, OmpSchedule, Program, Step};
use crate::util::rng::Rng;

/// Fixed OpenMP runtime constants (fork/join and chunk dispatch); these
/// exist even without any tool attached.
const OMP_FORK_BASE_S: f64 = 1.5e-6;
const OMP_FORK_PER_THREAD_S: f64 = 2.0e-8;
const OMP_CHUNK_DISPATCH_S: f64 = 2.5e-7;

/// Everything needed to execute a program once.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub machine: MachineSpec,
    pub resources: ResourceConfig,
    pub noise: NoiseModel,
    pub seed: u64,
    pub counters: CounterModel,
}

impl RunConfig {
    pub fn new(machine: MachineSpec, resources: ResourceConfig) -> RunConfig {
        let counters = CounterModel::from_machine(&machine);
        RunConfig {
            machine,
            resources,
            noise: NoiseModel::typical(),
            seed: 0xC0FFEE,
            counters,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> RunConfig {
        self.seed = seed;
        self
    }

    pub fn with_noise(mut self, noise: NoiseModel) -> RunConfig {
        self.noise = noise;
        self
    }
}

/// Aggregate outcome of one run (tool-independent bookkeeping).
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Global wall time (max over ranks), including tool perturbation.
    pub elapsed_s: f64,
    pub per_rank_elapsed_s: Vec<f64>,
    /// Phase events emitted (incl. sub-event multiplicity).
    pub total_events: u64,
    /// Total trace bytes the sinks' cost models declared.
    pub trace_bytes: u64,
    /// Total instrumentation time injected across all cpus.
    pub perturbation_s: f64,
}

/// Execute `program` under `cfg`, reporting to `sinks`.
pub fn run(
    program: &Program,
    cfg: &RunConfig,
    sinks: &mut [&mut dyn EventSink],
) -> RunSummary {
    program
        .validate()
        .unwrap_or_else(|e| panic!("invalid program: {e}"));
    let n = cfg.resources.n_ranks as usize;
    let t_per = cfg.resources.threads_per_rank;
    let mut root_rng = Rng::new(cfg.seed);
    let mut rank_rng: Vec<Rng> =
        (0..n).map(|r| root_rng.fork(r as u64)).collect();
    let mut clock = vec![0.0f64; n];
    let costs: Vec<CostModel> = sinks.iter().map(|s| s.cost_model()).collect();

    let mut st = EngineState {
        total_events: 0,
        trace_bytes: 0,
        perturbation: 0.0,
        bytes_since_flush: vec![0u64; n],
    };

    // Implicit Global region (TALP creates it automatically).
    for r in 0..n as u32 {
        emit_region(sinks, &mut st, r, 0.0, "Global", true);
    }

    for step in &program.steps {
        match step {
            Step::RegionEnter(name) => {
                for r in 0..n as u32 {
                    let t = clock[r as usize];
                    let c = charge_region(&costs, sinks.len());
                    clock[r as usize] += c;
                    st.perturbation += c;
                    emit_region(sinks, &mut st, r, t, name, true);
                }
            }
            Step::RegionExit(name) => {
                for r in 0..n as u32 {
                    let t = clock[r as usize];
                    let c = charge_region(&costs, sinks.len());
                    clock[r as usize] += c;
                    st.perturbation += c;
                    emit_region(sinks, &mut st, r, t, name, false);
                }
            }
            Step::Serial { flops, working_set_bytes, rank_weights } => {
                for r in 0..n {
                    let w = rank_weight(rank_weights, r);
                    let jitter = cfg.noise.burst_multiplier(&mut rank_rng[r]);
                    // Serial phase: one active core per rank on the node.
                    let active = serial_active_fraction(cfg);
                    let burst = cfg.counters.burst(
                        &cfg.machine,
                        Work {
                            flops: flops * w,
                            working_set_bytes: *working_set_bytes,
                            insn_factor: 1.0,
                        },
                        active,
                        1,
                    );
                    let dur = burst.seconds * jitter;
                    let t0 = clock[r];
                    let ev = Event {
                        rank: r as u32,
                        thread: 0,
                        t_start: t0,
                        t_end: t0 + dur,
                        kind: PhaseKind::Useful,
                        instructions: burst.instructions,
                        cycles: scaled_cycles(&burst, jitter),
                        mpi_call: None,
                        bytes: 0,
                        sub_events: 1,
                    };
                    let c = emit(sinks, &costs, &mut st, &ev, r, true);
                    // Worker threads idle: OpenMP serialization time.
                    emit_worker_idle(
                        sinks,
                        &mut st,
                        r as u32,
                        t_per,
                        t0,
                        t0 + dur + c,
                        PhaseKind::OmpSerialization,
                    );
                    clock[r] = t0 + dur + c;
                }
            }
            Step::Parallel {
                flops,
                working_set_bytes,
                imbalance,
                schedule,
                rank_weights,
                insn_factor,
            } => {
                for r in 0..n {
                    let rw = rank_weight(rank_weights, r);
                    let dur = run_parallel_region(
                        cfg,
                        sinks,
                        &costs,
                        &mut st,
                        &mut rank_rng[r],
                        r as u32,
                        clock[r],
                        flops * rw,
                        *working_set_bytes,
                        imbalance,
                        *schedule,
                        *insn_factor,
                    );
                    clock[r] += dur;
                }
            }
            Step::Collective { kind, bytes_per_rank } => {
                let t_last = clock.iter().cloned().fold(0.0f64, f64::max);
                let cost = mpi::collective_cost(
                    &cfg.machine,
                    &cfg.resources,
                    *kind,
                    *bytes_per_rank,
                );
                let t_done = t_last + cost;
                for r in 0..n {
                    mpi_phase(
                        sinks, &costs, &mut st, r as u32, t_per, clock[r],
                        t_done, *kind, *bytes_per_rank,
                    );
                    clock[r] = t_done + charge_last_cost(&costs, &mut st);
                }
            }
            Step::Exchange { bytes_per_neighbor } => {
                let ready = clock.clone();
                for r in 0..n {
                    let mut t_partners = ready[r];
                    let mut xfer = 0.0;
                    if r > 0 {
                        t_partners = t_partners.max(ready[r - 1]);
                        xfer += mpi::p2p_cost(
                            &cfg.machine,
                            &cfg.resources,
                            r as u32,
                            (r - 1) as u32,
                            *bytes_per_neighbor,
                        );
                    }
                    if r + 1 < n {
                        t_partners = t_partners.max(ready[r + 1]);
                        xfer += mpi::p2p_cost(
                            &cfg.machine,
                            &cfg.resources,
                            r as u32,
                            (r + 1) as u32,
                            *bytes_per_neighbor,
                        );
                    }
                    let t_done = t_partners + xfer;
                    mpi_phase(
                        sinks,
                        &costs,
                        &mut st,
                        r as u32,
                        t_per,
                        ready[r],
                        t_done,
                        CollKind::Barrier, // placeholder call id for p2p
                        2 * *bytes_per_neighbor,
                    );
                    clock[r] = t_done + charge_last_cost(&costs, &mut st);
                }
            }
            Step::Io { bytes, parallel } => {
                if *parallel {
                    for r in 0..n {
                        let share = *bytes as f64 / n as f64;
                        let dur = share / cfg.machine.io_bw_bps + 1e-4;
                        io_phase(
                            sinks, &costs, &mut st, r as u32, t_per,
                            clock[r], dur, share as u64,
                        );
                        clock[r] += dur;
                    }
                } else {
                    // Rank 0 writes; the others run ahead (skew!).
                    let dur = *bytes as f64 / cfg.machine.io_bw_bps + 1e-4;
                    io_phase(
                        sinks, &costs, &mut st, 0, t_per, clock[0], dur,
                        *bytes,
                    );
                    clock[0] += dur;
                }
            }
        }
    }

    let elapsed = clock.iter().cloned().fold(0.0f64, f64::max);
    for r in 0..n as u32 {
        emit_region(sinks, &mut st, r, clock[r as usize], "Global", false);
    }
    for s in sinks.iter_mut() {
        s.on_finalize(elapsed);
    }
    RunSummary {
        elapsed_s: elapsed,
        per_rank_elapsed_s: clock,
        total_events: st.total_events,
        trace_bytes: st.trace_bytes,
        perturbation_s: st.perturbation,
    }
}

struct EngineState {
    total_events: u64,
    trace_bytes: u64,
    perturbation: f64,
    bytes_since_flush: Vec<u64>,
}

fn rank_weight(weights: &[f64], r: usize) -> f64 {
    if weights.is_empty() {
        1.0
    } else {
        weights[r % weights.len()]
    }
}

fn serial_active_fraction(cfg: &RunConfig) -> f64 {
    let ranks_per_node = (cfg.machine.cores_per_node()
        / cfg.resources.threads_per_rank)
        .max(1)
        .min(cfg.resources.n_ranks);
    ranks_per_node as f64 / cfg.machine.cores_per_node() as f64
}

fn scaled_cycles(b: &Burst, jitter: f64) -> u64 {
    // Noise stretches wall time at constant frequency: extra cycles are
    // stall cycles; counters still report them.
    (b.cycles as f64 * jitter).round() as u64
}

/// Sum of per-region-marker costs across sinks.
fn charge_region(costs: &[CostModel], _n_sinks: usize) -> f64 {
    costs.iter().map(|c| c.per_region_s).sum()
}

/// Emit an event to all sinks, charge its cost, track bytes/flushes.
/// Returns the charged cost. `charge` = false for idle bookkeeping
/// events that no tool pays for (see event.rs docs).
fn emit(
    sinks: &mut [&mut dyn EventSink],
    costs: &[CostModel],
    st: &mut EngineState,
    ev: &Event,
    rank: usize,
    charge: bool,
) -> f64 {
    st.total_events += ev.sub_events.max(1);
    let mut total_cost = 0.0;
    for (i, s) in sinks.iter_mut().enumerate() {
        s.on_event(ev);
        let cm = &costs[i];
        if charge {
            total_cost += cm.event_cost(ev);
            let bytes = cm.event_bytes(ev);
            st.trace_bytes += bytes;
            if cm.flush_every_bytes > 0 {
                st.bytes_since_flush[rank] += bytes;
                if st.bytes_since_flush[rank] >= cm.flush_every_bytes {
                    st.bytes_since_flush[rank] = 0;
                    total_cost += cm.flush_stall_s;
                }
            }
        }
    }
    if charge {
        st.perturbation += total_cost;
    }
    total_cost
}

fn emit_region(
    sinks: &mut [&mut dyn EventSink],
    st: &mut EngineState,
    rank: u32,
    t: f64,
    name: &str,
    enter: bool,
) {
    st.total_events += 1;
    let mark = RegionMark { rank, t, name: name.to_string(), enter };
    for s in sinks.iter_mut() {
        s.on_region(&mark);
    }
}

fn emit_worker_idle(
    sinks: &mut [&mut dyn EventSink],
    st: &mut EngineState,
    rank: u32,
    threads: u32,
    t0: f64,
    t1: f64,
    kind: PhaseKind,
) {
    for th in 1..threads {
        let ev = Event {
            rank,
            thread: th,
            t_start: t0,
            t_end: t1,
            kind,
            instructions: 0,
            cycles: 0,
            mpi_call: None,
            bytes: 0,
            sub_events: 1,
        };
        st.total_events += 1;
        for s in sinks.iter_mut() {
            s.on_event(&ev);
        }
    }
}

/// MPI call on the master thread + serialization on workers.
#[allow(clippy::too_many_arguments)]
fn mpi_phase(
    sinks: &mut [&mut dyn EventSink],
    costs: &[CostModel],
    st: &mut EngineState,
    rank: u32,
    threads: u32,
    t0: f64,
    t_done: f64,
    call: CollKind,
    bytes: u64,
) {
    let ev = Event {
        rank,
        thread: 0,
        t_start: t0,
        t_end: t_done,
        kind: PhaseKind::Mpi,
        instructions: 0,
        cycles: 0,
        mpi_call: Some(call),
        bytes,
        sub_events: 1,
    };
    emit(sinks, costs, st, &ev, rank as usize, true);
    emit_worker_idle(
        sinks,
        st,
        rank,
        threads,
        t0,
        t_done,
        PhaseKind::MpiWorkerIdle,
    );
}

/// The `emit` above already accumulated perturbation; MPI's cost was
/// returned there but the call sites in the collective path apply it to
/// the clock *after* synchronization, so track it explicitly.
fn charge_last_cost(costs: &[CostModel], st: &mut EngineState) -> f64 {
    let c: f64 = costs.iter().map(|c| c.per_mpi_s).sum();
    // per_mpi was already charged in event_cost; avoid double count by
    // charging zero here.  Kept as a hook for asymmetric exit costs.
    let _ = c;
    let _ = st;
    0.0
}

fn io_phase(
    sinks: &mut [&mut dyn EventSink],
    costs: &[CostModel],
    st: &mut EngineState,
    rank: u32,
    threads: u32,
    t0: f64,
    dur: f64,
    bytes: u64,
) {
    let ev = Event {
        rank,
        thread: 0,
        t_start: t0,
        t_end: t0 + dur,
        kind: PhaseKind::Io,
        instructions: 0,
        cycles: 0,
        mpi_call: None,
        bytes,
        sub_events: 1,
    };
    emit(sinks, costs, st, &ev, rank as usize, true);
    emit_worker_idle(
        sinks,
        st,
        rank,
        threads,
        t0,
        t0 + dur,
        PhaseKind::OmpSerialization,
    );
    let _ = bytes;
}

/// One OpenMP parallel region on one rank; returns the region wall time
/// (including instrumentation charged to the slowest thread).
#[allow(clippy::too_many_arguments)]
fn run_parallel_region(
    cfg: &RunConfig,
    sinks: &mut [&mut dyn EventSink],
    costs: &[CostModel],
    st: &mut EngineState,
    rng: &mut Rng,
    rank: u32,
    t0: f64,
    flops: f64,
    working_set_bytes: f64,
    imbalance: &Imbalance,
    schedule: OmpSchedule,
    insn_factor: f64,
) -> f64 {
    let t = cfg.resources.threads_per_rank;
    let threads_on_socket =
        t.min(cfg.machine.cores_per_socket).max(1);
    let active = cfg.resources.active_fraction(&cfg.machine);

    // Per-thread work shares.
    let mut weights: Vec<f64> = (0..t)
        .map(|th| imbalance.weight(th, t, || rng.lognormal_jitter(0.08)).max(0.05))
        .collect();
    let sum: f64 = weights.iter().sum();
    for w in &mut weights {
        *w *= t as f64 / sum;
    }

    // Dynamic scheduling rebalances to ~one-chunk granularity.
    let (effective, chunks_per_thread, dispatch_overhead) = match schedule {
        OmpSchedule::Static => (weights.clone(), 1u64, 0.0),
        OmpSchedule::Dynamic { chunks } => {
            let cpt = (chunks as f64 / t as f64).max(1.0);
            // Residual imbalance: one chunk of the heaviest weight.
            let max_w: f64 = weights.iter().cloned().fold(0.0, f64::max);
            let resid = (max_w - 1.0) / cpt + 1.0;
            let eff: Vec<f64> = (0..t)
                .map(|th| if th == 0 { resid } else { 1.0 })
                .collect();
            (eff, cpt.round() as u64, cpt * OMP_CHUNK_DISPATCH_S)
        }
    };

    let fork = OMP_FORK_BASE_S + OMP_FORK_PER_THREAD_S * t as f64;
    let mut thread_end = vec![0.0f64; t as usize];
    let mut max_end = 0.0f64;
    let mut bursts: Vec<(Burst, f64)> = Vec::with_capacity(t as usize);
    for th in 0..t {
        let share = effective[th as usize] / t as f64;
        let jitter = cfg.noise.burst_multiplier(rng);
        let burst = cfg.counters.burst(
            &cfg.machine,
            Work {
                flops: flops * share,
                working_set_bytes,
                insn_factor,
            },
            active,
            threads_on_socket,
        );
        let mut dur = burst.seconds * jitter + dispatch_overhead;
        // Instrumentation cost per chunk on this thread.
        let ev_probe = Event {
            rank,
            thread: th,
            t_start: 0.0,
            t_end: 0.0,
            kind: PhaseKind::Useful,
            instructions: 0,
            cycles: 0,
            mpi_call: None,
            bytes: 0,
            sub_events: chunks_per_thread,
        };
        let tool_cost: f64 =
            costs.iter().map(|c| c.event_cost(&ev_probe)).sum();
        dur += tool_cost;
        st.perturbation += tool_cost;
        let end = t0 + fork + dur;
        thread_end[th as usize] = end;
        max_end = max_end.max(end);
        bursts.push((burst, jitter));
    }

    // Emit events now that the barrier time is known.
    for th in 0..t {
        let (burst, _jitter) = bursts[th as usize];
        let start = t0 + fork;
        let end = thread_end[th as usize];
        // Cycle counters tick through dispatch and instrumentation time
        // too (PAPI cannot subtract the tool's own cycles) — charge the
        // whole interval at the burst's frequency.  This is what makes
        // heavy instrumentation *visibly* depress measured frequency and
        // IPC, as on real systems.
        let interval_cycles =
            ((end - start).max(0.0) * burst.freq_ghz * 1e9).round() as u64;
        let ev = Event {
            rank,
            thread: th,
            t_start: start,
            t_end: end,
            kind: PhaseKind::Useful,
            instructions: burst.instructions,
            cycles: interval_cycles,
            mpi_call: None,
            bytes: 0,
            sub_events: chunks_per_thread,
        };
        // Cost was charged inside the duration above; emit free here.
        st.total_events += ev.sub_events;
        let mut bytes_total = 0u64;
        for (i, s) in sinks.iter_mut().enumerate() {
            s.on_event(&ev);
            bytes_total += costs[i].event_bytes(&ev);
        }
        st.trace_bytes += bytes_total;
        // Barrier idle for early finishers.
        if end < max_end - 1e-12 {
            let idle = Event {
                rank,
                thread: th,
                t_start: end,
                t_end: max_end,
                kind: PhaseKind::OmpBarrier,
                instructions: 0,
                cycles: 0,
                mpi_call: None,
                bytes: 0,
                sub_events: 1,
            };
            st.total_events += 1;
            for s in sinks.iter_mut() {
                s.on_event(&idle);
            }
        }
    }
    // Fork/join overhead shows up as scheduling time on the master.
    let sched_ev = Event {
        rank,
        thread: 0,
        t_start: t0,
        t_end: t0 + fork,
        kind: PhaseKind::OmpScheduling,
        instructions: 0,
        cycles: 0,
        mpi_call: None,
        bytes: 0,
        sub_events: 1,
    };
    st.total_events += 1;
    for s in sinks.iter_mut() {
        s.on_event(&sched_ev);
    }
    // Dynamic dispatch overhead as scheduling time per thread.
    if dispatch_overhead > 0.0 {
        for th in 0..t {
            let ev = Event {
                rank,
                thread: th,
                t_start: thread_end[th as usize] - dispatch_overhead,
                t_end: thread_end[th as usize],
                kind: PhaseKind::OmpScheduling,
                instructions: 0,
                cycles: 0,
                mpi_call: None,
                bytes: 0,
                sub_events: 1,
            };
            st.total_events += 1;
            for s in sinks.iter_mut() {
                s.on_event(&ev);
            }
        }
    }
    max_end - t0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::NullSink;

    fn quick_cfg(ranks: u32, threads: u32) -> RunConfig {
        RunConfig::new(
            MachineSpec::marenostrum5(),
            ResourceConfig::new(ranks, threads),
        )
        .with_noise(NoiseModel::none())
    }

    fn compute_program(flops: f64) -> Program {
        let mut p = Program::new();
        p.push(Step::Parallel {
            flops,
            working_set_bytes: 1e8,
            imbalance: Imbalance::None,
            schedule: OmpSchedule::Static,
            rank_weights: vec![1.0],
            insn_factor: 1.0,
        });
        p.push(Step::Collective { kind: CollKind::Allreduce, bytes_per_rank: 8 });
        p
    }

    #[test]
    fn deterministic_runs() {
        let cfg = quick_cfg(4, 8);
        let prog = compute_program(1e9);
        let mut s1 = NullSink;
        let mut s2 = NullSink;
        let r1 = run(&prog, &cfg, &mut [&mut s1]);
        let r2 = run(&prog, &cfg, &mut [&mut s2]);
        assert_eq!(r1.elapsed_s, r2.elapsed_s);
        assert_eq!(r1.total_events, r2.total_events);
    }

    #[test]
    fn more_threads_faster_wall() {
        let prog = compute_program(4e10);
        let slow = run(&prog, &quick_cfg(1, 8), &mut []);
        let fast = run(&prog, &quick_cfg(1, 56), &mut []);
        assert!(
            fast.elapsed_s < slow.elapsed_s,
            "{} !< {}",
            fast.elapsed_s,
            slow.elapsed_s
        );
    }

    #[test]
    fn imbalance_stretches_wall_clock() {
        let mut balanced = Program::new();
        balanced.push(Step::Parallel {
            flops: 1e10,
            working_set_bytes: 1e8,
            imbalance: Imbalance::None,
            schedule: OmpSchedule::Static,
            rank_weights: vec![1.0],
            insn_factor: 1.0,
        });
        let mut skewed = Program::new();
        skewed.push(Step::Parallel {
            flops: 1e10,
            working_set_bytes: 1e8,
            imbalance: Imbalance::Linear { skew: 1.0 },
            schedule: OmpSchedule::Static,
            rank_weights: vec![1.0],
            insn_factor: 1.0,
        });
        let cfg = quick_cfg(1, 16);
        let b = run(&balanced, &cfg, &mut []);
        let s = run(&skewed, &cfg, &mut []);
        assert!(s.elapsed_s > 1.2 * b.elapsed_s);
    }

    #[test]
    fn dynamic_schedule_rebalances() {
        let imb = Imbalance::Linear { skew: 1.0 };
        let mk = |schedule| {
            let mut p = Program::new();
            p.push(Step::Parallel {
                flops: 1e10,
                working_set_bytes: 1e8,
                imbalance: imb.clone(),
                schedule,
                rank_weights: vec![1.0],
                insn_factor: 1.0,
            });
            p
        };
        let cfg = quick_cfg(1, 16);
        let stat = run(&mk(OmpSchedule::Static), &cfg, &mut []);
        let dyn_ = run(&mk(OmpSchedule::Dynamic { chunks: 512 }), &cfg, &mut []);
        assert!(dyn_.elapsed_s < stat.elapsed_s);
    }

    #[test]
    fn rank_imbalance_creates_wait_not_slowdown_for_light_ranks() {
        let mut p = Program::new();
        p.push(Step::Parallel {
            flops: 1e10,
            working_set_bytes: 1e8,
            imbalance: Imbalance::None,
            schedule: OmpSchedule::Static,
            rank_weights: vec![1.0, 2.0], // rank 1 does double work
            insn_factor: 1.0,
        });
        p.push(Step::Collective { kind: CollKind::Barrier, bytes_per_rank: 0 });
        let cfg = quick_cfg(2, 8);
        let r = run(&p, &cfg, &mut []);
        // All ranks leave the barrier together.
        let e0 = r.per_rank_elapsed_s[0];
        let e1 = r.per_rank_elapsed_s[1];
        assert!((e0 - e1).abs() < 1e-9, "{e0} vs {e1}");
    }

    #[test]
    fn serial_io_skews_rank0() {
        let mut p = Program::new();
        p.push(Step::Io { bytes: 500_000_000, parallel: false });
        let cfg = quick_cfg(4, 4);
        let r = run(&p, &cfg, &mut []);
        assert!(r.per_rank_elapsed_s[0] > 0.1);
        assert!(r.per_rank_elapsed_s[1] < 1e-6);
    }

    #[test]
    fn tool_cost_inflates_elapsed() {
        struct CostlySink;
        impl EventSink for CostlySink {
            fn name(&self) -> &str {
                "costly"
            }
            fn cost_model(&self) -> CostModel {
                CostModel {
                    per_event_s: 1e-5,
                    per_counter_read_s: 1e-5,
                    per_region_s: 1e-6,
                    per_mpi_s: 1e-5,
                    ..Default::default()
                }
            }
            fn on_event(&mut self, _ev: &Event) {}
            fn on_region(&mut self, _m: &RegionMark) {}
            fn on_finalize(&mut self, _e: f64) {}
        }
        let prog = compute_program(1e9);
        let cfg = quick_cfg(2, 8);
        let clean = run(&prog, &cfg, &mut []);
        let mut sink = CostlySink;
        let tooled = run(&prog, &cfg, &mut [&mut sink]);
        assert!(tooled.elapsed_s > clean.elapsed_s);
        assert!(tooled.perturbation_s > 0.0);
    }

    #[test]
    fn event_volume_counted() {
        let prog = compute_program(1e8);
        let cfg = quick_cfg(2, 4);
        let r = run(&prog, &cfg, &mut []);
        // >= threads useful events + mpi + workers idle + regions
        assert!(r.total_events >= (2 * 4 + 2 + 2 * 3) as u64);
    }
}
