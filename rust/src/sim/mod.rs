//! HPC substrate: a deterministic, phase-level simulator of hybrid
//! MPI + OpenMP executions on multi-socket cluster nodes.
//!
//! This is the stand-in for MareNostrum 5 / Raven (DESIGN.md §2): it
//! produces exactly the observable TALP and the trace-based tools
//! consume — per-thread time categories, hardware counters, MPI
//! synchronization — while staying fast enough to run thousands of
//! simulated configurations inside tests and benches.

pub mod cache;
pub mod corpus;
pub mod counters;
pub mod dvfs;
pub mod engine;
pub mod event;
pub mod machine;
pub mod mpi;
pub mod noise;
pub mod program;

pub use engine::{run, RunConfig, RunSummary};
pub use event::{CostModel, Event, EventSink, NullSink, PhaseKind, RegionMark};
pub use machine::{MachineSpec, ResourceConfig};
pub use noise::NoiseModel;
pub use program::{CollKind, Imbalance, OmpSchedule, Program, Step};
