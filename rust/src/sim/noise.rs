//! Run-to-run noise model.
//!
//! Two components:
//! * multiplicative lognormal jitter on every compute burst (scheduling,
//!   cache state, DRAM refresh — the ~0.1-0.5% runtime stddev the paper
//!   reports in Table 1), and
//! * rare OS-noise spikes (daemon wakeups) that hit one thread at a time
//!   — these are what make un-instrumented I/O regions skew factors, the
//!   paper's §Discussion caveat.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct NoiseModel {
    /// Sigma of the lognormal burst jitter (log space).
    pub burst_sigma: f64,
    /// Probability that a burst is hit by an OS-noise spike.
    pub spike_prob: f64,
    /// Spike magnitude as a fraction of the burst duration.
    pub spike_frac: f64,
}

impl NoiseModel {
    pub fn calm() -> NoiseModel {
        NoiseModel { burst_sigma: 0.002, spike_prob: 1e-4, spike_frac: 0.5 }
    }

    /// Production-like noise (default for experiments).
    pub fn typical() -> NoiseModel {
        NoiseModel { burst_sigma: 0.004, spike_prob: 5e-4, spike_frac: 1.0 }
    }

    /// An unstable platform (the [6]-style misconfigured system).
    pub fn noisy() -> NoiseModel {
        NoiseModel { burst_sigma: 0.03, spike_prob: 5e-3, spike_frac: 3.0 }
    }

    /// No noise at all (unit tests needing exact arithmetic).
    pub fn none() -> NoiseModel {
        NoiseModel { burst_sigma: 0.0, spike_prob: 0.0, spike_frac: 0.0 }
    }

    /// Multiplier to apply to one burst's duration.
    pub fn burst_multiplier(&self, rng: &mut Rng) -> f64 {
        let mut mult = if self.burst_sigma > 0.0 {
            rng.lognormal_jitter(self.burst_sigma)
        } else {
            1.0
        };
        if self.spike_prob > 0.0 && rng.bool_with_p(self.spike_prob) {
            mult += self.spike_frac * rng.f64();
        }
        mult
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_exactly_one() {
        let mut rng = Rng::new(1);
        let n = NoiseModel::none();
        for _ in 0..100 {
            assert_eq!(n.burst_multiplier(&mut rng), 1.0);
        }
    }

    #[test]
    fn typical_mean_near_one() {
        let mut rng = Rng::new(2);
        let n = NoiseModel::typical();
        let k = 20_000;
        let mean: f64 =
            (0..k).map(|_| n.burst_multiplier(&mut rng)).sum::<f64>() / k as f64;
        assert!((mean - 1.0).abs() < 0.01, "{mean}");
    }

    #[test]
    fn noisy_is_noisier() {
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        let calm = NoiseModel::calm();
        let noisy = NoiseModel::noisy();
        let k = 10_000;
        let var = |f: &mut dyn FnMut() -> f64| {
            let xs: Vec<f64> = (0..k).map(|_| f()).collect();
            let m = xs.iter().sum::<f64>() / k as f64;
            xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / k as f64
        };
        let v1 = var(&mut || calm.burst_multiplier(&mut r1));
        let v2 = var(&mut || noisy.burst_multiplier(&mut r2));
        assert!(v2 > 10.0 * v1, "{v1} vs {v2}");
    }
}
