//! Tool-visible events and the sink interface.
//!
//! The engine emits one `Event` per phase per thread (plus region
//! markers).  Performance tools attach as `EventSink`s: TALP accumulates
//! on the fly, the Extrae-like tracer streams records to disk, Score-P
//! builds call-path profiles, the CPT piggybacks vector clocks.  A
//! sink's `cost_model()` tells the engine how much time instrumenting
//! each event steals from the application — that perturbation is *added
//! to the simulated clocks*, which is how Table 1's overhead percentages
//! arise instead of being hard-coded.

use super::program::CollKind;

/// Category of time a phase event accounts for (mirrors TALP's timers).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PhaseKind {
    /// Computation the application wanted to do.
    Useful,
    /// Inside an MPI call (wait + transfer are both "MPI time" to TALP).
    Mpi,
    /// Worker thread idle while master runs serial code.
    OmpSerialization,
    /// Worker thread idle while the master thread sits in MPI.  Kept
    /// distinct from OmpSerialization so the POP hierarchy charges it to
    /// MPI parallel efficiency, not to the OpenMP factors (the formulas
    /// in pop::metrics rely on this separation to stay multiplicative).
    MpiWorkerIdle,
    /// OpenMP runtime overhead: fork/join, chunk dispatch.
    OmpScheduling,
    /// Idle at the parallel region's closing barrier (load imbalance).
    OmpBarrier,
    /// File I/O (TALP is blind to it: it lands in Useful unless the
    /// region is instrumented; kept distinct here so tests can check
    /// exactly that blindness).
    Io,
}

/// One instrumented interval on one cpu (rank, thread).
#[derive(Debug, Clone)]
pub struct Event {
    pub rank: u32,
    pub thread: u32,
    /// Seconds since program start (simulated, perturbed by tool costs).
    pub t_start: f64,
    pub t_end: f64,
    pub kind: PhaseKind,
    /// Instructions retired during the interval (0 for non-useful time).
    pub instructions: u64,
    /// Core cycles spent (freq * duration).
    pub cycles: u64,
    /// For Mpi events, which call.
    pub mpi_call: Option<CollKind>,
    /// Payload bytes (MPI message / IO volume); lets trace post-
    /// processors (Dimemas-like replay) model transfer vs wait time.
    pub bytes: u64,
    /// Fine-grained sub-events represented by this record (e.g. dynamic
    /// chunks); tools multiply their per-event costs by this.
    pub sub_events: u64,
}

/// Region boundary marker (TALP API annotation or implicit Global).
#[derive(Debug, Clone)]
pub struct RegionMark {
    pub rank: u32,
    pub t: f64,
    pub name: String,
    pub enter: bool,
}

/// Per-event instrumentation costs in seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostModel {
    /// Charged per phase event (per sub_event).
    pub per_event_s: f64,
    /// Extra cost when the tool reads hardware counters at the boundary.
    pub per_counter_read_s: f64,
    /// Charged per region marker.
    pub per_region_s: f64,
    /// Charged once per MPI call (PMPI wrapper, piggyback payload).
    pub per_mpi_s: f64,
    /// Periodic flush: every `flush_every_bytes` of trace data stalls
    /// the emitting rank for `flush_stall_s` (0 = no tracing buffer).
    pub flush_every_bytes: u64,
    pub flush_stall_s: f64,
    /// Bytes the tool writes per (sub-)event while the app runs.
    pub bytes_per_event: u64,
}

impl CostModel {
    /// Time stolen from the thread that produced `ev`.
    pub fn event_cost(&self, ev: &Event) -> f64 {
        let n = ev.sub_events.max(1) as f64;
        let mut c = n * (self.per_event_s + self.per_counter_read_s);
        if ev.kind == PhaseKind::Mpi {
            c += self.per_mpi_s;
        }
        c
    }

    /// Trace bytes generated for `ev`.
    pub fn event_bytes(&self, ev: &Event) -> u64 {
        ev.sub_events.max(1) * self.bytes_per_event
    }
}

/// A performance tool observing a run.
pub trait EventSink {
    fn name(&self) -> &str;

    /// Instrumentation cost model charged by the engine.
    fn cost_model(&self) -> CostModel;

    fn on_event(&mut self, ev: &Event);

    fn on_region(&mut self, mark: &RegionMark);

    /// Called once when the simulated app finishes; `elapsed` is the
    /// global (max-over-ranks) wall time including instrumentation
    /// perturbation.
    fn on_finalize(&mut self, elapsed: f64);
}

/// A sink that records nothing (clean baseline runs).
pub struct NullSink;

impl EventSink for NullSink {
    fn name(&self) -> &str {
        "none"
    }
    fn cost_model(&self) -> CostModel {
        CostModel::default()
    }
    fn on_event(&mut self, _ev: &Event) {}
    fn on_region(&mut self, _mark: &RegionMark) {}
    fn on_finalize(&mut self, _elapsed: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: PhaseKind, sub: u64) -> Event {
        Event {
            rank: 0,
            thread: 0,
            t_start: 0.0,
            t_end: 1.0,
            kind,
            instructions: 100,
            cycles: 50,
            mpi_call: None,
            bytes: 0,
            sub_events: sub,
        }
    }

    #[test]
    fn cost_scales_with_sub_events() {
        let cm = CostModel {
            per_event_s: 1e-6,
            per_counter_read_s: 1e-6,
            ..Default::default()
        };
        assert!((cm.event_cost(&ev(PhaseKind::Useful, 1)) - 2e-6).abs() < 1e-12);
        assert!((cm.event_cost(&ev(PhaseKind::Useful, 100)) - 2e-4).abs() < 1e-10);
    }

    #[test]
    fn mpi_surcharge_applied() {
        let cm = CostModel { per_mpi_s: 5e-6, ..Default::default() };
        let mut e = ev(PhaseKind::Mpi, 1);
        e.mpi_call = Some(CollKind::Allreduce);
        assert!((cm.event_cost(&e) - 5e-6).abs() < 1e-12);
        assert_eq!(cm.event_cost(&ev(PhaseKind::Useful, 1)), 0.0);
    }

    #[test]
    fn bytes_scale() {
        let cm = CostModel { bytes_per_event: 24, ..Default::default() };
        assert_eq!(cm.event_bytes(&ev(PhaseKind::Useful, 10)), 240);
    }
}
