//! DVFS / turbo model.
//!
//! Three effects, all visible in the paper's tables:
//!
//! 1. *Idle-core turbo*: fewer active cores per socket leave power and
//!    thermal headroom, raising clocks.
//! 2. *Stall turbo*: memory-stalled pipelines draw less power, so
//!    bandwidth-bound phases hold higher bins than cache-hot ones
//!    (paper Fig. 3 shows 2.15 -> 2.51 GHz when strong scaling relieves
//!    per-socket bandwidth pressure; the *relative* uplift is what we
//!    model).
//! 3. *IPC power penalty*: cache-resident code retiring ~3x the uops per
//!    cycle hits the package power limit and clocks *down* — this is
//!    Table 7's frequency scalability of ~0.88 next to an IPC
//!    scalability of ~3.1.

use super::machine::MachineSpec;

/// Effective core frequency in GHz for a phase.
///
/// * `active_fraction` — fraction of the socket's cores doing work.
/// * `stall_fraction`  — fraction of cycles stalled on memory ([0,1],
///   from the cache model).
/// * `ipc`             — the phase's achieved IPC.
pub fn frequency_ghz(
    m: &MachineSpec,
    active_fraction: f64,
    stall_fraction: f64,
    ipc: f64,
) -> f64 {
    let span = m.f_turbo_ghz - m.f_allcore_ghz;
    let uplift = span
        * (m.w_idle * (1.0 - active_fraction.clamp(0.0, 1.0))
            + m.w_stall * stall_fraction.clamp(0.0, 1.0));
    let power_penalty =
        1.0 - m.k_power * ((ipc / m.ipc_pwr_ref) - 1.0).max(0.0);
    ((m.f_allcore_ghz + uplift) * power_penalty).max(0.4 * m.f_allcore_ghz)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_load_memory_bound_near_allcore_plus_stall_turbo() {
        let m = MachineSpec::marenostrum5();
        let f = frequency_ghz(&m, 1.0, 0.85, 1.1);
        assert!(f > m.f_allcore_ghz, "{f}");
        assert!(f < m.f_turbo_ghz);
    }

    #[test]
    fn idle_cores_raise_frequency() {
        let m = MachineSpec::marenostrum5();
        let busy = frequency_ghz(&m, 1.0, 0.2, 1.2);
        let light = frequency_ghz(&m, 0.25, 0.2, 1.2);
        assert!(light > busy);
    }

    #[test]
    fn high_ipc_lowers_frequency() {
        // The Table 7 mechanism: strong scaling makes the working set
        // cache-resident -> IPC jumps -> frequency drops ~10%.
        let m = MachineSpec::marenostrum5();
        let mem_bound = frequency_ghz(&m, 1.0, 0.85, 1.1);
        let cache_hot = frequency_ghz(&m, 1.0, 0.15, 3.4);
        let ratio = cache_hot / mem_bound;
        assert!(
            (0.80..0.97).contains(&ratio),
            "frequency scalability {ratio} out of Table-7 band"
        );
    }

    #[test]
    fn frequency_never_collapses() {
        let m = MachineSpec::marenostrum5();
        let f = frequency_ghz(&m, 1.0, 0.0, 10.0);
        assert!(f >= 0.4 * m.f_allcore_ghz);
    }
}
