//! MPI cost models (hockney/LogGP-flavoured).
//!
//! Collectives pay a log2(P)-stage tree with per-stage software latency
//! plus a bandwidth term; point-to-point pays latency + bytes/bw, with
//! the intra- vs inter-node distinction taken from the rank placement.
//! These models only need to be *relatively* right: the POP factors the
//! paper reports are ratios of waiting/transfer time to useful time.

use super::machine::{MachineSpec, ResourceConfig};
use super::program::CollKind;

/// Transfer cost of one point-to-point message between `a` and `b`.
pub fn p2p_cost(
    m: &MachineSpec,
    cfg: &ResourceConfig,
    a: u32,
    b: u32,
    bytes: u64,
) -> f64 {
    let same_node = cfg.node_of_rank(a, m) == cfg.node_of_rank(b, m);
    let (lat, bw) = if same_node {
        (m.mpi_latency_intra_s, m.mpi_bw_intra_bps)
    } else {
        (m.mpi_latency_inter_s, m.mpi_bw_inter_bps)
    };
    lat + bytes as f64 / bw
}

/// Cost of a collective once all ranks have arrived (the engine adds the
/// wait-for-last-arrival separately, which is where load imbalance turns
/// into MPI time).
pub fn collective_cost(
    m: &MachineSpec,
    cfg: &ResourceConfig,
    kind: CollKind,
    bytes_per_rank: u64,
) -> f64 {
    let p = cfg.n_ranks.max(1);
    let stages = (p as f64).log2().ceil().max(1.0);
    let crosses_nodes = cfg.nodes_used(m) > 1;
    let (lat, bw) = if crosses_nodes {
        (m.mpi_latency_inter_s, m.mpi_bw_inter_bps)
    } else {
        (m.mpi_latency_intra_s, m.mpi_bw_intra_bps)
    };
    let stage_cost = m.coll_stage_s + lat;
    let bytes = bytes_per_rank as f64;
    match kind {
        CollKind::Barrier => stages * stage_cost,
        // Reduce-scatter + allgather style: 2 traversals of the data.
        CollKind::Allreduce => stages * stage_cost + 2.0 * bytes / bw,
        CollKind::Bcast => stages * stage_cost + bytes / bw,
        // Each rank ends with P * bytes; bandwidth term dominated by the
        // receive volume.
        CollKind::Allgather => {
            stages * stage_cost + (p as f64) * bytes / bw
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (MachineSpec, ResourceConfig) {
        (MachineSpec::marenostrum5(), ResourceConfig::new(4, 56))
    }

    #[test]
    fn intra_node_cheaper_than_inter() {
        let (m, cfg) = setup();
        // ranks 0,1 on node 0; rank 2 on node 1.
        let intra = p2p_cost(&m, &cfg, 0, 1, 1 << 20);
        let inter = p2p_cost(&m, &cfg, 1, 2, 1 << 20);
        assert!(inter > intra);
    }

    #[test]
    fn collective_scales_with_log_p() {
        let m = MachineSpec::marenostrum5();
        let c2 = collective_cost(&m, &ResourceConfig::new(2, 1), CollKind::Barrier, 0);
        let c256 =
            collective_cost(&m, &ResourceConfig::new(256, 1), CollKind::Barrier, 0);
        assert!(c256 > c2);
        assert!(c256 < 20.0 * c2, "log not linear scaling");
    }

    #[test]
    fn allreduce_costs_more_than_barrier() {
        let (m, cfg) = setup();
        let b = collective_cost(&m, &cfg, CollKind::Barrier, 8);
        let a = collective_cost(&m, &cfg, CollKind::Allreduce, 8);
        assert!(a > b);
    }

    #[test]
    fn bandwidth_term_visible_for_large_payloads() {
        let (m, cfg) = setup();
        let small = collective_cost(&m, &cfg, CollKind::Bcast, 8);
        let large = collective_cost(&m, &cfg, CollKind::Bcast, 1 << 30);
        assert!(large > 10.0 * small);
    }
}
