//! `talp-pages serve` — a resident monitoring service over a run
//! store, re-analyzing **incrementally** as artifacts arrive.
//!
//! The batch pipeline re-reads the whole corpus per CI push.  Serve
//! mode instead keeps a warm [`Monitor`] (store + scan + previous
//! analysis) behind a hand-rolled HTTP/1.1 listener ([`http`], std
//! only — the vendored-offline policy rules out server crates):
//!
//! * `POST /ingest` accepts one artifact body in any registered
//!   ingestion-adapter format (TALP, ROOT-bench, BeeSwarm — see
//!   [`crate::adapters`]; auto-detected, or pinned by a `format` query
//!   param; git metadata in query params mirrors `ingest --commit ...`),
//!   routes it through the store's content-addressed admission,
//!   re-analyzes **only the affected experiment**, and atomically
//!   swaps the served snapshot.
//! * `--watch <dir>` polls a drop directory through the same
//!   incremental path with per-file adapter auto-detection (a warm
//!   poll over an unchanged folder parses nothing).
//! * `GET /report.json`, `/gate.json`, `/badges/*.svg`, `/index.html`
//!   serve an immutable [`Snapshot`]: the files the **batch emitter
//!   set** ([`crate::session::default_emitters`]) produced for the
//!   current analysis, spooled at swap time.  Payloads are therefore
//!   byte-identical to `report --store`/`gate --store` over the same
//!   corpus by construction — there is no second emitter to drift.
//! * `GET /healthz` and `GET /statsz` expose liveness and the
//!   incrementality counters (`reanalyzed_histories_last` is the
//!   witness that a one-run ingest did not rescan unaffected
//!   histories).
//!
//! Concurrency model: readers clone an `Arc<Snapshot>` out of an
//! [`RwLock`] and serve from it lock-free — they observe the old or
//! the new snapshot, never a torn mix.  Writers (ingest, watch polls)
//! serialize on the [`Monitor`] mutex, and the monitor holds the
//! store's single-writer lockfile for its whole lifetime, so a
//! concurrent CLI `ingest` is refused instead of interleaving shard
//! appends.  SIGTERM/SIGINT (or `POST /shutdown`) drains in-flight
//! requests, flushes a pending watch ingest, releases the lock and
//! returns cleanly.
//!
//! Hardening (the fault model a resident monitor actually faces):
//!
//! * Every accepted socket gets read/write timeouts
//!   ([`ServeOptions::read_timeout_ms`] / `write_timeout_ms`), so a
//!   slowloris client that trickles header bytes is answered 408 and
//!   dropped instead of pinning a thread forever.
//! * Concurrent connections are capped
//!   ([`ServeOptions::max_connections`]); excess connections are
//!   answered `503 Service Unavailable` with `Retry-After: 1` off the
//!   accept loop, which itself never blocks on a peer.
//! * A failing incremental refresh (I/O error, injected fault) does
//!   **not** kill the server: the last good snapshot keeps being
//!   served, `/healthz` and `/statsz` report `degraded: true` with
//!   the error, the failed experiments stay dirty, and the next
//!   successful refresh clears the flag.  Watch-poll ingest failures
//!   retry with exponential backoff (capped at 30 s) instead of
//!   hot-looping on a broken drop directory.

pub mod http;
pub mod monitor;

pub use monitor::{Monitor, MonitorStats, RefreshPass};

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::adapters::{self, Detection};
use crate::pages::cache::content_hash;
use crate::session::{self, Analysis, AnalyzeOptions};
use crate::talp::GitMeta;
use crate::util::fs::TempDir;
use crate::util::json::Json;
use crate::util::timefmt;

use http::Request;

/// Server configuration (the `serve` CLI command maps onto this).
#[derive(Debug)]
pub struct ServeOptions {
    /// Run store root to serve (created if absent).
    pub store: PathBuf,
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    /// Optional artifact drop directory, polled every `poll_ms`.
    pub watch: Option<PathBuf>,
    /// Analysis options — same struct the batch `report` builds.
    pub analyze: AnalyzeOptions,
    /// Worker threads for analysis/ingest (0 = auto).
    pub jobs: usize,
    /// `POST /ingest` body cap (413 beyond it).
    pub max_body_bytes: usize,
    /// Watch-directory poll interval.
    pub poll_ms: u64,
    /// Per-connection socket read timeout (slowloris defence; an
    /// expired deadline answers 408 and closes).
    pub read_timeout_ms: u64,
    /// Per-connection socket write timeout (a peer that stops reading
    /// its response is dropped, not waited on).
    pub write_timeout_ms: u64,
    /// Concurrent-connection cap; excess connections are answered
    /// `503` + `Retry-After: 1` without entering the handler pool.
    pub max_connections: usize,
}

impl ServeOptions {
    pub fn new(store: impl Into<PathBuf>) -> ServeOptions {
        ServeOptions {
            store: store.into(),
            addr: "127.0.0.1:8787".to_string(),
            watch: None,
            analyze: AnalyzeOptions::default(),
            jobs: 0,
            max_body_bytes: 8 * 1024 * 1024,
            poll_ms: 1000,
            read_timeout_ms: 10_000,
            write_timeout_ms: 10_000,
            max_connections: 64,
        }
    }
}

/// One immutable generation of served payloads: every file the batch
/// emitter set produced for the analysis this snapshot was built from.
#[derive(Debug)]
pub struct Snapshot {
    /// Monotonic generation counter (1 = the startup analysis).
    pub seq: u64,
    /// Root-relative path (`report.json`, `badges/x.svg`, ...) → bytes.
    pub files: BTreeMap<String, Vec<u8>>,
}

/// Counters a serve loop hands back on clean shutdown.
#[derive(Debug, Clone, Copy)]
pub struct ServeSummary {
    pub requests: u64,
    pub ingested: u64,
    pub rejected: u64,
    pub snapshot_seq: u64,
}

/// State shared between the accept loop and connection threads.
struct Shared {
    monitor: Mutex<Monitor>,
    snapshot: RwLock<Arc<Snapshot>>,
    shutdown: AtomicBool,
    /// In-flight connection threads (drained on shutdown).
    active: AtomicUsize,
    requests: AtomicU64,
    ingested: AtomicU64,
    rejected: AtomicU64,
    max_body_bytes: usize,
    read_timeout_ms: u64,
    write_timeout_ms: u64,
    max_connections: usize,
    /// Why the served snapshot is stale (`None` = healthy): set when a
    /// refresh fails, cleared by the next successful one.  The last
    /// good snapshot keeps being served the whole time.
    degraded: Mutex<Option<String>>,
    refresh_failures: AtomicU64,
}

/// Read the degraded reason, surviving a poisoned mutex.
fn degraded_reason(shared: &Shared) -> Option<String> {
    shared
        .degraded
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .clone()
}

/// A running server (in-process API; the CLI wraps [`run`]).
pub struct ServeHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    thread: std::thread::JoinHandle<Result<ServeSummary>>,
}

impl ServeHandle {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown and wait for the clean-exit summary.
    pub fn shutdown(self) -> Result<ServeSummary> {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.wait()
    }

    /// Wait for the loop to end (signal, `POST /shutdown`, error).
    pub fn wait(self) -> Result<ServeSummary> {
        match self.thread.join() {
            Ok(summary) => summary,
            Err(_) => anyhow::bail!("serve loop panicked"),
        }
    }
}

/// Build, bind and start a server; returns once it is accepting.
pub fn spawn(opts: ServeOptions) -> Result<ServeHandle> {
    let ServeOptions {
        store,
        addr,
        watch,
        analyze,
        jobs,
        max_body_bytes,
        poll_ms,
        read_timeout_ms,
        write_timeout_ms,
        max_connections,
    } = opts;
    let monitor = Monitor::open(&store, analyze, jobs)?;
    let snapshot = build_snapshot(monitor.analysis(), 1)?;
    let listener = TcpListener::bind(&addr)
        .with_context(|| format!("binding {addr}"))?;
    listener
        .set_nonblocking(true)
        .context("non-blocking accept loop")?;
    let local = listener.local_addr()?;
    let shared = Arc::new(Shared {
        monitor: Mutex::new(monitor),
        snapshot: RwLock::new(Arc::new(snapshot)),
        shutdown: AtomicBool::new(false),
        active: AtomicUsize::new(0),
        requests: AtomicU64::new(0),
        ingested: AtomicU64::new(0),
        rejected: AtomicU64::new(0),
        max_body_bytes,
        read_timeout_ms,
        write_timeout_ms,
        max_connections: max_connections.max(1),
        degraded: Mutex::new(None),
        refresh_failures: AtomicU64::new(0),
    });
    let loop_shared = Arc::clone(&shared);
    let thread = std::thread::spawn(move || {
        serve_loop(listener, loop_shared, watch, poll_ms)
    });
    Ok(ServeHandle { addr: local, shared, thread })
}

/// CLI entry: install signal handlers, serve until SIGTERM/SIGINT
/// (or `POST /shutdown`), exit cleanly.
pub fn run(opts: ServeOptions) -> Result<ServeSummary> {
    install_signal_handlers();
    let watch = opts.watch.clone();
    let handle = spawn(opts)?;
    println!(
        "talp-pages serve: http://{} (store locked for writing{})",
        handle.addr(),
        match &watch {
            Some(d) => format!(", watching {}", d.display()),
            None => String::new(),
        }
    );
    let summary = handle.wait()?;
    println!(
        "talp-pages serve: clean shutdown — {} requests, {} ingested, \
         {} rejected, snapshot #{}",
        summary.requests,
        summary.ingested,
        summary.rejected,
        summary.snapshot_seq
    );
    Ok(summary)
}

/// SIGTERM/SIGINT latch for the CLI path ([`run`]); in-process
/// servers use `Shared::shutdown` / `POST /shutdown` instead.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_signum: i32) {
    // Only an atomic store: async-signal-safe.
    SIGNALLED.store(true, Ordering::SeqCst);
}

/// Install SIGINT/SIGTERM handlers via raw `signal(2)` — the `libc`
/// crate is unavailable offline (same pattern as main's SIGPIPE
/// restore).
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Accept/poll loop; returns the summary on clean shutdown.
fn serve_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    watch: Option<PathBuf>,
    poll_ms: u64,
) -> Result<ServeSummary> {
    let poll = Duration::from_millis(poll_ms.max(1));
    let backoff_cap = Duration::from_secs(30);
    let mut next_poll = Instant::now();
    let mut watch_failures: u32 = 0;
    while !shutdown_requested(&shared) {
        if watch.is_some() && Instant::now() >= next_poll {
            match poll_watch(&shared, watch.as_deref().unwrap()) {
                Ok(()) => {
                    watch_failures = 0;
                    next_poll = Instant::now() + poll;
                }
                Err(e) => {
                    // Exponential backoff on consecutive failures: a
                    // broken drop directory (or an injected refresh
                    // fault) must not hot-loop the same error; the
                    // first success resets the cadence.
                    watch_failures = watch_failures.saturating_add(1);
                    let backoff = poll
                        .saturating_mul(1u32 << watch_failures.min(5))
                        .min(backoff_cap);
                    eprintln!(
                        "talp-pages serve: watch ingest: {e:#} \
                         (retry in {} ms)",
                        backoff.as_millis()
                    );
                    next_poll = Instant::now() + backoff;
                }
            }
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.requests.fetch_add(1, Ordering::Relaxed);
                // The listener is non-blocking; accepted sockets must
                // be blocking-with-deadlines (inheritance of the
                // non-blocking flag is platform-dependent).
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_read_timeout(Some(
                    Duration::from_millis(shared.read_timeout_ms.max(1)),
                ));
                let _ = stream.set_write_timeout(Some(
                    Duration::from_millis(shared.write_timeout_ms.max(1)),
                ));
                if shared.active.load(Ordering::SeqCst)
                    >= shared.max_connections
                {
                    // Over the cap: answer 503 + Retry-After on a
                    // throwaway thread — even a short write can stall
                    // on a hostile peer, and the accept loop may not.
                    let conn = Arc::clone(&shared);
                    std::thread::spawn(move || reject_busy(stream, &conn));
                    continue;
                }
                shared.active.fetch_add(1, Ordering::SeqCst);
                let conn = Arc::clone(&shared);
                std::thread::spawn(move || {
                    // Guard, not a tail call: a panicking handler must
                    // still decrement or shutdown would never drain.
                    struct Active<'a>(&'a AtomicUsize);
                    impl Drop for Active<'_> {
                        fn drop(&mut self) {
                            self.0.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    let _guard = Active(&conn.active);
                    handle_conn(stream, &conn);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accepting a connection"),
        }
    }
    // Drain in-flight requests (bounded — a wedged client socket must
    // not turn SIGTERM into a hang), then flush any artifacts dropped
    // into the watch directory since the last poll.
    let deadline = Instant::now() + Duration::from_secs(5);
    while shared.active.load(Ordering::SeqCst) > 0
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    if let Some(dir) = &watch {
        if let Err(e) = poll_watch(&shared, dir) {
            eprintln!("talp-pages serve: final watch flush: {e:#}");
        }
    }
    let seq = shared.snapshot.read().map(|s| s.seq).unwrap_or(0);
    Ok(ServeSummary {
        requests: shared.requests.load(Ordering::Relaxed),
        ingested: shared.ingested.load(Ordering::Relaxed),
        rejected: shared.rejected.load(Ordering::Relaxed),
        snapshot_seq: seq,
    })
}

/// Answer an over-the-cap connection with `503` + `Retry-After: 1`.
/// Counted as rejected, never as active — it must not consume a slot
/// the cap exists to protect.
fn reject_busy(mut stream: TcpStream, shared: &Shared) {
    shared.rejected.fetch_add(1, Ordering::Relaxed);
    let _ = http::respond_with_headers(
        &mut stream,
        503,
        "application/json",
        &[("Retry-After", "1")],
        error_body("connection cap reached; retry shortly").as_bytes(),
    );
}

fn shutdown_requested(shared: &Shared) -> bool {
    shared.shutdown.load(Ordering::SeqCst)
        || SIGNALLED.load(Ordering::SeqCst)
}

/// Lock the monitor, recovering from a poisoned mutex — a panicking
/// connection thread must not wedge every later request.
fn lock_monitor(shared: &Shared) -> MutexGuard<'_, Monitor> {
    shared
        .monitor
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Ingest the watch directory; on fresh records, re-analyze and swap.
fn poll_watch(shared: &Shared, dir: &Path) -> Result<()> {
    if !dir.is_dir() {
        return Ok(()); // not created yet — poll again later
    }
    let mut monitor = lock_monitor(shared);
    let report = monitor.ingest_dir(dir)?;
    for w in &report.warnings {
        eprintln!("talp-pages serve: {w}");
    }
    if report.stored > 0 {
        refresh_and_swap(shared, &mut monitor)?;
        shared
            .ingested
            .fetch_add(report.stored as u64, Ordering::Relaxed);
    }
    Ok(())
}

/// Run the incremental refresh and publish a new snapshot if anything
/// was dirty.  The swap is atomic: readers keep the old `Arc` until
/// the fully-built replacement lands.
///
/// A failing refresh puts the server in **degraded mode** instead of
/// killing it: the error is recorded for `/healthz` + `/statsz`, the
/// last good snapshot keeps being served, and — because
/// [`Monitor::refresh`] fails before consuming its dirty set — the
/// next refresh retries the same experiments and clears the flag on
/// success.
fn refresh_and_swap(
    shared: &Shared,
    monitor: &mut Monitor,
) -> Result<Option<RefreshPass>> {
    let pass = match monitor.refresh() {
        Ok(pass) => pass,
        Err(e) => {
            shared.refresh_failures.fetch_add(1, Ordering::Relaxed);
            if let Ok(mut slot) = shared.degraded.lock() {
                *slot = Some(format!("{e:#}"));
            }
            return Err(e);
        }
    };
    if let Ok(mut slot) = shared.degraded.lock() {
        *slot = None;
    }
    if pass.is_some() {
        let seq = shared.snapshot.read().map(|s| s.seq).unwrap_or(0) + 1;
        let next = Arc::new(build_snapshot(monitor.analysis(), seq)?);
        if let Ok(mut slot) = shared.snapshot.write() {
            *slot = next;
        }
    }
    Ok(pass)
}

/// Spool the batch emitter set into a scratch directory and capture
/// every produced file — served bytes ARE batch bytes.
fn build_snapshot(analysis: &Analysis, seq: u64) -> Result<Snapshot> {
    let spool = TempDir::new("serve-snapshot")?;
    let mut emitters = session::default_emitters(spool.path());
    analysis.emit(&mut emitters)?;
    let mut files = BTreeMap::new();
    read_tree(spool.path(), "", &mut files)?;
    Ok(Snapshot { seq, files })
}

fn read_tree(
    dir: &Path,
    prefix: &str,
    files: &mut BTreeMap<String, Vec<u8>>,
) -> Result<()> {
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let rel = if prefix.is_empty() {
            name
        } else {
            format!("{prefix}/{name}")
        };
        let path = entry.path();
        if path.is_dir() {
            read_tree(&path, &rel, files)?;
        } else {
            files.insert(rel, std::fs::read(&path)?);
        }
    }
    Ok(())
}

/// Read one request, route it, answer it.  Socket errors on the way
/// out are ignored (the client hung up; nothing to salvage).
fn handle_conn(mut stream: TcpStream, shared: &Shared) {
    let req = match http::read_request(&mut stream, shared.max_body_bytes)
    {
        Ok(r) => r,
        Err(e) => {
            shared.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = http::respond(
                &mut stream,
                e.status,
                "application/json",
                error_body(&e.message).as_bytes(),
            );
            return;
        }
    };
    let (status, ctype, body) = route(&req, shared);
    if status >= 400 {
        shared.rejected.fetch_add(1, Ordering::Relaxed);
    }
    let _ = http::respond(&mut stream, status, ctype, &body);
}

type Response = (u16, &'static str, Vec<u8>);

fn route(req: &Request, shared: &Shared) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let seq = shared.snapshot.read().map(|s| s.seq).unwrap_or(0);
            // `degraded` appends after the long-standing keys so
            // substring consumers keep matching; `ok` stays true — the
            // process is alive and serving its last good snapshot.
            json_response(Json::from_pairs(vec![
                ("ok", Json::Bool(true)),
                ("snapshot_seq", Json::Num(seq as f64)),
                (
                    "degraded",
                    Json::Bool(degraded_reason(shared).is_some()),
                ),
            ]))
        }
        ("GET", "/statsz") => statsz(shared),
        ("GET", _) => snapshot_file(req, shared),
        ("POST", "/ingest") => handle_ingest(req, shared)
            .unwrap_or_else(|e| {
                (
                    500,
                    "application/json",
                    error_body(&format!("{e:#}")).into_bytes(),
                )
            }),
        ("POST", "/shutdown") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            json_response(Json::from_pairs(vec![(
                "ok",
                Json::Bool(true),
            )]))
        }
        (method, path) => (
            405,
            "application/json",
            error_body(&format!("{method} {path} is not supported"))
                .into_bytes(),
        ),
    }
}

/// Serve a file out of the current snapshot (`/` → `index.html`).
fn snapshot_file(req: &Request, shared: &Shared) -> Response {
    let rel = match req.path.trim_start_matches('/') {
        "" => "index.html",
        p => p,
    };
    let snap: Arc<Snapshot> = match shared.snapshot.read() {
        Ok(slot) => Arc::clone(&slot),
        Err(_) => {
            return (
                500,
                "application/json",
                error_body("snapshot lock poisoned").into_bytes(),
            )
        }
    };
    match snap.files.get(rel) {
        Some(bytes) => (200, http::content_type_for(rel), bytes.clone()),
        None => (
            404,
            "application/json",
            error_body(&format!("no {rel} in snapshot #{}", snap.seq))
                .into_bytes(),
        ),
    }
}

/// The incrementality witness: monitor counters + request counters.
fn statsz(shared: &Shared) -> Response {
    let (stats, formats) = {
        let monitor = lock_monitor(shared);
        let formats: Vec<(&'static str, Json)> = monitor
            .formats()
            .iter()
            .map(|(name, runs)| (*name, Json::Num(*runs as f64)))
            .collect();
        (monitor.stats(), formats)
    };
    let seq = shared.snapshot.read().map(|s| s.seq).unwrap_or(0);
    let reason = degraded_reason(shared);
    json_response(Json::from_pairs(vec![
        ("ok", Json::Bool(true)),
        ("snapshot_seq", Json::Num(seq as f64)),
        (
            "requests",
            Json::Num(shared.requests.load(Ordering::Relaxed) as f64),
        ),
        (
            "ingested",
            Json::Num(shared.ingested.load(Ordering::Relaxed) as f64),
        ),
        (
            "rejected",
            Json::Num(shared.rejected.load(Ordering::Relaxed) as f64),
        ),
        ("stored_runs", Json::Num(stats.stored_runs as f64)),
        ("experiments", Json::Num(stats.experiments as f64)),
        ("total_histories", Json::Num(stats.total_histories as f64)),
        ("analysis_passes", Json::Num(stats.analysis_passes as f64)),
        (
            "reanalyzed_histories_last",
            Json::Num(stats.reanalyzed_histories_last as f64),
        ),
        (
            "reanalyzed_histories_total",
            Json::Num(stats.reanalyzed_histories_total as f64),
        ),
        // New keys append after the long-standing ones so substring
        // consumers (the CI serve-smoke greps) keep matching.
        ("formats", Json::from_pairs(formats)),
        ("degraded", Json::Bool(reason.is_some())),
        (
            "refresh_failures",
            Json::Num(
                shared.refresh_failures.load(Ordering::Relaxed) as f64,
            ),
        ),
        (
            "last_refresh_error",
            Json::Str(reason.unwrap_or_default()),
        ),
    ]))
}

/// `POST /ingest`: one artifact body + query-param metadata,
/// mirroring the CLI `ingest` flags (`source` is required; `commit`,
/// `branch`, `timestamp`, `message`, `experiment`, `format` optional).
/// The body's ingestion adapter is auto-detected unless `format` pins
/// one; a multi-run artifact (e.g. a BeeSwarm scaling sweep) admits
/// every run it carries.  Any rejection answers 4xx **before** the
/// store or snapshot is touched.
fn handle_ingest(req: &Request, shared: &Shared) -> Result<Response> {
    let source = match req.query_get("source") {
        Some(s) if !s.is_empty() => s,
        _ => {
            return Ok(bad(
                "POST /ingest needs a source=<relative artifact path> \
                 query parameter",
            ))
        }
    };
    if source.starts_with('/')
        || source.contains('\\')
        || source.split('/').any(|seg| seg == ".." || seg.is_empty())
    {
        return Ok(bad(&format!(
            "source '{source}' must be a clean relative path"
        )));
    }
    if req.body.is_empty() {
        return Ok(bad(
            "empty request body (expected a performance artifact)",
        ));
    }
    // Same contract as `ingest --commit ...`: companions only mean
    // something with a commit, and a sloppy timestamp would scramble
    // the cross-commit ordering this metadata exists to protect.
    if req.query_get("commit").is_none() {
        for key in ["branch", "timestamp", "message"] {
            if req.query_get(key).is_some() {
                return Ok(bad(&format!("{key} requires commit")));
            }
        }
    }
    let commit_timestamp = match req.query_get("timestamp") {
        Some(t) => match timefmt::from_iso8601(t) {
            Some(ts) => ts,
            None => {
                return Ok(bad(&format!(
                    "timestamp '{t}' is not ISO-8601 (want e.g. \
                     2026-01-01T00:00:00Z)"
                )))
            }
        },
        None => timefmt::now_unix(),
    };
    let meta = req.query_get("commit").map(|sha| GitMeta {
        commit: sha.to_string(),
        branch: req.query_get("branch").unwrap_or("main").to_string(),
        commit_timestamp,
        message: req.query_get("message").unwrap_or("").to_string(),
    });
    let experiment = match req.query_get("experiment") {
        Some(e) if !e.is_empty() => e.to_string(),
        _ => default_experiment(source),
    };
    // Resolve the ingestion adapter: an explicit `format` query param
    // pins one, otherwise the body is sniffed — an ambiguous body is
    // a hard 400 (never a guess), an unrecognized one names the
    // registry so the client knows what this server speaks.
    let adapter = match req.query_get("format") {
        None | Some("auto") => match adapters::detect(&req.body) {
            Detection::Match(a) => a,
            Detection::Ambiguous(a, b) => {
                return Ok(bad(&format!(
                    "ambiguous artifact format — detected as both '{a}' \
                     and '{b}'; pass an explicit format= query parameter"
                )))
            }
            Detection::Unknown => {
                return Ok(bad(&format!(
                    "no registered adapter ({}) recognizes this body",
                    adapters::names()
                )))
            }
        },
        Some(name) => match adapters::by_name(name) {
            Some(a) => a,
            None => {
                return Ok(bad(&format!(
                    "unknown format '{name}' (auto|{})",
                    adapters::names()
                )))
            }
        },
    };

    let hash = content_hash(&req.body);
    let mut monitor = lock_monitor(shared);
    if monitor.store().contains_file(source, &hash) {
        let seq = shared.snapshot.read().map(|s| s.seq).unwrap_or(0);
        return Ok(ingest_response(false, seq, 0, adapter.name(), 0));
    }
    let runs = match adapter.parse(&req.body, source) {
        Ok(runs) => runs,
        Err(e) => {
            return Ok(bad(&format!(
                "unparsable {} artifact: {e:#}",
                adapter.name()
            )))
        }
    };
    let mut stored_runs = 0usize;
    for mut run in runs {
        if run.git.is_none() {
            run.git = meta.clone();
        }
        if monitor.ingest_run(&experiment, &hash, run)? {
            stored_runs += 1;
        }
    }
    monitor.note_format(adapter.name(), stored_runs as u64);
    let mut reanalyzed = 0;
    if stored_runs > 0 {
        if let Some(pass) = refresh_and_swap(shared, &mut monitor)? {
            reanalyzed = pass.reanalyzed_histories;
        }
        shared
            .ingested
            .fetch_add(stored_runs as u64, Ordering::Relaxed);
    }
    let seq = shared.snapshot.read().map(|s| s.seq).unwrap_or(0);
    Ok(ingest_response(
        stored_runs > 0,
        seq,
        reanalyzed,
        adapter.name(),
        stored_runs,
    ))
}

/// Default experiment id for an ingested source path: its parent
/// directory, matching the directory scanner's grouping rule (`"."`
/// for a top-level file).
fn default_experiment(source: &str) -> String {
    match source.rsplit_once('/') {
        Some((dir, _file)) => dir.to_string(),
        None => ".".to_string(),
    }
}

fn ingest_response(
    stored: bool,
    seq: u64,
    reanalyzed: usize,
    format: &str,
    runs: usize,
) -> Response {
    // `format`/`runs` append after the long-standing keys so substring
    // consumers (the CI serve-smoke greps) keep matching.
    json_response(Json::from_pairs(vec![
        ("stored", Json::Bool(stored)),
        ("snapshot_seq", Json::Num(seq as f64)),
        ("reanalyzed_histories", Json::Num(reanalyzed as f64)),
        ("format", Json::Str(format.to_string())),
        ("runs", Json::Num(runs as f64)),
    ]))
}

fn json_response(doc: Json) -> Response {
    (200, "application/json", doc.to_string_compact().into_bytes())
}

fn bad(message: &str) -> Response {
    (400, "application/json", error_body(message).into_bytes())
}

fn error_body(message: &str) -> String {
    Json::from_pairs(vec![("error", Json::Str(message.to_string()))])
        .to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::Session;

    #[test]
    fn default_experiment_matches_scanner_grouping() {
        assert_eq!(default_experiment("exp/2x8/run.json"), "exp/2x8");
        assert_eq!(default_experiment("run.json"), ".");
    }

    #[test]
    fn snapshot_is_bytewise_the_batch_emitter_output() {
        // The invariant everything else rests on: a snapshot holds
        // exactly the files (names AND bytes) the batch pipeline
        // writes for the same corpus.
        let td = TempDir::new("serve-snap").unwrap();
        let root = crate::serve::monitor::tests::seeded_store(&td, 2);
        let monitor =
            Monitor::open(&root, AnalyzeOptions::default(), 0).unwrap();
        let snap = build_snapshot(monitor.analysis(), 1).unwrap();
        drop(monitor); // release the writer lock

        let out = td.path().join("batch");
        let analysis = Session::from_store(&root)
            .scan()
            .unwrap()
            .analyze(&AnalyzeOptions::default());
        analysis
            .emit(&mut session::default_emitters(&out))
            .unwrap();
        let mut batch = BTreeMap::new();
        read_tree(&out, "", &mut batch).unwrap();

        assert!(snap.files.contains_key("report.json"));
        assert!(snap.files.contains_key("index.html"));
        assert_eq!(
            snap.files.keys().collect::<Vec<_>>(),
            batch.keys().collect::<Vec<_>>(),
            "same file set"
        );
        for (name, bytes) in &snap.files {
            assert_eq!(
                bytes,
                &batch[name],
                "{name} must be byte-identical to the batch emitter"
            );
        }
    }
}
