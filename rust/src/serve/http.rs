//! Minimal HTTP/1.1 over [`std::net::TcpStream`] — just enough
//! protocol for the serve endpoints, hand-rolled because the repo's
//! vendored-offline policy rules out dependency crates.
//!
//! Scope (deliberate): one request per connection (`Connection:
//! close`), `Content-Length` bodies only (no chunked encoding), a
//! bounded header block and a caller-chosen body cap.  Anything
//! outside that scope is a structured 4xx [`HttpError`], never a
//! panic, because a resident monitor's sockets face arbitrary bytes.

use std::io::{Read as _, Write as _};
use std::net::TcpStream;

/// Parsed request: method, decoded path, decoded query pairs, body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Request path without the query string (undecoded — served
    /// snapshot paths are plain ASCII file names).
    pub path: String,
    /// Percent-decoded `key=value` query pairs, in request order.
    pub query: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value for a query key.
    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// A request that could not be read: the status to answer with and a
/// message for the JSON error body.
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError { status, message: message.into() }
    }
}

/// Header block cap: no legitimate client of these endpoints sends
/// more than a few hundred bytes of headers.
const MAX_HEADER_BYTES: usize = 16 * 1024;

/// Map a socket read failure to its HTTP answer: an expired
/// `set_read_timeout` deadline (slowloris defence) is a 408, anything
/// else is the client's malformed traffic (400).
fn read_error(e: std::io::Error, what: &str) -> HttpError {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::TimedOut | ErrorKind::WouldBlock => {
            HttpError::new(408, format!("{what} timed out"))
        }
        _ => HttpError::new(400, format!("{what}: {e}")),
    }
}

/// Read one request from `stream`.  `max_body` bounds the declared
/// `Content-Length` (413 beyond it); a missing length on POST means
/// an empty body (the server rejects empty ingests at routing level).
pub fn read_request(
    stream: &mut TcpStream,
    max_body: usize,
) -> Result<Request, HttpError> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_header_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(HttpError::new(400, "header block too large"));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| read_error(e, "read"))?;
        if n == 0 {
            return Err(HttpError::new(
                400,
                "connection closed before the header block ended",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = String::from_utf8_lossy(&buf[..header_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split(' ');
    let (method, target, version) = (
        parts.next().unwrap_or_default(),
        parts.next().unwrap_or_default(),
        parts.next().unwrap_or_default(),
    );
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/")
    {
        return Err(HttpError::new(
            400,
            format!("malformed request line '{request_line}'"),
        ));
    }
    let mut content_length: usize = 0;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| {
                        HttpError::new(
                            400,
                            format!("bad content-length '{}'", value.trim()),
                        )
                    })?;
            }
        }
    }
    if content_length > max_body {
        // Drain what the client already sent (bounded) before
        // answering: closing with unread bytes in the socket can turn
        // into a reset that eats the 413 response.
        const DRAIN_CAP: usize = 1024 * 1024;
        let mut remaining = content_length
            .saturating_sub(buf.len() - (header_end + 4))
            .min(DRAIN_CAP);
        while remaining > 0 {
            match stream.read(&mut chunk) {
                Ok(0) | Err(_) => break,
                Ok(n) => remaining = remaining.saturating_sub(n),
            }
        }
        return Err(HttpError::new(
            413,
            format!("body of {content_length} B exceeds the {max_body} B cap"),
        ));
    }

    let mut body = buf[header_end + 4..].to_vec();
    if body.len() > content_length {
        // Trailing bytes beyond the declared length (pipelining is
        // out of scope) — keep exactly the declared body.
        body.truncate(content_length);
    }
    while body.len() < content_length {
        let n = stream
            .read(&mut chunk)
            .map_err(|e| read_error(e, "read body"))?;
        if n == 0 {
            return Err(HttpError::new(
                400,
                format!(
                    "connection closed {} B into a {content_length} B body",
                    body.len()
                ),
            ));
        }
        let take = n.min(content_length - body.len());
        body.extend_from_slice(&chunk[..take]);
    }

    let (path, query) = parse_target(target);
    Ok(Request { method: method.to_string(), path, query, body })
}

/// Write a complete response; the connection closes after it.
pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    respond_with_headers(stream, status, content_type, &[], body)
}

/// [`respond`] with extra response headers (e.g. `Retry-After` on the
/// connection-cap 503).
pub fn respond_with_headers(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    extra: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        reason(status),
        body.len()
    );
    for (name, value) in extra {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Canonical reason phrases for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Content type for a served snapshot path, by extension.
pub fn content_type_for(path: &str) -> &'static str {
    match path.rsplit_once('.').map(|(_, ext)| ext) {
        Some("json") => "application/json",
        Some("svg") => "image/svg+xml",
        Some("html") => "text/html; charset=utf-8",
        Some("md") => "text/markdown; charset=utf-8",
        Some("xml") => "application/xml",
        _ => "application/octet-stream",
    }
}

/// Split `/path?k=v&k2=v2` into the path and decoded query pairs.
pub(crate) fn parse_target(target: &str) -> (String, Vec<(String, String)>) {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let pairs = query
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect();
    (path.to_string(), pairs)
}

/// Decode `%XX` escapes and `+`-as-space (query component rules).
/// Invalid escapes pass through literally — a monitoring endpoint
/// should answer 4xx at routing level, not lose the raw value here.
pub(crate) fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => out.push(b' '),
            b'%' if i + 2 < bytes.len() => {
                match u8::from_str_radix(
                    std::str::from_utf8(&bytes[i + 1..i + 3])
                        .unwrap_or(""),
                    16,
                ) {
                    Ok(b) => {
                        out.push(b);
                        i += 2;
                    }
                    Err(_) => out.push(b'%'),
                }
            }
            b => out.push(b),
        }
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Position of the `\r\n\r\n` header terminator.
fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_splits_path_and_decodes_query() {
        let (path, q) = parse_target(
            "/ingest?source=exp%2Frun.json&message=fix+the+bug&flag",
        );
        assert_eq!(path, "/ingest");
        assert_eq!(
            q,
            [
                ("source".to_string(), "exp/run.json".to_string()),
                ("message".to_string(), "fix the bug".to_string()),
                ("flag".to_string(), String::new()),
            ]
        );
        let (path, q) = parse_target("/report.json");
        assert_eq!(path, "/report.json");
        assert!(q.is_empty());
    }

    #[test]
    fn percent_decoding_is_lossless_on_damage() {
        assert_eq!(percent_decode("a%20b"), "a b");
        assert_eq!(percent_decode("a+b"), "a b");
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn content_types_cover_the_emitted_files() {
        assert_eq!(content_type_for("report.json"), "application/json");
        assert_eq!(content_type_for("badges/a__2x8.svg"), "image/svg+xml");
        assert_eq!(
            content_type_for("index.html"),
            "text/html; charset=utf-8"
        );
        assert_eq!(content_type_for("gate.xml"), "application/xml");
        assert_eq!(
            content_type_for("no-extension"),
            "application/octet-stream"
        );
    }

    #[test]
    fn header_end_detection() {
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n\r\nbody"), Some(14));
        assert_eq!(find_header_end(b"GET / HTTP/1.1\r\n"), None);
    }
}
