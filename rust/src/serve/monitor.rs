//! The resident analysis core behind `talp-pages serve` (and the
//! `serve_warm_reanalyze` bench): a warm [`RunStore`] plus a
//! persistent scan and the previous [`Analysis`], re-analyzing
//! **incrementally** — an ingested run marks only its experiment
//! dirty, [`Monitor::refresh`] rebuilds just that experiment's scan
//! view from the live records and routes it through
//! [`analyze_incremental`], and every clean experiment's analysis is
//! carried to the next pass by reference.
//!
//! The monitor holds the store's single-writer lock
//! ([`crate::store::StoreLock`]) for its whole lifetime, so a resident
//! server and a concurrent CLI `ingest` cannot interleave shard
//! appends.  Read-only consumers (batch `report --store` beside a
//! running server) do not take the lock and keep working.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::pages::scanner::MetricScan;
use crate::pop::RunMetrics;
use crate::session::{
    analyze_incremental, Analysis, AnalyzeOptions,
};
use crate::store::{self, IngestReport, RunStore, StoreLock};

/// Counters of one [`Monitor::refresh`] pass — the incrementality
/// witness `/statsz` exposes and the CI serve-smoke job asserts.
#[derive(Debug, Clone, Copy)]
pub struct RefreshPass {
    /// (experiment, config) histories recomputed this pass.
    pub reanalyzed_histories: usize,
    /// Experiments reused from the previous analysis by reference.
    pub reused_experiments: usize,
}

/// Point-in-time monitor statistics (for `/statsz`).
#[derive(Debug, Clone, Copy)]
pub struct MonitorStats {
    pub stored_runs: usize,
    pub experiments: usize,
    /// (experiment, config) histories in the current analysis.
    pub total_histories: usize,
    /// Completed analysis passes (the initial full pass counts).
    pub analysis_passes: u64,
    pub reanalyzed_histories_last: usize,
    pub reanalyzed_histories_total: u64,
}

/// Warm store + scan + analysis, re-analyzed incrementally.
pub struct Monitor {
    root: PathBuf,
    input: String,
    store: RunStore,
    scan: MetricScan,
    opts: AnalyzeOptions,
    jobs: usize,
    analysis: Analysis,
    dirty: BTreeSet<String>,
    /// Runs admitted per ingestion-adapter format (POST /ingest and
    /// the watch poll both feed this; `/statsz` exposes it).
    formats: BTreeMap<&'static str, u64>,
    passes: u64,
    reanalyzed_last: usize,
    reanalyzed_total: u64,
    // Held for the monitor's lifetime; Drop releases it.
    _lock: StoreLock,
}

impl Monitor {
    /// Acquire the writer lock, load (or create) the store at `root`
    /// and run the initial full analysis.
    pub fn open(
        root: &Path,
        opts: AnalyzeOptions,
        jobs: usize,
    ) -> Result<Monitor> {
        let lock = StoreLock::acquire(root)?;
        let store = if root.join(store::MANIFEST_FILE_NAME).exists() {
            RunStore::open_with_jobs(root, jobs)?
        } else {
            RunStore::create_or_open(root)?
        };
        // Input display string matches Session::from_store so every
        // byte the emitters produce matches a batch report over the
        // same store path.
        let input = root.display().to_string();
        let scan = store.to_scan();
        let pass = analyze_incremental(&input, &scan, jobs, &opts, None);
        Ok(Monitor {
            root: root.to_path_buf(),
            input,
            store,
            scan,
            opts,
            jobs,
            analysis: pass.analysis,
            dirty: BTreeSet::new(),
            formats: BTreeMap::new(),
            passes: 1,
            reanalyzed_last: pass.reanalyzed_histories,
            reanalyzed_total: pass.reanalyzed_histories as u64,
            _lock: lock,
        })
    }

    /// The store root this monitor serves.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The current analysis (always present; refreshed by
    /// [`Monitor::refresh`]).
    pub fn analysis(&self) -> &Analysis {
        &self.analysis
    }

    /// Read access to the warm store (identity checks, stats).
    pub fn store(&self) -> &RunStore {
        &self.store
    }

    /// Append one already-reduced run; marks its experiment dirty.
    /// Returns whether a record was actually appended (a duplicate
    /// `(source, hash)` identity is dropped, like every store path).
    pub fn ingest_run(
        &mut self,
        experiment: &str,
        hash: &str,
        run: RunMetrics,
    ) -> Result<bool> {
        let appended = self.store.append(experiment, hash, run)?;
        if appended {
            self.dirty.insert(experiment.to_string());
        }
        Ok(appended)
    }

    /// Credit `runs` admitted runs to an ingestion-adapter format
    /// (the `POST /ingest` handler calls this after [`Monitor::ingest_run`],
    /// which has no knowledge of the wire format it came from).
    pub fn note_format(&mut self, name: &'static str, runs: u64) {
        if runs > 0 {
            *self.formats.entry(name).or_insert(0) += runs;
        }
    }

    /// Runs admitted per adapter format since the monitor opened.
    pub fn formats(&self) -> &BTreeMap<&'static str, u64> {
        &self.formats
    }

    /// Ingest a drop directory (the `--watch` poll): content-addressed
    /// through [`store::Admission`] with per-file adapter auto-detect,
    /// so a warm poll over an unchanged folder parses nothing and a
    /// non-TALP drop (ROOT-bench, BeeSwarm) is admitted instead of
    /// rejected.  Fresh records mark their experiments dirty.
    pub fn ingest_dir(&mut self, dir: &Path) -> Result<IngestReport> {
        let report = store::Admission::new()
            .jobs(self.jobs)
            .ingest_dir(&mut self.store, dir)?;
        for (name, runs) in &report.formats {
            self.note_format(name, *runs as u64);
        }
        self.dirty.extend(report.stored_experiments.iter().cloned());
        Ok(report)
    }

    /// Re-analyze if anything is dirty: refresh index sidecars for the
    /// appended shards, rebuild only the dirty experiments' scan views
    /// from the live records, and fold them through
    /// [`analyze_incremental`] (clean experiments ride along by
    /// reference).  `None` when nothing was dirty — the caller keeps
    /// its current snapshot.
    pub fn refresh(&mut self) -> Result<Option<RefreshPass>> {
        if self.dirty.is_empty() {
            return Ok(None);
        }
        // Consulted before the dirty set is taken: a failed refresh
        // leaves its experiments dirty, so the next pass retries them
        // (the serve layer keeps the last good snapshot meanwhile).
        crate::util::failpoint::check("serve", "refresh")?;
        self.store.refresh_indexes()?;
        let dirty = std::mem::take(&mut self.dirty);
        for id in &dirty {
            let exp = self.store.experiment_scan(id);
            let at = self
                .scan
                .experiments
                .binary_search_by(|e| e.id.as_str().cmp(id));
            match at {
                Ok(i) if exp.runs.is_empty() => {
                    self.scan.experiments.remove(i);
                }
                Ok(i) => self.scan.experiments[i] = exp,
                Err(i) if !exp.runs.is_empty() => {
                    self.scan.experiments.insert(i, exp);
                }
                Err(_) => {}
            }
        }
        // The scan-wide counters describe "everything served stored":
        // keep them consistent with a cold load of the same records.
        self.scan.cache_hits =
            self.scan.experiments.iter().map(|e| e.runs.len()).sum();
        let pass = analyze_incremental(
            &self.input,
            &self.scan,
            self.jobs,
            &self.opts,
            Some((&self.analysis, &dirty)),
        );
        self.analysis = pass.analysis;
        self.passes += 1;
        self.reanalyzed_last = pass.reanalyzed_histories;
        self.reanalyzed_total += pass.reanalyzed_histories as u64;
        Ok(Some(RefreshPass {
            reanalyzed_histories: pass.reanalyzed_histories,
            reused_experiments: pass.reused_experiments,
        }))
    }

    /// Current counters for `/statsz`.
    pub fn stats(&self) -> MonitorStats {
        MonitorStats {
            stored_runs: self.store.len(),
            experiments: self.analysis.experiments.len(),
            total_histories: self
                .analysis
                .experiments
                .iter()
                .map(|e| e.histories.len())
                .sum(),
            analysis_passes: self.passes,
            reanalyzed_histories_last: self.reanalyzed_last,
            reanalyzed_histories_total: self.reanalyzed_total,
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::pages::cache::content_hash;
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::store::LOCK_FILE_NAME;
    use crate::util::fs::TempDir;

    /// Shared fixture (also used by the serve module tests): a store
    /// with `experiments` experiments of 3 runs each.
    pub(crate) fn seeded_store(td: &TempDir, experiments: usize) -> PathBuf {
        let root = td.path().join("store");
        let mut s = RunStore::create_or_open(&root).unwrap();
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        let mut app = Genex::salpha(1, CodeVersion::fixed());
        app.timesteps = 2;
        let (base, _) = run_with_talp(&app, &machine, &res, 3, 0);
        let mut batch = Vec::new();
        for e in 0..experiments {
            for i in 0..3 {
                let mut d = base.clone();
                d.timestamp = 1_700_000_000 + i as i64 * 60;
                let source = format!("exp{e}/2x8/run_{i}.json");
                batch.push((
                    format!("exp{e}"),
                    format!("{e:04x}{i:08x}"),
                    RunMetrics::from_run(&d, &source),
                ));
            }
        }
        s.append_all(batch).unwrap();
        s.refresh_indexes().unwrap();
        root
    }

    fn fresh_run(source: &str, ts: i64) -> (String, RunMetrics) {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        let mut app = Genex::salpha(1, CodeVersion::fixed());
        app.timesteps = 2;
        let (mut d, _) = run_with_talp(&app, &machine, &res, 99, 0);
        d.timestamp = ts;
        let bytes = d.to_json().to_string_pretty();
        (
            content_hash(bytes.as_bytes()),
            RunMetrics::from_run(&d, source),
        )
    }

    #[test]
    fn one_run_ingest_reanalyzes_one_history() {
        let td = TempDir::new("monitor-incr").unwrap();
        let root = seeded_store(&td, 3);
        let mut m =
            Monitor::open(&root, AnalyzeOptions::default(), 0).unwrap();
        let s0 = m.stats();
        assert_eq!(s0.stored_runs, 9);
        assert_eq!(s0.experiments, 3);
        assert_eq!(s0.total_histories, 3);
        assert_eq!(s0.reanalyzed_histories_last, 3, "cold pass is full");

        // Nothing dirty: refresh is a no-op.
        assert!(m.refresh().unwrap().is_none());

        let (hash, run) = fresh_run("exp1/2x8/fresh.json", 1_700_500_000);
        assert!(m.ingest_run("exp1", &hash, run).unwrap());
        let pass = m.refresh().unwrap().expect("dirty experiment");
        assert_eq!(pass.reanalyzed_histories, 1, "only exp1 recomputes");
        assert_eq!(pass.reused_experiments, 2);
        let s1 = m.stats();
        assert_eq!(s1.stored_runs, 10);
        assert_eq!(s1.reanalyzed_histories_last, 1);
        assert_eq!(s1.analysis_passes, 2);
        let exp1 = m
            .analysis()
            .experiments
            .iter()
            .find(|e| e.id == "exp1")
            .unwrap();
        assert_eq!(exp1.total_runs, 4);

        // Duplicate identity: dropped, nothing goes dirty.
        let (hash, run) = fresh_run("exp1/2x8/fresh.json", 1_700_500_000);
        assert!(!m.ingest_run("exp1", &hash, run).unwrap());
        assert!(m.refresh().unwrap().is_none());
    }

    #[test]
    fn monitor_analysis_matches_batch_session() {
        let td = TempDir::new("monitor-batch").unwrap();
        let root = seeded_store(&td, 2);
        let mut m =
            Monitor::open(&root, AnalyzeOptions::default(), 0).unwrap();
        let (hash, run) = fresh_run("exp0/2x8/late.json", 1_700_600_000);
        m.ingest_run("exp0", &hash, run).unwrap();
        m.refresh().unwrap();

        // A batch store session over the same (mutated) corpus must
        // see the same analysis — serve reads and batch reads may not
        // disagree.  (Byte-level emitter identity is pinned by the
        // serve_http integration tests.)
        let batch = crate::session::Session::from_store(&root)
            .scan()
            .unwrap()
            .analyze(&AnalyzeOptions::default());
        assert_eq!(batch.experiments.len(), m.analysis().experiments.len());
        for (a, b) in
            batch.experiments.iter().zip(&m.analysis().experiments)
        {
            assert_eq!(a.id, b.id);
            assert_eq!(a.total_runs, b.total_runs);
            assert_eq!(a.histories.len(), b.histories.len());
            for ((ca, ra), (cb, rb)) in a.histories.iter().zip(&b.histories)
            {
                assert_eq!(ca, cb);
                let (sa, sb): (Vec<_>, Vec<_>) = (
                    ra.iter().map(|r| r.source.as_str()).collect(),
                    rb.iter().map(|r| r.source.as_str()).collect(),
                );
                assert_eq!(sa, sb);
            }
        }
    }

    #[test]
    fn monitor_holds_the_writer_lock() {
        let td = TempDir::new("monitor-lock").unwrap();
        let root = seeded_store(&td, 1);
        let m =
            Monitor::open(&root, AnalyzeOptions::default(), 0).unwrap();
        assert!(root.join(LOCK_FILE_NAME).exists());
        // A second writer is refused while the monitor lives...
        assert!(StoreLock::acquire(&root).is_err());
        drop(m);
        // ...and admitted the moment it is gone.
        assert!(!root.join(LOCK_FILE_NAME).exists());
        StoreLock::acquire(&root).unwrap();
    }
}
