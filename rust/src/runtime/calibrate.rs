//! Counter-model calibration against the real AOT executables.
//!
//! Runs the compiled Pallas CG on small subdomains, validates numerics
//! against the rust-native reference, and measures seconds-per-flop of
//! the real kernel.  The validation result anchors the simulator's
//! counter model to the actual compiled code (DESIGN.md §7); the
//! measured CPU timings are *not* used as a TPU/SPR proxy — only the
//! flop accounting is.

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::client::XlaRuntime;
use super::native;
use super::registry::Registry;

#[derive(Debug, Clone)]
pub struct Calibration {
    pub platform: String,
    /// Max |x - x_ref| over validated CG solves.
    pub max_abs_err: f64,
    /// Residual drop of the compiled solver (rr_last / rr_first).
    pub residual_drop: f64,
    /// Wall seconds per analytic flop of the compiled kernel on this
    /// host (diagnostic only).
    pub sec_per_flop: f64,
    pub artifacts_validated: usize,
}

impl Calibration {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("platform", Json::Str(self.platform.clone())),
            ("max_abs_err", Json::Num(self.max_abs_err)),
            ("residual_drop", Json::Num(self.residual_drop)),
            ("sec_per_flop", Json::Num(self.sec_per_flop)),
            (
                "artifacts_validated",
                Json::Num(self.artifacts_validated as f64),
            ),
        ])
    }
}

/// Validate every cg_solve artifact and time the smallest one.
pub fn run(registry: &Registry) -> Result<Calibration> {
    let mut rt = XlaRuntime::cpu()?;
    let mut max_err = 0.0f64;
    let mut residual_drop = 1.0f64;
    let mut validated = 0usize;
    let mut sec_per_flop = 0.0f64;

    let cgs: Vec<_> = registry
        .artifacts
        .iter()
        .filter(|a| a.entry == "cg_solve")
        .collect();
    anyhow::ensure!(!cgs.is_empty(), "no cg_solve artifacts in registry");

    for meta in &cgs {
        rt.load(meta)?;
        let (h, w) = (meta.h as usize, meta.w as usize);
        let b = native::Grid::initial_condition(h, w);
        let c = native::build_coefficients(h, w, 0.5, 1.0);
        let inputs: Vec<(&[f32], Vec<i64>)> = vec![
            (&b.data, vec![h as i64, w as i64]),
            (&c.kx.data, vec![h as i64, (w + 1) as i64]),
            (&c.ky.data, vec![h as i64, w as i64]),
            (&c.d.data, vec![h as i64, w as i64]),
        ];
        let args: Vec<(&[f32], &[i64])> = inputs
            .iter()
            .map(|(d, s)| (*d, s.as_slice()))
            .collect();
        let t0 = std::time::Instant::now();
        let out = rt
            .execute(&meta.name, &args)
            .with_context(|| format!("executing {}", meta.name))?;
        let dt = t0.elapsed().as_secs_f64();
        if sec_per_flop == 0.0 {
            sec_per_flop = dt / meta.flops as f64;
        }
        let (x_ref, _) = native::cg_solve(&b, &c, meta.iters as usize);
        for k in 0..out[0].data.len() {
            max_err = max_err
                .max((out[0].data[k] - x_ref.data[k]).abs() as f64);
        }
        let hist = &out[1].data;
        residual_drop = residual_drop
            .min(hist[hist.len() - 1] as f64 / hist[0].max(1e-30) as f64);
        validated += 1;
    }
    Ok(Calibration {
        platform: rt.platform(),
        max_abs_err: max_err,
        residual_drop,
        sec_per_flop,
        artifacts_validated: validated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_validates_all_cg_artifacts() {
        let Some(reg) = Registry::open_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let cal = run(&reg).expect("calibration");
        assert!(cal.artifacts_validated >= 3);
        assert!(cal.max_abs_err < 5e-3, "err {}", cal.max_abs_err);
        assert!(cal.residual_drop < 1e-6, "drop {}", cal.residual_drop);
        assert!(cal.sec_per_flop > 0.0);
        let j = cal.to_json().to_string_compact();
        assert!(j.contains("residual_drop"));
    }
}
