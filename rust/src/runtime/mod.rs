//! PJRT runtime: loads the AOT artifacts (`make artifacts`) and runs
//! them from rust — python never executes at report/serve time.
//!
//! * [`registry`]  — manifest.json discovery and (entry, shape) lookup.
//! * [`client`]    — HLO-text → compile → execute on the CPU PJRT client.
//! * [`native`]    — rust-native reference numerics (cross-validation).
//! * [`calibrate`] — validates artifacts vs the native reference and
//!   anchors the simulator's counter model.

pub mod calibrate;
pub mod client;
pub mod native;
pub mod registry;

pub use calibrate::Calibration;
pub use client::{HostTensor, XlaRuntime};
pub use registry::{ArtifactMeta, Registry};
