//! Rust-native f32 reference implementation of the stencil operator and
//! CG solve — the cross-language oracle that validates what the PJRT
//! runtime executes (python's ref.py validated the Pallas kernel; this
//! validates the full AOT→HLO→PJRT round trip from the rust side).
//!
//! Mirrors python/compile/kernels/ref.py exactly (same operator, same
//! coefficient construction, same fixed-iteration CG).

/// Dense row-major f32 grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Grid {
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Grid {
    pub fn zeros(h: usize, w: usize) -> Grid {
        Grid { h, w, data: vec![0.0; h * w] }
    }

    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.w + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.w + j] = v;
    }

    /// The deterministic smooth-bump initial field (must match
    /// model.initial_condition in python).
    pub fn initial_condition(h: usize, w: usize) -> Grid {
        let mut g = Grid::zeros(h, w);
        for i in 0..h {
            for j in 0..w {
                let x = i as f32 / h as f32;
                let y = j as f32 / w as f32;
                let v = (core::f32::consts::PI * x).sin()
                    * (core::f32::consts::PI * y).sin()
                    + 0.1 * (9.0 * x * y).sin();
                g.set(i, j, v);
            }
        }
        g
    }
}

/// TeaLeaf-style coefficients (ref.build_coefficients).
pub struct Coefficients {
    /// (h, w+1): x faces.
    pub kx: Grid,
    /// (h, w): north faces (ky[0] = physical boundary = 0).
    pub ky: Grid,
    /// (h, w): diagonal.
    pub d: Grid,
}

pub fn build_coefficients(h: usize, w: usize, dt: f32, conductivity: f32) -> Coefficients {
    let mut kx = Grid::zeros(h, w + 1);
    let mut ky = Grid::zeros(h, w);
    let k = dt * conductivity;
    for i in 0..h {
        for j in 0..=w {
            let v = if j == 0 || j == w { 0.0 } else { k };
            kx.set(i, j, v);
        }
        for j in 0..w {
            ky.set(i, j, if i == 0 { 0.0 } else { k });
        }
    }
    let mut d = Grid::zeros(h, w);
    for i in 0..h {
        for j in 0..w {
            let ky_south = if i + 1 < h { ky.at(i + 1, j) } else { 0.0 };
            d.set(
                i,
                j,
                1.0 + kx.at(i, j) + kx.at(i, j + 1) + ky.at(i, j) + ky_south,
            );
        }
    }
    Coefficients { kx, ky, d }
}

/// Apply the operator: out = A p  (Dirichlet-zero ghosts).
pub fn apply_operator(p: &Grid, c: &Coefficients) -> Grid {
    let (h, w) = (p.h, p.w);
    let mut out = Grid::zeros(h, w);
    for i in 0..h {
        for j in 0..w {
            let north = if i > 0 { p.at(i - 1, j) } else { 0.0 };
            let south = if i + 1 < h { p.at(i + 1, j) } else { 0.0 };
            let west = if j > 0 { p.at(i, j - 1) } else { 0.0 };
            let east = if j + 1 < w { p.at(i, j + 1) } else { 0.0 };
            let ky_south = if i + 1 < h { c.ky.at(i + 1, j) } else { 0.0 };
            out.set(
                i,
                j,
                c.d.at(i, j) * p.at(i, j)
                    - c.ky.at(i, j) * north
                    - ky_south * south
                    - c.kx.at(i, j) * west
                    - c.kx.at(i, j + 1) * east,
            );
        }
    }
    out
}

fn dot(a: &Grid, b: &Grid) -> f64 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| *x as f64 * *y as f64)
        .sum()
}

/// Fixed-iteration CG; returns (x, rr_history).
pub fn cg_solve(b: &Grid, c: &Coefficients, n_iters: usize) -> (Grid, Vec<f64>) {
    let mut x = Grid::zeros(b.h, b.w);
    let mut r = b.clone();
    let mut p = b.clone();
    let mut rr = dot(&r, &r);
    let mut hist = Vec::with_capacity(n_iters);
    for _ in 0..n_iters {
        let ap = apply_operator(&p, c);
        let alpha = rr / dot(&p, &ap);
        for k in 0..x.data.len() {
            x.data[k] += (alpha * p.data[k] as f64) as f32;
            r.data[k] -= (alpha * ap.data[k] as f64) as f32;
        }
        let rr_new = dot(&r, &r);
        let beta = rr_new / rr;
        for k in 0..p.data.len() {
            p.data[k] = r.data[k] + (beta * p.data[k] as f64) as f32;
        }
        rr = rr_new;
        hist.push(rr_new);
    }
    (x, hist)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn operator_is_spd_on_builtin_coefficients() {
        let (h, w) = (16, 16);
        let c = build_coefficients(h, w, 0.5, 1.0);
        let p = Grid::initial_condition(h, w);
        let ap = apply_operator(&p, &c);
        // <p, Ap> > 0
        assert!(dot(&p, &ap) > 0.0);
        // symmetry: <Ap, q> == <p, Aq>
        let mut q = Grid::zeros(h, w);
        for (k, v) in q.data.iter_mut().enumerate() {
            *v = ((k * 2654435761) % 1000) as f32 / 1000.0 - 0.5;
        }
        let aq = apply_operator(&q, &c);
        let lhs = dot(&ap, &q);
        let rhs = dot(&p, &aq);
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
    }

    #[test]
    fn cg_converges() {
        let (h, w) = (32, 32);
        let c = build_coefficients(h, w, 0.5, 1.0);
        let b = Grid::initial_condition(h, w);
        let (x, hist) = cg_solve(&b, &c, 40);
        assert!(hist[39] < 1e-8 * hist[0], "{:?}", &hist[..5]);
        // A x ~= b
        let ax = apply_operator(&x, &c);
        let mut err = 0.0f64;
        let mut nb = 0.0f64;
        for k in 0..ax.data.len() {
            err += (ax.data[k] - b.data[k]).powi(2) as f64;
            nb += (b.data[k] as f64).powi(2);
        }
        assert!((err / nb).sqrt() < 1e-3);
    }

    #[test]
    fn initial_condition_matches_python_formula() {
        let g = Grid::initial_condition(8, 8);
        let (i, j) = (3usize, 5usize);
        let x = i as f32 / 8.0;
        let y = j as f32 / 8.0;
        let expected = (core::f32::consts::PI * x).sin()
            * (core::f32::consts::PI * y).sin()
            + 0.1 * (9.0 * x * y).sin();
        assert!((g.at(i, j) - expected).abs() < 1e-6);
    }
}
