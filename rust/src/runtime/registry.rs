//! Artifact registry: discovers the AOT outputs (`artifacts/*.hlo.txt`
//! plus `manifest.json` from `python -m compile.aot`) and resolves the
//! right executable for an (entry, shape) request.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub entry: String,
    pub h: u32,
    pub w: u32,
    pub iters: u32,
    pub flops: u64,
    pub file: PathBuf,
}

#[derive(Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

/// Default artifact locations: $TALP_PAGES_ARTIFACTS, ./artifacts, or
/// the crate root's artifacts dir (tests run from the workspace).
pub fn default_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("TALP_PAGES_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    for cand in ["artifacts", env!("CARGO_MANIFEST_DIR")] {
        let p = if cand == "artifacts" {
            PathBuf::from("artifacts")
        } else {
            Path::new(cand).join("artifacts")
        };
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

impl Registry {
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let format = j.str_or("format", "");
        if format != "hlo-text-v1" {
            bail!("unsupported manifest format '{format}'");
        }
        let mut artifacts = Vec::new();
        for (name, meta) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest: artifacts")?
        {
            artifacts.push(ArtifactMeta {
                name: name.clone(),
                entry: meta.str_or("entry", "").to_string(),
                h: meta.num_or("h", 0.0) as u32,
                w: meta.num_or("w", 0.0) as u32,
                iters: meta.num_or("iters", 0.0) as u32,
                flops: meta.num_or("flops", 0.0) as u64,
                file: dir.join(meta.str_or("file", "")),
            });
        }
        artifacts.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Registry { dir: dir.to_path_buf(), artifacts })
    }

    /// Open the default location if it exists.
    pub fn open_default() -> Option<Registry> {
        default_dir().and_then(|d| Registry::open(&d).ok())
    }

    pub fn find(&self, entry: &str, h: u32, w: u32) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.entry == entry && a.h == h && a.w == w)
    }

    /// Largest artifact of `entry` with h, w <= the given bounds (the
    /// simulator maps subdomains to the nearest compiled shape).
    pub fn best_fit(&self, entry: &str, h: u32, w: u32) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == entry && a.h <= h && a.w <= w)
            .max_by_key(|a| (a.h as u64) * (a.w as u64))
    }

    pub fn entries(&self) -> Vec<&str> {
        let mut v: Vec<&str> =
            self.artifacts.iter().map(|a| a.entry.as_str()).collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn fake_manifest(dir: &Path) {
        let text = r#"{
  "format": "hlo-text-v1",
  "artifacts": {
    "cg_solve_64x64_i30": {"entry": "cg_solve", "h": 64, "w": 64,
      "iters": 30, "flops": 1000, "file": "cg_solve_64x64_i30.hlo.txt"},
    "matvec_halo_128x128": {"entry": "matvec_halo", "h": 128, "w": 128,
      "iters": 1, "flops": 200, "file": "matvec_halo_128x128.hlo.txt"},
    "matvec_halo_64x64": {"entry": "matvec_halo", "h": 64, "w": 64,
      "iters": 1, "flops": 100, "file": "matvec_halo_64x64.hlo.txt"}
  }
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_and_finds() {
        let td = TempDir::new("registry").unwrap();
        fake_manifest(td.path());
        let r = Registry::open(td.path()).unwrap();
        assert_eq!(r.artifacts.len(), 3);
        assert!(r.find("cg_solve", 64, 64).is_some());
        assert!(r.find("cg_solve", 65, 64).is_none());
        assert_eq!(r.entries(), ["cg_solve", "matvec_halo"]);
    }

    #[test]
    fn best_fit_picks_largest_below() {
        let td = TempDir::new("registry2").unwrap();
        fake_manifest(td.path());
        let r = Registry::open(td.path()).unwrap();
        let m = r.best_fit("matvec_halo", 100, 100).unwrap();
        assert_eq!((m.h, m.w), (64, 64));
        let m = r.best_fit("matvec_halo", 1000, 1000).unwrap();
        assert_eq!((m.h, m.w), (128, 128));
        assert!(r.best_fit("matvec_halo", 10, 10).is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let td = TempDir::new("registry3").unwrap();
        std::fs::write(
            td.path().join("manifest.json"),
            r#"{"format": "v999", "artifacts": {}}"#,
        )
        .unwrap();
        assert!(Registry::open(td.path()).is_err());
    }

    #[test]
    fn real_manifest_parses_when_built() {
        // Exercised fully only after `make artifacts`.
        if let Some(r) = Registry::open_default() {
            assert!(r.find("cg_solve", 64, 64).is_some());
            assert!(r.find("matvec_halo", 128, 128).is_some());
            assert!(r.find("genex_step", 128, 128).is_some());
            for a in &r.artifacts {
                assert!(a.file.exists(), "{} missing", a.file.display());
                assert!(a.flops > 0);
            }
        } else {
            eprintln!("skipping: no artifacts built (run `make artifacts`)");
        }
    }
}
