//! PJRT client wrapper: load HLO text produced by `compile.aot`, compile
//! on the CPU PJRT client, execute with f32 grids.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//! Python never runs on this path — the binary is self-contained once
//! `make artifacts` has produced the files.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::Path;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::registry::ArtifactMeta;

/// Owns the PJRT client and a cache of compiled executables.
#[cfg(feature = "pjrt")]
pub struct XlaRuntime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// A f32 tensor result.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

/// Offline stub: the `xla` bindings crate is absent from the build
/// image, so without the `pjrt` cargo feature [`XlaRuntime::cpu`]
/// reports that PJRT support is not compiled in.  Everything gated on
/// `Registry::open_default()` (no artifacts without `make artifacts`)
/// skips before reaching this.
#[cfg(not(feature = "pjrt"))]
pub struct XlaRuntime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl XlaRuntime {
    pub fn cpu() -> Result<XlaRuntime> {
        anyhow::bail!(
            "PJRT support not compiled in (rebuild with --features pjrt \
             and the xla bindings crate available)"
        )
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load(&mut self, _meta: &ArtifactMeta) -> Result<()> {
        anyhow::bail!("PJRT support not compiled in")
    }

    pub fn execute(
        &self,
        _name: &str,
        _inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<HostTensor>> {
        anyhow::bail!("PJRT support not compiled in")
    }

    pub fn loaded(&self) -> Vec<&str> {
        Vec::new()
    }
}

#[cfg(feature = "pjrt")]
impl XlaRuntime {
    pub fn cpu() -> Result<XlaRuntime> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact.
    pub fn load(&mut self, meta: &ArtifactMeta) -> Result<()> {
        if self.cache.contains_key(&meta.name) {
            return Ok(());
        }
        let exe = self.compile_file(&meta.file)?;
        self.cache.insert(meta.name.clone(), exe);
        Ok(())
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Execute a cached artifact with f32 inputs of the given shapes.
    /// Returns the flattened tuple outputs.
    pub fn execute(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<HostTensor>> {
        let exe = self
            .cache
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                if dims.len() == 1 && dims[0] as usize == data.len() {
                    Ok(lit)
                } else {
                    lit.reshape(dims).map_err(|e| anyhow::anyhow!("{e:?}"))
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                Ok(HostTensor { dims, data })
            })
            .collect()
    }

    pub fn loaded(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.cache.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native;
    use crate::runtime::registry::Registry;

    /// Full AOT round-trip: python-lowered Pallas CG vs the rust-native
    /// reference.  Skipped (with a notice) when artifacts are missing.
    #[test]
    fn cg_solve_artifact_matches_native_reference() {
        let Some(reg) = Registry::open_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let meta = reg.find("cg_solve", 64, 64).expect("cg artifact");
        let mut rt = XlaRuntime::cpu().expect("pjrt cpu");
        rt.load(meta).expect("compile");

        let (h, w) = (64usize, 64usize);
        let b = native::Grid::initial_condition(h, w);
        let c = native::build_coefficients(h, w, 0.5, 1.0);
        // ky grid for python layout: (h, w) north faces; kx (h, w+1).
        let out = rt
            .execute(
                &meta.name,
                &[
                    (&b.data, &[h as i64, w as i64]),
                    (&c.kx.data, &[h as i64, (w + 1) as i64]),
                    (&c.ky.data, &[h as i64, w as i64]),
                    (&c.d.data, &[h as i64, w as i64]),
                ],
            )
            .expect("execute");
        assert_eq!(out.len(), 2, "x and rr_hist");
        let x = &out[0];
        let hist = &out[1];
        assert_eq!(x.dims, vec![h, w]);
        assert_eq!(hist.dims, vec![meta.iters as usize]);

        let (x_ref, hist_ref) = native::cg_solve(&b, &c, meta.iters as usize);
        // Converged solutions agree.
        let mut max_err = 0.0f32;
        for k in 0..x.data.len() {
            max_err = max_err.max((x.data[k] - x_ref.data[k]).abs());
        }
        assert!(max_err < 2e-3, "max |x - x_ref| = {max_err}");
        // Residual curve drops by many orders and tracks the native one.
        assert!(hist.data[hist.data.len() - 1] < 1e-6 * hist.data[0]);
        let mid = hist.data.len() / 2;
        let rel = (hist.data[mid] as f64 - hist_ref[mid]).abs()
            / hist_ref[mid].max(1e-30);
        assert!(rel < 0.15, "mid-curve rel err {rel}");
    }

    /// Distributed matvec: two ranks exchanging halo rows through the
    /// coordinator reproduce the single-domain operator.
    #[test]
    fn matvec_halo_artifact_supports_distributed_exchange() {
        let Some(reg) = Registry::open_default() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let meta = reg.find("matvec_halo", 64, 64).expect("matvec artifact");
        let mut rt = XlaRuntime::cpu().expect("pjrt cpu");
        rt.load(meta).expect("compile");

        let (h, w) = (128usize, 64usize); // 2 stacked 64x64 subdomains
        let p = native::Grid::initial_condition(h, w);
        let c = native::build_coefficients(h, w, 0.5, 1.0);
        let full = native::apply_operator(&p, &c);

        let half = h / 2;
        let run_rank = |top: bool| -> Vec<f32> {
            let rows = if top { 0..half } else { half..h };
            let slice =
                |g: &native::Grid, w_: usize| -> Vec<f32> {
                    rows.clone()
                        .flat_map(|i| {
                            (0..w_).map(move |j| g.at(i, j))
                        })
                        .collect()
                };
            let p_loc = slice(&p, w);
            let kx_loc = slice(&c.kx, w + 1);
            let ky_loc = slice(&c.ky, w);
            let d_loc = slice(&c.d, w);
            // Halo exchange (what the coordinator does between ranks):
            let zero = vec![0.0f32; w];
            let north: Vec<f32> = if top {
                zero.clone()
            } else {
                (0..w).map(|j| p.at(half - 1, j)).collect()
            };
            let south: Vec<f32> = if top {
                (0..w).map(|j| p.at(half, j)).collect()
            } else {
                zero.clone()
            };
            // ky face below the last local row (owned by the neighbour).
            let ky_bottom: Vec<f32> = if top {
                (0..w).map(|j| c.ky.at(half, j)).collect()
            } else {
                zero
            };
            let out = rt
                .execute(
                    &meta.name,
                    &[
                        (&p_loc, &[half as i64, w as i64]),
                        (&north, &[w as i64]),
                        (&south, &[w as i64]),
                        (&kx_loc, &[half as i64, (w + 1) as i64]),
                        (&ky_loc, &[half as i64, w as i64]),
                        (&ky_bottom, &[w as i64]),
                        (&d_loc, &[half as i64, w as i64]),
                    ],
                )
                .expect("execute");
            out[0].data.clone()
        };
        let top = run_rank(true);
        let bot = run_rank(false);
        let mut max_err = 0.0f32;
        for i in 0..half {
            for j in 0..w {
                max_err = max_err
                    .max((top[i * w + j] - full.at(i, j)).abs());
                max_err = max_err
                    .max((bot[i * w + j] - full.at(half + i, j)).abs());
            }
        }
        assert!(max_err < 1e-4, "distributed != fused, err {max_err}");
    }
}
