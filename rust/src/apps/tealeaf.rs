//! The TeaLeaf CG mini-app [Martineau et al. 2017] on the simulator.
//!
//! This is the paper's evaluation workload (Tables 1, 2, 6, 7): a 2-D
//! heat-conduction solver whose hot loop is a 5-point-stencil conjugate
//! gradient.  The *numerics* of that loop exist for real in this repo as
//! the Pallas kernel (python/compile/kernels/stencil.py) AOT-compiled to
//! `artifacts/cg_solve_*.hlo.txt`; `runtime::calibrate` executes them and
//! anchors the flop/instruction constants used here.  The *parallel
//! envelope* (decomposition, halo exchange, reductions, I/O) is what this
//! module emits as a simulator program.
//!
//! Cost anatomy per CG iteration on an nx x ny grid with P ranks
//! (1-D row decomposition) and T threads:
//!   * matvec: 9 flops/cell (exactly the kernel's count),
//!   * 2 axpy + p-update: 6 flops/cell, 2 dot products: 4 flops/cell,
//!   * halo exchange: 2 ghost rows of nx * 8 bytes with both neighbours,
//!   * 2 Allreduce(8B) for alpha/beta.

use crate::sim::{
    CollKind, Imbalance, MachineSpec, OmpSchedule, Program, ResourceConfig,
    Step,
};

use super::workload::{decomposition_weights, Workload};

/// Flops per cell of one operator application (== the Pallas kernel's
/// `flops_per_application` and the manifest entry; test-enforced against
/// artifacts/manifest.json when present).
pub const MATVEC_FLOPS_PER_CELL: f64 = 9.0;
/// Vector-update flops per cell per CG iteration (2 dots + 2 axpy + p).
pub const VECTOR_FLOPS_PER_CELL: f64 = 10.0;
/// CG state: p, r, x, w, b (f64).  Coefficient arrays stream with unit
/// stride and near-perfect prefetch, so they do not contend for cache
/// residency — with this per-cell footprint the paper's strong-scaling
/// configuration (2x56 -> 4x56 on 4000^2) straddles the per-socket
/// cache share exactly as Tables 1/7 show.
pub const BYTES_PER_CELL: f64 = 5.0 * 8.0;

/// Configuration of one TeaLeaf execution.
#[derive(Debug, Clone)]
pub struct TeaLeaf {
    pub nx: u64,
    pub ny: u64,
    pub timesteps: u32,
    pub cg_iters: u32,
    /// Cells per dynamically-scheduled OpenMP chunk (one 4000-cell grid
    /// row at the paper's reference size).  Fixed chunk *work* is what
    /// makes per-chunk tool costs explode when strong scaling makes the
    /// chunks cache-resident and fast — the Table 1 "worst case" the
    /// paper calls out — while weak scaling keeps them benign.
    pub cells_per_chunk: u64,
    /// Extra instructions per flop from decomposition surface terms,
    /// charged per extra rank (models instruction-scaling < 1).
    pub halo_insn_overhead: f64,
    /// Relative per-thread jitter in the sweeps (OpenMP load balance).
    pub thread_jitter: f64,
    /// Write a results file at the end (serial on rank 0 — the paper's
    /// I/O-variance trap when left uninstrumented).
    pub write_output: bool,
}

impl TeaLeaf {
    /// The paper's benchmark case: 4000^2, 4 timesteps.
    pub fn paper_4000() -> TeaLeaf {
        TeaLeaf::with_grid(4000, 4000)
    }

    /// The weak-scaled case: 8000^2 on 4x the resources.
    pub fn paper_8000() -> TeaLeaf {
        TeaLeaf::with_grid(8000, 8000)
    }

    pub fn with_grid(nx: u64, ny: u64) -> TeaLeaf {
        TeaLeaf {
            nx,
            ny,
            timesteps: 4,
            cg_iters: 40,
            cells_per_chunk: 4000,
            halo_insn_overhead: 0.004,
            thread_jitter: 0.035,
            write_output: true,
        }
    }

    pub fn cells(&self) -> f64 {
        (self.nx * self.ny) as f64
    }

    /// Total useful flops of the whole run (all ranks).
    pub fn total_flops(&self) -> f64 {
        let per_iter = self.cells()
            * (MATVEC_FLOPS_PER_CELL + VECTOR_FLOPS_PER_CELL);
        per_iter * (self.cg_iters * self.timesteps) as f64
    }
}

impl Workload for TeaLeaf {
    fn name(&self) -> &str {
        "tealeaf"
    }

    fn regions(&self) -> Vec<String> {
        vec!["initialize".into(), "solve".into()]
    }

    fn build(&self, res: &ResourceConfig, _machine: &MachineSpec) -> Program {
        let p = res.n_ranks;
        let t = res.threads_per_rank;
        let cells_per_rank = self.cells() / p as f64;
        let ws_per_thread = cells_per_rank * BYTES_PER_CELL / t as f64;
        let rank_weights = decomposition_weights(p, 0.015, self.nx ^ self.ny);
        let insn_factor =
            1.0 + self.halo_insn_overhead * (p.saturating_sub(1)) as f64;
        // Halo rows: one row of nx cells, f64, to each neighbour.
        let halo_bytes = self.nx * 8;
        // Dynamic worksharing with fixed chunk work.
        let chunks = ((cells_per_rank as u64) / self.cells_per_chunk.max(1))
            .max(t as u64) as u32;
        let solve_schedule = OmpSchedule::Dynamic { chunks };

        let mut prog = Program::new();
        prog.region("initialize", |prog| {
            // Read the input deck (rank 0), broadcast setup.
            prog.push(Step::Io { bytes: 2 << 20, parallel: false });
            prog.push(Step::Collective {
                kind: CollKind::Bcast,
                bytes_per_rank: 64 << 10,
            });
            // Mesh + coefficient setup: one parallel sweep over the grid.
            prog.push(Step::Parallel {
                flops: cells_per_rank * 6.0,
                working_set_bytes: ws_per_thread,
                imbalance: Imbalance::Random { sigma: self.thread_jitter },
                schedule: OmpSchedule::Static,
                rank_weights: rank_weights.clone(),
                insn_factor,
            });
            prog.push(Step::Collective {
                kind: CollKind::Barrier,
                bytes_per_rank: 0,
            });
        });
        prog.region("solve", |prog| {
            for _ in 0..self.timesteps {
                for _ in 0..self.cg_iters {
                    // Halo exchange for the matvec.
                    prog.push(Step::Exchange {
                        bytes_per_neighbor: halo_bytes,
                    });
                    // Matvec + vector updates, one fused parallel sweep.
                    prog.push(Step::Parallel {
                        flops: cells_per_rank
                            * (MATVEC_FLOPS_PER_CELL + VECTOR_FLOPS_PER_CELL),
                        working_set_bytes: ws_per_thread,
                        imbalance: Imbalance::Random {
                            sigma: self.thread_jitter,
                        },
                        schedule: solve_schedule,
                        rank_weights: rank_weights.clone(),
                        insn_factor,
                    });
                    // alpha and beta reductions.
                    prog.push(Step::Collective {
                        kind: CollKind::Allreduce,
                        bytes_per_rank: 8,
                    });
                    prog.push(Step::Collective {
                        kind: CollKind::Allreduce,
                        bytes_per_rank: 8,
                    });
                }
                // Residual check + field swap once per timestep.
                prog.push(Step::Parallel {
                    flops: cells_per_rank * 2.0,
                    working_set_bytes: ws_per_thread,
                    imbalance: Imbalance::Random { sigma: self.thread_jitter },
                    schedule: OmpSchedule::Static,
                    rank_weights: rank_weights.clone(),
                    insn_factor,
                });
                prog.push(Step::Collective {
                    kind: CollKind::Allreduce,
                    bytes_per_rank: 8,
                });
            }
        });
        if self.write_output {
            prog.push(Step::Io { bytes: 8 << 20, parallel: false });
        }
        prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::workload::{run_clean, run_with_talp};
    use crate::pop;

    fn mn5() -> MachineSpec {
        MachineSpec::marenostrum5()
    }

    /// Scaled-down grid (DESIGN.md §2: we run the structure, not the
    /// authors' node-hours).  Output disabled so compute dominates; the
    /// I/O-skew behaviour has its own test below.
    fn small() -> TeaLeaf {
        let mut t = TeaLeaf::with_grid(800, 800);
        t.timesteps = 2;
        t.cg_iters = 10;
        t.write_output = false;
        t
    }

    #[test]
    fn program_is_valid_and_sized() {
        let app = small();
        let p = app.build(&ResourceConfig::new(2, 8), &mn5());
        assert!(p.validate().is_ok());
        // 2 regions + per-iteration steps.
        assert!(p.steps.len() > 2 * 10 * 3);
    }

    #[test]
    fn strong_scaling_reduces_elapsed() {
        let app = small();
        let e2 = run_clean(&app, &mn5(), &ResourceConfig::new(2, 8), 1).elapsed_s;
        let e4 = run_clean(&app, &mn5(), &ResourceConfig::new(4, 8), 1).elapsed_s;
        assert!(e4 < e2, "{e4} !< {e2}");
    }

    #[test]
    fn talp_run_produces_regions_and_sane_pe() {
        let app = small();
        let (data, _) =
            run_with_talp(&app, &mn5(), &ResourceConfig::new(2, 8), 7, 1_700_000_000);
        assert_eq!(data.region("initialize").is_some(), true);
        assert_eq!(data.region("solve").is_some(), true);
        let g = data.region("Global").unwrap();
        let m = pop::compute(g, data.threads);
        assert!(
            (0.3..=1.0).contains(&m.parallel_efficiency),
            "PE {}",
            m.parallel_efficiency
        );
        assert!(m.useful_ipc > 0.5 && m.useful_ipc < 4.5);
        assert!(m.frequency_ghz > 1.0 && m.frequency_ghz < 3.5);
    }

    #[test]
    fn weak_scaling_detected_on_grown_grid() {
        // 2x56 on 400^2  vs  8x56 on 800^2: 4x cells, 4x cpus.
        let mut a = TeaLeaf::with_grid(400, 400);
        a.timesteps = 1;
        a.cg_iters = 6;
        let mut b = TeaLeaf::with_grid(800, 800);
        b.timesteps = 1;
        b.cg_iters = 6;
        let (da, _) =
            run_with_talp(&a, &mn5(), &ResourceConfig::new(2, 14), 3, 0);
        let (db, _) =
            run_with_talp(&b, &mn5(), &ResourceConfig::new(8, 14), 3, 0);
        let t = pop::build("Global", &[&da, &db]).unwrap();
        assert_eq!(t.mode, pop::ScalingMode::Weak);
    }

    #[test]
    fn strong_scaling_detected_on_fixed_grid() {
        let app = small();
        let (da, _) =
            run_with_talp(&app, &mn5(), &ResourceConfig::new(2, 14), 3, 0);
        let (db, _) =
            run_with_talp(&app, &mn5(), &ResourceConfig::new(4, 14), 3, 0);
        let t = pop::build("Global", &[&da, &db]).unwrap();
        assert_eq!(t.mode, pop::ScalingMode::Strong);
    }

    #[test]
    fn total_flops_formula() {
        let app = TeaLeaf::paper_4000();
        let per_iter = 4000.0 * 4000.0 * 19.0;
        assert!(
            (app.total_flops() - per_iter * (40 * 4) as f64).abs() < 1.0
        );
    }
}
