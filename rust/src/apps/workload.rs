//! Workload abstraction + the one-call helper that runs an app under a
//! tool and returns the TALP JSON data.

use crate::sim::{
    self, MachineSpec, NoiseModel, Program, ResourceConfig, RunConfig,
    RunSummary,
};
use crate::talp::{RunData, TalpMonitor};
use crate::util::rng::Rng;

/// An application that can be compiled to a simulator [`Program`].
pub trait Workload {
    fn name(&self) -> &str;

    /// TALP-API regions the app annotates (beyond the implicit Global).
    fn regions(&self) -> Vec<String>;

    /// Emit the SPMD program for the given resources.
    fn build(&self, resources: &ResourceConfig, machine: &MachineSpec) -> Program;
}

/// Run `app` under TALP and return its JSON data plus the engine summary.
pub fn run_with_talp(
    app: &dyn Workload,
    machine: &MachineSpec,
    resources: &ResourceConfig,
    seed: u64,
    timestamp: i64,
) -> (RunData, RunSummary) {
    let program = app.build(resources, machine);
    let cfg = RunConfig::new(machine.clone(), resources.clone()).with_seed(seed);
    let mut mon = TalpMonitor::new(resources.n_ranks, resources.threads_per_rank);
    let summary = sim::run(&program, &cfg, &mut [&mut mon]);
    let report = mon.finalize();
    let data =
        RunData::from_report(&report, app.name(), machine, resources, timestamp);
    (data, summary)
}

/// `run_with_talp` with an explicit noise model (reliability ablations).
pub fn run_with_talp_noise(
    app: &dyn Workload,
    machine: &MachineSpec,
    resources: &ResourceConfig,
    seed: u64,
    timestamp: i64,
    noise: NoiseModel,
) -> (RunData, RunSummary) {
    let program = app.build(resources, machine);
    let cfg = RunConfig::new(machine.clone(), resources.clone())
        .with_seed(seed)
        .with_noise(noise);
    let mut mon = TalpMonitor::new(resources.n_ranks, resources.threads_per_rank);
    let summary = sim::run(&program, &cfg, &mut [&mut mon]);
    let report = mon.finalize();
    let data =
        RunData::from_report(&report, app.name(), machine, resources, timestamp);
    (data, summary)
}

/// Run `app` with no tool attached (clean baseline for overhead
/// measurements, Table 1).
pub fn run_clean(
    app: &dyn Workload,
    machine: &MachineSpec,
    resources: &ResourceConfig,
    seed: u64,
) -> RunSummary {
    let program = app.build(resources, machine);
    let cfg = RunConfig::new(machine.clone(), resources.clone()).with_seed(seed);
    sim::run(&program, &cfg, &mut [])
}

/// Run with explicit noise (repeatability studies).
pub fn run_clean_noisy(
    app: &dyn Workload,
    machine: &MachineSpec,
    resources: &ResourceConfig,
    seed: u64,
    noise: NoiseModel,
) -> RunSummary {
    let program = app.build(resources, machine);
    let cfg = RunConfig::new(machine.clone(), resources.clone())
        .with_seed(seed)
        .with_noise(noise);
    sim::run(&program, &cfg, &mut [])
}

/// Deterministic per-rank work weights with a small boundary effect:
/// edge ranks of a 1-D decomposition own one halo less (lighter), plus a
/// reproducible per-rank jitter.
pub fn decomposition_weights(n_ranks: u32, jitter_sigma: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x5eed);
    (0..n_ranks)
        .map(|r| {
            let edge = r == 0 || r + 1 == n_ranks;
            let base = if edge && n_ranks > 1 { 0.985 } else { 1.0 };
            base * (1.0 + jitter_sigma * (rng.f64() - 0.5))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_deterministic_and_near_one() {
        let a = decomposition_weights(8, 0.02, 42);
        let b = decomposition_weights(8, 0.02, 42);
        assert_eq!(a, b);
        for w in &a {
            assert!((0.9..1.1).contains(w));
        }
        // Edges lighter than interior on average.
        assert!(a[0] < 1.0);
    }

    #[test]
    fn single_rank_has_no_edge_discount() {
        let w = decomposition_weights(1, 0.0, 1);
        assert_eq!(w, vec![1.0]);
    }
}
