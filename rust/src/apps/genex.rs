//! A GENE-X-like plasma turbulence app (paper §Integration into GENE-X).
//!
//! GENE-X itself is closed; what Fig. 7 needs from it is the *causal
//! story*: an `initialize` region with an OpenMP serialization bug whose
//! cost grows with thread count, a `timestep` region that is healthy, and
//! a commit history in which the bug gets fixed — after which elapsed
//! time drops, IPC/instructions/frequency stay flat, and the OpenMP
//! serialization efficiency is the factor that explains the change.
//! `CodeVersion` carries the per-commit tuning knobs the CI engine
//! manipulates.
//!
//! The timestep numerics mirror `genex_step` in python/compile/model.py
//! (4 stencil sweeps + bounded nonlinear update per step), so the same
//! region structure is backed by a real AOT kernel.

use crate::sim::{
    CollKind, Imbalance, MachineSpec, OmpSchedule, Program, ResourceConfig,
    Step,
};

use super::workload::{decomposition_weights, Workload};

/// Flops per cell per sweep (matvec 9 + update/tanh ~16, matching
/// model.flops("genex_step")).
const SWEEP_FLOPS_PER_CELL: f64 = 25.0;
const SWEEPS_PER_TIMESTEP: u32 = 4;
const BYTES_PER_CELL: f64 = 6.0 * 8.0;

/// Per-commit code state (what the CI history mutates).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodeVersion {
    /// The scaling bug: initialization work that runs *serialized* on
    /// the master thread with cost growing with the thread count.
    pub serialization_bug: bool,
    /// Generic slowdown multiplier on useful work (for injecting plain
    /// performance regressions into histories).
    pub compute_slowdown: f64,
}

impl CodeVersion {
    pub fn buggy() -> CodeVersion {
        CodeVersion { serialization_bug: true, compute_slowdown: 1.0 }
    }

    pub fn fixed() -> CodeVersion {
        CodeVersion { serialization_bug: false, compute_slowdown: 1.0 }
    }
}

/// GENE-X-like application instance.
#[derive(Debug, Clone)]
pub struct Genex {
    /// Case name (paper: "salpha").
    pub case: String,
    /// Grid resolution preset 1..=3 (paper: resolution_2, resolution_3).
    pub resolution: u32,
    pub timesteps: u32,
    pub version: CodeVersion,
}

impl Genex {
    pub fn salpha(resolution: u32, version: CodeVersion) -> Genex {
        Genex {
            case: "salpha".into(),
            resolution,
            timesteps: 12,
            version,
        }
    }

    pub fn cells(&self) -> f64 {
        // resolution_1: 512^2, each level doubles linear size.
        let n = 512u64 << (self.resolution.saturating_sub(1));
        (n * n) as f64
    }

    pub fn resolution_label(&self) -> String {
        format!("resolution_{}", self.resolution)
    }
}

impl Workload for Genex {
    fn name(&self) -> &str {
        "genex"
    }

    fn regions(&self) -> Vec<String> {
        vec!["initialize".into(), "timestep".into()]
    }

    fn build(&self, res: &ResourceConfig, _machine: &MachineSpec) -> Program {
        let p = res.n_ranks;
        let t = res.threads_per_rank;
        let cells_per_rank = self.cells() / p as f64;
        let ws_per_thread = cells_per_rank * BYTES_PER_CELL / t as f64;
        let weights = decomposition_weights(p, 0.02, self.resolution as u64);
        let slow = self.version.compute_slowdown;

        let mut prog = Program::new();
        prog.region("initialize", |prog| {
            // Input deck + equilibrium read.
            prog.push(Step::Io { bytes: 1 << 20, parallel: false });
            prog.push(Step::Collective {
                kind: CollKind::Bcast,
                bytes_per_rank: 256 << 10,
            });
            // Healthy parallel part of the setup.
            prog.push(Step::Parallel {
                flops: cells_per_rank * 200.0 * slow,
                working_set_bytes: ws_per_thread,
                imbalance: Imbalance::Random { sigma: 0.03 },
                schedule: OmpSchedule::Static,
                rank_weights: weights.clone(),
                insn_factor: 1.0,
            });
            // THE BUG: metric/geometry tables built inside an `omp
            // single` — the *same work* (same instructions!) runs
            // serialized on the master instead of across the team, so
            // elapsed time balloons while counters stay flat — the
            // paper's Fig. 7 signature.  The fix parallelizes it.
            let geometry_flops = cells_per_rank * 60.0 * slow;
            if self.version.serialization_bug {
                prog.push(Step::Serial {
                    flops: geometry_flops,
                    // Tables are built slice by slice: per-slice working
                    // set, so IPC matches the parallel version.
                    working_set_bytes: ws_per_thread,
                    rank_weights: weights.clone(),
                });
            } else {
                prog.push(Step::Parallel {
                    flops: geometry_flops,
                    working_set_bytes: ws_per_thread,
                    imbalance: Imbalance::Random { sigma: 0.03 },
                    schedule: OmpSchedule::Static,
                    rank_weights: weights.clone(),
                    insn_factor: 1.0,
                });
            }
            prog.push(Step::Collective {
                kind: CollKind::Barrier,
                bytes_per_rank: 0,
            });
        });
        for _ in 0..self.timesteps {
            prog.region("timestep", |prog| {
                for _ in 0..SWEEPS_PER_TIMESTEP {
                    prog.push(Step::Exchange {
                        bytes_per_neighbor: (self.cells().sqrt() as u64) * 8,
                    });
                    prog.push(Step::Parallel {
                        flops: cells_per_rank * SWEEP_FLOPS_PER_CELL * slow,
                        working_set_bytes: ws_per_thread,
                        imbalance: Imbalance::Random { sigma: 0.04 },
                        schedule: OmpSchedule::Dynamic { chunks: 8 * t },
                        rank_weights: weights.clone(),
                        insn_factor: 1.0,
                    });
                }
                // Field solve reduction.
                prog.push(Step::Collective {
                    kind: CollKind::Allreduce,
                    bytes_per_rank: 256,
                });
            });
        }
        // Diagnostics dump.
        prog.push(Step::Io { bytes: 2 << 20, parallel: true });
        prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::workload::run_with_talp;
    use crate::pop;
    use crate::talp::RunData;

    fn mn5() -> MachineSpec {
        MachineSpec::marenostrum5()
    }

    fn run(version: CodeVersion, threads: u32) -> RunData {
        let mut app = Genex::salpha(2, version);
        app.timesteps = 4;
        let (d, _) =
            run_with_talp(&app, &mn5(), &ResourceConfig::new(2, threads), 11, 0);
        d
    }

    #[test]
    fn program_valid_and_has_regions() {
        let app = Genex::salpha(2, CodeVersion::buggy());
        let p = app.build(&ResourceConfig::new(4, 8), &mn5());
        assert!(p.validate().is_ok());
        let d = run(CodeVersion::buggy(), 8);
        assert!(d.region("initialize").is_some());
        assert!(d.region("timestep").is_some());
    }

    #[test]
    fn bug_fix_speeds_up_initialize_not_timestep() {
        let buggy = run(CodeVersion::buggy(), 14);
        let fixed = run(CodeVersion::fixed(), 14);
        let e = |d: &RunData, r: &str| d.region(r).unwrap().elapsed_s;
        assert!(
            e(&fixed, "initialize") < 0.6 * e(&buggy, "initialize"),
            "initialize {} !<< {}",
            e(&fixed, "initialize"),
            e(&buggy, "initialize")
        );
        let ts_b = e(&buggy, "timestep");
        let ts_f = e(&fixed, "timestep");
        assert!(
            (ts_f - ts_b).abs() < 0.05 * ts_b,
            "timestep should be unaffected: {ts_b} vs {ts_f}"
        );
    }

    #[test]
    fn fix_is_explained_by_omp_serialization_efficiency() {
        // The Fig. 7 causal chain, as a test.
        let buggy = run(CodeVersion::buggy(), 14);
        let fixed = run(CodeVersion::fixed(), 14);
        let m = |d: &RunData| {
            pop::compute(d.region("initialize").unwrap(), d.threads)
        };
        let mb = m(&buggy);
        let mf = m(&fixed);
        // Serialization efficiency jumps...
        assert!(
            mf.omp_serialization_efficiency
                > mb.omp_serialization_efficiency + 0.15,
            "serialization {} -> {}",
            mb.omp_serialization_efficiency,
            mf.omp_serialization_efficiency
        );
        // ...while computation counters stay flat (IPC within 15%).
        let rel =
            (mf.useful_ipc - mb.useful_ipc).abs() / mb.useful_ipc.max(1e-9);
        assert!(rel < 0.15, "IPC moved {rel}");
        let relf = (mf.frequency_ghz - mb.frequency_ghz).abs()
            / mb.frequency_ghz.max(1e-9);
        assert!(relf < 0.15, "frequency moved {relf}");
    }

    #[test]
    fn bug_cost_grows_with_threads() {
        let narrow = run(CodeVersion::buggy(), 4);
        let wide = run(CodeVersion::buggy(), 28);
        let pe = |d: &RunData| {
            pop::compute(d.region("initialize").unwrap(), d.threads)
                .omp_serialization_efficiency
        };
        assert!(
            pe(&wide) < pe(&narrow),
            "more threads should hurt more: {} vs {}",
            pe(&wide),
            pe(&narrow)
        );
    }

    #[test]
    fn compute_slowdown_injects_regression() {
        let base = run(CodeVersion::fixed(), 8);
        let slow = run(
            CodeVersion { serialization_bug: false, compute_slowdown: 1.5 },
            8,
        );
        assert!(
            slow.region("Global").unwrap().elapsed_s
                > 1.2 * base.region("Global").unwrap().elapsed_s
        );
    }
}
