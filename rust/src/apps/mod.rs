//! Workloads that run on the simulator substrate.
//!
//! * [`tealeaf`]   — the paper's TeaLeaf CG mini-app (numerics backed by
//!   the AOT Pallas kernel via `runtime`).
//! * [`genex`]     — the GENE-X-like case study with the injectable
//!   OpenMP serialization bug (Fig. 7).
//! * [`synthetic`] — knob-per-effect app for tests + the MPI-only
//!   Fig. 3 stencil.

pub mod genex;
pub mod synthetic;
pub mod tealeaf;
pub mod workload;

pub use genex::{CodeVersion, Genex};
pub use synthetic::{MpiStencil, Synthetic};
pub use tealeaf::TeaLeaf;
pub use workload::{run_clean, run_with_talp, run_with_talp_noise, Workload};
