//! Fully parameterized synthetic workload for tests and ablations, plus
//! the MPI-only stencil app used for the paper's Fig. 3 experiment.

use crate::sim::{
    CollKind, Imbalance, MachineSpec, OmpSchedule, Program, ResourceConfig,
    Step,
};

use super::workload::Workload;

/// Knob-per-effect synthetic app.
#[derive(Debug, Clone)]
pub struct Synthetic {
    pub name: String,
    pub phases: u32,
    pub flops_per_phase: f64,
    pub working_set_bytes: f64,
    pub imbalance: Imbalance,
    pub schedule: OmpSchedule,
    pub rank_weights: Vec<f64>,
    pub mpi_bytes: u64,
    pub serial_fraction: f64,
}

impl Default for Synthetic {
    fn default() -> Synthetic {
        Synthetic {
            name: "synthetic".into(),
            phases: 10,
            flops_per_phase: 1e9,
            working_set_bytes: 1e8,
            imbalance: Imbalance::None,
            schedule: OmpSchedule::Static,
            rank_weights: vec![1.0],
            mpi_bytes: 8,
            serial_fraction: 0.0,
        }
    }
}

impl Workload for Synthetic {
    fn name(&self) -> &str {
        &self.name
    }

    fn regions(&self) -> Vec<String> {
        vec!["work".into()]
    }

    fn build(&self, _res: &ResourceConfig, _machine: &MachineSpec) -> Program {
        let mut prog = Program::new();
        prog.region("work", |prog| {
            for _ in 0..self.phases {
                if self.serial_fraction > 0.0 {
                    prog.push(Step::Serial {
                        flops: self.flops_per_phase * self.serial_fraction,
                        working_set_bytes: self.working_set_bytes,
                        rank_weights: self.rank_weights.clone(),
                    });
                }
                prog.push(Step::Parallel {
                    flops: self.flops_per_phase,
                    working_set_bytes: self.working_set_bytes,
                    imbalance: self.imbalance.clone(),
                    schedule: self.schedule,
                    rank_weights: self.rank_weights.clone(),
                    insn_factor: 1.0,
                });
                prog.push(Step::Collective {
                    kind: CollKind::Allreduce,
                    bytes_per_rank: self.mpi_bytes,
                });
            }
        });
        prog
    }
}

/// MPI-only strong-scaling stencil app (Fig. 3: 112xMPI vs 224xMPI).
///
/// Pure-MPI codes exchange bigger halos (2-D decomposition, one domain
/// per core) and pay per-rank instruction overhead for halo packing —
/// that overhead is what drives Fig. 3's instruction scaling of 0.84.
#[derive(Debug, Clone)]
pub struct MpiStencil {
    pub nx: u64,
    pub ny: u64,
    pub iterations: u32,
    /// Fractional extra instructions per doubling of ranks beyond
    /// `base_ranks`.
    pub pack_overhead: f64,
    /// Rank count at which packing overhead is zero (the experiment's
    /// reference configuration).
    pub base_ranks: f64,
}

impl MpiStencil {
    pub fn fig3() -> MpiStencil {
        MpiStencil {
            nx: 4000,
            ny: 4000,
            iterations: 300,
            pack_overhead: 0.19,
            base_ranks: 112.0,
        }
    }
}

impl Workload for MpiStencil {
    fn name(&self) -> &str {
        "mpi_stencil"
    }

    fn regions(&self) -> Vec<String> {
        vec![]
    }

    fn build(&self, res: &ResourceConfig, _machine: &MachineSpec) -> Program {
        let p = res.n_ranks as f64;
        let cells = (self.nx * self.ny) as f64;
        let cells_per_rank = cells / p;
        // One rank per core: the whole rank state is its working set.
        let ws = cells_per_rank * 5.0 * 8.0;
        // Instruction overhead grows with the decomposition surface.
        let insn_factor =
            1.0 + self.pack_overhead * (p / self.base_ranks - 1.0).max(0.0);
        // 2-D decomposition: halo per neighbour ~ perimeter / 4.
        let halo = ((cells_per_rank.sqrt()) * 8.0) as u64;
        let mut prog = Program::new();
        for _ in 0..self.iterations {
            prog.push(Step::Exchange { bytes_per_neighbor: halo });
            prog.push(Step::Parallel {
                flops: cells_per_rank * 9.0,
                working_set_bytes: ws,
                imbalance: Imbalance::None,
                schedule: OmpSchedule::Static,
                rank_weights: vec![1.0, 1.02, 0.99, 1.01], // mild per-rank spread
                insn_factor,
            });
            prog.push(Step::Collective {
                kind: CollKind::Allreduce,
                bytes_per_rank: 8,
            });
        }
        prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::workload::run_with_talp;
    use crate::pop;

    fn mn5() -> MachineSpec {
        MachineSpec::marenostrum5()
    }

    #[test]
    fn synthetic_builds_and_runs() {
        let app = Synthetic::default();
        let (d, _) =
            run_with_talp(&app, &mn5(), &ResourceConfig::new(2, 4), 1, 0);
        assert!(d.region("work").is_some());
    }

    #[test]
    fn serial_fraction_lowers_serialization_efficiency() {
        let clean = Synthetic::default();
        let dirty = Synthetic {
            serial_fraction: 0.5,
            name: "dirty".into(),
            ..Synthetic::default()
        };
        let eff = |app: &Synthetic| {
            let (d, _) =
                run_with_talp(app, &mn5(), &ResourceConfig::new(1, 8), 1, 0);
            pop::compute(d.region("work").unwrap(), 8)
                .omp_serialization_efficiency
        };
        assert!(eff(&dirty) < eff(&clean) - 0.05);
    }

    #[test]
    fn mpi_stencil_strong_scaling_shape() {
        // Scaled-down Fig. 3: 28 vs 56 single-thread ranks.
        let mut app = MpiStencil::fig3();
        app.nx = 1000;
        app.ny = 1000;
        app.iterations = 40;
        app.base_ranks = 28.0; // rescale the knee to the test's ranks
        let (d1, _) =
            run_with_talp(&app, &mn5(), &ResourceConfig::new(28, 1), 5, 0);
        let (d2, _) =
            run_with_talp(&app, &mn5(), &ResourceConfig::new(56, 1), 5, 0);
        let t = pop::build("Global", &[&d1, &d2]).unwrap();
        assert_eq!(t.mode, pop::ScalingMode::Strong);
        // Fig. 3 shape: global efficiency decays, driven by parallel
        // efficiency; instruction scaling < 1 from packing overhead.
        let ge0 = t.cell("Global efficiency", 0).unwrap();
        let ge1 = t.cell("Global efficiency", 1).unwrap();
        assert!(ge1 < ge0, "{ge1} !< {ge0}");
        let insc = t.cell("Instructions scaling", 1).unwrap();
        assert!((0.5..0.99).contains(&insc), "instr scaling {insc}");
    }
}
