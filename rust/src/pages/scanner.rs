//! The TALP-Pages input folder scanner (paper Fig. 2).
//!
//! Semantics:
//! * the CLI points at one *top-level folder*;
//! * every directory that directly contains `.json` files is one
//!   *experiment* (weak scaling, strong scaling, or a comparison of
//!   resource configurations);
//! * multiple runs of the same configuration in one experiment are the
//!   configuration's *history* (previous CI pipelines' artifacts);
//! * the *latest* run per configuration feeds the scaling-efficiency
//!   table, the full history feeds the time-evolution plots.
//!
//! Unparsable files produce warnings, not failures — a CI report must
//! survive one corrupt artifact.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

use crate::talp::RunData;

/// One experiment folder's parsed content.
#[derive(Debug)]
pub struct Experiment {
    /// Path relative to the scan root, e.g. "mesh_1/strong_scaling".
    pub id: String,
    pub runs: Vec<RunData>,
}

impl Experiment {
    /// Distinct resource configurations, ordered by resources.
    pub fn configs(&self) -> Vec<String> {
        let mut cfgs: Vec<(u32, u32)> = self
            .runs
            .iter()
            .map(|r| (r.ranks, r.threads))
            .collect();
        cfgs.sort_by_key(|&(r, t)| (r * t, r));
        cfgs.dedup();
        cfgs.iter().map(|(r, t)| format!("{r}x{t}")).collect()
    }

    /// Latest run per configuration (the table inputs).
    pub fn latest_per_config(&self) -> Vec<&RunData> {
        self.configs()
            .iter()
            .filter_map(|label| {
                self.history_for_config(label).into_iter().next_back()
            })
            .collect()
    }

    /// All runs of one configuration, oldest first.
    pub fn history_for_config(&self, label: &str) -> Vec<&RunData> {
        let mut runs: Vec<&RunData> = self
            .runs
            .iter()
            .filter(|r| r.resources().label() == label)
            .collect();
        runs.sort_by_key(|r| r.effective_timestamp());
        runs
    }

    /// Region names present in any run, Global first.
    pub fn regions(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for run in &self.runs {
            for reg in &run.regions {
                if !names.contains(&reg.name) {
                    names.push(reg.name.clone());
                }
            }
        }
        names.sort_by_key(|n| (n != "Global", n.clone()));
        names
    }
}

/// Scan outcome: experiments plus non-fatal warnings.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub experiments: Vec<Experiment>,
    pub warnings: Vec<String>,
}

/// Scan `root` per the Fig. 2 layout.
///
/// Parsing is parallelized across worker threads: CI histories grow to
/// hundreds of JSONs and per-file open/read latency dominates the
/// report path (EXPERIMENTS.md §Perf) — results stay in deterministic
/// file order regardless of worker scheduling.
pub fn scan(root: &Path) -> Result<ScanResult> {
    ensure!(root.is_dir(), "{} is not a directory", root.display());
    // Pass 1 (sequential): discover experiment dirs + their files.
    let mut found: Vec<(String, Vec<PathBuf>)> = Vec::new();
    walk(root, root, &mut found);
    found.sort_by(|a, b| a.0.cmp(&b.0));

    // Pass 2 (parallel): parse every file.
    let all_files: Vec<&PathBuf> =
        found.iter().flat_map(|(_, fs)| fs.iter()).collect();
    let n = all_files.len();
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(16)
        .max(1);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut parsed: Vec<Option<Result<RunData>>> =
        (0..n).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<Option<Result<RunData>>>> =
        (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() =
                    Some(RunData::read_file(all_files[i]));
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        parsed[i] = slot.into_inner().unwrap();
    }

    // Pass 3: assemble experiments in order, collecting warnings.
    let mut result = ScanResult::default();
    let mut cursor = 0usize;
    for (id, files) in found {
        let mut runs = Vec::new();
        for path in &files {
            match parsed[cursor].take() {
                Some(Ok(r)) => runs.push(r),
                Some(Err(e)) => result
                    .warnings
                    .push(format!("skipping {}: {e:#}", path.display())),
                None => unreachable!("worker skipped a file"),
            }
            cursor += 1;
        }
        if !runs.is_empty() {
            result.experiments.push(Experiment { id, runs });
        }
    }
    Ok(result)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<(String, Vec<PathBuf>)>) {
    let mut jsons: Vec<PathBuf> = Vec::new();
    let mut subdirs: Vec<PathBuf> = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            subdirs.push(p);
        } else if p.extension().and_then(|e| e.to_str()) == Some("json") {
            jsons.push(p);
        }
    }
    jsons.sort();
    subdirs.sort();
    if !jsons.is_empty() {
        let id = dir
            .strip_prefix(root)
            .map(|r| r.to_string_lossy().replace('\\', "/"))
            .unwrap_or_default();
        let id = if id.is_empty() { ".".to_string() } else { id };
        out.push((id, jsons));
    }
    for sub in subdirs {
        walk(root, &sub, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::{GitMeta, ProcStats, RegionData};
    use crate::util::fs::TempDir;

    fn run(ranks: u32, threads: u32, ts: i64) -> RunData {
        RunData {
            dlb_version: "t".into(),
            app: "app".into(),
            machine: "mn5".into(),
            timestamp: ts,
            ranks,
            threads,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 1.0,
                visits: 1,
                procs: (0..ranks)
                    .map(|r| ProcStats {
                        rank: r,
                        elapsed_s: 1.0,
                        useful_s: threads as f64 * 0.9,
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: None,
        }
    }

    /// Builds the paper's Fig. 2 structure.
    fn fig2_tree() -> TempDir {
        let td = TempDir::new("scan").unwrap();
        let w = |rel: &str, r: RunData| {
            r.write_file(&td.path().join(rel)).unwrap();
        };
        w("mesh_1/comparison/talp_1x112.json", run(1, 112, 100));
        w("mesh_1/comparison/talp_2x56.json", run(2, 56, 100));
        w("mesh_1/comparison/talp_4x28.json", run(4, 28, 100));
        w("mesh_1/strong_scaling/talp_8x14.json", run(8, 14, 100));
        w("mesh_1/strong_scaling/talp_8x28.json", run(8, 28, 100));
        w("mesh_2/weak_scaling/talp_8x14_9dc04ca.json", run(8, 14, 200));
        w("mesh_2/weak_scaling/talp_8x28_9dc04ca.json", run(8, 28, 200));
        w("mesh_2/weak_scaling/talp_8x14_ed8b9ef.json", run(8, 14, 300));
        w("mesh_2/weak_scaling/talp_8x28_ed8b9ef.json", run(8, 28, 300));
        td
    }

    #[test]
    fn scans_fig2_structure() {
        let td = fig2_tree();
        let res = scan(td.path()).unwrap();
        let ids: Vec<&str> =
            res.experiments.iter().map(|e| e.id.as_str()).collect();
        assert_eq!(
            ids,
            [
                "mesh_1/comparison",
                "mesh_1/strong_scaling",
                "mesh_2/weak_scaling"
            ]
        );
        assert!(res.warnings.is_empty());
    }

    #[test]
    fn configs_ordered_by_resources() {
        let td = fig2_tree();
        let res = scan(td.path()).unwrap();
        let comp = &res.experiments[0];
        assert_eq!(comp.configs(), ["1x112", "2x56", "4x28"]);
    }

    #[test]
    fn history_and_latest() {
        let td = fig2_tree();
        let res = scan(td.path()).unwrap();
        let weak = &res.experiments[2];
        let hist = weak.history_for_config("8x14");
        assert_eq!(hist.len(), 2);
        assert!(hist[0].timestamp < hist[1].timestamp);
        let latest = weak.latest_per_config();
        assert_eq!(latest.len(), 2);
        assert!(latest.iter().all(|r| r.timestamp == 300));
    }

    #[test]
    fn git_timestamp_preferred_in_history_order() {
        let td = TempDir::new("scan-git").unwrap();
        let mut early_commit = run(2, 2, 1000);
        early_commit.git = Some(GitMeta {
            commit: "aaa".into(),
            branch: "main".into(),
            commit_timestamp: 10,
            message: String::new(),
        });
        let late_commit = run(2, 2, 500); // executed earlier, no git meta
        early_commit
            .write_file(&td.path().join("exp/a.json"))
            .unwrap();
        late_commit
            .write_file(&td.path().join("exp/b.json"))
            .unwrap();
        let res = scan(td.path()).unwrap();
        let hist = res.experiments[0].history_for_config("2x2");
        // commit_timestamp 10 sorts before execution timestamp 500.
        assert_eq!(hist[0].effective_timestamp(), 10);
    }

    #[test]
    fn corrupt_file_warns_but_continues() {
        let td = fig2_tree();
        std::fs::write(td.path().join("mesh_1/comparison/bad.json"), "{oops")
            .unwrap();
        let res = scan(td.path()).unwrap();
        assert_eq!(res.warnings.len(), 1);
        assert_eq!(res.experiments.len(), 3);
    }

    #[test]
    fn empty_or_missing_root() {
        let td = TempDir::new("scan-empty").unwrap();
        let res = scan(td.path()).unwrap();
        assert!(res.experiments.is_empty());
        assert!(scan(&td.path().join("nope")).is_err());
    }

    #[test]
    fn jsons_at_root_become_dot_experiment() {
        let td = TempDir::new("scan-root").unwrap();
        run(1, 1, 1).write_file(&td.path().join("x.json")).unwrap();
        let res = scan(td.path()).unwrap();
        assert_eq!(res.experiments[0].id, ".");
    }
}
