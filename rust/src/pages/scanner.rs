//! The TALP-Pages input folder scanner (paper Fig. 2).
//!
//! Semantics:
//! * the CLI points at one *top-level folder*;
//! * every directory that directly contains `.json` files is one
//!   *experiment* (weak scaling, strong scaling, or a comparison of
//!   resource configurations);
//! * multiple runs of the same configuration in one experiment are the
//!   configuration's *history* (previous CI pipelines' artifacts);
//! * the *latest* run per configuration feeds the scaling-efficiency
//!   table, the full history feeds the time-evolution plots.
//!
//! Unparsable files produce warnings, not failures — a CI report must
//! survive one corrupt artifact.  Hidden files and directories (name
//! starting with `.`) are never artifacts, so a metrics cache stored
//! inside the scan root is ignored rather than warned about.
//!
//! Two scan paths share the [`discover`] pass:
//! * [`scan`] parses every artifact to full [`RunData`] (CLI `detect`,
//!   `model`, tests);
//! * [`scan_metrics`] is the report engine's path: artifacts reduce to
//!   [`RunMetrics`] through the content-hash cache (`pages::cache`), so
//!   unchanged files from previous CI pipelines skip parse + reduce
//!   entirely, and everything else parses on a worker pool.
//!
//! History ordering is fully deterministic: runs sort by
//! `effective_timestamp()` with the **source file name as tie-break**,
//! so equal-timestamp runs (same CI pipeline, coarse clocks) cannot
//! make badges or tables depend on directory-iteration order.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Result};

use crate::check::{Diagnostic, Span};
use crate::pop::RunMetrics;
use crate::util::json::error_offset;
use crate::talp::RunData;
use crate::util::par::parallel_map;

use super::cache::{content_hash, MetricsCache};

/// One experiment folder's parsed content.
///
/// `sources[i]` is the scan-root-relative file `runs[i]` came from;
/// the two vectors are always index-aligned.
#[derive(Debug)]
pub struct Experiment {
    /// Path relative to the scan root, e.g. "mesh_1/strong_scaling".
    pub id: String,
    pub runs: Vec<RunData>,
    pub sources: Vec<String>,
}

/// Shared ordering rule: timestamp first, source file name as the
/// deterministic tie-break.
fn history_order(
    a_ts: i64,
    a_src: &str,
    b_ts: i64,
    b_src: &str,
) -> std::cmp::Ordering {
    a_ts.cmp(&b_ts).then_with(|| a_src.cmp(b_src))
}

/// Distinct resource configurations of (ranks, threads) pairs, ordered
/// by resources.
fn config_labels(mut cfgs: Vec<(u32, u32)>) -> Vec<String> {
    cfgs.sort_by_key(|&(r, t)| (r * t, r));
    cfgs.dedup();
    cfgs.iter().map(|(r, t)| format!("{r}x{t}")).collect()
}

/// Region names with Global first, then alphabetical.
fn order_regions(mut names: Vec<String>) -> Vec<String> {
    names.sort_by_key(|n| (n != "Global", n.clone()));
    names
}

impl Experiment {
    /// Distinct resource configurations, ordered by resources.
    pub fn configs(&self) -> Vec<String> {
        config_labels(
            self.runs.iter().map(|r| (r.ranks, r.threads)).collect(),
        )
    }

    /// Latest run per configuration (the table inputs).
    pub fn latest_per_config(&self) -> Vec<&RunData> {
        self.configs()
            .iter()
            .filter_map(|label| {
                self.history_for_config(label).into_iter().next_back()
            })
            .collect()
    }

    /// All runs of one configuration, oldest first; equal timestamps
    /// tie-break on source file name.
    pub fn history_for_config(&self, label: &str) -> Vec<&RunData> {
        let mut idx: Vec<usize> = (0..self.runs.len())
            .filter(|&i| self.runs[i].resources().label() == label)
            .collect();
        idx.sort_by(|&a, &b| {
            history_order(
                self.runs[a].effective_timestamp(),
                self.source(a),
                self.runs[b].effective_timestamp(),
                self.source(b),
            )
        });
        idx.into_iter().map(|i| &self.runs[i]).collect()
    }

    fn source(&self, i: usize) -> &str {
        self.sources.get(i).map(String::as_str).unwrap_or("")
    }

    /// Region names present in any run, Global first.
    pub fn regions(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for run in &self.runs {
            for reg in &run.regions {
                if !names.contains(&reg.name) {
                    names.push(reg.name.clone());
                }
            }
        }
        order_regions(names)
    }
}

/// Scan outcome: experiments plus non-fatal warnings.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub experiments: Vec<Experiment>,
    pub warnings: Vec<String>,
}

/// One experiment reduced to cached metrics (the report engine's form).
#[derive(Debug)]
pub struct MetricExperiment {
    pub id: String,
    pub runs: Vec<RunMetrics>,
}

impl MetricExperiment {
    pub fn configs(&self) -> Vec<String> {
        config_labels(
            self.runs.iter().map(|r| (r.ranks, r.threads)).collect(),
        )
    }

    pub fn latest_per_config(&self) -> Vec<&RunMetrics> {
        self.configs()
            .iter()
            .filter_map(|label| {
                self.history_for_config(label).into_iter().next_back()
            })
            .collect()
    }

    /// Indices into `runs` of one configuration's history, oldest
    /// first; equal timestamps tie-break on source file name.  The
    /// distinct configurations partition the runs, so every index
    /// appears under exactly one label — callers may move runs out by
    /// index without collisions.
    pub fn history_indices_for_config(&self, label: &str) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.runs.len())
            .filter(|&i| self.runs[i].resources().label() == label)
            .collect();
        idx.sort_by(|&a, &b| {
            history_order(
                self.runs[a].effective_timestamp(),
                &self.runs[a].source,
                self.runs[b].effective_timestamp(),
                &self.runs[b].source,
            )
        });
        idx
    }

    /// Oldest first; equal timestamps tie-break on source file name.
    pub fn history_for_config(&self, label: &str) -> Vec<&RunMetrics> {
        self.history_indices_for_config(label)
            .into_iter()
            .map(|i| &self.runs[i])
            .collect()
    }

    pub fn regions(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for run in &self.runs {
            for reg in &run.regions {
                if !names.contains(&reg.name) {
                    names.push(reg.name.clone());
                }
            }
        }
        order_regions(names)
    }
}

/// Outcome of the cached scan.
///
/// Warnings are structured [`Diagnostic`]s (TP013 unreadable, TP001
/// invalid JSON with a byte-offset span, TP002 schema rejection), so
/// report documents and `talp-pages check` share one vocabulary.
#[derive(Debug, Default)]
pub struct MetricScan {
    pub experiments: Vec<MetricExperiment>,
    pub warnings: Vec<Diagnostic>,
    /// Artifacts served from the content-hash cache (not re-parsed).
    pub cache_hits: usize,
    /// Artifacts parsed + reduced this run.
    pub cache_misses: usize,
}

/// Pass 1: discover experiment directories and their artifact files,
/// in deterministic (sorted) order.
pub fn discover(root: &Path) -> Result<Vec<(String, Vec<PathBuf>)>> {
    ensure!(root.is_dir(), "{} is not a directory", root.display());
    let mut found: Vec<(String, Vec<PathBuf>)> = Vec::new();
    walk(root, root, &mut found);
    found.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(found)
}

/// Scan-root-relative display path (also the store's ingest source
/// key, so stored runs keep the exact `source` a direct scan yields).
pub(crate) fn rel_str(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .map(|r| r.to_string_lossy().replace('\\', "/"))
        .unwrap_or_else(|_| path.to_string_lossy().into_owned())
}

/// Scan `root` per the Fig. 2 layout, parsing to full [`RunData`].
///
/// Parsing is parallelized across worker threads: CI histories grow to
/// hundreds of JSONs and per-file open/read latency dominates the
/// report path (EXPERIMENTS.md §Perf) — results stay in deterministic
/// file order regardless of worker scheduling.
pub fn scan(root: &Path) -> Result<ScanResult> {
    let found = discover(root)?;
    let all: Vec<PathBuf> = found
        .iter()
        .flat_map(|(_, fs)| fs.iter().cloned())
        .collect();
    let parsed: Vec<Result<RunData>> =
        parallel_map(&all, 0, |p| RunData::read_file(p));

    let mut result = ScanResult::default();
    let mut next = parsed.into_iter();
    for (id, files) in found {
        let mut runs = Vec::new();
        let mut sources = Vec::new();
        for path in &files {
            match next.next().expect("parser skipped a file") {
                Ok(r) => {
                    runs.push(r);
                    sources.push(rel_str(root, path));
                }
                Err(e) => result
                    .warnings
                    .push(format!("skipping {}: {e:#}", path.display())),
            }
        }
        if !runs.is_empty() {
            result.experiments.push(Experiment { id, runs, sources });
        }
    }
    Ok(result)
}

/// Scan `root` through the metrics cache on up to `jobs` workers
/// (0 = auto).  Unchanged artifacts (same content hash) are served from
/// `cache` without being read into the JSON parser at all; fresh or
/// changed artifacts parse + reduce in parallel and are inserted.
/// Entries for vanished files are pruned.
pub fn scan_metrics(
    root: &Path,
    cache: &mut MetricsCache,
    jobs: usize,
) -> Result<MetricScan> {
    enum Outcome {
        Hit(RunMetrics),
        Miss(String, RunMetrics),
        Bad(Diagnostic),
    }

    let found = discover(root)?;
    let all: Vec<(String, PathBuf)> = found
        .iter()
        .flat_map(|(_, fs)| {
            fs.iter().map(|p| (rel_str(root, p), p.clone()))
        })
        .collect();

    let cache_ref: &MetricsCache = cache;
    let outcomes: Vec<Outcome> = parallel_map(&all, jobs, |(rel, path)| {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) => {
                return Outcome::Bad(Diagnostic::warning(
                    "TP013",
                    path.display().to_string(),
                    format!("unreadable ({e}) — skipped"),
                ))
            }
        };
        let content_hash = content_hash(&bytes);
        if let Some(hit) = cache_ref.lookup(rel, &content_hash) {
            return Outcome::Hit(hit.clone());
        }
        // Streaming decode straight from the bytes just hashed — no
        // UTF-8 revalidation pass, no Json tree.
        match RunData::from_slice(&bytes, path) {
            Ok(data) => Outcome::Miss(
                content_hash,
                RunMetrics::from_run(&data, rel),
            ),
            Err(e) => Outcome::Bad(match error_offset(&e) {
                // A JSON syntax error carries a byte offset: TP001
                // with a span.  Anything else failed the TALP schema:
                // TP002.  Both are skip-warnings here; `check`
                // escalates them to errors.
                Some(off) => Diagnostic::warning(
                    "TP001",
                    path.display().to_string(),
                    format!("invalid JSON: {} — skipped", e.root_cause()),
                )
                .with_span(Span { start: off, len: 1 }),
                None => Diagnostic::warning(
                    "TP002",
                    path.display().to_string(),
                    format!(
                        "not a valid TALP artifact: {} — skipped",
                        e.root_cause()
                    ),
                ),
            }),
        }
    });

    let mut scan = MetricScan::default();
    let mut next = outcomes.into_iter();
    let mut flat = all.iter();
    for (id, files) in &found {
        let mut runs = Vec::new();
        for _ in files {
            let (rel, _) = flat.next().expect("discover/flat mismatch");
            match next.next().expect("worker skipped a file") {
                Outcome::Hit(rm) => {
                    scan.cache_hits += 1;
                    runs.push(rm);
                }
                Outcome::Miss(content_hash, rm) => {
                    scan.cache_misses += 1;
                    cache.insert(rel, &content_hash, rm.clone());
                    runs.push(rm);
                }
                Outcome::Bad(warning) => scan.warnings.push(warning),
            }
        }
        if !runs.is_empty() {
            scan.experiments
                .push(MetricExperiment { id: id.clone(), runs });
        }
    }

    let live: std::collections::HashSet<&str> =
        all.iter().map(|(rel, _)| rel.as_str()).collect();
    cache.retain_paths(|p| live.contains(p));
    Ok(scan)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<(String, Vec<PathBuf>)>) {
    let mut jsons: Vec<PathBuf> = Vec::new();
    let mut subdirs: Vec<PathBuf> = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in rd.flatten() {
        let p = entry.path();
        // Hidden files are never artifacts — this keeps a metrics
        // cache stored inside the scan root (e.g. `.talp-cache.json`)
        // from being picked up as a corrupt TALP JSON.
        let hidden = p
            .file_name()
            .and_then(|n| n.to_str())
            .map_or(false, |n| n.starts_with('.'));
        if hidden {
            continue;
        }
        if p.is_dir() {
            subdirs.push(p);
        } else if p.extension().and_then(|e| e.to_str()) == Some("json") {
            jsons.push(p);
        }
    }
    jsons.sort();
    subdirs.sort();
    if !jsons.is_empty() {
        let id = dir
            .strip_prefix(root)
            .map(|r| r.to_string_lossy().replace('\\', "/"))
            .unwrap_or_default();
        let id = if id.is_empty() { ".".to_string() } else { id };
        out.push((id, jsons));
    }
    for sub in subdirs {
        walk(root, &sub, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::{GitMeta, ProcStats, RegionData};
    use crate::util::fs::TempDir;

    fn run(ranks: u32, threads: u32, ts: i64) -> RunData {
        RunData {
            dlb_version: "t".into(),
            app: "app".into(),
            machine: "mn5".into(),
            timestamp: ts,
            ranks,
            threads,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 1.0,
                visits: 1,
                procs: (0..ranks)
                    .map(|r| ProcStats {
                        rank: r,
                        elapsed_s: 1.0,
                        useful_s: threads as f64 * 0.9,
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: None,
        }
    }

    /// Builds the paper's Fig. 2 structure.
    fn fig2_tree() -> TempDir {
        let td = TempDir::new("scan").unwrap();
        let w = |rel: &str, r: RunData| {
            r.write_file(&td.path().join(rel)).unwrap();
        };
        w("mesh_1/comparison/talp_1x112.json", run(1, 112, 100));
        w("mesh_1/comparison/talp_2x56.json", run(2, 56, 100));
        w("mesh_1/comparison/talp_4x28.json", run(4, 28, 100));
        w("mesh_1/strong_scaling/talp_8x14.json", run(8, 14, 100));
        w("mesh_1/strong_scaling/talp_8x28.json", run(8, 28, 100));
        w("mesh_2/weak_scaling/talp_8x14_9dc04ca.json", run(8, 14, 200));
        w("mesh_2/weak_scaling/talp_8x28_9dc04ca.json", run(8, 28, 200));
        w("mesh_2/weak_scaling/talp_8x14_ed8b9ef.json", run(8, 14, 300));
        w("mesh_2/weak_scaling/talp_8x28_ed8b9ef.json", run(8, 28, 300));
        td
    }

    #[test]
    fn scans_fig2_structure() {
        let td = fig2_tree();
        let res = scan(td.path()).unwrap();
        let ids: Vec<&str> =
            res.experiments.iter().map(|e| e.id.as_str()).collect();
        assert_eq!(
            ids,
            [
                "mesh_1/comparison",
                "mesh_1/strong_scaling",
                "mesh_2/weak_scaling"
            ]
        );
        assert!(res.warnings.is_empty());
    }

    #[test]
    fn configs_ordered_by_resources() {
        let td = fig2_tree();
        let res = scan(td.path()).unwrap();
        let comp = &res.experiments[0];
        assert_eq!(comp.configs(), ["1x112", "2x56", "4x28"]);
    }

    #[test]
    fn history_and_latest() {
        let td = fig2_tree();
        let res = scan(td.path()).unwrap();
        let weak = &res.experiments[2];
        let hist = weak.history_for_config("8x14");
        assert_eq!(hist.len(), 2);
        assert!(hist[0].timestamp < hist[1].timestamp);
        let latest = weak.latest_per_config();
        assert_eq!(latest.len(), 2);
        assert!(latest.iter().all(|r| r.timestamp == 300));
    }

    #[test]
    fn git_timestamp_preferred_in_history_order() {
        let td = TempDir::new("scan-git").unwrap();
        let mut early_commit = run(2, 2, 1000);
        early_commit.git = Some(GitMeta {
            commit: "aaa".into(),
            branch: "main".into(),
            commit_timestamp: 10,
            message: String::new(),
        });
        let late_commit = run(2, 2, 500); // executed earlier, no git meta
        early_commit
            .write_file(&td.path().join("exp/a.json"))
            .unwrap();
        late_commit
            .write_file(&td.path().join("exp/b.json"))
            .unwrap();
        let res = scan(td.path()).unwrap();
        let hist = res.experiments[0].history_for_config("2x2");
        // commit_timestamp 10 sorts before execution timestamp 500.
        assert_eq!(hist[0].effective_timestamp(), 10);
    }

    #[test]
    fn equal_timestamps_tie_break_on_file_name() {
        // Same CI pipeline, coarse clocks: three runs of one config
        // with the same timestamp must order by file name, not by
        // directory-iteration accidents.
        let td = TempDir::new("scan-tie").unwrap();
        for (file, app) in
            [("zz.json", "last"), ("aa.json", "first"), ("mm.json", "mid")]
        {
            let mut r = run(2, 2, 777);
            r.app = app.into();
            r.write_file(&td.path().join("exp").join(file)).unwrap();
        }
        let res = scan(td.path()).unwrap();
        let hist = res.experiments[0].history_for_config("2x2");
        let order: Vec<&str> =
            hist.iter().map(|r| r.app.as_str()).collect();
        assert_eq!(order, ["first", "mid", "last"]);
        // latest_per_config picks the file-name-largest run.
        let latest = res.experiments[0].latest_per_config();
        assert_eq!(latest[0].app, "last");

        // The metrics path applies the identical rule.
        let mut cache = MetricsCache::new();
        let ms = scan_metrics(td.path(), &mut cache, 1).unwrap();
        let hist = ms.experiments[0].history_for_config("2x2");
        let order: Vec<&str> =
            hist.iter().map(|r| r.app.as_str()).collect();
        assert_eq!(order, ["first", "mid", "last"]);
        assert_eq!(
            ms.experiments[0].latest_per_config()[0].source,
            "exp/zz.json"
        );
    }

    #[test]
    fn corrupt_file_warns_but_continues() {
        let td = fig2_tree();
        std::fs::write(td.path().join("mesh_1/comparison/bad.json"), "{oops")
            .unwrap();
        let res = scan(td.path()).unwrap();
        assert_eq!(res.warnings.len(), 1);
        assert_eq!(res.experiments.len(), 3);
    }

    #[test]
    fn corrupt_file_warns_but_continues_in_metrics_scan() {
        // The report path must also survive a truncated artifact next
        // to valid runs (paper: "a CI report must survive one corrupt
        // artifact").
        let td = fig2_tree();
        std::fs::write(
            td.path().join("mesh_1/comparison/trunc.json"),
            "{\"resources\": {\"num_mpi_ranks\": 2,",
        )
        .unwrap();
        let mut cache = MetricsCache::new();
        let ms = scan_metrics(td.path(), &mut cache, 0).unwrap();
        assert_eq!(ms.warnings.len(), 1);
        assert!(ms.warnings[0].to_string().contains("trunc.json"));
        // The truncated artifact is a JSON syntax error with a span
        // inside the file (not past its end).
        assert_eq!(ms.warnings[0].code, "TP001");
        let span = ms.warnings[0].span.expect("syntax error carries span");
        assert!(span.start <= "{\"resources\": {\"num_mpi_ranks\": 2,".len());
        assert_eq!(ms.experiments.len(), 3);
        assert_eq!(ms.experiments[0].runs.len(), 3, "valid runs kept");
        // The corrupt file must not be cached; a rescan warns again.
        let ms2 = scan_metrics(td.path(), &mut cache, 0).unwrap();
        assert_eq!(ms2.warnings.len(), 1);
        assert_eq!(ms2.cache_misses, 0, "valid files all hit");
    }

    #[test]
    fn metrics_scan_hits_cache_on_rescan() {
        let td = fig2_tree();
        let mut cache = MetricsCache::new();
        let cold = scan_metrics(td.path(), &mut cache, 0).unwrap();
        assert_eq!(cold.cache_hits, 0);
        assert_eq!(cold.cache_misses, 9);
        assert_eq!(cache.len(), 9);

        let warm = scan_metrics(td.path(), &mut cache, 0).unwrap();
        assert_eq!(warm.cache_hits, 9, "unchanged artifacts must hit");
        assert_eq!(warm.cache_misses, 0);

        // Touch one file's *content*: only that file re-parses.
        run(8, 14, 999)
            .write_file(
                &td.path().join("mesh_2/weak_scaling/talp_8x14_ed8b9ef.json"),
            )
            .unwrap();
        let mixed = scan_metrics(td.path(), &mut cache, 0).unwrap();
        assert_eq!(mixed.cache_hits, 8);
        assert_eq!(mixed.cache_misses, 1);

        // Delete a file: its entry is pruned.
        std::fs::remove_file(
            td.path().join("mesh_1/comparison/talp_1x112.json"),
        )
        .unwrap();
        scan_metrics(td.path(), &mut cache, 0).unwrap();
        assert_eq!(cache.len(), 8);
    }

    #[test]
    fn metrics_scan_matches_rundata_scan() {
        let td = fig2_tree();
        let a = scan(td.path()).unwrap();
        let mut cache = MetricsCache::new();
        let b = scan_metrics(td.path(), &mut cache, 2).unwrap();
        assert_eq!(a.experiments.len(), b.experiments.len());
        for (ea, eb) in a.experiments.iter().zip(&b.experiments) {
            assert_eq!(ea.id, eb.id);
            assert_eq!(ea.configs(), eb.configs());
            assert_eq!(ea.regions(), eb.regions());
            assert_eq!(ea.runs.len(), eb.runs.len());
        }
    }

    #[test]
    fn empty_or_missing_root() {
        let td = TempDir::new("scan-empty").unwrap();
        let res = scan(td.path()).unwrap();
        assert!(res.experiments.is_empty());
        assert!(scan(&td.path().join("nope")).is_err());
        let mut cache = MetricsCache::new();
        assert!(
            scan_metrics(&td.path().join("nope"), &mut cache, 0).is_err()
        );
    }

    #[test]
    fn jsons_at_root_become_dot_experiment() {
        let td = TempDir::new("scan-root").unwrap();
        run(1, 1, 1).write_file(&td.path().join("x.json")).unwrap();
        let res = scan(td.path()).unwrap();
        assert_eq!(res.experiments[0].id, ".");
    }

    #[test]
    fn hidden_files_and_dirs_are_not_artifacts() {
        // A metrics cache stored inside the scan root (the Session
        // default when callers point it there) must not be scanned as
        // a corrupt TALP JSON — same for any other dotfile.
        let td = TempDir::new("scan-hidden").unwrap();
        run(2, 2, 1).write_file(&td.path().join("exp/a.json")).unwrap();
        std::fs::write(td.path().join(".talp-cache.json"), "{}").unwrap();
        std::fs::write(td.path().join("exp/.hidden.json"), "][").unwrap();
        run(2, 2, 1)
            .write_file(&td.path().join(".git/blob.json"))
            .unwrap();
        let res = scan(td.path()).unwrap();
        assert!(res.warnings.is_empty(), "{:?}", res.warnings);
        assert_eq!(res.experiments.len(), 1);
        assert_eq!(res.experiments[0].runs.len(), 1);
        let mut cache = MetricsCache::new();
        let ms = scan_metrics(td.path(), &mut cache, 0).unwrap();
        assert!(ms.warnings.is_empty(), "{:?}", ms.warnings);
        assert_eq!(ms.cache_misses, 1);
    }
}
