//! Small self-contained SVG line-chart generator for the time-evolution
//! plots.  No external JS plotting library: the report must work from
//! static in-repository hosting (GitLab/GitHub Pages) with no CDN.
//!
//! Interactivity (the paper's "regions can be easily toggled on and
//! off") is done with one CSS class per series and a checkbox script in
//! html.rs.

use std::fmt::Write as _;

use crate::util::timefmt;

/// One series: label + (unix time, value) points.
pub struct Series {
    pub label: String,
    pub points: Vec<(i64, f64)>,
    pub color: String,
}

/// Palette for region series (repeats when exhausted).
pub const PALETTE: &[&str] = &[
    "#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b",
    "#e377c2", "#7f7f7f", "#bcbd22", "#17becf",
];

pub fn color(i: usize) -> String {
    PALETTE[i % PALETTE.len()].to_string()
}

const W: f64 = 760.0;
const H: f64 = 180.0;
const ML: f64 = 64.0; // left margin (y labels)
const MR: f64 = 10.0;
const MT: f64 = 18.0;
const MB: f64 = 26.0;

/// Render one chart.  `css_class_of_series[i]` becomes a class on the
/// series' polyline + markers so the page JS can hide whole regions.
pub fn line_chart(title: &str, series: &[Series], y_unit: &str) -> String {
    let mut all_t: Vec<i64> = Vec::new();
    let mut all_v: Vec<f64> = Vec::new();
    for s in series {
        for (t, v) in &s.points {
            all_t.push(*t);
            if v.is_finite() {
                all_v.push(*v);
            }
        }
    }
    if all_t.is_empty() || all_v.is_empty() {
        return format!(
            "<svg class=\"chart\" viewBox=\"0 0 {W} {H}\"><text x=\"10\" y=\"20\">{}</text><text x=\"10\" y=\"40\" fill=\"#888\">no data</text></svg>",
            esc(title)
        );
    }
    let (t0, t1) = (
        *all_t.iter().min().unwrap(),
        *all_t.iter().max().unwrap(),
    );
    let (mut v0, mut v1) = (
        all_v.iter().cloned().fold(f64::INFINITY, f64::min),
        all_v.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    if (v1 - v0).abs() < 1e-12 {
        v0 -= 0.5;
        v1 += 0.5;
    } else {
        let pad = 0.08 * (v1 - v0);
        v0 -= pad;
        v1 += pad;
    }
    let tspan = ((t1 - t0) as f64).max(1.0);
    let x = |t: i64| ML + (t - t0) as f64 / tspan * (W - ML - MR);
    let y = |v: f64| H - MB - (v - v0) / (v1 - v0) * (H - MT - MB);

    // Pre-size for the dominant cost: one <circle> (~170 B) per point.
    let npoints: usize = series.iter().map(|s| s.points.len()).sum();
    let mut svg = String::with_capacity(2048 + 170 * npoints);
    let _ = write!(svg,
        "<svg class=\"chart\" viewBox=\"0 0 {W} {H}\" xmlns=\"http://www.w3.org/2000/svg\">\n"
    );
    let _ = write!(svg,
        "<text x=\"{ML}\" y=\"13\" class=\"charttitle\">{}</text>\n",
        esc(title)
    );
    // Gridlines + y labels (4 ticks).
    for i in 0..=3 {
        let v = v0 + (v1 - v0) * i as f64 / 3.0;
        let yy = y(v);
        let _ = write!(svg,
            "<line x1=\"{ML}\" y1=\"{yy:.1}\" x2=\"{:.1}\" y2=\"{yy:.1}\" class=\"grid\"/>\n",
            W - MR
        );
        let _ = write!(svg,
            "<text x=\"{:.1}\" y=\"{:.1}\" class=\"ylabel\">{}</text>\n",
            ML - 6.0,
            yy + 3.5,
            fmt_val(v)
        );
    }
    // X labels: first and last timestamp.
    for (t, anchor) in [(t0, "start"), (t1, "end")] {
        let _ = write!(svg,
            "<text x=\"{:.1}\" y=\"{:.1}\" class=\"xlabel\" text-anchor=\"{anchor}\">{}</text>\n",
            x(t),
            H - 8.0,
            timefmt::to_iso8601(t).split('T').next().unwrap_or("")
        );
    }
    let _ = write!(svg,
        "<text x=\"12\" y=\"{:.1}\" class=\"yunit\" transform=\"rotate(-90 12 {:.1})\">{}</text>\n",
        (H - MB + MT) / 2.0,
        (H - MB + MT) / 2.0,
        esc(y_unit)
    );
    // Series.
    for s in series {
        if s.points.is_empty() {
            continue;
        }
        let cls = css_class(&s.label);
        let mut path = String::with_capacity(14 * s.points.len());
        for (t, v) in s.points.iter().filter(|(_, v)| v.is_finite()) {
            let _ = write!(path, "{:.1},{:.1} ", x(*t), y(*v));
        }
        let _ = write!(svg,
            "<polyline class=\"series {cls}\" fill=\"none\" stroke=\"{}\" stroke-width=\"1.6\" points=\"{}\"/>\n",
            s.color,
            path.trim_end()
        );
        for (t, v) in s.points.iter().filter(|(_, v)| v.is_finite()) {
            let _ = write!(svg,
                "<circle class=\"series {cls}\" cx=\"{:.1}\" cy=\"{:.1}\" r=\"2.4\" fill=\"{}\"><title>{}: {} @ {}</title></circle>\n",
                x(*t),
                y(*v),
                s.color,
                esc(&s.label),
                fmt_val(*v),
                timefmt::to_iso8601(*t)
            );
        }
    }
    svg.push_str("</svg>\n");
    svg
}

/// CSS-safe class for a series label ("initialize" -> "r-initialize").
pub fn css_class(label: &str) -> String {
    let mut out = String::from("r-");
    for c in label.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c.to_ascii_lowercase());
        } else {
            out.push('_');
        }
    }
    out
}

fn fmt_val(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1e6 {
        format!("{:.2e}", v)
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.3}")
    }
}

pub fn esc(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
        .replace('"', "&quot;")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series() -> Vec<Series> {
        vec![
            Series {
                label: "Global".into(),
                points: vec![(1000, 10.0), (2000, 8.0), (3000, 8.1)],
                color: color(0),
            },
            Series {
                label: "initialize".into(),
                points: vec![(1000, 4.0), (2000, 1.5), (3000, 1.4)],
                color: color(1),
            },
        ]
    }

    #[test]
    fn chart_contains_series_and_classes() {
        let svg = line_chart("Elapsed time", &series(), "s");
        assert!(svg.contains("polyline"));
        assert!(svg.contains("r-global"));
        assert!(svg.contains("r-initialize"));
        assert!(svg.contains("Elapsed time"));
        assert_eq!(svg.matches("<circle").count(), 6);
    }

    #[test]
    fn empty_series_renders_placeholder() {
        let svg = line_chart("x", &[], "s");
        assert!(svg.contains("no data"));
    }

    #[test]
    fn constant_series_does_not_divide_by_zero() {
        let s = vec![Series {
            label: "flat".into(),
            points: vec![(0, 1.0), (100, 1.0)],
            color: color(0),
        }];
        let svg = line_chart("flat", &s, "");
        assert!(!svg.contains("NaN"));
        assert!(!svg.contains("inf"));
    }

    #[test]
    fn css_class_sanitizes() {
        assert_eq!(css_class("My Region/2"), "r-my_region_2");
    }

    #[test]
    fn escaping() {
        assert_eq!(esc("a<b>&\"c\""), "a&lt;b&gt;&amp;&quot;c&quot;");
    }
}
