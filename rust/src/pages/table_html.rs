//! HTML rendering of the scaling-efficiency table with the POP color
//! convention (Fig. 3 of the paper): efficiencies colored by band,
//! hierarchy shown as indentation, footer rows plain.

use crate::pop::ScalingTable;

use super::svgplot::esc;

/// Cell background for an efficiency value (scalabilities > 1 are good;
/// the paper leaves footer rows uncolored).
fn cell_color(v: f64) -> &'static str {
    if v >= 0.8 {
        "#c6e9c6" // green
    } else if v >= 0.6 {
        "#f6eab8" // yellow
    } else {
        "#f3c6bd" // red
    }
}

pub fn render(table: &ScalingTable) -> String {
    let mut html = String::with_capacity(4096);
    html.push_str(&format!(
        "<table class=\"efftable\" data-region=\"{}\">\n<thead><tr><th>Metrics ({} scaling)</th>",
        esc(&table.region),
        table.mode.name()
    ));
    for c in &table.columns {
        html.push_str(&format!("<th>{}</th>", esc(c)));
    }
    html.push_str("</tr></thead>\n<tbody>\n");
    for row in &table.rows {
        html.push_str("<tr>");
        html.push_str(&format!(
            "<td class=\"label d{}\">{}</td>",
            row.depth.min(4),
            esc(&row.label)
        ));
        for cell in &row.cells {
            match cell {
                None => html.push_str("<td class=\"num\">-</td>"),
                Some(v) => {
                    let style = if row.is_footer {
                        String::new()
                    } else {
                        format!(" style=\"background:{}\"", cell_color(*v))
                    };
                    html.push_str(&format!(
                        "<td class=\"num\"{style}>{}</td>",
                        ScalingTable::fmt_cell(Some(*v), row.is_footer)
                    ));
                }
            }
        }
        html.push_str("</tr>\n");
    }
    html.push_str("</tbody></table>\n");
    html
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pop::{self};
    use crate::talp::{ProcStats, RegionData, RunData};

    fn sample_table() -> ScalingTable {
        let run = |ranks: u32, useful: f64, e: f64| RunData {
            dlb_version: "t".into(),
            app: "t".into(),
            machine: "mn5".into(),
            timestamp: 0,
            ranks,
            threads: 2,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: e,
                visits: 1,
                procs: (0..ranks)
                    .map(|r| ProcStats {
                        rank: r,
                        elapsed_s: e,
                        useful_s: useful,
                        useful_instructions: 1000,
                        useful_cycles: 400,
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: None,
        };
        let a = run(2, 3.6, 2.0);
        let b = run(4, 1.2, 1.0);
        pop::build("Global", &[&a, &b]).unwrap()
    }

    #[test]
    fn renders_header_and_rows() {
        let html = render(&sample_table());
        assert!(html.contains("<table class=\"efftable\""));
        assert!(html.contains("<th>2x2</th>"));
        assert!(html.contains("<th>4x2</th>"));
        assert!(html.contains("Parallel efficiency"));
        assert!(html.contains("Elapsed time [s]"));
    }

    #[test]
    fn colors_follow_bands() {
        let html = render(&sample_table());
        // PE col 0 = 3.6/(4*2)=0.9 -> green present.
        assert!(html.contains("#c6e9c6"));
    }

    #[test]
    fn footer_rows_uncolored() {
        let html = render(&sample_table());
        // The elapsed-time row must not carry a background style.
        let footer_part = html
            .split("Elapsed time [s]")
            .nth(1)
            .unwrap()
            .split("</tr>")
            .next()
            .unwrap();
        assert!(!footer_part.contains("background"));
    }

    #[test]
    fn cell_color_bands() {
        assert_eq!(cell_color(0.9), "#c6e9c6");
        assert_eq!(cell_color(0.7), "#f6eab8");
        assert_eq!(cell_color(0.2), "#f3c6bd");
    }
}
