//! On-disk metrics cache — the incremental half of the report engine.
//!
//! The common CI case (paper Fig. 6) is: pipeline N's `talp/` folder is
//! pipeline N-1's folder plus one new run per matrix job.  Re-parsing
//! the whole history every run is the dominant report cost, so the engine
//! persists each artifact's reduced [`RunMetrics`] keyed by the
//! artifact's **content hash**:
//!
//! ```json
//! {
//!   "version": 2,
//!   "entries": {
//!     "<path relative to scan root>": {
//!       "hash": "<fnv1a-64 of the raw file bytes, hex>",
//!       "run": { ...pop::summary::RunMetrics... }
//!     }
//!   }
//! }
//! ```
//!
//! Invalidation rule: an entry is used iff its `hash` equals the
//! current file content's FNV-1a 64.  Renamed-but-identical files miss
//! (path is the index key); touched-but-identical files hit (mtimes are
//! irrelevant — CI artifact downloads reset them anyway); any content
//! change misses.  Stale entries (file gone) are dropped on save.
//!
//! The CLI keeps the file at `<out_dir>/.talp-cache.json` by default;
//! `Session::cache` points it anywhere (the in-process CI engine uses
//! a location that survives per-pipeline work dirs).  Entries are
//! serialized in sorted path order so cache files are byte-reproducible
//! and never differ between `--jobs` settings.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::pop::RunMetrics;
use crate::util::hash;
use crate::util::json::{Event, Json, JsonReader, JsonWriter};

/// The content-hash key shared by this cache and the persistent run
/// store (`crate::store`): FNV-1a 64 over the raw artifact bytes,
/// fixed-width hex.  One key function means an artifact ingested into
/// the store and one served from the cache can never disagree about
/// identity.
pub fn content_hash(bytes: &[u8]) -> String {
    hash::to_hex(hash::fnv1a_64(bytes))
}

/// Cache schema version; bump when `RunMetrics`' JSON shape changes
/// (old caches are discarded wholesale, never migrated — `load`
/// self-invalidates on any mismatch, older OR newer).
///
/// v2: reserved the schema for gate-era metadata (the regression gate
/// consumes cached entries directly), so v1 caches written by pre-gate
/// builds self-invalidate instead of being extended in place.
pub const CACHE_VERSION: u64 = 2;

/// Default cache file name inside the report output directory.
pub const CACHE_FILE_NAME: &str = ".talp-cache.json";

#[derive(Debug, Clone)]
struct Entry {
    hash: String,
    run: RunMetrics,
}

/// Content-addressed store of reduced runs.
#[derive(Debug, Default)]
pub struct MetricsCache {
    entries: BTreeMap<String, Entry>,
}

impl MetricsCache {
    pub fn new() -> MetricsCache {
        MetricsCache::default()
    }

    /// Load from disk; a missing, unreadable, corrupt or
    /// version-mismatched file yields an empty cache (a cold start is
    /// always safe — the cache is a pure accelerator).  The decode is
    /// a single streaming pass over the raw bytes — no `Json` tree —
    /// and is all-or-nothing: any malformed entry discards the whole
    /// file (we wrote it; a bad entry means the file is not ours or is
    /// damaged, and a cold start costs only one re-parse).
    pub fn load(path: &Path) -> MetricsCache {
        let Ok(bytes) = std::fs::read(path) else {
            return MetricsCache::new();
        };
        decode_cache(&bytes).unwrap_or_default()
    }

    /// Look up `rel_path`; hits only when the stored content hash
    /// matches `hash`.
    pub fn lookup(&self, rel_path: &str, hash: &str) -> Option<&RunMetrics> {
        self.entries
            .get(rel_path)
            .filter(|e| e.hash == hash)
            .map(|e| &e.run)
    }

    /// Insert or replace an entry.
    pub fn insert(&mut self, rel_path: &str, hash: &str, run: RunMetrics) {
        self.entries.insert(
            rel_path.to_string(),
            Entry { hash: hash.to_string(), run },
        );
    }

    /// Drop entries whose path is not in `live` (files that vanished
    /// from the scan root).
    pub fn retain_paths<F: Fn(&str) -> bool>(&mut self, live: F) {
        self.entries.retain(|k, _| live(k));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize (sorted path order — byte-reproducible).
    pub fn to_json(&self) -> Json {
        let mut entries = Json::obj();
        for (path, e) in &self.entries {
            entries.push_field(
                path,
                Json::from_pairs(vec![
                    ("hash", Json::Str(e.hash.clone())),
                    ("run", e.run.to_json()),
                ]),
            );
        }
        let mut root = Json::obj();
        root.push_field("version", Json::Num(CACHE_VERSION as f64));
        root.push_field("entries", entries);
        root
    }

    /// Statically diagnose a cache file without loading it into a
    /// session — the `talp-pages check` surface.  Everything here is a
    /// *warning*: a bad cache only costs a cold start, never
    /// correctness.  (A missing file is not diagnosed at all; callers
    /// skip nonexistent paths.)
    pub fn check_file(path: &Path) -> Vec<crate::check::Diagnostic> {
        use crate::check::{Diagnostic, Span};
        let disp = path.display().to_string();
        let hint = "delete the cache file; the next report cold-starts \
                    safely";
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) => {
                return vec![Diagnostic::warning(
                    "TP013",
                    disp,
                    format!("unreadable ({e}) — skipped"),
                )]
            }
        };
        let doc = match Json::from_slice(&bytes) {
            Ok(doc) => doc,
            Err(e) => {
                return vec![Diagnostic::warning(
                    "TP021",
                    disp,
                    format!("invalid JSON: {}", e.message),
                )
                .with_span(Span { start: e.offset, len: 1 })
                .with_hint(hint)]
            }
        };
        match doc.get("version").and_then(Json::as_u64) {
            None => {
                return vec![Diagnostic::warning(
                    "TP021",
                    disp,
                    "cache document has no version — it will cold-start",
                )
                .with_hint(hint)]
            }
            Some(v) if v != CACHE_VERSION => {
                return vec![Diagnostic::warning(
                    "TP020",
                    disp,
                    format!(
                        "cache version {v} does not match this build's \
                         version {CACHE_VERSION} — it will cold-start"
                    ),
                )]
            }
            Some(_) => {}
        }
        if decode_cache(&bytes).is_none() {
            return vec![Diagnostic::warning(
                "TP021",
                disp,
                "malformed cache entry — the whole file will cold-start",
            )
            .with_hint(hint)];
        }
        Vec::new()
    }

    /// Persist to `path`, creating parent directories.  Streams
    /// straight into one pre-sized buffer (byte-identical to the
    /// `to_json().to_string_pretty()` tree path — pinned by a test).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        // ~1.6 KB per pretty-printed single-region entry.
        let mut w =
            JsonWriter::with_capacity(256 + self.entries.len() * 1600, true);
        w.begin_obj();
        w.key("version");
        w.num(CACHE_VERSION as f64);
        w.key("entries");
        w.begin_obj();
        for (path_key, e) in &self.entries {
            w.key(path_key);
            w.begin_obj();
            w.key("hash");
            w.str_val(&e.hash);
            w.key("run");
            e.run.write_to(&mut w);
            w.end_obj();
        }
        w.end_obj();
        w.end_obj();
        w.newline();
        std::fs::write(path, w.into_string())
            .with_context(|| format!("writing cache {}", path.display()))
    }
}

/// Streaming decode of a cache file; `None` means cold start.
fn decode_cache(bytes: &[u8]) -> Option<MetricsCache> {
    let mut r = JsonReader::new(bytes);
    match r.next().ok()? {
        Event::ObjStart => {}
        _ => return None,
    }
    let mut version: Option<u64> = None;
    let mut entries: BTreeMap<String, Entry> = BTreeMap::new();
    loop {
        match r.next().ok()? {
            Event::ObjEnd => break,
            Event::Key(k) => match k.as_ref() {
                "version" => version = r.u64_opt().ok()?,
                "entries" => match r.next().ok()? {
                    Event::ObjStart => loop {
                        match r.next().ok()? {
                            Event::ObjEnd => break,
                            Event::Key(path_key) => {
                                let path_key = path_key.into_owned();
                                entries
                                    .insert(path_key, decode_entry(&mut r)?);
                            }
                            _ => unreachable!("object events"),
                        }
                    },
                    _ => return None,
                },
                _ => r.skip_value().ok()?,
            },
            _ => unreachable!("object events"),
        }
    }
    r.finish().ok()?;
    // The version key may appear anywhere in the file; validate after
    // the full pass, like the order-insensitive tree decoder did.
    (version == Some(CACHE_VERSION)).then_some(MetricsCache { entries })
}

/// Decode one `{"hash": .., "run": ..}` entry; `None` → cold start.
fn decode_entry(r: &mut JsonReader<'_>) -> Option<Entry> {
    match r.next().ok()? {
        Event::ObjStart => {}
        _ => return None,
    }
    let mut hash: Option<String> = None;
    let mut run: Option<RunMetrics> = None;
    loop {
        match r.next().ok()? {
            Event::ObjEnd => break,
            Event::Key(k) => match k.as_ref() {
                "hash" => {
                    hash = Some(r.str_opt().ok()??.into_owned());
                }
                "run" => run = Some(RunMetrics::from_events(r).ok()?),
                _ => r.skip_value().ok()?,
            },
            _ => unreachable!("object events"),
        }
    }
    Some(Entry { hash: hash?, run: run? })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::{ProcStats, RegionData, RunData};
    use crate::util::fs::TempDir;

    fn run_metrics(source: &str, useful: f64) -> RunMetrics {
        let data = RunData {
            dlb_version: "t".into(),
            app: "a".into(),
            machine: "mn5".into(),
            timestamp: 100,
            ranks: 1,
            threads: 2,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 1.0,
                visits: 1,
                procs: vec![ProcStats {
                    rank: 0,
                    elapsed_s: 1.0,
                    useful_s: useful,
                    ..Default::default()
                }],
            }],
            git: None,
        };
        RunMetrics::from_run(&data, source)
    }

    #[test]
    fn lookup_validates_content_hash() {
        let mut c = MetricsCache::new();
        c.insert("exp/a.json", "aaaa", run_metrics("exp/a.json", 1.5));
        assert!(c.lookup("exp/a.json", "aaaa").is_some());
        assert!(c.lookup("exp/a.json", "bbbb").is_none(), "stale content");
        assert!(c.lookup("exp/other.json", "aaaa").is_none());
    }

    #[test]
    fn save_load_roundtrip() {
        let td = TempDir::new("cache").unwrap();
        let path = td.path().join("out/.talp-cache.json");
        let mut c = MetricsCache::new();
        c.insert("exp/a.json", "0123abcd", run_metrics("exp/a.json", 1.5));
        c.insert("exp/b.json", "ffff0000", run_metrics("exp/b.json", 0.7));
        c.save(&path).unwrap();
        let back = MetricsCache::load(&path);
        assert_eq!(back.len(), 2);
        let hit = back.lookup("exp/a.json", "0123abcd").unwrap();
        assert_eq!(hit.source, "exp/a.json");
        let m = hit.region("Global").unwrap().metrics;
        let orig = c.lookup("exp/a.json", "0123abcd").unwrap();
        assert_eq!(m, orig.region("Global").unwrap().metrics);
    }

    #[test]
    fn corrupt_or_missing_cache_is_cold_start() {
        let td = TempDir::new("cache2").unwrap();
        assert!(MetricsCache::load(&td.path().join("nope.json")).is_empty());
        let bad = td.path().join("bad.json");
        std::fs::write(&bad, "{not json").unwrap();
        assert!(MetricsCache::load(&bad).is_empty());
        // Version mismatch discards too — newer...
        std::fs::write(&bad, r#"{"version": 999, "entries": {}}"#).unwrap();
        assert!(MetricsCache::load(&bad).is_empty());
        // ...and older: a pre-gate v1 cache self-invalidates wholesale.
        std::fs::write(&bad, r#"{"version": 1, "entries": {}}"#).unwrap();
        assert!(MetricsCache::load(&bad).is_empty());
    }

    #[test]
    fn saved_cache_carries_current_version() {
        let td = TempDir::new("cache-ver").unwrap();
        let path = td.path().join(".talp-cache.json");
        let mut c = MetricsCache::new();
        c.insert("a.json", "aa", run_metrics("a.json", 1.0));
        c.save(&path).unwrap();
        let j = crate::util::json::Json::parse(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        assert_eq!(j.num_or("version", 0.0) as u64, CACHE_VERSION);
        assert_eq!(CACHE_VERSION, 2);
        // The same file with entries reloads fine at v2...
        assert_eq!(MetricsCache::load(&path).len(), 1);
        // ...but stamped as v1 (a pre-gate cache) its entries are
        // discarded wholesale, not migrated.
        let text = std::fs::read_to_string(&path).unwrap();
        let downgraded = text.replace("\"version\": 2", "\"version\": 1");
        assert_ne!(text, downgraded, "version field must be present");
        std::fs::write(&path, downgraded).unwrap();
        assert!(MetricsCache::load(&path).is_empty());
    }

    #[test]
    fn streamed_save_matches_tree_serialization() {
        // The pre-sized streaming writer must emit the exact bytes the
        // old tree path did — cache files stay byte-reproducible
        // across builds.
        let td = TempDir::new("cache-stream").unwrap();
        let path = td.path().join(".talp-cache.json");
        let mut c = MetricsCache::new();
        c.insert("exp/a.json", "0123abcd", run_metrics("exp/a.json", 1.5));
        c.insert("exp/β.json", "ffff0000", run_metrics("exp/β.json", 0.7));
        c.save(&path).unwrap();
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            c.to_json().to_string_pretty()
        );
    }

    #[test]
    fn byte_level_corruption_is_cold_start() {
        let td = TempDir::new("cache-bytes").unwrap();
        let path = td.path().join(".talp-cache.json");
        let mut c = MetricsCache::new();
        c.insert("a.json", "aa", run_metrics("a.json", 1.0));
        c.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // Truncated mid-file (killed writer): cold start.
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(MetricsCache::load(&path).is_empty());

        // Invalid UTF-8 spliced into a string: cold start, no panic.
        let mut bad = good.clone();
        let pos = bad.windows(2).position(|w| w == b"aa").unwrap();
        bad[pos] = 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(MetricsCache::load(&path).is_empty());

        // A single malformed entry discards the file wholesale (the
        // cache is all-or-nothing; cold starts are always safe).
        let text = String::from_utf8(good.clone()).unwrap();
        let broken = text.replace("\"hash\"", "\"not_hash\"");
        assert_ne!(text, broken);
        std::fs::write(&path, broken).unwrap();
        assert!(MetricsCache::load(&path).is_empty());

        // And the untouched bytes still load.
        std::fs::write(&path, &good).unwrap();
        assert_eq!(MetricsCache::load(&path).len(), 1);
    }

    #[test]
    fn retain_drops_vanished_paths() {
        let mut c = MetricsCache::new();
        c.insert("keep.json", "aa", run_metrics("keep.json", 1.0));
        c.insert("gone.json", "bb", run_metrics("gone.json", 1.0));
        c.retain_paths(|p| p == "keep.json");
        assert_eq!(c.len(), 1);
        assert!(c.lookup("keep.json", "aa").is_some());
    }

    #[test]
    fn serialization_is_deterministic() {
        let mut a = MetricsCache::new();
        let mut b = MetricsCache::new();
        // Insert in different orders; BTreeMap canonicalizes.
        a.insert("x.json", "11", run_metrics("x.json", 1.0));
        a.insert("b.json", "22", run_metrics("b.json", 2.0));
        b.insert("b.json", "22", run_metrics("b.json", 2.0));
        b.insert("x.json", "11", run_metrics("x.json", 1.0));
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty()
        );
    }
}
