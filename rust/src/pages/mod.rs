//! TALP-Pages proper: the paper's contribution.  Scans the Fig. 2
//! folder structure, computes the POP factors, and renders the static
//! HTML report (scaling-efficiency tables, time-evolution plots, SVG
//! badges) that in-repository pages hosting serves.

pub mod badge;
pub mod cache;
pub mod detect;
pub mod html;
pub mod report;
pub mod scanner;
pub mod svgplot;
pub mod table_html;
pub mod timeseries;

pub use cache::MetricsCache;
pub use report::{generate, ReportOptions, ReportSummary};
pub use scanner::{
    scan, scan_metrics, Experiment, MetricExperiment, MetricScan, ScanResult,
};
