//! TALP-Pages data layer: the folder scanner (paper Fig. 2), the
//! content-hash metrics cache, change detection, time series and the
//! HTML/SVG rendering primitives.
//!
//! The staged pipeline that ties these together — scan, analyze, emit —
//! lives in [`crate::session`]; this module provides the pieces it
//! composes (and the lower-level `scan`/`scan_metrics` entry points for
//! tools that want raw histories).

pub mod badge;
pub mod cache;
pub mod detect;
pub mod html;
pub mod scanner;
pub mod svgplot;
pub mod table_html;
pub mod timeseries;

pub use cache::MetricsCache;
pub use scanner::{
    scan, scan_metrics, Experiment, MetricExperiment, MetricScan, ScanResult,
};
