//! SVG badges (paper: "a SVG badge displaying the parallel efficiency
//! for each resource configuration") — shields.io-style, self-contained.

/// Color scale for efficiency badges (POP convention: green is fine,
/// yellow needs a look, red is a problem).
pub fn efficiency_color(value: f64) -> &'static str {
    if value >= 0.8 {
        "#4c1" // bright green
    } else if value >= 0.6 {
        "#dfb317" // yellow
    } else {
        "#e05d44" // red
    }
}

/// Render a two-segment badge: `label | value`.
pub fn render(label: &str, value_text: &str, color: &str) -> String {
    // Approximate text width: 6.5 px per char + padding (the DejaVu
    // metrics shields.io uses; fine for monospace-ish labels).
    let lw = (label.len() as f64 * 6.5 + 12.0).ceil();
    let vw = (value_text.len() as f64 * 6.5 + 12.0).ceil();
    let total = lw + vw;
    format!(
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{total}" height="20" role="img" aria-label="{label}: {value_text}">
  <linearGradient id="s" x2="0" y2="100%">
    <stop offset="0" stop-color="#bbb" stop-opacity=".1"/>
    <stop offset="1" stop-opacity=".1"/>
  </linearGradient>
  <clipPath id="r"><rect width="{total}" height="20" rx="3" fill="#fff"/></clipPath>
  <g clip-path="url(#r)">
    <rect width="{lw}" height="20" fill="#555"/>
    <rect x="{lw}" width="{vw}" height="20" fill="{color}"/>
    <rect width="{total}" height="20" fill="url(#s)"/>
  </g>
  <g fill="#fff" text-anchor="middle" font-family="Verdana,Geneva,DejaVu Sans,sans-serif" font-size="11">
    <text x="{lx}" y="14">{label}</text>
    <text x="{vx}" y="14">{value_text}</text>
  </g>
</svg>
"##,
        lx = lw / 2.0,
        vx = lw + vw / 2.0,
    )
}

/// The regression-gate badge: overall verdict of the latest gate run.
pub fn gate_badge(status: crate::gate::GateStatus) -> String {
    use crate::gate::GateStatus;
    let (text, color) = match status {
        GateStatus::Pass => ("passing", "#4c1"),
        GateStatus::Warn => ("warning", "#dfb317"),
        GateStatus::Fail => ("failing", "#e05d44"),
    };
    render("perf gate", text, color)
}

/// The parallel-efficiency badge for one resource configuration.
pub fn parallel_efficiency_badge(
    region: &str,
    config: &str,
    efficiency: f64,
) -> String {
    render(
        &format!("PE {region} {config}"),
        &format!("{efficiency:.2}"),
        efficiency_color(efficiency),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn color_bands() {
        assert_eq!(efficiency_color(0.95), "#4c1");
        assert_eq!(efficiency_color(0.7), "#dfb317");
        assert_eq!(efficiency_color(0.3), "#e05d44");
    }

    #[test]
    fn badge_is_valid_svgish() {
        let svg = parallel_efficiency_badge("timestep", "8x56", 0.83);
        assert!(svg.starts_with("<svg"));
        assert!(svg.contains("0.83"));
        assert!(svg.contains("PE timestep 8x56"));
        assert!(svg.contains("#4c1"));
        assert!(svg.trim_end().ends_with("</svg>"));
    }

    #[test]
    fn gate_badge_states() {
        use crate::gate::GateStatus;
        let pass = gate_badge(GateStatus::Pass);
        assert!(pass.contains("perf gate"));
        assert!(pass.contains("passing"));
        assert!(pass.contains("#4c1"));
        assert!(gate_badge(GateStatus::Warn).contains("#dfb317"));
        assert!(gate_badge(GateStatus::Fail).contains("failing"));
    }

    #[test]
    fn width_scales_with_text() {
        let short = render("a", "1", "#4c1");
        let long = render("a-very-long-label", "1", "#4c1");
        let w = |svg: &str| -> f64 {
            let i = svg.find("width=\"").unwrap() + 7;
            svg[i..].split('"').next().unwrap().parse().unwrap()
        };
        assert!(w(&long) > w(&short));
    }
}
