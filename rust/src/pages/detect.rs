//! Automated regression / improvement detection over a configuration's
//! history — the paper's promise ("detect performance degradation early
//! in the development process ... detect and explain a performance
//! improvement") as an API instead of an eyeball.
//!
//! For every (region, consecutive-commit pair) the detector compares
//! elapsed time against the noise floor of the preceding window, and
//! when a change fires it ranks the POP factors by their relative
//! movement to produce the *explanation* (Fig. 7: "OpenMP serialization
//! efficiency is responsible").

use crate::talp::RunData;

use super::timeseries::{self, TimeSeries};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChangeKind {
    Regression,
    Improvement,
}

/// One detected change.
#[derive(Debug, Clone)]
pub struct Finding {
    pub region: String,
    pub config: String,
    /// Index into the history (the run where the change appears).
    pub at_index: usize,
    pub commit: Option<String>,
    pub kind: ChangeKind,
    /// elapsed(after) / elapsed(before).
    pub factor: f64,
    /// The POP factor that moved the most, with its before/after values
    /// — empty when the change is unexplained (pure compute speed).
    pub explanation: Option<(String, f64, f64)>,
}

impl Finding {
    pub fn describe(&self) -> String {
        let verb = match self.kind {
            ChangeKind::Regression => "slowed down",
            ChangeKind::Improvement => "sped up",
        };
        let expl = match &self.explanation {
            Some((name, b, a)) => {
                format!("; explained by {name}: {b:.2} -> {a:.2}")
            }
            None => "; counters moved with it (compute-rate change)".into(),
        };
        format!(
            "region '{}' @ {} {} by x{:.2} at {}{}",
            self.region,
            self.config,
            verb,
            if self.factor >= 1.0 { self.factor } else { 1.0 / self.factor },
            self.commit.as_deref().unwrap_or("(no commit)"),
            expl
        )
    }
}

/// Detection options.
#[derive(Debug, Clone)]
pub struct DetectOptions {
    /// Minimum relative change in elapsed time to fire (e.g. 0.15).
    pub threshold: f64,
    /// Multiples of the trailing noise (stddev/mean) the change must
    /// also exceed — suppresses findings on noisy platforms.
    pub noise_gate: f64,
}

impl Default for DetectOptions {
    fn default() -> DetectOptions {
        DetectOptions { threshold: 0.15, noise_gate: 4.0 }
    }
}

/// Efficiency metrics eligible as explanations, with display names.
const EXPLAIN_METRICS: &[(&str, &str)] = &[
    ("parallel_efficiency", "Parallel efficiency"),
    ("mpi_parallel_efficiency", "MPI Parallel efficiency"),
    ("mpi_load_balance", "MPI Load balance"),
    ("mpi_communication_efficiency", "MPI Communication efficiency"),
    ("omp_load_balance", "OpenMP Load balance"),
    ("omp_scheduling_efficiency", "OpenMP Scheduling efficiency"),
    ("omp_serialization_efficiency", "OpenMP Serialization efficiency"),
];

/// Shared noise-floor test (used by this detector and by
/// `gate::engine`): does `after` escape the trailing window's noise?
/// A window that is too short or perfectly flat cannot establish a
/// noise floor, so the change counts as exceeding it.
pub fn exceeds_noise_floor(window: &[f64], after: f64, sigma: f64) -> bool {
    if window.len() < 2 {
        return true;
    }
    let mean = crate::util::stats::mean(window);
    let sd = {
        let mut w = crate::util::stats::Welford::new();
        for v in window {
            w.push(*v);
        }
        w.stddev()
    };
    sd <= 0.0 || (after - mean).abs() >= sigma * sd
}

/// Scan one configuration's history (oldest first) for changes.
pub fn detect(
    config: &str,
    history: &[&RunData],
    opts: &DetectOptions,
) -> Vec<Finding> {
    detect_series(&timeseries::build(config, history, &[]), config, opts)
}

/// Run the detector over an already-built [`TimeSeries`] (the
/// incremental report engine builds one series per configuration from
/// cached metrics and reuses it for plots and findings alike).
pub fn detect_series(
    ts: &TimeSeries,
    config: &str,
    opts: &DetectOptions,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for region in ts.regions() {
        findings.extend(detect_region(ts, &region, config, opts));
    }
    findings
}

fn detect_region(
    ts: &TimeSeries,
    region: &str,
    config: &str,
    opts: &DetectOptions,
) -> Vec<Finding> {
    let elapsed = ts.metric(region, "elapsed");
    let mut out = Vec::new();
    for i in 1..elapsed.len() {
        let before = elapsed[i - 1].1;
        let after = elapsed[i].1;
        if before <= 0.0 {
            continue;
        }
        let rel = (after - before) / before;
        if rel.abs() < opts.threshold {
            continue;
        }
        // Noise gate over the trailing window (up to 4 points).
        let lo = i.saturating_sub(4);
        let window: Vec<f64> =
            elapsed[lo..i].iter().map(|(_, v)| *v).collect();
        if !exceeds_noise_floor(&window, after, opts.noise_gate) {
            continue; // within platform noise
        }
        let kind = if rel > 0.0 {
            ChangeKind::Regression
        } else {
            ChangeKind::Improvement
        };
        // Counters flat?  Then some efficiency must explain it.
        let ipc = ts.metric(region, "ipc");
        let insn = ts.metric(region, "instructions");
        let counters_flat = value_flat(&ipc, i) && value_flat(&insn, i);
        let explanation = if counters_flat {
            best_explanation(ts, region, i)
        } else {
            None
        };
        out.push(Finding {
            region: region.to_string(),
            config: config.to_string(),
            at_index: i,
            commit: ts.points[i].commit.clone(),
            kind,
            factor: after / before,
            explanation,
        });
    }
    out
}

fn value_flat(series: &[(i64, f64)], i: usize) -> bool {
    if i == 0 || i >= series.len() {
        return true;
    }
    let (b, a) = (series[i - 1].1, series[i].1);
    if b.abs() < 1e-12 {
        return a.abs() < 1e-12;
    }
    ((a - b) / b).abs() < 0.15
}

fn best_explanation(
    ts: &TimeSeries,
    region: &str,
    i: usize,
) -> Option<(String, f64, f64)> {
    let mut best: Option<(String, f64, f64, f64)> = None;
    for (id, label) in EXPLAIN_METRICS {
        let series = ts.metric(region, id);
        if i >= series.len() {
            continue;
        }
        let (b, a) = (series[i - 1].1, series[i].1);
        let delta = (a - b).abs();
        if delta < 0.05 {
            continue;
        }
        if best.as_ref().map(|(_, _, _, d)| delta > *d).unwrap_or(true) {
            best = Some((label.to_string(), b, a, delta));
        }
    }
    best.map(|(n, b, a, _)| (n, b, a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::talp::GitMeta;

    fn history(versions: &[CodeVersion]) -> Vec<RunData> {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 14);
        versions
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let mut app = Genex::salpha(2, *v);
                app.timesteps = 2;
                let (mut d, _) =
                    run_with_talp(&app, &machine, &res, 50 + i as u64, 0);
                d.git = Some(GitMeta {
                    commit: format!("commit{i:02}"),
                    branch: "main".into(),
                    commit_timestamp: 1000 + i as i64,
                    message: String::new(),
                });
                d
            })
            .collect()
    }

    #[test]
    fn detects_and_explains_the_fig7_fix() {
        let runs = history(&[
            CodeVersion::buggy(),
            CodeVersion::buggy(),
            CodeVersion::buggy(),
            CodeVersion::fixed(),
            CodeVersion::fixed(),
        ]);
        let refs: Vec<&RunData> = runs.iter().collect();
        let findings = detect("2x14", &refs, &DetectOptions::default());
        let fix = findings
            .iter()
            .find(|f| {
                f.region == "initialize"
                    && f.kind == ChangeKind::Improvement
            })
            .expect("fix finding");
        assert_eq!(fix.at_index, 3);
        assert_eq!(fix.commit.as_deref(), Some("commit03"));
        assert!(fix.factor < 0.7, "{}", fix.factor);
        let (name, b, a) = fix.explanation.as_ref().expect("explained");
        assert_eq!(name, "OpenMP Serialization efficiency");
        assert!(*a > *b + 0.15);
        assert!(fix.describe().contains("sped up"));
        // timestep must NOT fire.
        assert!(findings.iter().all(|f| f.region != "timestep"));
    }

    #[test]
    fn detects_plain_regression_without_false_explanation() {
        let runs = history(&[
            CodeVersion::fixed(),
            CodeVersion::fixed(),
            CodeVersion {
                serialization_bug: false,
                compute_slowdown: 1.6,
            },
        ]);
        let refs: Vec<&RunData> = runs.iter().collect();
        let findings = detect("2x14", &refs, &DetectOptions::default());
        let reg = findings
            .iter()
            .find(|f| {
                f.region == "Global" && f.kind == ChangeKind::Regression
            })
            .expect("regression");
        // A compute slowdown moves instructions/IPC, so it must not be
        // "explained" by an efficiency factor.
        assert!(reg.explanation.is_none(), "{:?}", reg.explanation);
        assert!(reg.describe().contains("slowed down"));
    }

    #[test]
    fn quiet_history_has_no_findings() {
        let runs = history(&[
            CodeVersion::fixed(),
            CodeVersion::fixed(),
            CodeVersion::fixed(),
            CodeVersion::fixed(),
        ]);
        let refs: Vec<&RunData> = runs.iter().collect();
        let findings = detect("2x14", &refs, &DetectOptions::default());
        assert!(findings.is_empty(), "{findings:?}");
    }

    // ---- explanation ranking on hand-built series ----
    // The simulator tests above exercise end-to-end behaviour; these
    // pin the *ranking* rule itself: the POP factor with the largest
    // absolute movement wins, sub-0.05 movers are ignored, and ties
    // resolve to the first metric in the hierarchy order.

    use super::super::timeseries::{RegionPoint, TimePoint, TimeSeries};

    fn point(
        elapsed: f64,
        factors: &[(&str, f64)],
        commit: &str,
        ts: i64,
    ) -> TimePoint {
        let get = |key: &str, default: f64| {
            factors
                .iter()
                .find(|(k, _)| *k == key)
                .map(|(_, v)| *v)
                .unwrap_or(default)
        };
        TimePoint {
            timestamp: ts,
            commit: Some(commit.to_string()),
            branch: Some("main".to_string()),
            regions: vec![RegionPoint {
                region: "solve".to_string(),
                elapsed_s: elapsed,
                useful_ipc: 2.0,
                frequency_ghz: 2.5,
                instructions: 1e9,
                parallel_efficiency: get("parallel_efficiency", 0.8),
                mpi_parallel_efficiency: get("mpi_parallel_efficiency", 0.9),
                omp_parallel_efficiency: get("omp_parallel_efficiency", 0.9),
                omp_load_balance: get("omp_load_balance", 0.9),
                omp_scheduling_efficiency: get(
                    "omp_scheduling_efficiency",
                    0.95,
                ),
                omp_serialization_efficiency: get(
                    "omp_serialization_efficiency",
                    0.97,
                ),
                mpi_load_balance: get("mpi_load_balance", 0.92),
                mpi_communication_efficiency: get(
                    "mpi_communication_efficiency",
                    0.94,
                ),
            }],
        }
    }

    fn series_of(points: Vec<TimePoint>) -> TimeSeries {
        TimeSeries { config: "2x8".to_string(), points }
    }

    #[test]
    fn explanation_picks_largest_factor_movement() {
        // Elapsed doubles with flat counters; two factors move, the
        // OpenMP load balance by far the most.
        let ts = series_of(vec![
            point(
                10.0,
                &[("omp_load_balance", 0.90), ("mpi_load_balance", 0.92)],
                "before00",
                1000,
            ),
            point(
                20.0,
                &[("omp_load_balance", 0.50), ("mpi_load_balance", 0.82)],
                "after000",
                2000,
            ),
        ]);
        let findings =
            detect_series(&ts, "2x8", &DetectOptions::default());
        assert_eq!(findings.len(), 1, "{findings:?}");
        let f = &findings[0];
        assert_eq!(f.kind, ChangeKind::Regression);
        let (name, b, a) = f.explanation.as_ref().expect("explained");
        assert_eq!(name, "OpenMP Load balance");
        assert_eq!((*b, *a), (0.90, 0.50));
    }

    #[test]
    fn explanation_ignores_sub_threshold_movers() {
        // Every factor moves by < 0.05: the change stays unexplained
        // even though elapsed fires.
        let ts = series_of(vec![
            point(10.0, &[("omp_load_balance", 0.90)], "before00", 1000),
            point(20.0, &[("omp_load_balance", 0.87)], "after000", 2000),
        ]);
        let findings =
            detect_series(&ts, "2x8", &DetectOptions::default());
        assert_eq!(findings.len(), 1);
        assert!(findings[0].explanation.is_none(), "{:?}", findings[0]);
    }

    #[test]
    fn explanation_tie_breaks_on_hierarchy_order() {
        // Two factors move by exactly the same delta; the strict
        // greater-than keeps the first in EXPLAIN_METRICS order
        // (MPI Load balance ranks before OpenMP Load balance).
        let ts = series_of(vec![
            point(
                10.0,
                &[("mpi_load_balance", 0.90), ("omp_load_balance", 0.90)],
                "before00",
                1000,
            ),
            point(
                20.0,
                &[("mpi_load_balance", 0.60), ("omp_load_balance", 0.60)],
                "after000",
                2000,
            ),
        ]);
        let findings =
            detect_series(&ts, "2x8", &DetectOptions::default());
        assert_eq!(findings.len(), 1);
        let (name, _, _) =
            findings[0].explanation.as_ref().expect("explained");
        assert_eq!(name, "MPI Load balance");
    }

    #[test]
    fn noise_floor_helper_contract() {
        // Short or flat windows cannot suppress.
        assert!(exceeds_noise_floor(&[], 10.0, 4.0));
        assert!(exceeds_noise_floor(&[10.0], 99.0, 4.0));
        assert!(exceeds_noise_floor(&[10.0, 10.0, 10.0], 10.1, 4.0));
        // A jittery window absorbs a change inside sigma * sd.
        assert!(!exceeds_noise_floor(&[8.0, 12.0, 8.0, 12.0], 13.0, 4.0));
        // ...but not one far outside it.
        assert!(exceeds_noise_floor(&[8.0, 12.0, 8.0, 12.0], 30.0, 4.0));
    }

    #[test]
    fn threshold_suppresses_small_changes() {
        let runs = history(&[
            CodeVersion::fixed(),
            CodeVersion {
                serialization_bug: false,
                compute_slowdown: 1.05, // 5% — under the 15% threshold
            },
        ]);
        let refs: Vec<&RunData> = runs.iter().collect();
        let findings = detect("2x14", &refs, &DetectOptions::default());
        assert!(findings.is_empty(), "{findings:?}");
    }
}
