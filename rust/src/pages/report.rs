//! The `talp ci-report` engine: scan a Fig. 2 folder, emit the full
//! static site — index, one page per experiment (scaling-efficiency
//! tables + time-evolution plots), and SVG badges.
//!
//! # The parallel, incremental engine
//!
//! Report generation is the paper's Table 2 hot path: it runs inside
//! every CI pipeline, so its latency is a budget, not a nicety.  Two
//! mechanisms keep it flat as histories grow:
//!
//! 1. **Content-hash cache** (`pages::cache`): every artifact's reduced
//!    [`pop::RunMetrics`] is persisted in `.talp-cache.json` keyed by
//!    the file's FNV-1a-64 content hash.  On a warm run — the common CI
//!    case, where only the newest pipeline's files are new — unchanged
//!    artifacts skip JSON parse *and* POP reduction entirely
//!    ([`ReportSummary::cache_hits`] counts them).
//! 2. **Worker-pool fan-out** (`util::par`): artifact parsing/reduction
//!    and per-experiment page rendering both run on a scoped-thread
//!    pool sized by [`ReportOptions::jobs`] (0 = auto).  Results merge
//!    in deterministic experiment order, so `--jobs 1` and `--jobs N`
//!    produce byte-identical output directories.
//!
//! File writes stay on the calling thread, in scan order.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::pop;
use crate::util::par::parallel_map;
use crate::util::timefmt;

use super::badge;
use super::cache::{MetricsCache, CACHE_FILE_NAME};
use super::detect::{self, DetectOptions};
use super::html;
use super::scanner::{self, MetricExperiment};
use super::svgplot::{self, esc, Series};
use super::table_html;
use super::timeseries;

/// Report options (mirrors the paper's CLI flags).
#[derive(Debug, Clone, Default)]
pub struct ReportOptions {
    /// Regions to build tables/plots for (empty = every region found).
    pub regions: Vec<String>,
    /// Region whose parallel efficiency feeds the badges (default the
    /// implicit whole-execution region).
    pub region_for_badge: Option<String>,
    /// Worker threads for parsing and page rendering; 0 = auto
    /// (available parallelism, capped at 16).  Output is byte-identical
    /// for every value.
    pub jobs: usize,
    /// Metrics-cache location; None = `<out_dir>/.talp-cache.json`.
    /// The in-process CI engine points this at a path that outlives
    /// per-pipeline work directories.
    pub cache_path: Option<PathBuf>,
    /// Regression-gate policy: when set, the scan the report just used
    /// is also folded into a [`crate::gate::GateVerdict`] — written as
    /// `gate.json`/`gate.md`/`gate.xml` next to the pages, rendered as
    /// a `badges/gate.svg` badge and an index section, and returned in
    /// [`ReportSummary::gate`].  No extra artifact parsing happens.
    pub gate: Option<crate::gate::GatePolicy>,
}

/// What was generated.
#[derive(Debug)]
pub struct ReportSummary {
    pub experiments: usize,
    pub pages_written: usize,
    pub badges_written: usize,
    pub warnings: Vec<String>,
    /// Artifacts served from the metrics cache (not re-parsed).
    pub cache_hits: usize,
    /// Artifacts parsed + reduced this run.
    pub cache_misses: usize,
    /// Regression-gate verdict (when [`ReportOptions::gate`] was set).
    pub gate: Option<crate::gate::GateVerdict>,
}

/// One experiment's render product (built on a worker, written by the
/// caller in deterministic order).
struct RenderedExperiment {
    file: String,
    body: String,
    /// (out_dir-relative path, svg content).
    badges: Vec<(String, String)>,
}

/// Generate the full report from `input` into `out_dir`.
pub fn generate(
    input: &Path,
    out_dir: &Path,
    opts: &ReportOptions,
) -> Result<ReportSummary> {
    let cache_path = opts
        .cache_path
        .clone()
        .unwrap_or_else(|| out_dir.join(CACHE_FILE_NAME));
    let mut cache = MetricsCache::load(&cache_path);
    let scan = scanner::scan_metrics(input, &mut cache, opts.jobs)?;
    std::fs::create_dir_all(out_dir.join("badges"))
        .with_context(|| format!("creating {}", out_dir.display()))?;

    // ---- regression gate (on the scan we already have) ----
    let gate_verdict = opts
        .gate
        .as_ref()
        .map(|policy| crate::gate::evaluate(&scan, policy));
    let mut gate_badges = 0usize;
    if let Some(v) = &gate_verdict {
        crate::gate::write_outputs(v, out_dir)?;
        std::fs::write(
            out_dir.join("badges/gate.svg"),
            badge::gate_badge(v.status),
        )?;
        gate_badges += 1;
    }

    let rendered: Vec<RenderedExperiment> =
        parallel_map(&scan.experiments, opts.jobs, |exp| {
            render_experiment(exp, opts)
        });

    let mut pages = 0usize;
    let mut badges = 0usize;
    let mut index_items = String::new();
    for (exp, r) in scan.experiments.iter().zip(rendered) {
        std::fs::write(
            out_dir.join(&r.file),
            html::page(&format!("TALP report — {}", exp.id), &r.body),
        )?;
        pages += 1;
        for (name, svg) in &r.badges {
            std::fs::write(out_dir.join(name), svg)?;
            badges += 1;
        }
        index_items.push_str(&format!(
            "<li><a href=\"{}\">{}</a> — {} configs, {} runs</li>\n",
            r.file,
            esc(&exp.id),
            exp.configs().len(),
            exp.runs.len()
        ));
    }

    let mut index_body = String::from("<h1>TALP-Pages performance report</h1>\n");
    if let Some(v) = &gate_verdict {
        let cls = match v.status {
            crate::gate::GateStatus::Pass => "gate-pass",
            crate::gate::GateStatus::Warn => "gate-warn",
            crate::gate::GateStatus::Fail => "gate-fail",
        };
        index_body.push_str(&format!(
            "<div class=\"gate {cls}\"><b>Performance gate: {}</b> — {}\n",
            v.status.label(),
            esc(&v.summary_line())
        ));
        let notable: Vec<_> = v.notable().collect();
        if !notable.is_empty() {
            index_body.push_str("<ul>\n");
            for c in notable {
                index_body.push_str(&format!(
                    "<li class=\"{}\">[{}] {} / {} / {} — {}</li>\n",
                    c.outcome.id(),
                    c.outcome.id().to_uppercase(),
                    esc(&c.experiment),
                    esc(&c.config),
                    esc(&c.region),
                    esc(&c.detail)
                ));
            }
            index_body.push_str("</ul>\n");
        }
        index_body.push_str(
            "<p><a href=\"gate.md\">gate.md</a> · \
             <a href=\"gate.json\">gate.json</a> · \
             <a href=\"gate.xml\">gate.xml</a></p></div>\n",
        );
    }
    if !scan.warnings.is_empty() {
        index_body.push_str("<div class=\"warn\"><b>Warnings:</b><ul>");
        for w in &scan.warnings {
            index_body.push_str(&format!("<li>{}</li>", esc(w)));
        }
        index_body.push_str("</ul></div>\n");
    }
    index_body.push_str(&format!(
        "<p>{} experiment(s) found under <code>{}</code>.</p>\n<ul class=\"exp-list\">\n{index_items}</ul>\n",
        scan.experiments.len(),
        esc(&input.display().to_string()),
    ));
    std::fs::write(
        out_dir.join("index.html"),
        html::page("TALP-Pages report", &index_body),
    )?;
    pages += 1;

    cache.save(&cache_path)?;

    Ok(ReportSummary {
        experiments: scan.experiments.len(),
        pages_written: pages,
        badges_written: badges + gate_badges,
        warnings: scan.warnings,
        cache_hits: scan.cache_hits,
        cache_misses: scan.cache_misses,
        gate: gate_verdict,
    })
}

fn slug(id: &str) -> String {
    id.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Render one experiment's page body and badges (pure — no IO).
fn render_experiment(
    exp: &MetricExperiment,
    opts: &ReportOptions,
) -> RenderedExperiment {
    let mut body = format!("<h1>{}</h1>\n", esc(&exp.id));
    let latest = exp.latest_per_config();
    let badge_region = opts
        .region_for_badge
        .clone()
        .unwrap_or_else(|| "Global".to_string());

    // ---- badges ----
    let mut badges = Vec::new();
    body.push_str("<div class=\"badges\">\n");
    for run in &latest {
        let Some(reg) = run.region(&badge_region) else {
            continue;
        };
        let cfg = run.resources().label();
        let svg = badge::parallel_efficiency_badge(
            &badge_region,
            &cfg,
            reg.metrics.parallel_efficiency,
        );
        body.push_str(&svg);
        badges.push((format!("badges/{}__{}.svg", slug(&exp.id), cfg), svg));
    }
    body.push_str("</div>\n");

    // ---- scaling-efficiency tables ----
    let all_regions = exp.regions();
    let table_regions: Vec<String> = if opts.regions.is_empty() {
        all_regions.clone()
    } else {
        all_regions
            .iter()
            .filter(|r| {
                *r == "Global" || opts.regions.contains(r)
            })
            .cloned()
            .collect()
    };
    for region in &table_regions {
        let items: Vec<(crate::sim::ResourceConfig, pop::RegionMetrics)> =
            latest
                .iter()
                .filter_map(|run| {
                    run.region(region)
                        .map(|r| (run.resources(), r.metrics))
                })
                .collect();
        if let Some(table) = pop::build_from_metrics(region, &items) {
            body.push_str(&format!(
                "<h2>Scaling efficiency — region <code>{}</code></h2>\n",
                esc(region)
            ));
            body.push_str(&table_html::render(&table));
        }
    }

    // ---- per-config series: findings + plots in one pass ----
    // Each configuration's history is filtered/sorted and its full
    // TimeSeries built exactly once; the detector and the plots share
    // it (a filtered copy is only built when regions were selected).
    let plot_regions: Vec<String> = if opts.regions.is_empty() {
        all_regions
    } else {
        // Selected regions are highlighted; Global is always kept so the
        // whole-program trend stays visible (paper: "The selected
        // regions are also highlighted in the time-series plots").
        let mut v = vec!["Global".to_string()];
        v.extend(opts.regions.iter().cloned());
        v.dedup();
        v
    };
    let mut findings_html = String::new();
    let mut plots_html = String::new();
    for cfg in exp.configs() {
        let history = exp.history_for_config(&cfg);
        if history.len() < 2 {
            continue; // nothing to compare or plot yet
        }
        let full_ts = timeseries::build_from_metrics(&cfg, &history, &[]);
        for f in
            detect::detect_series(&full_ts, &cfg, &DetectOptions::default())
        {
            findings_html.push_str(&format!(
                "<li class=\"{}\">{}</li>\n",
                match f.kind {
                    detect::ChangeKind::Regression => "regression",
                    detect::ChangeKind::Improvement => "improvement",
                },
                esc(&f.describe())
            ));
        }

        // Plot series: with no region selection the full series IS the
        // plotted one; otherwise build the filtered subset.
        let filtered_ts;
        let ts = if opts.regions.is_empty() {
            &full_ts
        } else {
            filtered_ts = timeseries::build_from_metrics(
                &cfg,
                &history,
                &plot_regions,
            );
            &filtered_ts
        };
        let regions = ts.regions();
        plots_html.push_str(&format!(
            "<h2>Time evolution — {} ({} runs)</h2>\n",
            esc(&cfg),
            history.len()
        ));
        let toggle_info: Vec<(String, String, String)> = regions
            .iter()
            .enumerate()
            .map(|(i, r)| {
                (r.clone(), svgplot::css_class(r), svgplot::color(i))
            })
            .collect();
        plots_html.push_str(&html::toggles(&toggle_info));
        for (metric, label) in timeseries::PLOT_METRICS {
            let series: Vec<Series> = regions
                .iter()
                .enumerate()
                .map(|(i, r)| Series {
                    label: r.clone(),
                    points: ts.metric(r, metric),
                    color: svgplot::color(i),
                })
                .filter(|s| !s.points.is_empty())
                .collect();
            if series.is_empty() {
                continue;
            }
            plots_html.push_str(&svgplot::line_chart(label, &series, ""));
        }
        // Commit annotations under the plots.
        let commits: Vec<String> = ts
            .points
            .iter()
            .filter_map(|p| {
                p.commit.as_ref().map(|c| {
                    format!(
                        "<code>{}</code> ({})",
                        esc(&c[..c.len().min(8)]),
                        timefmt::to_iso8601(p.timestamp)
                    )
                })
            })
            .collect();
        if !commits.is_empty() {
            plots_html.push_str(&format!(
                "<p>Commits: {}</p>\n",
                commits.join(" · ")
            ));
        }
    }

    if !findings_html.is_empty() {
        body.push_str(&format!(
            "<h2>Detected changes</h2>\n<ul class=\"findings\">\n{findings_html}</ul>\n"
        ));
    }

    // ---- Extra-P-style scaling models (>= 3 configurations) ----
    if latest.len() >= 3 {
        let models =
            pop::extrap::fit_experiment_metrics(&latest, &table_regions);
        if !models.is_empty() {
            body.push_str("<h2>Scaling models (Extra-P-style)</h2>\n<ul>\n");
            for (region, m) in &models {
                body.push_str(&format!(
                    "<li><code>{}</code>: elapsed(p) ≈ {} (SMAPE {:.1}%){}</li>\n",
                    esc(region),
                    esc(&m.formula()),
                    m.smape * 100.0,
                    if m.grows() {
                        " <b>⚠ grows with resources</b>"
                    } else {
                        ""
                    }
                ));
            }
            body.push_str("</ul>\n");
        }
    }

    // ---- time-evolution plots per configuration ----
    body.push_str(&plots_html);
    RenderedExperiment {
        file: format!("{}.html", slug(&exp.id)),
        body,
        badges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::talp::GitMeta;
    use crate::util::fs::TempDir;

    /// Build a realistic input folder: one experiment, one config,
    /// 4-commit history with the Fig. 7 bug fix in the middle.
    fn build_input(td: &TempDir) {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        for i in 0..4 {
            let version = if i < 2 {
                CodeVersion::buggy()
            } else {
                CodeVersion::fixed()
            };
            let mut app = Genex::salpha(1, version);
            app.timesteps = 2;
            let (mut d, _) =
                run_with_talp(&app, &machine, &res, 100 + i, 0);
            d.git = Some(GitMeta {
                commit: format!("{i:07x}a"),
                branch: "main".into(),
                commit_timestamp: 1_700_000_000 + i as i64 * 86400,
                message: format!("commit {i}"),
            });
            d.write_file(
                &td.path()
                    .join(format!("salpha/resolution_1/run_{i}.json")),
            )
            .unwrap();
        }
    }

    #[test]
    fn generates_full_site() {
        let td = TempDir::new("report-in").unwrap();
        let out = TempDir::new("report-out").unwrap();
        build_input(&td);
        let opts = ReportOptions {
            regions: vec!["initialize".into(), "timestep".into()],
            region_for_badge: Some("timestep".into()),
            ..Default::default()
        };
        let summary = generate(td.path(), out.path(), &opts).unwrap();
        assert_eq!(summary.experiments, 1);
        assert_eq!(summary.pages_written, 2); // index + 1 experiment
        assert_eq!(summary.badges_written, 1);
        assert_eq!(summary.cache_hits, 0);
        assert_eq!(summary.cache_misses, 4);
        assert!(out.path().join("index.html").exists());
        let page = std::fs::read_to_string(
            out.path().join("salpha_resolution_1.html"),
        )
        .unwrap();
        assert!(page.contains("Scaling efficiency"));
        assert!(page.contains("Time evolution"));
        assert!(page.contains("initialize"));
        assert!(page.contains("polyline"));
        assert!(page.contains("Commits:"));
        // The bug->fix history must surface as an automated finding.
        assert!(page.contains("Detected changes"), "no findings section");
        assert!(page.contains("sped up"));
        assert!(page.contains("OpenMP Serialization efficiency"));
        // Badge file exists and mentions the badge region.
        let badge = std::fs::read_to_string(
            out.path().join("badges/salpha_resolution_1__2x8.svg"),
        )
        .unwrap();
        assert!(badge.contains("timestep"));
    }

    #[test]
    fn warm_rerun_hits_cache_and_is_byte_identical() {
        let td = TempDir::new("report-in-warm").unwrap();
        let out = TempDir::new("report-out-warm").unwrap();
        build_input(&td);
        let opts = ReportOptions::default();
        let cold = generate(td.path(), out.path(), &opts).unwrap();
        assert_eq!(cold.cache_misses, 4);
        let page1 = std::fs::read_to_string(
            out.path().join("salpha_resolution_1.html"),
        )
        .unwrap();
        assert!(out.path().join(CACHE_FILE_NAME).exists());

        let warm = generate(td.path(), out.path(), &opts).unwrap();
        assert_eq!(warm.cache_hits, 4, "all artifacts unchanged");
        assert_eq!(warm.cache_misses, 0);
        let page2 = std::fs::read_to_string(
            out.path().join("salpha_resolution_1.html"),
        )
        .unwrap();
        assert_eq!(page1, page2, "cache round-trip changed the page");
    }

    #[test]
    fn single_run_config_has_table_but_no_plot() {
        let td = TempDir::new("report-in2").unwrap();
        let out = TempDir::new("report-out2").unwrap();
        let machine = MachineSpec::marenostrum5();
        let mut app = Genex::salpha(1, CodeVersion::fixed());
        app.timesteps = 2;
        let (d, _) = run_with_talp(
            &app,
            &machine,
            &ResourceConfig::new(2, 8),
            1,
            1_700_000_000,
        );
        d.write_file(&td.path().join("exp/one.json")).unwrap();
        let summary =
            generate(td.path(), out.path(), &ReportOptions::default())
                .unwrap();
        assert_eq!(summary.experiments, 1);
        let page =
            std::fs::read_to_string(out.path().join("exp.html")).unwrap();
        assert!(page.contains("Scaling efficiency"));
        assert!(!page.contains("Time evolution"));
    }

    #[test]
    fn gated_report_writes_verdict_badge_and_index_section() {
        let td = TempDir::new("report-gate-in").unwrap();
        let out = TempDir::new("report-gate-out").unwrap();
        build_input(&td);
        let opts = ReportOptions {
            gate: Some(crate::gate::GatePolicy::default()),
            ..Default::default()
        };
        let summary = generate(td.path(), out.path(), &opts).unwrap();
        let verdict = summary.gate.as_ref().expect("verdict present");
        // The fixture's history is a bug -> fix (an improvement), so
        // the gate passes.
        assert_eq!(verdict.status, crate::gate::GateStatus::Pass);
        for f in ["gate.json", "gate.md", "gate.xml", "badges/gate.svg"] {
            assert!(out.path().join(f).exists(), "{f} missing");
        }
        let index =
            std::fs::read_to_string(out.path().join("index.html")).unwrap();
        assert!(index.contains("Performance gate: PASS"));
        assert!(index.contains("gate.json"));
        let badge = std::fs::read_to_string(
            out.path().join("badges/gate.svg"),
        )
        .unwrap();
        assert!(badge.contains("perf gate"));
        assert!(badge.contains("passing"));
        // Ungated reports stay verdict-free.
        let plain = generate(
            td.path(),
            TempDir::new("report-gate-out2").unwrap().path(),
            &ReportOptions::default(),
        )
        .unwrap();
        assert!(plain.gate.is_none());
    }

    #[test]
    fn warnings_surface_in_index() {
        let td = TempDir::new("report-in3").unwrap();
        let out = TempDir::new("report-out3").unwrap();
        build_input(&td);
        std::fs::write(td.path().join("salpha/resolution_1/bad.json"), "][")
            .unwrap();
        let summary =
            generate(td.path(), out.path(), &ReportOptions::default())
                .unwrap();
        assert_eq!(summary.warnings.len(), 1);
        let index =
            std::fs::read_to_string(out.path().join("index.html")).unwrap();
        assert!(index.contains("Warnings"));
    }
}
