//! The `talp ci-report` engine: scan a Fig. 2 folder, emit the full
//! static site — index, one page per experiment (scaling-efficiency
//! tables + time-evolution plots), and SVG badges.

use std::path::Path;

use anyhow::{Context, Result};

use crate::pop;
use crate::util::timefmt;

use super::badge;
use super::detect::{self, DetectOptions};
use super::html;
use super::scanner::{self, Experiment};
use super::svgplot::{self, esc, Series};
use super::table_html;
use super::timeseries;

/// Report options (mirrors the paper's CLI flags).
#[derive(Debug, Clone, Default)]
pub struct ReportOptions {
    /// Regions to build tables/plots for (empty = every region found).
    pub regions: Vec<String>,
    /// Region whose parallel efficiency feeds the badges (default the
    /// implicit whole-execution region).
    pub region_for_badge: Option<String>,
}

/// What was generated.
#[derive(Debug)]
pub struct ReportSummary {
    pub experiments: usize,
    pub pages_written: usize,
    pub badges_written: usize,
    pub warnings: Vec<String>,
}

/// Generate the full report from `input` into `out_dir`.
pub fn generate(
    input: &Path,
    out_dir: &Path,
    opts: &ReportOptions,
) -> Result<ReportSummary> {
    let scan = scanner::scan(input)?;
    std::fs::create_dir_all(out_dir.join("badges"))
        .with_context(|| format!("creating {}", out_dir.display()))?;

    let mut pages = 0usize;
    let mut badges = 0usize;
    let mut index_items = String::new();

    for exp in &scan.experiments {
        let file = format!("{}.html", slug(&exp.id));
        let (body, nbadges) =
            experiment_page(exp, opts, out_dir).with_context(|| {
                format!("rendering experiment '{}'", exp.id)
            })?;
        std::fs::write(
            out_dir.join(&file),
            html::page(&format!("TALP report — {}", exp.id), &body),
        )?;
        pages += 1;
        badges += nbadges;
        index_items.push_str(&format!(
            "<li><a href=\"{file}\">{}</a> — {} configs, {} runs</li>\n",
            esc(&exp.id),
            exp.configs().len(),
            exp.runs.len()
        ));
    }

    let mut index_body = String::from("<h1>TALP-Pages performance report</h1>\n");
    if !scan.warnings.is_empty() {
        index_body.push_str("<div class=\"warn\"><b>Warnings:</b><ul>");
        for w in &scan.warnings {
            index_body.push_str(&format!("<li>{}</li>", esc(w)));
        }
        index_body.push_str("</ul></div>\n");
    }
    index_body.push_str(&format!(
        "<p>{} experiment(s) found under <code>{}</code>.</p>\n<ul class=\"exp-list\">\n{index_items}</ul>\n",
        scan.experiments.len(),
        esc(&input.display().to_string()),
    ));
    std::fs::write(
        out_dir.join("index.html"),
        html::page("TALP-Pages report", &index_body),
    )?;
    pages += 1;

    Ok(ReportSummary {
        experiments: scan.experiments.len(),
        pages_written: pages,
        badges_written: badges,
        warnings: scan.warnings,
    })
}

fn slug(id: &str) -> String {
    id.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Render one experiment's page body; also writes its badges.
fn experiment_page(
    exp: &Experiment,
    opts: &ReportOptions,
    out_dir: &Path,
) -> Result<(String, usize)> {
    let mut body = format!("<h1>{}</h1>\n", esc(&exp.id));
    let latest = exp.latest_per_config();
    let badge_region = opts
        .region_for_badge
        .clone()
        .unwrap_or_else(|| "Global".to_string());

    // ---- badges ----
    let mut nbadges = 0usize;
    body.push_str("<div class=\"badges\">\n");
    for run in &latest {
        let Some(reg) = run.region(&badge_region) else {
            continue;
        };
        let m = pop::compute(reg, run.threads);
        let cfg = run.resources().label();
        let svg = badge::parallel_efficiency_badge(
            &badge_region,
            &cfg,
            m.parallel_efficiency,
        );
        let name = format!("badges/{}__{}.svg", slug(&exp.id), cfg);
        std::fs::write(out_dir.join(&name), &svg)?;
        nbadges += 1;
        body.push_str(&svg);
    }
    body.push_str("</div>\n");

    // ---- scaling-efficiency tables ----
    let all_regions = exp.regions();
    let table_regions: Vec<String> = if opts.regions.is_empty() {
        all_regions.clone()
    } else {
        all_regions
            .iter()
            .filter(|r| {
                *r == "Global" || opts.regions.contains(r)
            })
            .cloned()
            .collect()
    };
    for region in &table_regions {
        if let Some(table) = pop::build(region, &latest) {
            body.push_str(&format!(
                "<h2>Scaling efficiency — region <code>{}</code></h2>\n",
                esc(region)
            ));
            body.push_str(&table_html::render(&table));
        }
    }

    // ---- automated findings (regressions / improvements) ----
    let mut findings_html = String::new();
    for cfg in exp.configs() {
        let history = exp.history_for_config(&cfg);
        if history.len() < 2 {
            continue;
        }
        for f in detect::detect(&cfg, &history, &DetectOptions::default()) {
            findings_html.push_str(&format!(
                "<li class=\"{}\">{}</li>\n",
                match f.kind {
                    detect::ChangeKind::Regression => "regression",
                    detect::ChangeKind::Improvement => "improvement",
                },
                esc(&f.describe())
            ));
        }
    }
    if !findings_html.is_empty() {
        body.push_str(&format!(
            "<h2>Detected changes</h2>\n<ul class=\"findings\">\n{findings_html}</ul>\n"
        ));
    }

    // ---- Extra-P-style scaling models (>= 3 configurations) ----
    if latest.len() >= 3 {
        let models =
            crate::pop::extrap::fit_experiment(&latest, &table_regions);
        if !models.is_empty() {
            body.push_str("<h2>Scaling models (Extra-P-style)</h2>\n<ul>\n");
            for (region, m) in &models {
                body.push_str(&format!(
                    "<li><code>{}</code>: elapsed(p) ≈ {} (SMAPE {:.1}%){}</li>\n",
                    esc(region),
                    esc(&m.formula()),
                    m.smape * 100.0,
                    if m.grows() {
                        " <b>⚠ grows with resources</b>"
                    } else {
                        ""
                    }
                ));
            }
            body.push_str("</ul>\n");
        }
    }

    // ---- time-evolution plots per configuration ----
    let plot_regions: Vec<String> = if opts.regions.is_empty() {
        all_regions
    } else {
        // Selected regions are highlighted; Global is always kept so the
        // whole-program trend stays visible (paper: "The selected
        // regions are also highlighted in the time-series plots").
        let mut v = vec!["Global".to_string()];
        v.extend(opts.regions.iter().cloned());
        v.dedup();
        v
    };
    for cfg in exp.configs() {
        let history = exp.history_for_config(&cfg);
        if history.len() < 2 {
            continue; // nothing to plot yet
        }
        let ts = timeseries::build(&cfg, &history, &plot_regions);
        let regions = ts.regions();
        body.push_str(&format!(
            "<h2>Time evolution — {} ({} runs)</h2>\n",
            esc(&cfg),
            history.len()
        ));
        let toggle_info: Vec<(String, String, String)> = regions
            .iter()
            .enumerate()
            .map(|(i, r)| {
                (r.clone(), svgplot::css_class(r), svgplot::color(i))
            })
            .collect();
        body.push_str(&html::toggles(&toggle_info));
        for (metric, label) in timeseries::PLOT_METRICS {
            let series: Vec<Series> = regions
                .iter()
                .enumerate()
                .map(|(i, r)| Series {
                    label: r.clone(),
                    points: ts.metric(r, metric),
                    color: svgplot::color(i),
                })
                .filter(|s| !s.points.is_empty())
                .collect();
            if series.is_empty() {
                continue;
            }
            body.push_str(&svgplot::line_chart(label, &series, ""));
        }
        // Commit annotations under the plots.
        let commits: Vec<String> = ts
            .points
            .iter()
            .filter_map(|p| {
                p.commit.as_ref().map(|c| {
                    format!(
                        "<code>{}</code> ({})",
                        esc(&c[..c.len().min(8)]),
                        timefmt::to_iso8601(p.timestamp)
                    )
                })
            })
            .collect();
        if !commits.is_empty() {
            body.push_str(&format!(
                "<p>Commits: {}</p>\n",
                commits.join(" · ")
            ));
        }
    }
    Ok((body, nbadges))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::talp::GitMeta;
    use crate::util::fs::TempDir;

    /// Build a realistic input folder: one experiment, one config,
    /// 4-commit history with the Fig. 7 bug fix in the middle.
    fn build_input(td: &TempDir) {
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        for i in 0..4 {
            let version = if i < 2 {
                CodeVersion::buggy()
            } else {
                CodeVersion::fixed()
            };
            let mut app = Genex::salpha(1, version);
            app.timesteps = 2;
            let (mut d, _) =
                run_with_talp(&app, &machine, &res, 100 + i, 0);
            d.git = Some(GitMeta {
                commit: format!("{i:07x}a"),
                branch: "main".into(),
                commit_timestamp: 1_700_000_000 + i as i64 * 86400,
                message: format!("commit {i}"),
            });
            d.write_file(
                &td.path()
                    .join(format!("salpha/resolution_1/run_{i}.json")),
            )
            .unwrap();
        }
    }

    #[test]
    fn generates_full_site() {
        let td = TempDir::new("report-in").unwrap();
        let out = TempDir::new("report-out").unwrap();
        build_input(&td);
        let opts = ReportOptions {
            regions: vec!["initialize".into(), "timestep".into()],
            region_for_badge: Some("timestep".into()),
        };
        let summary = generate(td.path(), out.path(), &opts).unwrap();
        assert_eq!(summary.experiments, 1);
        assert_eq!(summary.pages_written, 2); // index + 1 experiment
        assert_eq!(summary.badges_written, 1);
        assert!(out.path().join("index.html").exists());
        let page = std::fs::read_to_string(
            out.path().join("salpha_resolution_1.html"),
        )
        .unwrap();
        assert!(page.contains("Scaling efficiency"));
        assert!(page.contains("Time evolution"));
        assert!(page.contains("initialize"));
        assert!(page.contains("polyline"));
        assert!(page.contains("Commits:"));
        // The bug->fix history must surface as an automated finding.
        assert!(page.contains("Detected changes"), "no findings section");
        assert!(page.contains("sped up"));
        assert!(page.contains("OpenMP Serialization efficiency"));
        // Badge file exists and mentions the badge region.
        let badge = std::fs::read_to_string(
            out.path().join("badges/salpha_resolution_1__2x8.svg"),
        )
        .unwrap();
        assert!(badge.contains("timestep"));
    }

    #[test]
    fn single_run_config_has_table_but_no_plot() {
        let td = TempDir::new("report-in2").unwrap();
        let out = TempDir::new("report-out2").unwrap();
        let machine = MachineSpec::marenostrum5();
        let mut app = Genex::salpha(1, CodeVersion::fixed());
        app.timesteps = 2;
        let (d, _) = run_with_talp(
            &app,
            &machine,
            &ResourceConfig::new(2, 8),
            1,
            1_700_000_000,
        );
        d.write_file(&td.path().join("exp/one.json")).unwrap();
        let summary =
            generate(td.path(), out.path(), &ReportOptions::default())
                .unwrap();
        assert_eq!(summary.experiments, 1);
        let page =
            std::fs::read_to_string(out.path().join("exp.html")).unwrap();
        assert!(page.contains("Scaling efficiency"));
        assert!(!page.contains("Time evolution"));
    }

    #[test]
    fn warnings_surface_in_index() {
        let td = TempDir::new("report-in3").unwrap();
        let out = TempDir::new("report-out3").unwrap();
        build_input(&td);
        std::fs::write(td.path().join("salpha/resolution_1/bad.json"), "][")
            .unwrap();
        let summary =
            generate(td.path(), out.path(), &ReportOptions::default())
                .unwrap();
        assert_eq!(summary.warnings.len(), 1);
        let index =
            std::fs::read_to_string(out.path().join("index.html")).unwrap();
        assert!(index.contains("Warnings"));
    }
}
