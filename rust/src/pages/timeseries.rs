//! Time-evolution series (paper §Time-evolution plots, Fig. 7).
//!
//! One series set per (experiment, resource configuration): for every
//! run in the configuration's history, per-region elapsed time, the
//! computation indicators (IPC, frequency, instructions) and the
//! parallel-efficiency hierarchy.  The x-axis is the git commit
//! timestamp when present, the execution end time otherwise.

use crate::pop;
use crate::talp::RunData;

/// One region's metrics at one point in time.
#[derive(Debug, Clone)]
pub struct RegionPoint {
    pub region: String,
    pub elapsed_s: f64,
    pub useful_ipc: f64,
    pub frequency_ghz: f64,
    pub instructions: f64,
    pub parallel_efficiency: f64,
    pub mpi_parallel_efficiency: f64,
    pub omp_parallel_efficiency: f64,
    pub omp_load_balance: f64,
    pub omp_scheduling_efficiency: f64,
    pub omp_serialization_efficiency: f64,
    pub mpi_load_balance: f64,
    pub mpi_communication_efficiency: f64,
}

/// One history point (one run).
#[derive(Debug, Clone)]
pub struct TimePoint {
    pub timestamp: i64,
    pub commit: Option<String>,
    pub branch: Option<String>,
    pub regions: Vec<RegionPoint>,
}

/// The full series for one resource configuration.
#[derive(Debug, Clone)]
pub struct TimeSeries {
    pub config: String,
    pub points: Vec<TimePoint>,
}

/// One plotted point from one region's precomputed factors.
fn region_point(name: &str, m: &pop::RegionMetrics) -> RegionPoint {
    RegionPoint {
        region: name.to_string(),
        elapsed_s: m.elapsed_s,
        useful_ipc: m.useful_ipc,
        frequency_ghz: m.frequency_ghz,
        instructions: m.total_useful_instructions as f64,
        parallel_efficiency: m.parallel_efficiency,
        mpi_parallel_efficiency: m.mpi_parallel_efficiency,
        omp_parallel_efficiency: m.omp_parallel_efficiency,
        omp_load_balance: m.omp_load_balance,
        omp_scheduling_efficiency: m.omp_scheduling_efficiency,
        omp_serialization_efficiency: m.omp_serialization_efficiency,
        mpi_load_balance: m.mpi_load_balance,
        mpi_communication_efficiency: m.mpi_communication_efficiency,
    }
}

/// Build the series from a configuration's history (oldest first), for
/// the selected regions (empty = all).
pub fn build(config: &str, history: &[&RunData], regions: &[String]) -> TimeSeries {
    let mut points = Vec::with_capacity(history.len());
    for run in history {
        let mut region_points = Vec::new();
        for reg in &run.regions {
            if !regions.is_empty() && !regions.contains(&reg.name) {
                continue;
            }
            let m = pop::compute(reg, run.threads);
            region_points.push(region_point(&reg.name, &m));
        }
        points.push(TimePoint {
            timestamp: run.effective_timestamp(),
            commit: run.git.as_ref().map(|g| g.commit.clone()),
            branch: run.git.as_ref().map(|g| g.branch.clone()),
            regions: region_points,
        });
    }
    TimeSeries { config: config.to_string(), points }
}

/// Same series from precomputed per-run metrics (the incremental report
/// engine's path) — no per-process data is touched.
pub fn build_from_metrics(
    config: &str,
    history: &[&pop::RunMetrics],
    regions: &[String],
) -> TimeSeries {
    let mut points = Vec::with_capacity(history.len());
    for run in history {
        let region_points = run
            .regions
            .iter()
            .filter(|r| regions.is_empty() || regions.contains(&r.name))
            .map(|r| region_point(&r.name, &r.metrics))
            .collect();
        points.push(TimePoint {
            timestamp: run.effective_timestamp(),
            commit: run.git.as_ref().map(|g| g.commit.clone()),
            branch: run.git.as_ref().map(|g| g.branch.clone()),
            regions: region_points,
        });
    }
    TimeSeries { config: config.to_string(), points }
}

impl TimeSeries {
    /// Values of one metric for one region across time.
    pub fn metric(&self, region: &str, metric: &str) -> Vec<(i64, f64)> {
        self.points
            .iter()
            .filter_map(|p| {
                let r = p.regions.iter().find(|r| r.region == region)?;
                let v = match metric {
                    "elapsed" => r.elapsed_s,
                    "ipc" => r.useful_ipc,
                    "frequency" => r.frequency_ghz,
                    "instructions" => r.instructions,
                    "parallel_efficiency" => r.parallel_efficiency,
                    "mpi_parallel_efficiency" => r.mpi_parallel_efficiency,
                    "omp_parallel_efficiency" => r.omp_parallel_efficiency,
                    "omp_load_balance" => r.omp_load_balance,
                    "omp_scheduling_efficiency" => r.omp_scheduling_efficiency,
                    "omp_serialization_efficiency" => {
                        r.omp_serialization_efficiency
                    }
                    "mpi_load_balance" => r.mpi_load_balance,
                    "mpi_communication_efficiency" => {
                        r.mpi_communication_efficiency
                    }
                    _ => return None,
                };
                Some((p.timestamp, v))
            })
            .collect()
    }

    /// Regions present anywhere in the series.
    pub fn regions(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for p in &self.points {
            for r in &p.regions {
                if !names.contains(&r.region) {
                    names.push(r.region.clone());
                }
            }
        }
        names
    }
}

/// Metric ids + display labels for the report rows (order = plot rows in
/// the paper's Fig. 7: elapsed, computation indicators, efficiency
/// hierarchy).
pub const PLOT_METRICS: &[(&str, &str)] = &[
    ("elapsed", "Elapsed time [s]"),
    ("ipc", "Useful IPC"),
    ("frequency", "Frequency [GHz]"),
    ("instructions", "Useful instructions"),
    ("parallel_efficiency", "Parallel efficiency"),
    ("mpi_parallel_efficiency", "MPI Parallel efficiency"),
    ("omp_parallel_efficiency", "OpenMP Parallel efficiency"),
    ("omp_load_balance", "OpenMP Load balance"),
    ("omp_scheduling_efficiency", "OpenMP Scheduling efficiency"),
    ("omp_serialization_efficiency", "OpenMP Serialization efficiency"),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::talp::GitMeta;

    fn history() -> Vec<RunData> {
        // 4 commits: bug, bug, fix, fix.
        let machine = MachineSpec::marenostrum5();
        let res = ResourceConfig::new(2, 8);
        (0..4)
            .map(|i| {
                let version = if i < 2 {
                    CodeVersion::buggy()
                } else {
                    CodeVersion::fixed()
                };
                let mut app = Genex::salpha(1, version);
                app.timesteps = 2;
                let (mut d, _) =
                    run_with_talp(&app, &machine, &res, 100 + i, 0);
                d.git = Some(GitMeta {
                    commit: format!("c{i:07}"),
                    branch: "main".into(),
                    commit_timestamp: 1000 + i as i64 * 100,
                    message: String::new(),
                });
                d
            })
            .collect()
    }

    #[test]
    fn series_ordered_and_complete() {
        let runs = history();
        let refs: Vec<&RunData> = runs.iter().collect();
        let ts = build("2x8", &refs, &[]);
        assert_eq!(ts.points.len(), 4);
        assert_eq!(ts.points[0].commit.as_deref(), Some("c0000000"));
        assert!(ts
            .regions()
            .iter()
            .any(|r| r == "initialize"));
    }

    #[test]
    fn fig7_signature_visible_in_series() {
        let runs = history();
        let refs: Vec<&RunData> = runs.iter().collect();
        let ts = build("2x8", &refs, &[]);
        let elapsed = ts.metric("initialize", "elapsed");
        // elapsed drops at the fix commit...
        assert!(elapsed[2].1 < 0.7 * elapsed[1].1, "{elapsed:?}");
        // ...serialization efficiency rises...
        let ser = ts.metric("initialize", "omp_serialization_efficiency");
        assert!(ser[2].1 > ser[1].1 + 0.1, "{ser:?}");
        // ...and instructions stay flat.
        let insn = ts.metric("initialize", "instructions");
        let rel = (insn[2].1 - insn[1].1).abs() / insn[1].1;
        assert!(rel < 0.05, "instructions moved {rel}");
        // timestep unaffected.
        let ts_elapsed = ts.metric("timestep", "elapsed");
        let rel =
            (ts_elapsed[2].1 - ts_elapsed[1].1).abs() / ts_elapsed[1].1;
        assert!(rel < 0.1, "timestep moved {rel}");
    }

    #[test]
    fn region_filter_applies() {
        let runs = history();
        let refs: Vec<&RunData> = runs.iter().collect();
        let ts = build("2x8", &refs, &["timestep".to_string()]);
        assert_eq!(ts.regions(), ["timestep"]);
        assert!(ts.metric("initialize", "elapsed").is_empty());
    }

    #[test]
    fn unknown_metric_empty() {
        let runs = history();
        let refs: Vec<&RunData> = runs.iter().collect();
        let ts = build("2x8", &refs, &[]);
        assert!(ts.metric("Global", "nope").is_empty());
    }
}
