//! Resource metering for post-processing chains (Table 2).
//!
//! Three dimensions, matching the paper: peak *memory* the chain needed,
//! *storage* it read/wrote on disk, and wall-clock *time*.  Memory is
//! tracked by explicit accounting (post-processors register their big
//! allocations) — deterministic and allocator-independent; storage is
//! real bytes on disk; time is real wall time of this process.

use std::time::Instant;

/// Accumulates one chain's resource usage.
#[derive(Debug, Default)]
pub struct ResourceMeter {
    current_bytes: u64,
    peak_bytes: u64,
    storage_bytes: u64,
    started: Option<Instant>,
    elapsed_s: f64,
}

/// Final, reportable usage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceUsage {
    pub peak_memory_bytes: u64,
    pub storage_bytes: u64,
    pub wall_time_s: f64,
}

impl ResourceMeter {
    pub fn new() -> ResourceMeter {
        ResourceMeter::default()
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.elapsed_s += t0.elapsed().as_secs_f64();
        }
    }

    /// Register an allocation of `bytes` held by the chain.
    pub fn alloc(&mut self, bytes: u64) {
        self.current_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    /// Register freeing `bytes`.
    pub fn free(&mut self, bytes: u64) {
        self.current_bytes = self.current_bytes.saturating_sub(bytes);
    }

    /// Register bytes read from or written to disk.
    pub fn storage(&mut self, bytes: u64) {
        self.storage_bytes += bytes;
    }

    /// If the chain has multiple steps, Table 2 takes the max per step;
    /// merge peak memory with max, storage and time with sum.
    pub fn merge_step(&mut self, other: &ResourceUsage) {
        self.peak_bytes = self.peak_bytes.max(other.peak_memory_bytes);
        self.storage_bytes += other.storage_bytes;
        self.elapsed_s += other.wall_time_s;
    }

    pub fn usage(&self) -> ResourceUsage {
        ResourceUsage {
            peak_memory_bytes: self.peak_bytes,
            storage_bytes: self.storage_bytes,
            wall_time_s: self.elapsed_s
                + self
                    .started
                    .map(|t| t.elapsed().as_secs_f64())
                    .unwrap_or(0.0),
        }
    }
}

impl ResourceUsage {
    pub fn zero() -> ResourceUsage {
        ResourceUsage {
            peak_memory_bytes: 0,
            storage_bytes: 0,
            wall_time_s: 0.0,
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "mem {} | storage {} | time {}",
            crate::util::stats::fmt_bytes(self.peak_memory_bytes),
            crate::util::stats::fmt_bytes(self.storage_bytes),
            crate::util::stats::fmt_duration(self.wall_time_s)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut m = ResourceMeter::new();
        m.alloc(100);
        m.alloc(200);
        m.free(150);
        m.alloc(50);
        let u = m.usage();
        assert_eq!(u.peak_memory_bytes, 300);
    }

    #[test]
    fn time_accumulates_across_start_stop() {
        let mut m = ResourceMeter::new();
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.stop();
        m.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.stop();
        assert!(m.usage().wall_time_s >= 0.008);
    }

    #[test]
    fn merge_takes_max_memory_sum_rest() {
        let mut m = ResourceMeter::new();
        m.alloc(100);
        m.storage(10);
        m.merge_step(&ResourceUsage {
            peak_memory_bytes: 500,
            storage_bytes: 20,
            wall_time_s: 1.0,
        });
        let u = m.usage();
        assert_eq!(u.peak_memory_bytes, 500);
        assert_eq!(u.storage_bytes, 30);
        assert!(u.wall_time_s >= 1.0);
    }

    #[test]
    fn summary_formats() {
        let u = ResourceUsage {
            peak_memory_bytes: 2_000_000_000,
            storage_bytes: 1000,
            wall_time_s: 2.0,
        };
        let s = u.summary();
        assert!(s.contains("2.00GB"));
        assert!(s.contains("2.00s"));
    }
}
