//! Binary trace format shared by the Extrae-like and Score-P-like
//! tracers: fixed-size little-endian records, one file per rank, plus a
//! text header with run metadata.  Post-processors stream these files
//! back; their size is what Table 2's storage column measures.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::sim::{CollKind, Event, PhaseKind, RegionMark};

/// Record size on disk (bytes): see `encode`.
pub const RECORD_BYTES: usize = 48;

/// One trace record.  Phase records carry timing+counters; region
/// records (kind = REGION_*) reuse t_start and stash the region id in
/// `instructions`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub rank: u32,
    pub thread: u32,
    pub t_start: f64,
    pub t_end: f64,
    pub kind: u8,
    pub mpi_call: u8,
    pub instructions: u64,
    pub cycles: u64,
    pub bytes: u64,
}

pub const KIND_USEFUL: u8 = 0;
pub const KIND_MPI: u8 = 1;
pub const KIND_OMP_SERIAL: u8 = 2;
pub const KIND_MPI_WORKER_IDLE: u8 = 3;
pub const KIND_OMP_SCHED: u8 = 4;
pub const KIND_OMP_BARRIER: u8 = 5;
pub const KIND_IO: u8 = 6;
pub const KIND_REGION_ENTER: u8 = 7;
pub const KIND_REGION_EXIT: u8 = 8;

pub fn kind_code(k: PhaseKind) -> u8 {
    match k {
        PhaseKind::Useful => KIND_USEFUL,
        PhaseKind::Mpi => KIND_MPI,
        PhaseKind::OmpSerialization => KIND_OMP_SERIAL,
        PhaseKind::MpiWorkerIdle => KIND_MPI_WORKER_IDLE,
        PhaseKind::OmpScheduling => KIND_OMP_SCHED,
        PhaseKind::OmpBarrier => KIND_OMP_BARRIER,
        PhaseKind::Io => KIND_IO,
    }
}

pub fn phase_kind(code: u8) -> Option<PhaseKind> {
    Some(match code {
        KIND_USEFUL => PhaseKind::Useful,
        KIND_MPI => PhaseKind::Mpi,
        KIND_OMP_SERIAL => PhaseKind::OmpSerialization,
        KIND_MPI_WORKER_IDLE => PhaseKind::MpiWorkerIdle,
        KIND_OMP_SCHED => PhaseKind::OmpScheduling,
        KIND_OMP_BARRIER => PhaseKind::OmpBarrier,
        KIND_IO => PhaseKind::Io,
        _ => return None,
    })
}

fn call_code(c: Option<CollKind>) -> u8 {
    match c {
        None => 0,
        Some(CollKind::Barrier) => 1,
        Some(CollKind::Allreduce) => 2,
        Some(CollKind::Bcast) => 3,
        Some(CollKind::Allgather) => 4,
    }
}

impl TraceRecord {
    pub fn from_event(ev: &Event) -> TraceRecord {
        TraceRecord {
            rank: ev.rank,
            thread: ev.thread,
            t_start: ev.t_start,
            t_end: ev.t_end,
            kind: kind_code(ev.kind),
            mpi_call: call_code(ev.mpi_call),
            instructions: ev.instructions,
            cycles: ev.cycles,
            bytes: ev.bytes,
        }
    }

    pub fn from_region(mark: &RegionMark, region_id: u64) -> TraceRecord {
        TraceRecord {
            rank: mark.rank,
            thread: 0,
            t_start: mark.t,
            t_end: mark.t,
            kind: if mark.enter {
                KIND_REGION_ENTER
            } else {
                KIND_REGION_EXIT
            },
            mpi_call: 0,
            instructions: region_id,
            cycles: 0,
            bytes: 0,
        }
    }

    pub fn encode(&self, out: &mut [u8; RECORD_BYTES]) {
        out[0..4].copy_from_slice(&self.rank.to_le_bytes());
        out[4..6].copy_from_slice(&(self.thread as u16).to_le_bytes());
        out[6] = self.kind;
        out[7] = self.mpi_call;
        out[8..16].copy_from_slice(&self.t_start.to_le_bytes());
        out[16..24].copy_from_slice(&self.t_end.to_le_bytes());
        out[24..32].copy_from_slice(&self.instructions.to_le_bytes());
        out[32..40].copy_from_slice(&self.cycles.to_le_bytes());
        out[40..48].copy_from_slice(&self.bytes.to_le_bytes());
    }

    pub fn decode(buf: &[u8; RECORD_BYTES]) -> TraceRecord {
        TraceRecord {
            rank: u32::from_le_bytes(buf[0..4].try_into().unwrap()),
            thread: u16::from_le_bytes(buf[4..6].try_into().unwrap()) as u32,
            kind: buf[6],
            mpi_call: buf[7],
            t_start: f64::from_le_bytes(buf[8..16].try_into().unwrap()),
            t_end: f64::from_le_bytes(buf[16..24].try_into().unwrap()),
            instructions: u64::from_le_bytes(buf[24..32].try_into().unwrap()),
            cycles: u64::from_le_bytes(buf[32..40].try_into().unwrap()),
            bytes: u64::from_le_bytes(buf[40..48].try_into().unwrap()),
        }
    }
}

/// Streaming writer: one binary file per rank in `dir`.
pub struct TraceWriter {
    writers: Vec<BufWriter<std::fs::File>>,
    pub records_written: u64,
    dir: PathBuf,
}

impl TraceWriter {
    pub fn create(dir: &Path, ranks: u32, ext: &str) -> Result<TraceWriter> {
        std::fs::create_dir_all(dir)?;
        let writers = (0..ranks)
            .map(|r| {
                let path = dir.join(format!("rank_{r:05}.{ext}"));
                Ok(BufWriter::with_capacity(
                    1 << 20,
                    std::fs::File::create(&path).with_context(|| {
                        format!("creating {}", path.display())
                    })?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TraceWriter {
            writers,
            records_written: 0,
            dir: dir.to_path_buf(),
        })
    }

    pub fn write(&mut self, rec: &TraceRecord) -> Result<()> {
        let mut buf = [0u8; RECORD_BYTES];
        rec.encode(&mut buf);
        self.writers[rec.rank as usize].write_all(&buf)?;
        self.records_written += 1;
        Ok(())
    }

    pub fn finish(mut self) -> Result<(PathBuf, u64)> {
        for w in &mut self.writers {
            w.flush()?;
        }
        Ok((self.dir, self.records_written))
    }
}

/// Read every record of one rank file.
pub fn read_rank_file(path: &Path) -> Result<Vec<TraceRecord>> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let len = file.metadata()?.len();
    if len % RECORD_BYTES as u64 != 0 {
        bail!(
            "{}: size {len} not a multiple of record size",
            path.display()
        );
    }
    let mut reader = BufReader::with_capacity(1 << 20, file);
    let mut out = Vec::with_capacity((len / RECORD_BYTES as u64) as usize);
    let mut buf = [0u8; RECORD_BYTES];
    loop {
        match reader.read_exact(&mut buf) {
            Ok(()) => out.push(TraceRecord::decode(&buf)),
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(out)
}

/// All rank files of a trace directory, sorted.
pub fn rank_files(dir: &Path, ext: &str) -> Vec<PathBuf> {
    crate::util::fs::files_with_ext(dir, ext)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn rec(rank: u32, kind: u8) -> TraceRecord {
        TraceRecord {
            rank,
            thread: 3,
            t_start: 1.25,
            t_end: 2.5,
            kind,
            mpi_call: 2,
            instructions: 123_456_789,
            cycles: 987_654,
            bytes: 4096,
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let r = rec(7, KIND_MPI);
        let mut buf = [0u8; RECORD_BYTES];
        r.encode(&mut buf);
        assert_eq!(TraceRecord::decode(&buf), r);
    }

    #[test]
    fn write_read_multi_rank() {
        let td = TempDir::new("trace").unwrap();
        let mut w = TraceWriter::create(td.path(), 3, "prv").unwrap();
        for i in 0..100u32 {
            w.write(&rec(i % 3, KIND_USEFUL)).unwrap();
        }
        let (dir, n) = w.finish().unwrap();
        assert_eq!(n, 100);
        let files = rank_files(&dir, "prv");
        assert_eq!(files.len(), 3);
        let r0 = read_rank_file(&files[0]).unwrap();
        assert_eq!(r0.len(), 34); // ranks 0: i = 0,3,...,99
        assert!(r0.iter().all(|r| r.rank == 0));
    }

    #[test]
    fn file_size_matches_record_count() {
        let td = TempDir::new("tracesz").unwrap();
        let mut w = TraceWriter::create(td.path(), 1, "prv").unwrap();
        for _ in 0..10 {
            w.write(&rec(0, KIND_USEFUL)).unwrap();
        }
        let (dir, _) = w.finish().unwrap();
        assert_eq!(
            crate::util::fs::dir_size(&dir),
            10 * RECORD_BYTES as u64
        );
    }

    #[test]
    fn corrupt_file_rejected() {
        let td = TempDir::new("tracebad").unwrap();
        let p = td.path().join("rank_00000.prv");
        std::fs::write(&p, vec![0u8; RECORD_BYTES + 7]).unwrap();
        assert!(read_rank_file(&p).is_err());
    }

    #[test]
    fn kind_roundtrip() {
        for k in [
            PhaseKind::Useful,
            PhaseKind::Mpi,
            PhaseKind::OmpSerialization,
            PhaseKind::MpiWorkerIdle,
            PhaseKind::OmpScheduling,
            PhaseKind::OmpBarrier,
            PhaseKind::Io,
        ] {
            assert_eq!(phase_kind(kind_code(k)), Some(k));
        }
        assert_eq!(phase_kind(99), None);
    }
}
