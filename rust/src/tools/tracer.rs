//! Extrae-like full tracer (the BSC toolchain's collection side).
//!
//! Streams every event — including each dynamic-scheduling chunk — as a
//! fixed-size binary record to per-rank files, with hardware counters
//! read at every boundary.  Costs mirror that architecture: the highest
//! per-event overhead of the four tools (Table 1) and trace volumes that
//! dwarf TALP's JSON (Table 2), plus periodic buffer-flush stalls.

use std::path::Path;

use anyhow::Result;

use crate::sim::{CostModel, Event, EventSink, RegionMark};
use crate::util::json::Json;

use super::trace::{TraceRecord, TraceWriter};

/// Extrae-like cost model (see DESIGN.md §5 calibration).
pub const EXTRAE_COST: CostModel = CostModel {
    per_event_s: 8.5e-7,
    per_counter_read_s: 1.1e-6,
    per_region_s: 9.0e-7,
    per_mpi_s: 1.6e-6,
    flush_every_bytes: 8 << 20,
    flush_stall_s: 2.0e-3,
    bytes_per_event: super::trace::RECORD_BYTES as u64,
};

pub struct ExtraeSink {
    writer: Option<TraceWriter>,
    regions: Vec<String>,
    records: u64,
    io_error: Option<anyhow::Error>,
}

impl ExtraeSink {
    pub fn create(dir: &Path, ranks: u32) -> Result<ExtraeSink> {
        Ok(ExtraeSink {
            writer: Some(TraceWriter::create(dir, ranks, "prv")?),
            regions: Vec::new(),
            records: 0,
            io_error: None,
        })
    }

    fn region_id(&mut self, name: &str) -> u64 {
        if let Some(i) = self.regions.iter().position(|r| r == name) {
            return i as u64;
        }
        self.regions.push(name.to_string());
        (self.regions.len() - 1) as u64
    }

    fn write(&mut self, rec: TraceRecord) {
        if self.io_error.is_some() {
            return;
        }
        if let Some(w) = &mut self.writer {
            if let Err(e) = w.write(&rec) {
                self.io_error = Some(e);
            } else {
                self.records += 1;
            }
        }
    }

    /// Flush files and write the metadata header; returns records written.
    pub fn finish(mut self, dir: &Path) -> Result<u64> {
        if let Some(e) = self.io_error.take() {
            return Err(e);
        }
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        let mut meta = Json::obj();
        meta.set(
            "regions",
            Json::Arr(
                self.regions
                    .iter()
                    .map(|r| Json::Str(r.clone()))
                    .collect(),
            ),
        );
        std::fs::write(dir.join("regions.json"), meta.to_string_pretty())?;
        Ok(self.records)
    }
}

impl EventSink for ExtraeSink {
    fn name(&self) -> &str {
        "extrae"
    }

    fn cost_model(&self) -> CostModel {
        EXTRAE_COST
    }

    fn on_event(&mut self, ev: &Event) {
        let n = ev.sub_events.max(1);
        if n == 1 {
            self.write(TraceRecord::from_event(ev));
            return;
        }
        // Expand fine-grained chunks into real records — this is what
        // makes trace files explode at fine granularity.
        let dt = (ev.t_end - ev.t_start) / n as f64;
        let insn = ev.instructions / n;
        let cyc = ev.cycles / n;
        for i in 0..n {
            let mut sub = ev.clone();
            sub.t_start = ev.t_start + dt * i as f64;
            sub.t_end = ev.t_start + dt * (i + 1) as f64;
            sub.instructions = insn;
            sub.cycles = cyc;
            sub.sub_events = 1;
            self.write(TraceRecord::from_event(&sub));
        }
    }

    fn on_region(&mut self, mark: &RegionMark) {
        let id = self.region_id(&mark.name);
        self.write(TraceRecord::from_region(mark, id));
    }

    fn on_finalize(&mut self, _elapsed: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Synthetic, Workload};
    use crate::sim::{
        self, MachineSpec, OmpSchedule, ResourceConfig, RunConfig,
    };
    use crate::util::fs::TempDir;

    #[test]
    fn traces_chunks_as_individual_records() {
        let td = TempDir::new("extrae").unwrap();
        let app = Synthetic {
            phases: 3,
            schedule: OmpSchedule::Dynamic { chunks: 64 },
            ..Synthetic::default()
        };
        let res = ResourceConfig::new(2, 4);
        let cfg = RunConfig::new(MachineSpec::marenostrum5(), res.clone());
        let mut sink = ExtraeSink::create(td.path(), 2).unwrap();
        let prog = app.build(&res, &cfg.machine);
        sim::run(&prog, &cfg, &mut [&mut sink]);
        let n = sink.finish(td.path()).unwrap();
        // 3 phases x 2 ranks x 64 chunks = 384 useful records minimum.
        assert!(n >= 384, "{n}");
        let files = super::super::trace::rank_files(td.path(), "prv");
        assert_eq!(files.len(), 2);
        let recs = super::super::trace::read_rank_file(&files[0]).unwrap();
        assert!(recs.iter().any(|r| r.kind == super::super::trace::KIND_MPI));
        assert!(recs
            .iter()
            .any(|r| r.kind == super::super::trace::KIND_REGION_ENTER));
    }

    #[test]
    fn chunk_expansion_preserves_totals() {
        let td = TempDir::new("extrae2").unwrap();
        let mut sink = ExtraeSink::create(td.path(), 1).unwrap();
        let ev = Event {
            rank: 0,
            thread: 0,
            t_start: 0.0,
            t_end: 1.0,
            kind: crate::sim::PhaseKind::Useful,
            instructions: 1000,
            cycles: 500,
            mpi_call: None,
            bytes: 0,
            sub_events: 10,
        };
        sink.on_event(&ev);
        sink.finish(td.path()).unwrap();
        let files = super::super::trace::rank_files(td.path(), "prv");
        let recs = super::super::trace::read_rank_file(&files[0]).unwrap();
        assert_eq!(recs.len(), 10);
        let total_insn: u64 = recs.iter().map(|r| r.instructions).sum();
        assert_eq!(total_insn, 1000);
        assert!((recs.last().unwrap().t_end - 1.0).abs() < 1e-12);
    }
}
