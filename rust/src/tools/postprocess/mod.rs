//! Post-processing chains of the trace-based tool suites:
//! [`merge`] (trace loading + event attribution), [`scalasca`] (JSC
//! parallel replay), [`dimemas`] (BSC sequential network replay) and
//! [`basicanalysis`] (final table synthesis with the comm split).

pub mod basicanalysis;
pub mod dimemas;
pub mod merge;
pub mod scalasca;
