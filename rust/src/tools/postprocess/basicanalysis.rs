//! Basicanalysis-like table generation: turns reconstructed run data
//! plus a communication split into the final scaling-efficiency table
//! (the BSC chain's last step; also reused for the CPT's table).

use crate::pop::{self, Row, ScalingTable};
use crate::talp::RunData;

/// Per-config transfer/wait seconds per rank (from dimemas::replay or
/// the CPT's online piggybacking).
#[derive(Debug, Clone, Default)]
pub struct CommSplitPerConfig {
    pub wait_s: Vec<f64>,
    pub transfer_s: Vec<f64>,
}

/// Build the table and append the MPI Serialization/Transfer efficiency
/// rows (the split only trace-replay or vector-clock tools can compute).
///
/// Definitions (consistent with pop::metrics):
///   SerE     = max_p(E_p - wait_p) / E   (efficiency on an ideal network)
///   TransferE = CommE / SerE
pub fn table_with_comm_split(
    region: &str,
    runs: &[&RunData],
    splits: &[CommSplitPerConfig],
) -> Option<ScalingTable> {
    assert_eq!(runs.len(), splits.len());
    let mut table = pop::build(region, runs)?;

    // Recover the column order the table used (sorted by resources).
    let mut order: Vec<usize> = (0..runs.len()).collect();
    order.sort_by_key(|&i| {
        (runs[i].resources().total_cpus(), runs[i].ranks, runs[i].threads)
    });

    let mut ser_cells = Vec::with_capacity(order.len());
    let mut xfer_cells = Vec::with_capacity(order.len());
    for (col, &i) in order.iter().enumerate() {
        let run = runs[i];
        let split = &splits[i];
        let Some(reg) = run.region(region) else {
            ser_cells.push(None);
            xfer_cells.push(None);
            continue;
        };
        let e = reg.elapsed_s.max(1e-12);
        let ser = reg
            .procs
            .iter()
            .map(|p| {
                let wait =
                    split.wait_s.get(p.rank as usize).copied().unwrap_or(0.0);
                (p.elapsed_s - wait).max(0.0)
            })
            .fold(0.0f64, f64::max)
            / e;
        let ser = ser.clamp(0.0, 1.0);
        let comm_e = table.cell("MPI Communication efficiency", col);
        let xfer = comm_e.map(|c| if ser > 0.0 { (c / ser).clamp(0.0, 1.0) } else { 0.0 });
        ser_cells.push(Some(ser));
        xfer_cells.push(xfer);
    }
    let ncols = table.columns.len();
    table.insert_after(
        "MPI Communication efficiency",
        Row {
            label: "MPI Serialization efficiency".into(),
            depth: 4,
            cells: ser_cells.into_iter().take(ncols).collect(),
            is_footer: false,
        },
    );
    table.insert_after(
        "MPI Serialization efficiency",
        Row {
            label: "MPI Transfer efficiency".into(),
            depth: 4,
            cells: xfer_cells.into_iter().take(ncols).collect(),
            is_footer: false,
        },
    );
    Some(table)
}

/// Blank the counter-derived rows (what the CPT cannot measure).
pub fn blank_counter_rows(table: &mut ScalingTable) {
    for label in [
        "Global efficiency",
        "Computation scalability",
        "Instructions scaling",
        "IPC scaling",
        "Frequency scaling",
        "Useful IPC",
        "Frequency [GHz]",
    ] {
        table.blank_row(label);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::{ProcStats, RegionData};

    fn run(ranks: u32, useful: f64, mpi: f64, e: f64) -> RunData {
        let procs = (0..ranks)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: e,
                useful_s: useful,
                mpi_s: mpi,
                useful_instructions: 1000,
                useful_cycles: 500,
                ..Default::default()
            })
            .collect();
        RunData {
            dlb_version: "t".into(),
            app: "t".into(),
            machine: "mn5".into(),
            timestamp: 0,
            ranks,
            threads: 1,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: e,
                visits: 1,
                procs,
            }],
            git: None,
        }
    }

    #[test]
    fn split_rows_inserted_and_bounded() {
        let a = run(2, 8.0, 2.0, 10.0);
        let b = run(4, 3.5, 1.5, 5.0);
        let splits = vec![
            CommSplitPerConfig {
                wait_s: vec![1.5, 0.5],
                transfer_s: vec![0.5, 0.5],
            },
            CommSplitPerConfig {
                wait_s: vec![1.0, 0.2, 0.2, 0.2],
                transfer_s: vec![0.5, 0.3, 0.3, 0.3],
            },
        ];
        let t = table_with_comm_split("Global", &[&a, &b], &splits).unwrap();
        for col in 0..2 {
            let ser = t.cell("MPI Serialization efficiency", col).unwrap();
            let xfer = t.cell("MPI Transfer efficiency", col).unwrap();
            let comm = t.cell("MPI Communication efficiency", col).unwrap();
            assert!((0.0..=1.0).contains(&ser));
            assert!((0.0..=1.0).contains(&xfer));
            // product reconstructs CommE
            assert!((ser * xfer - comm).abs() < 1e-9, "{ser}*{xfer} != {comm}");
            assert!(ser >= comm - 1e-9, "ideal network can't be worse");
        }
    }

    #[test]
    fn blanking_counter_rows() {
        let a = run(2, 8.0, 2.0, 10.0);
        let splits = vec![CommSplitPerConfig::default()];
        let mut t = table_with_comm_split("Global", &[&a], &splits).unwrap();
        blank_counter_rows(&mut t);
        assert_eq!(t.cell("IPC scaling", 0), None);
        assert_eq!(t.cell("Global efficiency", 0), None);
        assert!(t.cell("Parallel efficiency", 0).is_some());
    }
}
