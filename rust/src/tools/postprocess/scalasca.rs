//! Scalasca-like parallel trace analysis (the JSC chain).
//!
//! Scalasca replays the Score-P trace *in parallel* (one analysis
//! process per application rank) to classify wait states, then merges
//! with the profiling run into a Cube file.  Our version parallelizes
//! the per-region reconstruction across OS threads and writes a
//! cube-like JSON — faster and leaner than the sequential Dimemas
//! replay, which is exactly the JSC-vs-BSC gap in Table 2.

use std::path::Path;

use anyhow::Result;

use crate::talp::RegionData;
use crate::tools::resources::ResourceMeter;
use crate::util::json::Json;

use super::merge::{self, LoadedTrace};

/// Analyze `regions` of a loaded trace; writes `cube.json` to
/// `out_path` and returns the reconstructed per-region data.
pub fn analyze(
    trace: &LoadedTrace,
    regions: &[String],
    node_of_rank: &(dyn Fn(u32) -> u32 + Sync),
    out_path: &Path,
    meter: &mut ResourceMeter,
) -> Result<Vec<RegionData>> {
    // Parallel replay: one worker per region (bounded by the host).
    let results: Vec<Option<RegionData>> = std::thread::scope(|scope| {
        let handles: Vec<_> = regions
            .iter()
            .map(|r| {
                let name = r.clone();
                scope.spawn(move || merge::region_data(trace, &name, node_of_rank))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let found: Vec<RegionData> = results.into_iter().flatten().collect();

    // Cube-like output (the artifact CubeGUI would read).
    let mut cube = Json::obj();
    cube.set("format", Json::Str("cube-sim".into()));
    let mut regs = Json::obj();
    for rd in &found {
        let procs: Vec<Json> = rd
            .procs
            .iter()
            .map(|p| {
                Json::from_pairs(vec![
                    ("rank", Json::Num(p.rank as f64)),
                    ("useful_s", Json::Num(p.useful_s)),
                    ("mpi_s", Json::Num(p.mpi_s)),
                    (
                        "instructions",
                        Json::Num(p.useful_instructions as f64),
                    ),
                    ("cycles", Json::Num(p.useful_cycles as f64)),
                ])
            })
            .collect();
        regs.set(&rd.name, Json::Arr(procs));
    }
    cube.set("regions", regs);
    let text = cube.to_string_pretty();
    meter.storage(text.len() as u64);
    meter.alloc(text.len() as u64);
    std::fs::write(out_path, &text)?;
    meter.free(text.len() as u64);
    Ok(found)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Synthetic, Workload};
    use crate::sim::{self, MachineSpec, ResourceConfig, RunConfig};
    use crate::tools::scorep::ScorepTraceSink;
    use crate::util::fs::TempDir;

    #[test]
    fn analyzes_all_regions_in_parallel() {
        let app = Synthetic { phases: 5, ..Synthetic::default() };
        let res = ResourceConfig::new(2, 4);
        let machine = MachineSpec::marenostrum5();
        let cfg = RunConfig::new(machine.clone(), res.clone());
        let td = TempDir::new("scalasca").unwrap();
        let mut sink = ScorepTraceSink::create(td.path(), 2).unwrap();
        sim::run(&app.build(&res, &machine), &cfg, &mut [&mut sink]);
        sink.finish(td.path()).unwrap();

        let mut meter = ResourceMeter::new();
        let trace = merge::load(td.path(), "otf2", &mut meter).unwrap();
        let cube = td.path().join("cube.json");
        let out = analyze(
            &trace,
            &["Global".into(), "work".into()],
            &|_| 0,
            &cube,
            &mut meter,
        )
        .unwrap();
        assert_eq!(out.len(), 2);
        assert!(cube.exists());
        assert!(out.iter().all(|r| r.procs.len() == 2));
        // Global covers work.
        let g = out.iter().find(|r| r.name == "Global").unwrap();
        let w = out.iter().find(|r| r.name == "work").unwrap();
        let useful = |r: &RegionData| -> f64 {
            r.procs.iter().map(|p| p.useful_s).sum()
        };
        assert!(useful(g) >= useful(w) - 1e-9);
    }
}
