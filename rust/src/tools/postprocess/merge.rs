//! Trace loading and event->region attribution (the "merge" step both
//! trace-based chains start with).
//!
//! This is where Table 2's memory floor comes from: the whole trace is
//! materialized in memory before analysis can start (the paper's 19-138
//! GB), metered through [`ResourceMeter`].

use std::path::Path;

use anyhow::{Context, Result};

use crate::talp::{ProcStats, RegionData};
use crate::tools::resources::ResourceMeter;
use crate::tools::trace::{
    self, TraceRecord, KIND_REGION_ENTER, KIND_REGION_EXIT, RECORD_BYTES,
};
use crate::util::json::Json;

/// A trace fully loaded in memory.
pub struct LoadedTrace {
    /// Records per rank, in file order (time-ordered per rank).
    pub per_rank: Vec<Vec<TraceRecord>>,
    pub region_names: Vec<String>,
    pub total_records: u64,
}

/// Load every rank file of `dir` (extension `ext`), metering memory and
/// storage.
pub fn load(dir: &Path, ext: &str, meter: &mut ResourceMeter) -> Result<LoadedTrace> {
    let files = trace::rank_files(dir, ext);
    anyhow::ensure!(!files.is_empty(), "no trace files in {}", dir.display());
    let mut per_rank = Vec::with_capacity(files.len());
    let mut total = 0u64;
    for f in &files {
        let recs = trace::read_rank_file(f)
            .with_context(|| format!("loading {}", f.display()))?;
        meter.alloc((recs.len() * std::mem::size_of::<TraceRecord>()) as u64);
        meter.storage((recs.len() * RECORD_BYTES) as u64);
        total += recs.len() as u64;
        per_rank.push(recs);
    }
    let region_names = read_region_names(dir)?;
    Ok(LoadedTrace { per_rank, region_names, total_records: total })
}

fn read_region_names(dir: &Path) -> Result<Vec<String>> {
    let p = dir.join("regions.json");
    let text = std::fs::read_to_string(&p)
        .with_context(|| format!("reading {}", p.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(j.get("regions")
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect()
        })
        .unwrap_or_default())
}

/// Reconstruct one region's per-process stats from a loaded trace —
/// what Scalasca/Basicanalysis derive during replay.
///
/// `node_of_rank` supplies placement (from the run's meta.json).
pub fn region_data(
    trace: &LoadedTrace,
    region: &str,
    node_of_rank: &dyn Fn(u32) -> u32,
) -> Option<RegionData> {
    let region_id = trace
        .region_names
        .iter()
        .position(|n| n == region)? as u64;
    let mut procs: Vec<ProcStats> = Vec::with_capacity(trace.per_rank.len());
    let mut max_elapsed = 0.0f64;
    let mut visits = 0u64;
    for (rank, recs) in trace.per_rank.iter().enumerate() {
        // Pass 1: the region's open intervals on this rank.
        let mut windows: Vec<(f64, f64)> = Vec::new();
        let mut open: Option<f64> = None;
        for r in recs {
            if r.instructions == region_id {
                if r.kind == KIND_REGION_ENTER {
                    open = Some(r.t_start);
                } else if r.kind == KIND_REGION_EXIT {
                    if let Some(t0) = open.take() {
                        windows.push((t0, r.t_start));
                    }
                }
            }
        }
        if let Some(t0) = open {
            // unterminated (crashed run): close at last record time.
            let t_last = recs.last().map(|r| r.t_end).unwrap_or(t0);
            windows.push((t0, t_last));
        }
        if rank == 0 {
            visits = windows.len() as u64;
        }
        let elapsed: f64 = windows.iter().map(|(a, b)| (b - a).max(0.0)).sum();
        max_elapsed = max_elapsed.max(elapsed);

        // Pass 2: accumulate phases falling inside the windows.
        let mut p = ProcStats {
            rank: rank as u32,
            node: node_of_rank(rank as u32),
            elapsed_s: elapsed,
            ..Default::default()
        };
        let mut wi = 0usize;
        for r in recs {
            if r.kind == KIND_REGION_ENTER || r.kind == KIND_REGION_EXIT {
                continue;
            }
            // advance window cursor (records are time-ordered per rank)
            while wi < windows.len() && r.t_start >= windows[wi].1 {
                wi += 1;
            }
            if wi >= windows.len() {
                break;
            }
            if r.t_start < windows[wi].0 {
                continue;
            }
            let dur = (r.t_end - r.t_start).max(0.0);
            match r.kind {
                trace::KIND_USEFUL => {
                    p.useful_s += dur;
                    p.useful_instructions += r.instructions;
                    p.useful_cycles += r.cycles;
                }
                trace::KIND_IO => p.useful_s += dur,
                trace::KIND_MPI => p.mpi_s += dur,
                trace::KIND_MPI_WORKER_IDLE => p.mpi_worker_idle_s += dur,
                trace::KIND_OMP_SERIAL => p.omp_serialization_s += dur,
                trace::KIND_OMP_SCHED => p.omp_scheduling_s += dur,
                trace::KIND_OMP_BARRIER => p.omp_barrier_s += dur,
                _ => {}
            }
        }
        procs.push(p);
    }
    Some(RegionData {
        name: region.to_string(),
        elapsed_s: max_elapsed,
        visits,
        procs,
    })
}

/// Free a loaded trace's metered memory.
pub fn unload(trace: LoadedTrace, meter: &mut ResourceMeter) {
    for recs in &trace.per_rank {
        meter.free((recs.len() * std::mem::size_of::<TraceRecord>()) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Synthetic, Workload};
    use crate::pop;
    use crate::sim::{self, MachineSpec, ResourceConfig, RunConfig};
    use crate::talp::TalpMonitor;
    use crate::tools::tracer::ExtraeSink;
    use crate::util::fs::TempDir;

    /// Trace-reconstructed metrics must agree with TALP's on-the-fly
    /// ones — this is the Tables 6/7 "all tools tell the same story"
    /// property, as a test.
    #[test]
    fn trace_reconstruction_matches_talp() {
        let app = Synthetic {
            phases: 8,
            rank_weights: vec![1.0, 1.3],
            serial_fraction: 0.2,
            ..Synthetic::default()
        };
        let res = ResourceConfig::new(2, 4);
        let machine = MachineSpec::marenostrum5();
        let prog = app.build(&res, &machine);

        // TALP run.
        let cfg = RunConfig::new(machine.clone(), res.clone()).with_seed(5);
        let mut talp = TalpMonitor::new(2, 4);
        sim::run(&prog, &cfg, &mut [&mut talp]);
        let talp_data = crate::talp::RunData::from_report(
            &talp.finalize(),
            "synthetic",
            &machine,
            &res,
            0,
        );

        // Extrae run (same seed; slightly different perturbation).
        let td = TempDir::new("merge").unwrap();
        let mut sink = ExtraeSink::create(td.path(), 2).unwrap();
        sim::run(&prog, &cfg, &mut [&mut sink]);
        sink.finish(td.path()).unwrap();

        let mut meter = ResourceMeter::new();
        let trace = load(td.path(), "prv", &mut meter).unwrap();
        let reg = region_data(&trace, "work", &|_| 0).unwrap();
        let talp_reg = talp_data.region("work").unwrap();

        let mt = pop::compute(talp_reg, 4);
        let mx = pop::compute(&reg, 4);
        assert!(
            (mt.parallel_efficiency - mx.parallel_efficiency).abs() < 0.05,
            "PE: talp {} vs trace {}",
            mt.parallel_efficiency,
            mx.parallel_efficiency
        );
        assert!(
            (mt.mpi_load_balance - mx.mpi_load_balance).abs() < 0.05
        );
        // Counters identical up to chunk-split rounding.
        let rel = (mt.total_useful_instructions as f64
            - mx.total_useful_instructions as f64)
            .abs()
            / mt.total_useful_instructions as f64;
        assert!(rel < 0.01, "instructions differ {rel}");
        assert!(meter.usage().peak_memory_bytes > 0);
        assert!(meter.usage().storage_bytes > 0);
    }

    #[test]
    fn missing_region_returns_none() {
        let td = TempDir::new("merge2").unwrap();
        let app = Synthetic::default();
        let res = ResourceConfig::new(1, 2);
        let machine = MachineSpec::marenostrum5();
        let cfg = RunConfig::new(machine.clone(), res.clone());
        let mut sink = ExtraeSink::create(td.path(), 1).unwrap();
        sim::run(&app.build(&res, &machine), &cfg, &mut [&mut sink]);
        sink.finish(td.path()).unwrap();
        let mut meter = ResourceMeter::new();
        let trace = load(td.path(), "prv", &mut meter).unwrap();
        assert!(region_data(&trace, "nonexistent", &|_| 0).is_none());
        assert!(region_data(&trace, "Global", &|_| 0).is_some());
    }

    #[test]
    fn load_rejects_empty_dir() {
        let td = TempDir::new("merge3").unwrap();
        let mut meter = ResourceMeter::new();
        assert!(load(td.path(), "prv", &mut meter).is_err());
    }
}
