//! Dimemas-like sequential network replay (the BSC chain's slow step).
//!
//! Dimemas re-simulates the whole execution through a network model to
//! split MPI time into *data transfer* and *serialization/wait*.  It is
//! single-threaded and touches every record in global time order —
//! that's the 10^3-10^4 s row of Table 2.  Our replay does the same
//! thing: merge all ranks' records into one time-ordered stream (real
//! O(N log N) work on the real trace), then walk it with a per-rank
//! network state machine.

use crate::tools::resources::ResourceMeter;
use crate::tools::trace::{TraceRecord, KIND_MPI};

use super::merge::LoadedTrace;

/// Per-rank communication split produced by the replay.
#[derive(Debug, Clone, Default)]
pub struct CommSplit {
    /// Wait-for-partner seconds per rank.
    pub wait_s: Vec<f64>,
    /// Wire-transfer seconds per rank.
    pub transfer_s: Vec<f64>,
    pub replayed_events: u64,
}

/// Network parameters of the replay model (Dimemas asks for these on its
/// command line; defaults roughly match the MN5 models in sim::machine).
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    pub latency_s: f64,
    pub bandwidth_bps: f64,
}

impl Default for NetworkModel {
    fn default() -> NetworkModel {
        NetworkModel { latency_s: 1.6e-6, bandwidth_bps: 12.5e9 }
    }
}

/// Sequential replay over the merged stream.
pub fn replay(
    trace: &LoadedTrace,
    net: NetworkModel,
    meter: &mut ResourceMeter,
) -> CommSplit {
    let ranks = trace.per_rank.len();
    // Merge all ranks by start time — the expensive, memory-hungry step.
    let total: usize = trace.per_rank.iter().map(Vec::len).sum();
    meter.alloc((total * std::mem::size_of::<TraceRecord>()) as u64);
    let mut merged: Vec<&TraceRecord> = Vec::with_capacity(total);
    for recs in &trace.per_rank {
        merged.extend(recs.iter());
    }
    merged.sort_by(|a, b| {
        a.t_start
            .partial_cmp(&b.t_start)
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    // State machine: group MPI records of one collective instance (same
    // exit time) and charge wait = last_arrival - own_arrival,
    // transfer = modelled wire time, capped by the observed interval.
    let mut split = CommSplit {
        wait_s: vec![0.0; ranks],
        transfer_s: vec![0.0; ranks],
        replayed_events: 0,
    };
    let mut group: Vec<&TraceRecord> = Vec::new();
    let mut group_end = f64::NAN;
    for rec in merged {
        split.replayed_events += 1;
        if rec.kind != KIND_MPI {
            continue;
        }
        if !group.is_empty() && (rec.t_end - group_end).abs() > 1e-12 {
            resolve(&group, net, &mut split);
            group.clear();
        }
        group_end = rec.t_end;
        group.push(rec);
    }
    if !group.is_empty() {
        resolve(&group, net, &mut split);
    }
    meter.free((total * std::mem::size_of::<TraceRecord>()) as u64);
    split
}

fn resolve(group: &[&TraceRecord], net: NetworkModel, split: &mut CommSplit) {
    let last_arrival = group
        .iter()
        .map(|r| r.t_start)
        .fold(f64::NEG_INFINITY, f64::max);
    for rec in group {
        let dur = (rec.t_end - rec.t_start).max(0.0);
        let wire = net.latency_s + rec.bytes as f64 / net.bandwidth_bps;
        let wait = (last_arrival - rec.t_start).max(0.0).min(dur);
        let transfer = wire.min(dur - wait);
        let r = rec.rank as usize;
        split.wait_s[r] += wait;
        split.transfer_s[r] += transfer.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Synthetic, Workload};
    use crate::sim::{self, MachineSpec, ResourceConfig, RunConfig};
    use crate::tools::postprocess::merge;
    use crate::tools::tracer::ExtraeSink;
    use crate::util::fs::TempDir;

    fn traced_run(rank_weights: Vec<f64>) -> (TempDir, u32) {
        let app = Synthetic {
            phases: 6,
            rank_weights,
            mpi_bytes: 1 << 18,
            ..Synthetic::default()
        };
        let res = ResourceConfig::new(2, 4);
        let machine = MachineSpec::marenostrum5();
        let cfg = RunConfig::new(machine.clone(), res.clone()).with_seed(2);
        let td = TempDir::new("dimemas").unwrap();
        let mut sink = ExtraeSink::create(td.path(), 2).unwrap();
        sim::run(&app.build(&res, &machine), &cfg, &mut [&mut sink]);
        sink.finish(td.path()).unwrap();
        (td, 2)
    }

    #[test]
    fn imbalance_shows_as_wait_on_light_rank() {
        let (td, _) = traced_run(vec![1.0, 1.8]);
        let mut meter = ResourceMeter::new();
        let trace = merge::load(td.path(), "prv", &mut meter).unwrap();
        let split = replay(&trace, NetworkModel::default(), &mut meter);
        assert!(split.replayed_events > 0);
        assert!(
            split.wait_s[0] > 3.0 * split.wait_s[1].max(1e-12),
            "wait {:?}",
            split.wait_s
        );
        // Transfer time exists and is symmetric-ish.
        assert!(split.transfer_s.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn wait_plus_transfer_bounded_by_interval() {
        let (td, _) = traced_run(vec![1.0, 1.2]);
        let mut meter = ResourceMeter::new();
        let trace = merge::load(td.path(), "prv", &mut meter).unwrap();
        let split = replay(&trace, NetworkModel::default(), &mut meter);
        // Total MPI time per rank from the trace:
        for (r, recs) in trace.per_rank.iter().enumerate() {
            let mpi: f64 = recs
                .iter()
                .filter(|x| x.kind == KIND_MPI)
                .map(|x| x.t_end - x.t_start)
                .sum();
            assert!(
                split.wait_s[r] + split.transfer_s[r] <= mpi + 1e-9,
                "rank {r}"
            );
        }
    }

    #[test]
    fn replay_meters_memory() {
        let (td, _) = traced_run(vec![1.0]);
        let mut meter = ResourceMeter::new();
        let trace = merge::load(td.path(), "prv", &mut meter).unwrap();
        let before = meter.usage().peak_memory_bytes;
        let _ = replay(&trace, NetworkModel::default(), &mut meter);
        assert!(meter.usage().peak_memory_bytes > before);
    }
}
