//! Critical-Path-Tool-like collector [Schwitanski et al. 2022].
//!
//! On-the-fly like TALP, but built on vector clocks piggybacked on MPI
//! messages rather than hardware counters: it can split communication
//! time into *wait* (serialization) and *transfer*, which TALP cannot,
//! but it reads no counters, so the computation-scalability half of the
//! table stays empty (the "-" cells of Tables 6/7).
//!
//! The vector-clock exchange is modelled by grouping MPI events per
//! collective instance: the piggybacked clocks tell each rank the last
//! arrival, i.e. exactly `wait = last_arrival - own_arrival` and
//! `transfer = exit - last_arrival`.

use std::path::Path;

use anyhow::Result;

use crate::sim::{CostModel, Event, EventSink, PhaseKind, RegionMark};
use crate::util::json::Json;

pub const CPT_COST: CostModel = CostModel {
    per_event_s: 9.0e-7,
    per_counter_read_s: 0.0, // no hardware counters — the tool's gap
    per_region_s: 3.0e-7,
    per_mpi_s: 2.4e-6, // piggyback payload on every call
    flush_every_bytes: 0,
    flush_stall_s: 0.0,
    bytes_per_event: 0,
};

#[derive(Debug, Clone, Copy, Default)]
struct CpuTimes {
    useful_s: f64,
    mpi_s: f64,
    mpi_wait_s: f64,
    mpi_transfer_s: f64,
    mpi_worker_idle_s: f64,
    omp_serialization_s: f64,
    omp_scheduling_s: f64,
    omp_barrier_s: f64,
}

/// Per-region matrix of times (region -> rank -> aggregate over threads).
pub struct CptSink {
    ranks: usize,
    regions: Vec<(String, Vec<CpuTimes>, Vec<f64>, Vec<Option<f64>>)>,
    open: Vec<Vec<usize>>,
    /// Pending MPI arrivals of the current collective instance, per
    /// region: (region idx agnostic) — grouped by identical t_end.
    pending_mpi: Vec<(u32, f64, f64)>, // (rank, t_start, t_end)
    elapsed: f64,
}

impl CptSink {
    pub fn new(ranks: u32) -> CptSink {
        let mut s = CptSink {
            ranks: ranks as usize,
            regions: Vec::new(),
            open: vec![Vec::new(); ranks as usize],
            pending_mpi: Vec::new(),
            elapsed: 0.0,
        };
        s.region_id("Global");
        s
    }

    fn region_id(&mut self, name: &str) -> usize {
        if let Some(i) = self.regions.iter().position(|(n, ..)| n == name) {
            return i;
        }
        self.regions.push((
            name.to_string(),
            vec![CpuTimes::default(); self.ranks],
            vec![0.0; self.ranks],
            vec![None; self.ranks],
        ));
        self.regions.len() - 1
    }

    /// A collective instance is complete when all ranks reported an MPI
    /// event with the same exit time; resolve wait/transfer then.
    fn resolve_mpi_group(&mut self) {
        if self.pending_mpi.is_empty() {
            return;
        }
        let last_arrival = self
            .pending_mpi
            .iter()
            .map(|(_, s, _)| *s)
            .fold(f64::NEG_INFINITY, f64::max);
        let group = std::mem::take(&mut self.pending_mpi);
        for (rank, t_start, t_end) in group {
            let wait = (last_arrival - t_start).max(0.0);
            let transfer = (t_end - last_arrival).max(0.0);
            for idx in self.open[rank as usize].clone() {
                let times = &mut self.regions[idx].1[rank as usize];
                times.mpi_wait_s += wait;
                times.mpi_transfer_s += transfer;
            }
        }
    }

    pub fn write_summary(&self, path: &Path) -> Result<()> {
        let mut regions = Json::obj();
        for (name, times, elapsed, _) in &self.regions {
            let procs: Vec<Json> = times
                .iter()
                .enumerate()
                .map(|(r, t)| {
                    Json::from_pairs(vec![
                        ("rank", Json::Num(r as f64)),
                        ("elapsed_s", Json::Num(elapsed[r])),
                        ("useful_s", Json::Num(t.useful_s)),
                        ("mpi_s", Json::Num(t.mpi_s)),
                        ("mpi_wait_s", Json::Num(t.mpi_wait_s)),
                        ("mpi_transfer_s", Json::Num(t.mpi_transfer_s)),
                        ("mpi_worker_idle_s", Json::Num(t.mpi_worker_idle_s)),
                        (
                            "omp_serialization_s",
                            Json::Num(t.omp_serialization_s),
                        ),
                        ("omp_scheduling_s", Json::Num(t.omp_scheduling_s)),
                        ("omp_barrier_s", Json::Num(t.omp_barrier_s)),
                    ])
                })
                .collect();
            regions.set(name, Json::Arr(procs));
        }
        let mut root = Json::obj();
        root.set("tool", Json::Str("cpt-sim".into()));
        root.set("elapsed_s", Json::Num(self.elapsed));
        root.set("regions", regions);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, root.to_string_pretty())?;
        Ok(())
    }
}

impl EventSink for CptSink {
    fn name(&self) -> &str {
        "cpt"
    }

    fn cost_model(&self) -> CostModel {
        CPT_COST
    }

    fn on_event(&mut self, ev: &Event) {
        let rank = ev.rank as usize;
        let dur = (ev.t_end - ev.t_start).max(0.0);
        if ev.kind == PhaseKind::Mpi {
            // Group by exit time: the engine gives every member of one
            // collective the same t_end.
            if let Some((_, _, te)) = self.pending_mpi.first() {
                if (te - ev.t_end).abs() > 1e-12 {
                    self.resolve_mpi_group();
                }
            }
            self.pending_mpi.push((ev.rank, ev.t_start, ev.t_end));
        }
        for idx in self.open[rank].clone() {
            let times = &mut self.regions[idx].1[rank];
            match ev.kind {
                PhaseKind::Useful | PhaseKind::Io => times.useful_s += dur,
                PhaseKind::Mpi => times.mpi_s += dur,
                PhaseKind::MpiWorkerIdle => times.mpi_worker_idle_s += dur,
                PhaseKind::OmpSerialization => {
                    times.omp_serialization_s += dur
                }
                PhaseKind::OmpScheduling => times.omp_scheduling_s += dur,
                PhaseKind::OmpBarrier => times.omp_barrier_s += dur,
            }
        }
    }

    fn on_region(&mut self, mark: &RegionMark) {
        self.resolve_mpi_group();
        let idx = self.region_id(&mark.name);
        let rank = mark.rank as usize;
        if mark.enter {
            self.regions[idx].3[rank] = Some(mark.t);
            self.open[rank].push(idx);
        } else {
            if let Some(t0) = self.regions[idx].3[rank].take() {
                self.regions[idx].2[rank] += (mark.t - t0).max(0.0);
            }
            if let Some(pos) = self.open[rank].iter().rposition(|&i| i == idx)
            {
                self.open[rank].remove(pos);
            }
        }
    }

    fn on_finalize(&mut self, elapsed: f64) {
        self.resolve_mpi_group();
        self.elapsed = elapsed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Synthetic, Workload};
    use crate::sim::{self, MachineSpec, ResourceConfig, RunConfig};
    use crate::util::fs::TempDir;

    fn run_cpt(rank_weights: Vec<f64>) -> Json {
        let app = Synthetic {
            phases: 6,
            rank_weights,
            mpi_bytes: 1 << 16,
            ..Synthetic::default()
        };
        let res = ResourceConfig::new(2, 4);
        let cfg = RunConfig::new(MachineSpec::marenostrum5(), res.clone());
        let mut sink = CptSink::new(2);
        sim::run(&app.build(&res, &cfg.machine), &cfg, &mut [&mut sink]);
        let td = TempDir::new("cpt").unwrap();
        let p = td.path().join("cpt.json");
        sink.write_summary(&p).unwrap();
        Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap()
    }

    #[test]
    fn wait_plus_transfer_bounded_by_mpi_time() {
        let j = run_cpt(vec![1.0, 1.6]);
        let procs = j.at(&["regions", "Global"]).unwrap().as_arr().unwrap();
        for p in procs {
            let mpi = p.num_or("mpi_s", 0.0);
            let wait = p.num_or("mpi_wait_s", 0.0);
            let xfer = p.num_or("mpi_transfer_s", 0.0);
            assert!(
                wait + xfer <= mpi + 1e-9,
                "wait {wait} + transfer {xfer} > mpi {mpi}"
            );
            assert!(xfer > 0.0);
        }
    }

    #[test]
    fn imbalanced_light_rank_waits_more() {
        let j = run_cpt(vec![1.0, 2.0]); // rank 1 heavy, rank 0 waits
        let procs = j.at(&["regions", "Global"]).unwrap().as_arr().unwrap();
        let wait0 = procs[0].num_or("mpi_wait_s", 0.0);
        let wait1 = procs[1].num_or("mpi_wait_s", 0.0);
        assert!(
            wait0 > 5.0 * wait1.max(1e-12),
            "light rank should wait: {wait0} vs {wait1}"
        );
    }

    #[test]
    fn no_counters_in_summary() {
        let j = run_cpt(vec![1.0]);
        // The CPT summary must carry no instruction/cycle fields.
        assert!(j.to_string_compact().find("instructions").is_none());
    }
}
