//! Score-P-like collection (the JSC toolchain's side).
//!
//! The paper's POP preset runs the application **twice**: a profiling
//! pass (cheap call-path aggregation, no counters) and a tracing pass
//! with hardware counters.  The profile keeps per-(region, rank, thread,
//! kind) aggregates in memory and writes a compact profile file; the
//! trace pass writes OTF2-like per-rank record files that Scalasca
//! post-processes.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::sim::{CostModel, Event, EventSink, RegionMark};
use crate::util::json::Json;

use super::trace::{TraceRecord, TraceWriter};

pub const SCOREP_PROFILE_COST: CostModel = CostModel {
    per_event_s: 3.5e-7,
    per_counter_read_s: 0.0, // profile pass reads no counters
    per_region_s: 4.0e-7,
    per_mpi_s: 6.0e-7,
    flush_every_bytes: 0,
    flush_stall_s: 0.0,
    bytes_per_event: 0,
};

pub const SCOREP_TRACE_COST: CostModel = CostModel {
    per_event_s: 4.5e-7,
    per_counter_read_s: 4.0e-7,
    per_region_s: 5.0e-7,
    per_mpi_s: 8.0e-7,
    flush_every_bytes: 16 << 20,
    flush_stall_s: 1.2e-3,
    bytes_per_event: super::trace::RECORD_BYTES as u64,
};

/// Profiling pass: call-path aggregation.
pub struct ScorepProfileSink {
    /// (region stack top per rank) for call-path attribution.
    stacks: Vec<Vec<String>>,
    /// (region, rank, thread, kind) -> (time, count)
    aggregates: HashMap<(String, u32, u32, u8), (f64, u64)>,
    elapsed: f64,
}

impl ScorepProfileSink {
    pub fn new(ranks: u32) -> ScorepProfileSink {
        ScorepProfileSink {
            stacks: vec![vec!["Global".to_string()]; ranks as usize],
            aggregates: HashMap::new(),
            elapsed: 0.0,
        }
    }

    pub fn write_profile(&self, path: &Path) -> Result<()> {
        let mut entries: Vec<Json> = Vec::new();
        let mut keys: Vec<_> = self.aggregates.keys().collect();
        keys.sort();
        for k in keys {
            let (t, n) = self.aggregates[k];
            entries.push(Json::from_pairs(vec![
                ("region", Json::Str(k.0.clone())),
                ("rank", Json::Num(k.1 as f64)),
                ("thread", Json::Num(k.2 as f64)),
                ("kind", Json::Num(k.3 as f64)),
                ("time_s", Json::Num(t)),
                ("visits", Json::Num(n as f64)),
            ]));
        }
        let mut root = Json::obj();
        root.set("format", Json::Str("cubex-sim".into()));
        root.set("elapsed_s", Json::Num(self.elapsed));
        root.set("entries", Json::Arr(entries));
        std::fs::write(path, root.to_string_pretty())?;
        Ok(())
    }
}

impl EventSink for ScorepProfileSink {
    fn name(&self) -> &str {
        "scorep-profile"
    }

    fn cost_model(&self) -> CostModel {
        SCOREP_PROFILE_COST
    }

    fn on_event(&mut self, ev: &Event) {
        let top = self.stacks[ev.rank as usize]
            .last()
            .cloned()
            .unwrap_or_else(|| "Global".to_string());
        let key = (
            top,
            ev.rank,
            ev.thread,
            super::trace::kind_code(ev.kind),
        );
        let slot = self.aggregates.entry(key).or_insert((0.0, 0));
        slot.0 += (ev.t_end - ev.t_start).max(0.0);
        slot.1 += ev.sub_events.max(1);
    }

    fn on_region(&mut self, mark: &RegionMark) {
        let stack = &mut self.stacks[mark.rank as usize];
        if mark.enter {
            stack.push(mark.name.clone());
        } else if stack.len() > 1 {
            stack.pop();
        }
    }

    fn on_finalize(&mut self, elapsed: f64) {
        self.elapsed = elapsed;
    }
}

/// Tracing pass: OTF2-like records (with counters), same on-disk format
/// as the Extrae sink but Score-P does *not* expand dynamic chunks into
/// individual records (it aggregates at region granularity, which is why
/// its traces are smaller — Table 2 JSC 29 GB vs BSC 165 GB).
pub struct ScorepTraceSink {
    writer: Option<TraceWriter>,
    regions: Vec<String>,
    records: u64,
    io_error: Option<anyhow::Error>,
}

impl ScorepTraceSink {
    pub fn create(dir: &Path, ranks: u32) -> Result<ScorepTraceSink> {
        Ok(ScorepTraceSink {
            writer: Some(TraceWriter::create(dir, ranks, "otf2")?),
            regions: Vec::new(),
            records: 0,
            io_error: None,
        })
    }

    fn region_id(&mut self, name: &str) -> u64 {
        if let Some(i) = self.regions.iter().position(|r| r == name) {
            return i as u64;
        }
        self.regions.push(name.to_string());
        (self.regions.len() - 1) as u64
    }

    fn write(&mut self, rec: TraceRecord) {
        if self.io_error.is_some() {
            return;
        }
        if let Some(w) = &mut self.writer {
            match w.write(&rec) {
                Ok(()) => self.records += 1,
                Err(e) => self.io_error = Some(e),
            }
        }
    }

    pub fn finish(mut self, dir: &Path) -> Result<u64> {
        if let Some(e) = self.io_error.take() {
            return Err(e);
        }
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        let mut meta = Json::obj();
        meta.set(
            "regions",
            Json::Arr(
                self.regions.iter().map(|r| Json::Str(r.clone())).collect(),
            ),
        );
        std::fs::write(dir.join("regions.json"), meta.to_string_pretty())?;
        Ok(self.records)
    }
}

impl EventSink for ScorepTraceSink {
    fn name(&self) -> &str {
        "scorep-trace"
    }

    fn cost_model(&self) -> CostModel {
        SCOREP_TRACE_COST
    }

    fn on_event(&mut self, ev: &Event) {
        // One record per phase event (sub_events collapse).
        self.write(TraceRecord::from_event(ev));
    }

    fn on_region(&mut self, mark: &RegionMark) {
        let id = self.region_id(&mark.name);
        self.write(TraceRecord::from_region(mark, id));
    }

    fn on_finalize(&mut self, _elapsed: f64) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Synthetic, Workload};
    use crate::sim::{self, MachineSpec, OmpSchedule, ResourceConfig, RunConfig};
    use crate::util::fs::TempDir;

    #[test]
    fn profile_aggregates_by_region() {
        let app = Synthetic {
            phases: 5,
            serial_fraction: 0.1,
            ..Synthetic::default()
        };
        let res = ResourceConfig::new(2, 4);
        let cfg = RunConfig::new(MachineSpec::marenostrum5(), res.clone());
        let mut sink = ScorepProfileSink::new(2);
        sim::run(&app.build(&res, &cfg.machine), &cfg, &mut [&mut sink]);
        let td = TempDir::new("scorep").unwrap();
        let p = td.path().join("profile.json");
        sink.write_profile(&p).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        assert!(!entries.is_empty());
        assert!(entries
            .iter()
            .any(|e| e.str_or("region", "") == "work"));
        assert!(j.num_or("elapsed_s", 0.0) > 0.0);
    }

    #[test]
    fn trace_is_smaller_than_extrae_for_same_run() {
        let app = Synthetic {
            phases: 4,
            schedule: OmpSchedule::Dynamic { chunks: 64 },
            ..Synthetic::default()
        };
        let res = ResourceConfig::new(2, 4);
        let cfg = RunConfig::new(MachineSpec::marenostrum5(), res.clone());
        let prog = app.build(&res, &cfg.machine);

        let td1 = TempDir::new("sp-tr").unwrap();
        let mut sp = ScorepTraceSink::create(td1.path(), 2).unwrap();
        sim::run(&prog, &cfg, &mut [&mut sp]);
        let n_sp = sp.finish(td1.path()).unwrap();

        let td2 = TempDir::new("ex-tr").unwrap();
        let mut ex =
            super::super::tracer::ExtraeSink::create(td2.path(), 2).unwrap();
        sim::run(&prog, &cfg, &mut [&mut ex]);
        let n_ex = ex.finish(td2.path()).unwrap();

        assert!(n_ex > 3 * n_sp, "extrae {n_ex} vs scorep {n_sp}");
    }
}
