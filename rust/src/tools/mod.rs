//! The four tool chains the paper compares (§Comparison to other tools):
//!
//! | chain        | collection                         | post-processing              |
//! |--------------|------------------------------------|------------------------------|
//! | TALP(-Pages) | on-the-fly accumulators + counters | read JSONs, build table      |
//! | CPT          | on-the-fly vector clocks, no ctrs  | copy files together          |
//! | JSC          | Score-P profile run + trace run    | Scalasca parallel replay     |
//! | BSC          | Extrae full trace + counters       | merge + Dimemas + basicanal. |
//!
//! `instrument` runs an app under one chain's collection side (clean
//! baseline included, for Table 1's overhead); `postprocess` executes
//! the chain's analysis side under a [`resources::ResourceMeter`]
//! (Table 2) and emits that chain's scaling-efficiency table
//! (Tables 6/7).

pub mod cpt;
pub mod postprocess;
pub mod resources;
pub mod scorep;
pub mod trace;
pub mod tracer;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::apps::Workload;
use crate::pop::{self, ScalingTable};
use crate::sim::{self, MachineSpec, ResourceConfig, RunConfig};
use crate::talp::{ProcStats, RegionData, RunData, TalpMonitor};
use crate::util::json::Json;

use postprocess::basicanalysis::{self, CommSplitPerConfig};
use postprocess::{dimemas, merge, scalasca};
use resources::{ResourceMeter, ResourceUsage};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ToolKind {
    Talp,
    Cpt,
    ScorepJsc,
    ExtraeBsc,
}

impl ToolKind {
    pub fn all() -> [ToolKind; 4] {
        [ToolKind::Talp, ToolKind::Cpt, ToolKind::ScorepJsc, ToolKind::ExtraeBsc]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ToolKind::Talp => "DLB/TALP",
            ToolKind::Cpt => "CPT",
            ToolKind::ScorepJsc => "Score-P (JSC)",
            ToolKind::ExtraeBsc => "Extrae (BSC)",
        }
    }

    pub fn short(&self) -> &'static str {
        match self {
            ToolKind::Talp => "talp",
            ToolKind::Cpt => "cpt",
            ToolKind::ScorepJsc => "jsc",
            ToolKind::ExtraeBsc => "bsc",
        }
    }
}

/// Result of running an app under one chain's collection side.
#[derive(Debug, Clone)]
pub struct InstrumentedRun {
    pub tool: ToolKind,
    pub app: String,
    pub machine: String,
    pub ranks: u32,
    pub threads: u32,
    pub nodes: u32,
    /// Instrumented elapsed (max over the chain's app executions).
    pub elapsed_s: f64,
    /// Un-instrumented elapsed, same seed.
    pub clean_elapsed_s: f64,
    /// Number of application executions the chain required (Score-P's
    /// POP preset needs two).
    pub app_runs: u32,
    pub output_dir: PathBuf,
    /// Bytes the collection side left on disk.
    pub output_bytes: u64,
}

impl InstrumentedRun {
    /// Table 1's "runtime overhead".
    pub fn overhead_fraction(&self) -> f64 {
        if self.clean_elapsed_s <= 0.0 {
            0.0
        } else {
            self.elapsed_s / self.clean_elapsed_s - 1.0
        }
    }
}

fn write_meta(
    dir: &Path,
    app: &dyn Workload,
    machine: &MachineSpec,
    res: &ResourceConfig,
) -> Result<()> {
    let mut meta = Json::obj();
    meta.set("app", Json::Str(app.name().to_string()));
    meta.set("machine", Json::Str(machine.name.clone()));
    meta.set("ranks", Json::Num(res.n_ranks as f64));
    meta.set("threads", Json::Num(res.threads_per_rank as f64));
    meta.set("nodes", Json::Num(res.nodes_used(machine) as f64));
    std::fs::write(dir.join("meta.json"), meta.to_string_pretty())?;
    Ok(())
}

fn read_meta(dir: &Path) -> Result<(String, String, u32, u32, u32)> {
    let text = std::fs::read_to_string(dir.join("meta.json"))
        .with_context(|| format!("meta.json in {}", dir.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok((
        j.str_or("app", "unknown").to_string(),
        j.str_or("machine", "mn5").to_string(),
        j.num_or("ranks", 1.0) as u32,
        j.num_or("threads", 1.0) as u32,
        j.num_or("nodes", 1.0) as u32,
    ))
}

/// Run `app` under chain `kind`, leaving the chain's raw outputs in
/// `out_dir`.  A clean run (same seed) provides the overhead baseline.
pub fn instrument(
    kind: ToolKind,
    app: &dyn Workload,
    machine: &MachineSpec,
    res: &ResourceConfig,
    seed: u64,
    timestamp: i64,
    out_dir: &Path,
) -> Result<InstrumentedRun> {
    std::fs::create_dir_all(out_dir)?;
    write_meta(out_dir, app, machine, res)?;
    let program = app.build(res, machine);
    let cfg = RunConfig::new(machine.clone(), res.clone()).with_seed(seed);
    let clean = sim::run(&program, &cfg, &mut []);

    let (elapsed, app_runs) = match kind {
        ToolKind::Talp => {
            let mut mon =
                TalpMonitor::new(res.n_ranks, res.threads_per_rank);
            let s = sim::run(&program, &cfg, &mut [&mut mon]);
            let report = mon.finalize();
            let data = RunData::from_report(
                &report, app.name(), machine, res, timestamp,
            );
            data.write_file(&out_dir.join("talp.json"))?;
            (s.elapsed_s, 1)
        }
        ToolKind::Cpt => {
            let mut sink = cpt::CptSink::new(res.n_ranks);
            let s = sim::run(&program, &cfg, &mut [&mut sink]);
            sink.write_summary(&out_dir.join("cpt.json"))?;
            (s.elapsed_s, 1)
        }
        ToolKind::ScorepJsc => {
            // POP preset: profile pass, then trace pass with counters.
            let mut prof = scorep::ScorepProfileSink::new(res.n_ranks);
            let s1 = sim::run(&program, &cfg, &mut [&mut prof]);
            prof.write_profile(&out_dir.join("profile.json"))?;
            let mut tr = scorep::ScorepTraceSink::create(out_dir, res.n_ranks)?;
            let s2 = sim::run(&program, &cfg, &mut [&mut tr]);
            tr.finish(out_dir)?;
            (s1.elapsed_s.max(s2.elapsed_s), 2)
        }
        ToolKind::ExtraeBsc => {
            let mut sink = tracer::ExtraeSink::create(out_dir, res.n_ranks)?;
            let s = sim::run(&program, &cfg, &mut [&mut sink]);
            sink.finish(out_dir)?;
            (s.elapsed_s, 1)
        }
    };
    Ok(InstrumentedRun {
        tool: kind,
        app: app.name().to_string(),
        machine: machine.name.clone(),
        ranks: res.n_ranks,
        threads: res.threads_per_rank,
        nodes: res.nodes_used(machine),
        elapsed_s: elapsed,
        clean_elapsed_s: clean.elapsed_s,
        app_runs,
        output_dir: out_dir.to_path_buf(),
        output_bytes: crate::util::fs::dir_size(out_dir),
    })
}

/// Run chain `kind`'s post-processing over one experiment's runs (one
/// per resource configuration) and produce its scaling-efficiency table
/// for `region`, metering resources (Table 2).
pub fn postprocess(
    kind: ToolKind,
    runs: &[&InstrumentedRun],
    region: &str,
) -> Result<(Option<ScalingTable>, ResourceUsage)> {
    let mut meter = ResourceMeter::new();
    meter.start();
    let table = match kind {
        ToolKind::Talp => {
            let mut datas = Vec::new();
            for run in runs {
                let p = run.output_dir.join("talp.json");
                let text = std::fs::read_to_string(&p)?;
                meter.alloc(text.len() as u64);
                meter.storage(text.len() as u64);
                datas.push(RunData::from_json(
                    &Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?,
                )?);
            }
            let refs: Vec<&RunData> = datas.iter().collect();
            pop::build(region, &refs)
        }
        ToolKind::Cpt => {
            let mut datas = Vec::new();
            let mut splits = Vec::new();
            for run in runs {
                let (data, split) = read_cpt_run(run)?;
                let text_len =
                    std::fs::metadata(run.output_dir.join("cpt.json"))?.len();
                meter.alloc(text_len);
                meter.storage(text_len);
                datas.push(data);
                splits.push(split);
            }
            let refs: Vec<&RunData> = datas.iter().collect();
            let mut t =
                basicanalysis::table_with_comm_split(region, &refs, &splits);
            if let Some(t) = &mut t {
                basicanalysis::blank_counter_rows(t);
            }
            t
        }
        ToolKind::ScorepJsc => {
            let mut datas = Vec::new();
            for run in runs {
                let (app, machine_name, ranks, threads, nodes) =
                    read_meta(&run.output_dir)?;
                let machine = MachineSpec::by_name(&machine_name)
                    .unwrap_or_else(MachineSpec::marenostrum5);
                let res = ResourceConfig::new(ranks, threads);
                let trace = merge::load(&run.output_dir, "otf2", &mut meter)?;
                let mut wanted = vec!["Global".to_string()];
                if region != "Global" {
                    wanted.push(region.to_string());
                }
                let node_of = |r: u32| res.node_of_rank(r, &machine);
                let regions = scalasca::analyze(
                    &trace,
                    &wanted,
                    &node_of,
                    &run.output_dir.join("cube.json"),
                    &mut meter,
                )?;
                merge::unload(trace, &mut meter);
                datas.push(RunData {
                    dlb_version: "scorep-sim".into(),
                    app,
                    machine: machine_name,
                    timestamp: 0,
                    ranks,
                    threads,
                    nodes,
                    regions,
                    git: None,
                });
            }
            let refs: Vec<&RunData> = datas.iter().collect();
            pop::build(region, &refs)
        }
        ToolKind::ExtraeBsc => {
            let mut datas = Vec::new();
            let mut splits = Vec::new();
            for run in runs {
                let (app, machine_name, ranks, threads, nodes) =
                    read_meta(&run.output_dir)?;
                let machine = MachineSpec::by_name(&machine_name)
                    .unwrap_or_else(MachineSpec::marenostrum5);
                let res = ResourceConfig::new(ranks, threads);
                let trace = merge::load(&run.output_dir, "prv", &mut meter)?;
                // Dimemas: sequential network replay over the merged
                // stream — the chain's dominating cost.
                let split = dimemas::replay(
                    &trace,
                    dimemas::NetworkModel::default(),
                    &mut meter,
                );
                let node_of = |r: u32| res.node_of_rank(r, &machine);
                let mut regions = Vec::new();
                let mut wanted = vec!["Global".to_string()];
                if region != "Global" {
                    wanted.push(region.to_string());
                }
                for w in &wanted {
                    if let Some(rd) = merge::region_data(&trace, w, &node_of)
                    {
                        regions.push(rd);
                    }
                }
                merge::unload(trace, &mut meter);
                datas.push(RunData {
                    dlb_version: "extrae-sim".into(),
                    app,
                    machine: machine_name,
                    timestamp: 0,
                    ranks,
                    threads,
                    nodes,
                    regions,
                    git: None,
                });
                splits.push(CommSplitPerConfig {
                    wait_s: split.wait_s,
                    transfer_s: split.transfer_s,
                });
            }
            let refs: Vec<&RunData> = datas.iter().collect();
            basicanalysis::table_with_comm_split(region, &refs, &splits)
        }
    };
    meter.stop();
    Ok((table, meter.usage()))
}

/// Parse a CPT summary into run data (zeroed counters) + comm split.
fn read_cpt_run(run: &InstrumentedRun) -> Result<(RunData, CommSplitPerConfig)> {
    let p = run.output_dir.join("cpt.json");
    let text = std::fs::read_to_string(&p)
        .with_context(|| format!("reading {}", p.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let (app, machine_name, ranks, threads, nodes) =
        read_meta(&run.output_dir)?;
    let machine = MachineSpec::by_name(&machine_name)
        .unwrap_or_else(MachineSpec::marenostrum5);
    let res = ResourceConfig::new(ranks, threads);
    let mut regions = Vec::new();
    let mut wait_global = vec![0.0; ranks as usize];
    let mut transfer_global = vec![0.0; ranks as usize];
    for (name, arr) in j
        .get("regions")
        .and_then(Json::as_obj)
        .context("cpt.json: regions")?
    {
        let mut procs = Vec::new();
        let mut max_elapsed = 0.0f64;
        for pj in arr.as_arr().context("region array")? {
            let rank = pj.num_or("rank", 0.0) as u32;
            let elapsed = pj.num_or("elapsed_s", 0.0);
            max_elapsed = max_elapsed.max(elapsed);
            if name == "Global" {
                wait_global[rank as usize] = pj.num_or("mpi_wait_s", 0.0);
                transfer_global[rank as usize] =
                    pj.num_or("mpi_transfer_s", 0.0);
            }
            procs.push(ProcStats {
                rank,
                node: res.node_of_rank(rank, &machine),
                elapsed_s: elapsed,
                useful_s: pj.num_or("useful_s", 0.0),
                mpi_s: pj.num_or("mpi_s", 0.0),
                mpi_worker_idle_s: pj.num_or("mpi_worker_idle_s", 0.0),
                omp_serialization_s: pj.num_or("omp_serialization_s", 0.0),
                omp_scheduling_s: pj.num_or("omp_scheduling_s", 0.0),
                omp_barrier_s: pj.num_or("omp_barrier_s", 0.0),
                useful_instructions: 0, // no counters!
                useful_cycles: 0,
            });
        }
        // Global elapsed: the engine closes it at per-rank end times but
        // CPT stores per-rank elapsed directly.
        regions.push(RegionData {
            name: name.clone(),
            elapsed_s: if name == "Global" {
                j.num_or("elapsed_s", max_elapsed)
            } else {
                max_elapsed
            },
            visits: 1,
            procs,
        });
    }
    Ok((
        RunData {
            dlb_version: "cpt-sim".into(),
            app,
            machine: machine_name,
            timestamp: 0,
            ranks,
            threads,
            nodes,
            regions,
            git: None,
        },
        CommSplitPerConfig { wait_s: wait_global, transfer_s: transfer_global },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::TeaLeaf;
    use crate::util::fs::TempDir;

    /// Scaled-down TeaLeaf with paper-like chunk granularity (~30 us per
    /// chunk), so instrumentation perturbs without dominating — at the
    /// perturbation floor the chains legitimately observe different
    /// executions and no tool agreement can be expected.
    fn small_tealeaf() -> TeaLeaf {
        let mut t = TeaLeaf::with_grid(1200, 1200);
        t.timesteps = 1;
        t.cg_iters = 6;
        t.cells_per_chunk = 4800; // 4 rows of the 1200-wide test grid
        t.write_output = false;
        t
    }

    /// The whole Tables 6/7 machinery, miniaturized: four chains, two
    /// configs, one table each; every chain must agree on parallel
    /// efficiency within a few points (the paper's headline claim 3).
    #[test]
    fn all_four_chains_agree_on_parallel_efficiency() {
        let td = TempDir::new("tools-agree").unwrap();
        let app = small_tealeaf();
        let machine = MachineSpec::marenostrum5();
        let configs =
            [ResourceConfig::new(2, 8), ResourceConfig::new(4, 8)];
        let mut pes: Vec<(ToolKind, f64)> = Vec::new();
        for kind in ToolKind::all() {
            let mut runs = Vec::new();
            for cfg in &configs {
                let dir = td
                    .path()
                    .join(kind.short())
                    .join(cfg.label());
                runs.push(
                    instrument(kind, &app, &machine, cfg, 42, 0, &dir)
                        .unwrap(),
                );
            }
            let refs: Vec<&InstrumentedRun> = runs.iter().collect();
            let (table, usage) = postprocess(kind, &refs, "Global").unwrap();
            let table = table.expect("table");
            assert_eq!(table.columns, vec!["2x8", "4x8"]);
            assert!(usage.wall_time_s > 0.0);
            pes.push((kind, table.cell("Parallel efficiency", 0).unwrap()));
        }
        let reference = pes[0].1;
        for (kind, pe) in &pes {
            assert!(
                (pe - reference).abs() < 0.06,
                "{} PE {} vs TALP {}",
                kind.name(),
                pe,
                reference
            );
        }
    }

    #[test]
    fn overheads_rank_and_trace_sizes_rank() {
        let td = TempDir::new("tools-oh").unwrap();
        let app = small_tealeaf();
        let machine = MachineSpec::marenostrum5();
        let cfg = ResourceConfig::new(2, 8);
        let mut by_kind = std::collections::HashMap::new();
        for kind in ToolKind::all() {
            let dir = td.path().join(kind.short());
            let run =
                instrument(kind, &app, &machine, &cfg, 7, 0, &dir).unwrap();
            assert!(
                run.overhead_fraction() > 0.0,
                "{} should cost something",
                kind.name()
            );
            by_kind.insert(kind, run);
        }
        // Extrae writes the biggest outputs; TALP the smallest.
        let bytes = |k: ToolKind| by_kind[&k].output_bytes;
        assert!(bytes(ToolKind::ExtraeBsc) > bytes(ToolKind::ScorepJsc));
        assert!(bytes(ToolKind::ScorepJsc) > bytes(ToolKind::Talp));
        assert!(bytes(ToolKind::Talp) < 100_000);
        // Score-P ran the app twice.
        assert_eq!(by_kind[&ToolKind::ScorepJsc].app_runs, 2);
    }

    #[test]
    fn cpt_table_has_blank_counter_rows_but_comm_split() {
        let td = TempDir::new("tools-cpt").unwrap();
        let app = small_tealeaf();
        let machine = MachineSpec::marenostrum5();
        let configs =
            [ResourceConfig::new(2, 8), ResourceConfig::new(4, 8)];
        let mut runs = Vec::new();
        for cfg in &configs {
            let dir = td.path().join(cfg.label());
            runs.push(
                instrument(ToolKind::Cpt, &app, &machine, cfg, 3, 0, &dir)
                    .unwrap(),
            );
        }
        let refs: Vec<&InstrumentedRun> = runs.iter().collect();
        let (table, _) = postprocess(ToolKind::Cpt, &refs, "Global").unwrap();
        let t = table.unwrap();
        assert_eq!(t.cell("IPC scaling", 1), None);
        assert_eq!(t.cell("Global efficiency", 0), None);
        assert!(t.cell("MPI Serialization efficiency", 0).is_some());
        assert!(t.cell("MPI Transfer efficiency", 0).is_some());
        assert!(t.cell("Parallel efficiency", 0).is_some());
    }

    /// Table 2's shape: TALP's post-processing is orders of magnitude
    /// cheaper than the trace chains, and BSC is the slowest.
    #[test]
    fn postprocessing_resource_ordering() {
        let td = TempDir::new("tools-res").unwrap();
        let app = small_tealeaf();
        let machine = MachineSpec::marenostrum5();
        let cfg = ResourceConfig::new(2, 8);
        let mut usage = std::collections::HashMap::new();
        for kind in ToolKind::all() {
            let dir = td.path().join(kind.short());
            let run =
                instrument(kind, &app, &machine, &cfg, 5, 0, &dir).unwrap();
            let (_, u) = postprocess(kind, &[&run], "Global").unwrap();
            usage.insert(kind, u);
        }
        let mem = |k: ToolKind| usage[&k].peak_memory_bytes;
        let sto = |k: ToolKind| usage[&k].storage_bytes;
        assert!(
            mem(ToolKind::Talp) * 10 < mem(ToolKind::ExtraeBsc),
            "talp {} vs bsc {}",
            mem(ToolKind::Talp),
            mem(ToolKind::ExtraeBsc)
        );
        assert!(mem(ToolKind::Talp) * 5 < mem(ToolKind::ScorepJsc));
        assert!(sto(ToolKind::Talp) * 10 < sto(ToolKind::ExtraeBsc));
        assert!(mem(ToolKind::ExtraeBsc) >= mem(ToolKind::ScorepJsc));
    }
}
