//! Pipeline and job definitions mirroring the paper's Fig. 5: a
//! `performance` stage instantiated as a matrix over (resolution,
//! configuration, machine), followed by the accumulating `talp-pages`
//! job (Fig. 6) which the runner executes.

use crate::sim::{MachineSpec, ResourceConfig};

/// One performance job (one cell of the Fig. 5 matrix).
#[derive(Debug, Clone)]
pub struct PerformanceJob {
    pub case: String,
    pub resolution: u32,
    /// "1Nx2MPI"-style configuration label from the paper's YAML.
    pub configuration: String,
    pub machine_tag: String,
    pub resources: ResourceConfig,
}

impl PerformanceJob {
    /// Folder the job copies its talp.json into (Fig. 5 line 9):
    /// `talp/<case>/<resolution>/<machine>/`.
    pub fn talp_subdir(&self) -> String {
        format!(
            "{}/resolution_{}/{}",
            self.case, self.resolution, self.machine_tag
        )
    }
}

/// Matrix expansion (Fig. 5's `parallel: matrix`).
#[derive(Debug, Clone)]
pub struct MatrixSpec {
    pub case: String,
    pub resolutions: Vec<u32>,
    /// (label, ranks, threads) triples, e.g. ("1Nx2MPI", 2, 56).
    pub configurations: Vec<(String, u32, u32)>,
    pub machine_tags: Vec<String>,
}

impl MatrixSpec {
    /// The paper's `performance-cpu-fast` job: salpha, resolution_2,
    /// 1 and 2 nodes, on mn5 and raven.  Node→rank mapping follows the
    /// paper's "one MPI rank per socket" pinning.
    pub fn performance_cpu_fast() -> MatrixSpec {
        MatrixSpec {
            case: "salpha".into(),
            resolutions: vec![2],
            configurations: vec![
                ("1Nx2MPI".into(), 2, 56),
                ("2Nx4MPI".into(), 4, 56),
            ],
            machine_tags: vec!["mn5".into(), "raven".into()],
        }
    }

    pub fn expand(&self) -> Vec<PerformanceJob> {
        let mut jobs = Vec::new();
        for res in &self.resolutions {
            for (label, ranks, threads) in &self.configurations {
                for tag in &self.machine_tags {
                    // Thread count is capped by the machine's socket
                    // width (raven sockets have 36 cores).
                    let machine = MachineSpec::by_name(tag)
                        .unwrap_or_else(MachineSpec::marenostrum5);
                    let t = (*threads).min(machine.cores_per_socket);
                    jobs.push(PerformanceJob {
                        case: self.case.clone(),
                        resolution: *res,
                        configuration: label.clone(),
                        machine_tag: tag.clone(),
                        resources: ResourceConfig::new(*ranks, t),
                    });
                }
            }
        }
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_expands_fully() {
        let jobs = MatrixSpec::performance_cpu_fast().expand();
        assert_eq!(jobs.len(), 4); // 1 res x 2 configs x 2 machines
        assert!(jobs.iter().any(|j| j.machine_tag == "raven"));
        assert!(jobs
            .iter()
            .any(|j| j.configuration == "2Nx4MPI" && j.resources.n_ranks == 4));
    }

    #[test]
    fn raven_thread_cap() {
        let jobs = MatrixSpec::performance_cpu_fast().expand();
        let raven = jobs.iter().find(|j| j.machine_tag == "raven").unwrap();
        assert_eq!(raven.resources.threads_per_rank, 36);
        let mn5 = jobs.iter().find(|j| j.machine_tag == "mn5").unwrap();
        assert_eq!(mn5.resources.threads_per_rank, 56);
    }

    #[test]
    fn talp_subdir_matches_fig5() {
        let jobs = MatrixSpec::performance_cpu_fast().expand();
        assert_eq!(jobs[0].talp_subdir(), "salpha/resolution_2/mn5");
    }
}
