//! Synthetic git repository: a commit history whose code state drives
//! the simulated application (apps::genex::CodeVersion).  This is the
//! "developer commits code changes" half of the paper's Fig. 1 loop.

use crate::apps::CodeVersion;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Commit {
    pub sha: String,
    pub branch: String,
    pub timestamp: i64,
    pub message: String,
    /// The code state this commit builds into.
    pub version: CodeVersion,
}

impl Commit {
    pub fn short(&self) -> &str {
        &self.sha[..8.min(self.sha.len())]
    }
}

#[derive(Debug, Clone)]
pub struct Repo {
    pub commits: Vec<Commit>,
}

impl Repo {
    /// The Fig. 7 history: `n` commits on main, the serialization-bug
    /// fix landing at index `fix_at` (earlier commits carry the bug).
    /// One commit per day starting at `t0`.
    pub fn genex_history(n: usize, fix_at: usize, seed: u64, t0: i64) -> Repo {
        let mut rng = Rng::new(seed);
        let messages_before = [
            "add salpha diagnostics",
            "refactor geometry module",
            "bump input deck defaults",
            "cleanup build flags",
            "tune field solver tolerances",
        ];
        let commits = (0..n)
            .map(|i| {
                let version = if i < fix_at {
                    CodeVersion::buggy()
                } else {
                    CodeVersion::fixed()
                };
                let message = if i == fix_at {
                    "fix: parallelize geometry table setup (omp single \
                     serialization)"
                        .to_string()
                } else {
                    messages_before[rng.below(
                        messages_before.len() as u64
                    ) as usize]
                        .to_string()
                };
                Commit {
                    sha: rng.hex(40),
                    branch: "main".into(),
                    timestamp: t0 + i as i64 * 86_400,
                    message,
                    version,
                }
            })
            .collect();
        Repo { commits }
    }

    /// History with an additional plain performance regression window
    /// [slow_from, slow_to) (for regression-detection ablations).
    pub fn with_regression(
        mut self,
        slow_from: usize,
        slow_to: usize,
        factor: f64,
    ) -> Repo {
        for (i, c) in self.commits.iter_mut().enumerate() {
            if i >= slow_from && i < slow_to {
                c.version.compute_slowdown = factor;
            }
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_has_fix_at_index() {
        let r = Repo::genex_history(10, 6, 1, 1_700_000_000);
        assert_eq!(r.commits.len(), 10);
        assert!(r.commits[5].version.serialization_bug);
        assert!(!r.commits[6].version.serialization_bug);
        assert!(r.commits[6].message.contains("fix"));
        // strictly increasing timestamps
        for w in r.commits.windows(2) {
            assert!(w[0].timestamp < w[1].timestamp);
        }
        // unique shas
        let mut shas: Vec<&str> =
            r.commits.iter().map(|c| c.sha.as_str()).collect();
        shas.sort();
        shas.dedup();
        assert_eq!(shas.len(), 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Repo::genex_history(5, 2, 9, 0);
        let b = Repo::genex_history(5, 2, 9, 0);
        assert_eq!(a.commits[3].sha, b.commits[3].sha);
    }

    #[test]
    fn regression_window() {
        let r = Repo::genex_history(8, 4, 1, 0).with_regression(2, 4, 1.5);
        assert_eq!(r.commits[1].version.compute_slowdown, 1.0);
        assert_eq!(r.commits[2].version.compute_slowdown, 1.5);
        assert_eq!(r.commits[4].version.compute_slowdown, 1.0);
    }
}
