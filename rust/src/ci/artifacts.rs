//! CI artifact management — the paper's replacement for databases and
//! secondary repositories: each pipeline uploads its `talp/` folder as a
//! zip artifact; the next pipeline downloads its predecessor's zip,
//! unpacks it, and copies it over the fresh results (Fig. 6's
//! `talp download-gitlab` + `unzip` + `cp -r`).
//!
//! Real zips via the `zip` crate: artifact size on disk is measurable,
//! and the paper's "with enough data the artifact management could
//! become inadequate" caveat can be demonstrated.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use zip::write::FileOptions;

/// Zip-file-backed artifact store, one subdirectory per pipeline.
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    pub fn new(root: &Path) -> Result<ArtifactStore> {
        std::fs::create_dir_all(root)?;
        Ok(ArtifactStore { root: root.to_path_buf() })
    }

    fn artifact_path(&self, pipeline_id: u64, name: &str) -> PathBuf {
        self.root
            .join(format!("pipeline_{pipeline_id:06}"))
            .join(format!("{name}.zip"))
    }

    /// Zip `dir` and store it as artifact `name` of `pipeline_id`.
    /// Returns the zip size in bytes.
    pub fn upload(
        &self,
        pipeline_id: u64,
        name: &str,
        dir: &Path,
    ) -> Result<u64> {
        let path = self.artifact_path(pipeline_id, name);
        std::fs::create_dir_all(path.parent().unwrap())?;
        let file = std::fs::File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut zip = zip::ZipWriter::new(file);
        let opts: FileOptions = FileOptions::default()
            .compression_method(zip::CompressionMethod::Deflated);
        let mut stack = vec![dir.to_path_buf()];
        let mut buf = Vec::new();
        while let Some(d) = stack.pop() {
            let mut entries: Vec<_> =
                std::fs::read_dir(&d)?.flatten().collect();
            entries.sort_by_key(|e| e.path());
            for entry in entries {
                let p = entry.path();
                let rel = p
                    .strip_prefix(dir)
                    .unwrap()
                    .to_string_lossy()
                    .replace('\\', "/");
                if p.is_dir() {
                    stack.push(p);
                } else {
                    zip.start_file(rel, opts)?;
                    buf.clear();
                    std::fs::File::open(&p)?.read_to_end(&mut buf)?;
                    zip.write_all(&buf)?;
                }
            }
        }
        zip.finish()?;
        Ok(std::fs::metadata(&path)?.len())
    }

    /// Fetch the most recent artifact `name` from any pipeline with id
    /// strictly below `pipeline_id` (the "download the previous
    /// pipeline's artifacts" step).
    pub fn download_previous(
        &self,
        pipeline_id: u64,
        name: &str,
    ) -> Option<PathBuf> {
        (0..pipeline_id)
            .rev()
            .map(|id| self.artifact_path(id, name))
            .find(|p| p.exists())
    }

    /// Unzip an artifact into `dest` (existing files are overwritten —
    /// the `cp -r talp_history/* talp` of Fig. 6 goes the other way, so
    /// the runner unzips into a scratch dir and copies over).
    pub fn extract(zip_path: &Path, dest: &Path) -> Result<u64> {
        let file = std::fs::File::open(zip_path)
            .with_context(|| format!("opening {}", zip_path.display()))?;
        let mut archive = zip::ZipArchive::new(file)?;
        let mut files = 0u64;
        for i in 0..archive.len() {
            let mut entry = archive.by_index(i)?;
            let Some(rel) = entry.enclosed_name().map(PathBuf::from) else {
                continue;
            };
            let out = dest.join(rel);
            if entry.is_dir() {
                std::fs::create_dir_all(&out)?;
                continue;
            }
            if let Some(parent) = out.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let mut f = std::fs::File::create(&out)?;
            std::io::copy(&mut entry, &mut f)?;
            files += 1;
        }
        Ok(files)
    }

    /// Total bytes stored (the artifact-bloat caveat, §Discussion).
    pub fn total_bytes(&self) -> u64 {
        crate::util::fs::dir_size(&self.root)
    }

    /// Retention policy for the §Discussion bloat problem: keep only
    /// the newest `keep` pipelines' artifacts (history travels forward
    /// inside each new artifact anyway).  Returns bytes freed.
    pub fn prune(&self, keep: usize) -> Result<u64> {
        let mut dirs = crate::util::fs::subdirs(&self.root);
        dirs.retain(|d| {
            d.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("pipeline_"))
                .unwrap_or(false)
        });
        if dirs.len() <= keep {
            return Ok(0);
        }
        let mut freed = 0;
        let drop_n = dirs.len() - keep;
        for d in dirs.into_iter().take(drop_n) {
            freed += crate::util::fs::dir_size(&d);
            std::fs::remove_dir_all(&d)?;
        }
        Ok(freed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn make_tree(root: &Path) {
        std::fs::create_dir_all(root.join("talp/case/res")).unwrap();
        std::fs::write(root.join("talp/case/res/a.json"), b"{\"x\":1}")
            .unwrap();
        std::fs::write(root.join("talp/top.json"), b"{}").unwrap();
    }

    #[test]
    fn upload_download_roundtrip() {
        let td = TempDir::new("artifacts").unwrap();
        let store = ArtifactStore::new(&td.path().join("store")).unwrap();
        let src = td.path().join("src");
        make_tree(&src);
        let size = store.upload(3, "talp", &src.join("talp")).unwrap();
        assert!(size > 0);

        // Pipeline 7 finds pipeline 3's artifact.
        let zip = store.download_previous(7, "talp").unwrap();
        let dest = td.path().join("restored");
        let files = ArtifactStore::extract(&zip, &dest).unwrap();
        assert_eq!(files, 2);
        assert_eq!(
            std::fs::read_to_string(dest.join("case/res/a.json")).unwrap(),
            "{\"x\":1}"
        );
    }

    #[test]
    fn no_previous_artifact_for_first_pipeline() {
        let td = TempDir::new("artifacts2").unwrap();
        let store = ArtifactStore::new(&td.path().join("store")).unwrap();
        assert!(store.download_previous(0, "talp").is_none());
    }

    #[test]
    fn most_recent_previous_wins() {
        let td = TempDir::new("artifacts3").unwrap();
        let store = ArtifactStore::new(&td.path().join("store")).unwrap();
        let src = td.path().join("src");
        make_tree(&src);
        store.upload(1, "talp", &src.join("talp")).unwrap();
        store.upload(4, "talp", &src.join("talp")).unwrap();
        let zip = store.download_previous(6, "talp").unwrap();
        assert!(zip.to_string_lossy().contains("pipeline_000004"));
    }

    #[test]
    fn prune_keeps_newest() {
        let td = TempDir::new("artifacts5").unwrap();
        let store = ArtifactStore::new(&td.path().join("store")).unwrap();
        let src = td.path().join("src");
        make_tree(&src);
        for id in 0..5 {
            store.upload(id, "talp", &src.join("talp")).unwrap();
        }
        let freed = store.prune(2).unwrap();
        assert!(freed > 0);
        // Oldest gone, newest still downloadable.
        assert!(store.download_previous(10, "talp").is_some());
        let zip = store.download_previous(10, "talp").unwrap();
        assert!(zip.to_string_lossy().contains("pipeline_000004"));
        assert!(store
            .download_previous(2, "talp")
            .is_none(), "pipelines 0/1 pruned");
        // No-op when already under the limit.
        assert_eq!(store.prune(10).unwrap(), 0);
    }

    #[test]
    fn total_bytes_grows() {
        let td = TempDir::new("artifacts4").unwrap();
        let store = ArtifactStore::new(&td.path().join("store")).unwrap();
        let src = td.path().join("src");
        make_tree(&src);
        store.upload(0, "talp", &src.join("talp")).unwrap();
        let b1 = store.total_bytes();
        store.upload(1, "talp", &src.join("talp")).unwrap();
        assert!(store.total_bytes() > b1);
    }
}
