//! The CI engine: executes the paper's Fig. 4 cycle for each commit.
//!
//! For one pipeline:
//! 1. every performance job runs the app (the commit's code state)
//!    under TALP on its target machine, dropping `talp.json` into the
//!    Fig. 5 folder structure;
//! 2. `talp metadata` stamps git info into the fresh JSONs;
//! 3. the accumulating job downloads the previous pipeline's `talp`
//!    artifact, unzips it and copies it over (history merge);
//! 4. the stamped tree is ingested into the engine-root
//!    [`crate::store::RunStore`] — the durable cross-commit record.
//!    Ingest is content-addressed, so only the fresh matrix-job files
//!    parse; every carried-over history artifact is recognized by hash
//!    and skipped ([`PipelineResult::store_ingested`] /
//!    [`PipelineResult::store_deduped`]);
//! 5. the report stage routes through the staged [`crate::session`]
//!    pipeline — scan (through the engine-root metrics cache), analyze,
//!    and emit the full site plus `report.json` into `public/talp`;
//!    when the pipeline options carry a gate policy, the verdict lands
//!    in [`PipelineResult::gate`] (the pipeline fails by verdict, not
//!    by abort — later commits keep running, like CI);
//! 6. both `talp/` (for the next pipeline) and `public/` (for pages
//!    hosting) are uploaded as artifacts, and `public/` is published.
//!
//! Because step 4 persists every run, the store outlives the
//! artifact-merge chain: a gate or report can later run over the full
//! history (`talp-pages gate --store <engine root>/store`) without any
//! pipeline work directory surviving.
//!
//! Jobs run on OS threads (one per matrix cell), mirroring concurrent
//! CI runners.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::apps::{run_with_talp, Genex};
use crate::session::{AnalyzeOptions, EmitSummary, Session};
use crate::sim::MachineSpec;
use crate::store::{self, RunStore};
use crate::talp::RunData;
use crate::util::timefmt;

use super::artifacts::ArtifactStore;
use super::gitmeta;
use super::pipeline::PerformanceJob;
use super::repo::Commit;

pub struct CiEngine {
    root: PathBuf,
    store: ArtifactStore,
    /// The persistent cross-commit run store (engine root, outlives
    /// every pipeline work dir).
    run_store: RunStore,
    /// Pages hosting directory (the GitLab-Pages stand-in).
    pages_dir: PathBuf,
    next_pipeline: u64,
}

/// Per-pipeline report options: what to analyze and how wide the
/// worker pool is.  The metrics cache always lives at the engine root
/// (it must outlive per-pipeline work directories).
#[derive(Debug, Clone, Default)]
pub struct PipelineOptions {
    pub analyze: AnalyzeOptions,
    /// Worker threads for the scan/analyze stages (0 = auto).
    pub jobs: usize,
}

#[derive(Debug)]
pub struct PipelineResult {
    pub pipeline_id: u64,
    pub commit_short: String,
    pub jobs_run: usize,
    pub history_files: u64,
    /// Runs this pipeline appended to the persistent store (the fresh
    /// matrix jobs — O(changed)).
    pub store_ingested: usize,
    /// Artifacts the store already held (hashed, never parsed).
    pub store_deduped: usize,
    pub report: EmitSummary,
    pub talp_artifact_bytes: u64,
    pub wall_time_s: f64,
}

impl PipelineResult {
    /// Regression-gate verdict for this pipeline (present when the
    /// report options carried a gate policy).  A failing verdict does
    /// not abort the engine — like real CI, the pipeline *records* red
    /// and later commits keep running.
    pub fn gate(&self) -> Option<&crate::gate::GateVerdict> {
        self.report.gate.as_ref()
    }

    /// Did this pipeline's gate stage pass (vacuously true ungated)?
    pub fn gate_passed(&self) -> bool {
        self.gate()
            .map(|v| v.status != crate::gate::GateStatus::Fail)
            .unwrap_or(true)
    }
}

impl CiEngine {
    pub fn new(root: &Path) -> Result<CiEngine> {
        let store = ArtifactStore::new(&root.join("artifacts"))?;
        let run_store = RunStore::create_or_open(&root.join("store"))?;
        let pages_dir = root.join("pages");
        std::fs::create_dir_all(&pages_dir)?;
        Ok(CiEngine {
            root: root.to_path_buf(),
            store,
            run_store,
            pages_dir,
            next_pipeline: 0,
        })
    }

    pub fn pages_dir(&self) -> &Path {
        &self.pages_dir
    }

    pub fn artifact_bytes(&self) -> u64 {
        self.store.total_bytes()
    }

    /// The persistent cross-commit run store every pipeline ingests
    /// into (rooted at `<engine root>/store`).
    pub fn run_store(&self) -> &RunStore {
        &self.run_store
    }

    /// Execute one full pipeline for `commit`.
    pub fn run_pipeline(
        &mut self,
        commit: &Commit,
        jobs: &[PerformanceJob],
        opts: &PipelineOptions,
    ) -> Result<PipelineResult> {
        let t0 = std::time::Instant::now();
        let id = self.next_pipeline;
        self.next_pipeline += 1;
        let work = self.root.join(format!("work/pipeline_{id:06}"));
        let talp_dir = work.join("talp");
        std::fs::create_dir_all(&talp_dir)?;

        // ---- performance stage: one thread per matrix job ----
        let results: Vec<Result<(PerformanceJob, RunData)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = jobs
                    .iter()
                    .map(|job| {
                        let job = job.clone();
                        let commit = commit.clone();
                        scope.spawn(move || run_performance_job(&job, &commit))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
        let mut jobs_run = 0usize;
        for res in results {
            let (job, data) = res?;
            // Fig. 5 stamps date+sha; we add the resource label so two
            // matrix cells of one commit never collide in one dir.
            let fname = format!(
                "talp_{}_{}_{}.json",
                data.resources().label(),
                timefmt::to_filename_stamp(commit.timestamp),
                commit.short()
            );
            data.write_file(
                &talp_dir.join(job.talp_subdir()).join(fname),
            )?;
            jobs_run += 1;
        }

        // ---- talp metadata ----
        gitmeta::stamp_tree(&talp_dir, commit)?;

        // ---- accumulate: download previous pipeline's history ----
        let mut history_files = 0;
        if let Some(zip) = self.store.download_previous(id, "talp") {
            let scratch = work.join("talp_history");
            history_files = ArtifactStore::extract(&zip, &scratch)
                .context("extracting history artifact")?;
            // `cp -r talp_history/* talp` — fresh files win on collision
            // (same commit re-run), history fills the rest.
            copy_missing(&scratch, &talp_dir)?;
        }

        // ---- store ingest: the durable cross-commit record ----
        // Stamped fresh runs + merged history go through the
        // content-addressed ingest; only the fresh files parse (the
        // history is recognized by hash), so the store accumulates
        // unbounded history at O(changed) cost per pipeline.
        let git = gitmeta::to_git_meta(commit);
        let ingest = store::Admission::new()
            .jobs(opts.jobs)
            .commit(Some(&git))
            .ingest_dir(&mut self.run_store, &talp_dir)?;
        // Keep the sidecar indexes warm: each pipeline appends to a
        // handful of shards, so refreshing here is O(appended) and
        // every store query between pipelines starts indexed.
        self.run_store.refresh_indexes()?;

        // ---- report stage (scan -> analyze -> emit) ----
        // The metrics cache lives at the engine root (not in the
        // per-pipeline work dir), so pipeline N's scan serves every
        // history artifact carried over from pipeline N-1 out of the
        // cache and only parses the fresh matrix-job files.
        let public = work.join("public/talp");
        std::fs::create_dir_all(&public)?;
        let report = Session::new(&talp_dir)
            .jobs(opts.jobs)
            .cache(self.root.join("talp-cache.json"))
            .scan()?
            .analyze(&opts.analyze)
            .emit(&mut crate::session::default_emitters(&public))?;

        // ---- artifacts + pages publish ----
        let talp_artifact_bytes = self.store.upload(id, "talp", &talp_dir)?;
        self.store.upload(id, "public", &work.join("public"))?;
        // Publish: wipe + copy (GitLab pages semantics).
        let _ = std::fs::remove_dir_all(&self.pages_dir);
        crate::util::fs::copy_tree(&work.join("public"), &self.pages_dir)?;

        Ok(PipelineResult {
            pipeline_id: id,
            commit_short: commit.short().to_string(),
            jobs_run,
            history_files,
            store_ingested: ingest.stored,
            store_deduped: ingest.already_stored,
            report,
            talp_artifact_bytes,
            wall_time_s: t0.elapsed().as_secs_f64(),
        })
    }
}

fn run_performance_job(
    job: &PerformanceJob,
    commit: &Commit,
) -> Result<(PerformanceJob, RunData)> {
    let machine = MachineSpec::by_name(&job.machine_tag)
        .with_context(|| format!("unknown machine '{}'", job.machine_tag))?;
    let mut app = Genex::salpha(job.resolution, commit.version);
    app.timesteps = 6;
    // Seed varies by commit + job so runs differ realistically but
    // deterministically.
    let seed = crate::util::hash::fnv1a_64_str(&format!(
        "{}:{}:{}",
        commit.sha,
        job.machine_tag,
        job.resources.label()
    ));
    let (data, _) = run_with_talp(
        &app,
        &machine,
        &job.resources,
        seed,
        commit.timestamp + 3600, // executed an hour after the commit
    );
    Ok((job.clone(), data))
}

/// Copy files from `src` into `dst` unless the destination exists.
fn copy_missing(src: &Path, dst: &Path) -> Result<u64> {
    let mut copied = 0;
    for f in crate::util::fs::files_with_ext(src, "json") {
        let rel = f.strip_prefix(src).unwrap();
        let to = dst.join(rel);
        if to.exists() {
            continue;
        }
        if let Some(parent) = to.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::copy(&f, &to)?;
        copied += 1;
    }
    Ok(copied)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ci::pipeline::MatrixSpec;
    use crate::ci::repo::Repo;
    use crate::util::fs::TempDir;

    fn small_jobs() -> Vec<PerformanceJob> {
        // Miniature matrix: 1 machine, 2 configs, resolution 1.
        let spec = MatrixSpec {
            case: "salpha".into(),
            resolutions: vec![1],
            configurations: vec![
                ("1Nx2MPI".into(), 2, 8),
                ("2Nx4MPI".into(), 4, 8),
            ],
            machine_tags: vec!["mn5".into()],
        };
        spec.expand()
    }

    #[test]
    fn pipeline_cycle_accumulates_history() {
        let td = TempDir::new("ci").unwrap();
        let mut engine = CiEngine::new(td.path()).unwrap();
        let repo = Repo::genex_history(3, 2, 1, 1_700_000_000);
        let jobs = small_jobs();
        let opts = PipelineOptions {
            analyze: AnalyzeOptions {
                regions: vec!["initialize".into(), "timestep".into()],
                region_for_badge: Some("timestep".into()),
                ..Default::default()
            },
            ..Default::default()
        };

        let r0 = engine
            .run_pipeline(&repo.commits[0], &jobs, &opts)
            .unwrap();
        assert_eq!(r0.jobs_run, 2);
        // The emitted site carries the machine-readable report too.
        assert!(engine
            .pages_dir()
            .join("talp/report.json")
            .exists());
        assert_eq!(r0.history_files, 0);
        assert_eq!(r0.report.experiments, 1); // salpha/resolution_1/mn5
        assert_eq!(r0.report.cache_hits, 0);
        assert_eq!(r0.report.cache_misses, 2);
        // Both fresh jobs landed in the persistent store.
        assert_eq!(r0.store_ingested, 2);
        assert_eq!(r0.store_deduped, 0);

        let r1 = engine
            .run_pipeline(&repo.commits[1], &jobs, &opts)
            .unwrap();
        assert!(r1.history_files >= 2, "{}", r1.history_files);
        // History artifacts carried over from pipeline 0 are served from
        // the engine-root metrics cache; only the fresh jobs parse.
        assert_eq!(r1.report.cache_hits, 2);
        assert_eq!(r1.report.cache_misses, 2);
        // Same O(changed) story for the store: the carried-over history
        // is recognized by content hash, only the fresh jobs ingest.
        assert_eq!(r1.store_ingested, 2);
        assert_eq!(r1.store_deduped, 2);

        let r2 = engine
            .run_pipeline(&repo.commits[2], &jobs, &opts)
            .unwrap();
        // Pipeline 2 carries runs of commits 0 and 1.
        assert!(r2.history_files >= 4, "{}", r2.history_files);
        assert_eq!(r2.report.cache_hits, 4);
        assert_eq!(r2.report.cache_misses, 2);
        assert_eq!(r2.store_ingested, 2);
        assert_eq!(r2.store_deduped, 4);
        // The store now holds the full cross-commit history.
        assert_eq!(engine.run_store().len(), 6);
        assert_eq!(engine.run_store().experiment_count(), 1);

        // Pages were published with plots (>= 2 history points).
        let page_files: Vec<_> =
            crate::util::fs::files_with_ext(engine.pages_dir(), "html");
        assert!(!page_files.is_empty());
        let exp_page = page_files
            .iter()
            .find(|p| !p.ends_with("index.html"))
            .unwrap();
        let html = std::fs::read_to_string(exp_page).unwrap();
        assert!(html.contains("Time evolution"));
        assert!(html.contains("Scaling efficiency"));
        // Artifacts grew over pipelines.
        assert!(engine.artifact_bytes() > 0);
    }

    #[test]
    fn pipelines_record_gate_verdicts_and_fail_on_regression() {
        let td = TempDir::new("ci-gate").unwrap();
        let mut engine = CiEngine::new(td.path()).unwrap();
        // 5 clean commits, the last one carrying a 1.8x compute
        // slowdown (Repo::with_regression window [4, 5)).
        let repo = Repo::genex_history(5, 0, 3, 1_700_000_000)
            .with_regression(4, 5, 1.8);
        let jobs = small_jobs();
        let opts = PipelineOptions {
            analyze: AnalyzeOptions {
                regions: vec!["initialize".into(), "timestep".into()],
                region_for_badge: Some("timestep".into()),
                gate: Some(crate::gate::GatePolicy::default()),
                ..Default::default()
            },
            ..Default::default()
        };
        let mut results = Vec::new();
        for commit in &repo.commits {
            results.push(engine.run_pipeline(commit, &jobs, &opts).unwrap());
        }
        // Every pipeline recorded a verdict.
        assert!(results.iter().all(|r| r.gate().is_some()));
        // Early pipelines lack min_samples (checks skip) or are clean.
        assert!(results[0].gate_passed());
        assert!(
            results[0].gate().unwrap().counts.skipped > 0,
            "single-point history must skip, not fail"
        );
        assert!(results[3].gate_passed(), "clean history stays green");
        // The regression commit flips the gate red...
        let last = results.last().unwrap();
        assert!(!last.gate_passed(), "{:?}", last.gate());
        let v = last.gate().unwrap();
        assert_eq!(v.exit_code(), 1);
        assert!(v.counts.fail > 0);
        // ...and the engine kept running (did not abort on red).
        assert_eq!(results.len(), 5);
        // The published pages carry the verdict artifacts and badge.
        let pages = engine.pages_dir().join("talp");
        for f in ["gate.json", "gate.md", "gate.xml", "badges/gate.svg"] {
            assert!(pages.join(f).exists(), "{f} missing from pages");
        }
        let badge =
            std::fs::read_to_string(pages.join("badges/gate.svg")).unwrap();
        assert!(badge.contains("failing"));
    }

    #[test]
    fn store_backed_report_matches_published_report_json() {
        // The store is a faithful record: a report generated from it
        // is byte-identical to the one the last pipeline published
        // from its merged artifact folder.
        let td = TempDir::new("ci-store").unwrap();
        let mut engine = CiEngine::new(td.path()).unwrap();
        let repo = Repo::genex_history(3, 1, 5, 1_700_000_000);
        let jobs = small_jobs();
        let opts = PipelineOptions {
            analyze: AnalyzeOptions {
                regions: vec!["initialize".into(), "timestep".into()],
                region_for_badge: Some("timestep".into()),
                ..Default::default()
            },
            ..Default::default()
        };
        for commit in &repo.commits {
            engine.run_pipeline(commit, &jobs, &opts).unwrap();
        }
        let published = std::fs::read_to_string(
            engine.pages_dir().join("talp/report.json"),
        )
        .unwrap();
        let analysis = Session::from_store(td.path().join("store"))
            .scan()
            .unwrap()
            .analyze(&opts.analyze);
        let from_store = crate::session::JsonReport::document(&analysis)
            .to_string_pretty();
        assert_eq!(published, from_store);
    }

    #[test]
    fn fresh_files_not_overwritten_by_history() {
        let td = TempDir::new("ci2").unwrap();
        let src = td.path().join("hist");
        let dst = td.path().join("cur");
        std::fs::create_dir_all(src.join("a")).unwrap();
        std::fs::create_dir_all(dst.join("a")).unwrap();
        std::fs::write(src.join("a/x.json"), b"old").unwrap();
        std::fs::write(dst.join("a/x.json"), b"new").unwrap();
        std::fs::write(src.join("a/y.json"), b"hist-only").unwrap();
        let n = copy_missing(&src, &dst).unwrap();
        assert_eq!(n, 1);
        assert_eq!(
            std::fs::read_to_string(dst.join("a/x.json")).unwrap(),
            "new"
        );
        assert_eq!(
            std::fs::read_to_string(dst.join("a/y.json")).unwrap(),
            "hist-only"
        );
    }
}
