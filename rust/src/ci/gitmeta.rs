//! `talp metadata` — the convenience wrapper of Fig. 6 that stamps
//! git-related metadata (commit hash, branch, commit timestamp) into
//! freshly generated TALP JSONs before they are archived.

use std::path::Path;

use anyhow::Result;

use crate::talp::{GitMeta, RunData};
use crate::util::fs;

use super::repo::Commit;

/// The [`GitMeta`] a commit stamps into artifacts — shared by
/// [`stamp_tree`] and the run-store ingest path so files and stored
/// records can never disagree about a commit's metadata.
pub fn to_git_meta(commit: &Commit) -> GitMeta {
    GitMeta {
        commit: commit.sha.clone(),
        branch: commit.branch.clone(),
        commit_timestamp: commit.timestamp,
        message: commit.message.clone(),
    }
}

/// Stamp every `.json` under `dir` that parses as a TALP file and does
/// not yet carry git metadata.  Returns the number of files stamped.
pub fn stamp_tree(dir: &Path, commit: &Commit) -> Result<u64> {
    let mut stamped = 0;
    for path in fs::files_with_ext(dir, "json") {
        let Ok(mut run) = RunData::read_file(&path) else {
            continue; // not a TALP json (e.g. regions.json) — skip
        };
        if run.git.is_some() {
            continue; // history entries already stamped by their pipeline
        }
        run.git = Some(to_git_meta(commit));
        run.write_file(&path)?;
        stamped += 1;
    }
    Ok(stamped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{run_with_talp, CodeVersion, Genex};
    use crate::ci::repo::Repo;
    use crate::sim::{MachineSpec, ResourceConfig};
    use crate::util::fs::TempDir;

    #[test]
    fn stamps_only_unstamped_talp_jsons() {
        let td = TempDir::new("gitmeta").unwrap();
        let machine = MachineSpec::marenostrum5();
        let mut app = Genex::salpha(1, CodeVersion::fixed());
        app.timesteps = 1;
        let (fresh, _) = run_with_talp(
            &app,
            &machine,
            &ResourceConfig::new(1, 4),
            1,
            1_000,
        );
        fresh.write_file(&td.path().join("a/fresh.json")).unwrap();

        let mut old = fresh.clone();
        old.git = Some(crate::talp::GitMeta {
            commit: "old".into(),
            branch: "main".into(),
            commit_timestamp: 5,
            message: String::new(),
        });
        old.write_file(&td.path().join("a/old.json")).unwrap();
        std::fs::write(td.path().join("a/regions.json"), "{\"x\":[]}")
            .unwrap();

        let repo = Repo::genex_history(1, 0, 7, 42);
        let n = stamp_tree(td.path(), &repo.commits[0]).unwrap();
        assert_eq!(n, 1);

        let restamped =
            RunData::read_file(&td.path().join("a/fresh.json")).unwrap();
        assert_eq!(
            restamped.git.as_ref().unwrap().commit,
            repo.commits[0].sha
        );
        let untouched =
            RunData::read_file(&td.path().join("a/old.json")).unwrap();
        assert_eq!(untouched.git.unwrap().commit, "old");
    }
}
