//! In-process CI engine (the GitLab stand-in, DESIGN.md §2): synthetic
//! commit history, matrix pipelines, zip artifact store with the
//! download-previous/merge cycle, and static pages publishing — the
//! full Fig. 4 workflow.

pub mod artifacts;
pub mod gitmeta;
pub mod pipeline;
pub mod repo;
pub mod runner;
pub mod templates;

pub use artifacts::ArtifactStore;
pub use pipeline::{MatrixSpec, PerformanceJob};
pub use repo::{Commit, Repo};
pub use runner::{CiEngine, PipelineOptions, PipelineResult};
